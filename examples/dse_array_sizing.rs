//! Design-space exploration (§V-B/§VI): size a processor array for GEMM.
//!
//! Because the analysis is symbolic, evaluating a candidate architecture
//! is a handful of expression evaluations — and the `dse` subsystem makes
//! the sweep parallel and cache-backed: every 2-D shape up to 64 PEs is
//! analyzed once, then three problem sizes are swept against the cached
//! expressions. The result is a multi-objective (energy, latency, PEs,
//! DRAM) Pareto frontier per size instead of a single EDP ranking —
//! exactly the early-design-stage use the paper motivates. Two further
//! sweeps turn the schedule vector (`with_schedules`: every feasible
//! `(permutation, λ^J, λ^K)` per mapping, priced against the same
//! cached analysis) and the per-phase shape assignment
//! (`with_phase_shapes`: each GEMVER phase on its own orientation under
//! the shared PE budget) into axes of their own.
//!
//! ```bash
//! cargo run --release --example dse_array_sizing
//! ```

use tcpa_energy::dse::{
    explore_with_cache, AnalysisCache, DesignSpace, ExploreConfig,
    PhasePolicy, SchedulePolicy,
};
use tcpa_energy::energy::Backend;
use tcpa_energy::workloads;

fn main() {
    let wl = workloads::by_name("gemm").unwrap();
    let cache = AnalysisCache::new();
    for n in [64i64, 128, 256] {
        let space = DesignSpace::new()
            .with_arrays_2d(64)
            .with_bounds(vec![n, n, n]);
        let res = explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        println!(
            "\nGEMM N={n}: {} design points in {:?} — {} on the Pareto \
             frontier (cache: {} analyses, {:.0}% hit)",
            res.points.len(),
            res.wall,
            res.frontier.len(),
            res.cache.entries,
            res.cache.hit_rate() * 100.0
        );
        println!(
            "{:>7} {:>4} {:>14} {:>14} {:>12} {:>12}",
            "array", "PEs", "E_tot [pJ]", "DRAM [pJ]", "L [cyc]", "EDP"
        );
        for p in res.frontier_points().iter().take(8) {
            println!(
                "{:>7} {:>4} {:>14.3e} {:>14.3e} {:>12} {:>12.3e}",
                p.point.array_label(),
                p.pes,
                p.energy_pj,
                p.dram_pj,
                p.latency_cycles,
                p.edp
            );
        }
        if let Some(k) = res.knee_point() {
            println!(
                "knee: {} — balanced energy/latency/area trade-off",
                k.point.array_label()
            );
        }
        // The point of the paper: wider arrays trade on-chip traffic for
        // latency while DRAM energy is invariant — verify and report.
        let serial = res
            .points
            .iter()
            .find(|p| p.point.array == vec![1, 1])
            .unwrap();
        let best = res.by_edp()[0];
        println!(
            "best-EDP {} improves latency {:.1}x over 1x1 at {:+.1}% energy",
            best.point.array_label(),
            serial.latency_cycles as f64 / best.latency_cycles as f64,
            100.0 * (best.energy_pj - serial.energy_pj) / serial.energy_pj
        );
    }
    // Cross-architecture comparison (§VI): pricing a CGRA next to the
    // TCPA is one more *scenario* on the same cached analyses — operand
    // transport crosses the shared register file / crossbar instead of
    // FD/ID registers, and the sweep reports one frontier per backend.
    let space = DesignSpace::new()
        .with_arrays_2d(64)
        .with_bounds(vec![128, 128, 128])
        .with_backends(vec![Backend::tcpa(), Backend::cgra()]);
    let res =
        explore_with_cache(&wl, &space, &ExploreConfig::default(), &cache);
    println!("\nTCPA vs CGRA at N=128 (same symbolic volumes):");
    for g in &res.groups {
        let knee = g.knee.map(|i| &res.points[i]).expect("knee");
        println!(
            "  {:8} frontier {:2} points, knee {:>5} — {:.3e} pJ, {} cyc",
            g.backend.name(),
            g.frontier.len(),
            knee.point.array_label(),
            knee.energy_pj,
            knee.latency_cycles
        );
    }
    let energy_of = |name: &str, array: &[i64]| {
        res.points
            .iter()
            .find(|p| {
                p.point.backend.name() == name && p.point.array == array
            })
            .map(|p| p.energy_pj)
            .expect("point")
    };
    let (t, c) = (energy_of("tcpa", &[8, 8]), energy_of("cgra", &[8, 8]));
    println!(
        "  8x8 array: CGRA transport costs {:+.1}% energy vs TCPA",
        100.0 * (c - t) / t
    );

    // Schedule sweep: `find_schedule` picks one λ per mapping, but a
    // mapping generally admits several causal dimension orders with the
    // same energy and different latency. Sweeping them is free — the λ
    // candidates share each shape's cached analysis — and on asymmetric
    // mappings a non-default schedule genuinely wins (GESUMMV on a 1×8
    // column: the swapped order keeps the accumulation offset off the
    // mapped dimension).
    let gsv = workloads::by_name("gesummv").unwrap();
    let sched_cache = AnalysisCache::new();
    let sched_space = DesignSpace::new()
        .with_arrays(vec![vec![1, 8], vec![8, 1], vec![4, 4]])
        .with_bounds(vec![64, 64])
        .with_schedules(SchedulePolicy::All);
    let res = explore_with_cache(
        &gsv,
        &sched_space,
        &ExploreConfig::default(),
        &sched_cache,
    );
    println!(
        "\nGESUMMV schedule sweep at N=64: {} λ candidates from {} \
         analyses",
        res.points.len(),
        sched_cache.stats().misses
    );
    println!(
        "{:>7} {:>14} {:>14} {:>12} {:>7}",
        "array", "schedule", "E_tot [pJ]", "L [cyc]", "pareto"
    );
    for (i, p) in res.points.iter().enumerate() {
        println!(
            "{:>7} {:>14} {:>14.3e} {:>12} {:>7}",
            p.point.array_label(),
            format!("{} ({})", p.point.schedule.label(), p.schedule_label),
            p.energy_pj,
            p.latency_cycles,
            if res.frontier.contains(&i) { "yes" } else { "" }
        );
    }

    // Per-phase heterogeneous mapping: GEMVER's phases accumulate along
    // different dimensions, so no single orientation suits all three.
    // `with_phase_shapes(PerPhase)` sweeps every shape combination under
    // the shared PE budget (phases run sequentially — a combination
    // costs the max, not the sum, of its phases' PEs), while each
    // (phase, shape) pair is analyzed exactly once. Composed with the
    // schedule axis, every assignment competes at its best λ — which is
    // what lets mixed orientations reach the frontier.
    let gemver = workloads::by_name("gemver").unwrap();
    let phase_cache = AnalysisCache::new();
    let phase_space = DesignSpace::new()
        .with_arrays(vec![vec![1, 8], vec![8, 1], vec![4, 2], vec![2, 4]])
        .with_bounds(vec![64, 64])
        .with_phase_shapes(PhasePolicy::PerPhase)
        .with_schedules(SchedulePolicy::All);
    let res = explore_with_cache(
        &gemver,
        &phase_space,
        &ExploreConfig::default(),
        &phase_cache,
    );
    println!(
        "\nGEMVER per-phase sweep at N=64: {} evaluated points (shape \
         combinations × λ candidates) from {} phase analyses",
        res.points.len(),
        phase_cache.stats().misses
    );
    println!(
        "{:>16} {:>4} {:>14} {:>12} {:>7}",
        "phases", "PEs", "E_tot [pJ]", "L [cyc]", "pareto"
    );
    for (i, p) in res.points.iter().enumerate() {
        if !res.frontier.contains(&i)
            && !p.point.phase_shapes.is_uniform()
        {
            continue; // keep the table short: frontier + uniform rows
        }
        println!(
            "{:>16} {:>4} {:>14.3e} {:>12} {:>7}",
            p.point.phase_shapes.label(),
            p.pes,
            p.energy_pj,
            p.latency_cycles,
            if res.frontier.contains(&i) { "yes" } else { "" }
        );
    }

    // Cache effect: every size and backend after the first sweep reused
    // the same per-shape analyses.
    let s = cache.stats();
    println!(
        "\ntotal symbolic analyses: {} (for {} evaluations — the O(1) \
         per-query claim of Fig. 4)",
        s.misses,
        s.hits + s.misses
    );
}
