//! Design-space exploration (§V-B/§VI): size a processor array for GEMM.
//!
//! Because the analysis is symbolic, evaluating a candidate architecture
//! is a handful of expression evaluations — this sweep covers every 2-D
//! array shape up to 64 PEs for three problem sizes and prints the
//! energy/latency/EDP frontier, exactly the early-design-stage use the
//! paper motivates.
//!
//! ```bash
//! cargo run --release --example dse_array_sizing
//! ```

use tcpa_energy::coordinator::dse_sweep;
use tcpa_energy::workloads;

fn main() {
    let wl = workloads::by_name("gemm").unwrap();
    for n in [64i64, 128, 256] {
        let t0 = std::time::Instant::now();
        let pts = dse_sweep(&wl, &[n, n, n], 64);
        let took = t0.elapsed();
        println!(
            "\nGEMM N={n}: {} design points in {took:?} (best by EDP first)",
            pts.len()
        );
        println!(
            "{:>7} {:>4} {:>14} {:>14} {:>12} {:>12}",
            "array", "PEs", "E_tot [pJ]", "DRAM [pJ]", "L [cyc]", "EDP"
        );
        for p in pts.iter().take(8) {
            println!(
                "{:>4}x{:<3} {:>4} {:>14.3e} {:>14.3e} {:>12} {:>12.3e}",
                p.array.0,
                p.array.1,
                p.pes,
                p.energy_pj,
                p.dram_pj,
                p.latency_cycles,
                p.edp
            );
        }
        // The point of the paper: wider arrays trade on-chip traffic for
        // latency while DRAM energy is invariant — verify and report.
        let serial = pts.iter().find(|p| p.array == (1, 1)).unwrap();
        let best = &pts[0];
        println!(
            "best {}x{} improves latency {:.1}x over 1x1 at {:+.1}% energy",
            best.array.0,
            best.array.1,
            serial.latency_cycles as f64 / best.latency_cycles as f64,
            100.0 * (best.energy_pj - serial.energy_pj) / serial.energy_pj
        );
    }
}
