//! End-to-end driver: proves all three layers compose on real small
//! workloads and reports the paper's headline metric.
//!
//! For every workload in the artifact catalog:
//!   1. L1/L2: execute the AOT-compiled JAX/Pallas artifact through the
//!      PJRT runtime (Rust, no Python),
//!   2. L3: run the symbolic energy analysis AND the cycle-accurate
//!      simulator on the same configuration,
//!   3. check (a) simulator outputs == PJRT outputs (functional), (b)
//!      symbolic counts == simulated counts (exact), and report the
//!      headline metric: symbolic analysis+eval time vs simulation time,
//!      plus the speedup at a larger problem size.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::path::Path;
use std::time::Instant;

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::runtime::{catalog, Runtime};
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{pad_array, tile_pra, ArrayMapping};
use tcpa_energy::workloads::{self, workload_inputs, Tensor};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        return Err("artifacts/ missing — run `make artifacts` first".into());
    }
    let mut rt = Runtime::new()?;
    if rt.is_stub() {
        return Err(
            "PJRT backend not built (stub runtime) — rebuild with \
             `--features pjrt` (see rust/Cargo.toml)"
                .into(),
        );
    }
    let loaded = rt.load_dir(dir)?;
    println!(
        "PJRT platform: {}; loaded {} artifacts\n",
        rt.platform(),
        loaded.len()
    );

    let mut all_ok = true;
    println!(
        "{:<10} {:>9} {:>12} {:>12} {:>8}  {}",
        "workload", "PJRT", "sym eval", "simulation", "counts", "functional"
    );
    for spec in catalog() {
        let wl = workloads::by_name(spec.name).unwrap();
        let params: Vec<Vec<i64>> = wl
            .phases
            .iter()
            .zip(spec.bounds)
            .map(|(ph, b)| {
                let t = pad_array(&[2, 2], ph.ndims);
                ArrayMapping::new(t).params_for(b)
            })
            .collect();
        let env = workload_inputs(&wl, &params);

        // L1/L2 via PJRT.
        let inputs: Vec<Tensor> =
            spec.inputs.iter().map(|n| env[*n].clone()).collect();
        let t0 = Instant::now();
        let pjrt_out = rt.execute(spec.name, &inputs)?;
        let pjrt_t = t0.elapsed();

        // L3: symbolic + simulation on the first phase.
        let phase = &wl.phases[0];
        let t = pad_array(&[2, 2], phase.ndims);
        let mapping = ArrayMapping::new(t.clone());
        let ana = SymbolicAnalysis::analyze(phase, &mapping);
        let t1 = Instant::now();
        let sym = ana.counts_at(&params[0]);
        let sym_t = t1.elapsed();

        let mut arch = ArchConfig::with_array(t);
        arch.regs.fd = 1 << 20;
        let tiled = tile_pra(phase, &mapping);
        let schedule = find_schedule(&tiled, 1).unwrap();
        let t2 = Instant::now();
        let sim = simulate(phase, &arch, &schedule, &params[0], &env);
        let sim_t = t2.elapsed();

        let counts_ok = sim.counters.diff_symbolic(&sym).is_empty();
        // Functional: PJRT tuple outputs vs simulator outputs where the
        // first phase produces them (multi-phase workloads compare the
        // phase-1 tensor).
        let mut func_ok = sim.violations.is_empty();
        for (name, out) in spec.outputs.iter().zip(&pjrt_out) {
            if let Some(sim_tensor) = sim.outputs.get(*name) {
                func_ok &= sim_tensor.allclose(out, 1e-3, 1e-3);
            }
        }
        all_ok &= counts_ok && func_ok;
        println!(
            "{:<10} {:>8.1?} {:>12.1?} {:>12.1?} {:>8} {:>10}",
            spec.name,
            pjrt_t,
            sym_t,
            sim_t,
            if counts_ok { "EXACT" } else { "DIFF" },
            if func_ok { "match" } else { "DIVERGE" },
        );
    }

    // Headline metric (Fig. 4): analysis-time scaling on GESUMMV 8×8.
    println!("\nheadline: GESUMMV on 8x8 — symbolic vs simulation");
    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![8, 8]);
    let t0 = Instant::now();
    let ana = SymbolicAnalysis::analyze(phase, &mapping);
    let one_time = t0.elapsed();
    println!("  one-time symbolic analysis: {one_time:?}");
    for n in [64i64, 256, 1024] {
        let params = mapping.params_for(&[n, n]);
        let t1 = Instant::now();
        let _ = ana.energy_at(&params);
        let eval_t = t1.elapsed();
        let env = workload_inputs(&wl, &[params.clone()]);
        let mut arch = ArchConfig::with_array(vec![8, 8]);
        arch.regs.fd = 1 << 20;
        let tiled = tile_pra(phase, &mapping);
        let schedule = find_schedule(&tiled, 1).unwrap();
        let t2 = Instant::now();
        let _ = simulate(phase, &arch, &schedule, &params, &env);
        let sim_t = t2.elapsed();
        println!(
            "  N={n:>5}: symbolic eval {eval_t:>10.1?}   simulation \
             {sim_t:>10.1?}   speedup {:>8.0}x",
            sim_t.as_secs_f64() / eval_t.as_secs_f64().max(1e-9)
        );
    }

    if !all_ok {
        return Err("some workloads diverged".into());
    }
    println!("\nall layers compose: PJRT == simulator, symbolic == simulated");
    Ok(())
}
