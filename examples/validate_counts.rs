//! §V-A validation in example form: print the per-memory-class count
//! comparison between the symbolic analysis and the cycle-accurate
//! simulator for every benchmark workload — the "matches exactly" claim,
//! visibly.
//!
//! ```bash
//! cargo run --release --example validate_counts
//! ```

use tcpa_energy::coordinator::validate_workload;
use tcpa_energy::workloads;

fn main() {
    let mut all_ok = true;
    for wl in workloads::all() {
        let bounds: Vec<i64> = match wl.name.as_str() {
            "jacobi1d" => vec![4, 12],
            _ => vec![12, 12],
        };
        for row in validate_workload(&wl, &bounds, &[2, 2]) {
            println!(
                "\n== {} / {}  N={:?} on {:?} array ==",
                row.workload, row.phase, row.bounds, row.array
            );
            println!("{:>6} {:>14} {:>14}", "class", "symbolic", "simulated");
            for (label, sym, sim) in &row.counts {
                let mark = if sym == sim { "" } else { "  <-- MISMATCH" };
                println!("{label:>6} {sym:>14} {sim:>14}{mark}");
            }
            println!(
                "energy: symbolic {:.2} pJ, simulated {:.2} pJ",
                row.energy_sym_pj, row.energy_sim_pj
            );
            println!(
                "status: {} / functional {}",
                if row.exact_match { "EXACT" } else { "MISMATCH" },
                if row.functional_ok { "ok" } else { "DIVERGED" }
            );
            all_ok &= row.exact_match && row.functional_ok;
        }
    }
    if all_ok {
        println!("\nall benchmarks: symbolic == simulated, exactly.");
    } else {
        eprintln!("\nVALIDATION FAILED");
        std::process::exit(1);
    }
}
