# MVT (PolyBench): X1 = X1in + A·Y1 and X2 = X2in + Aᵀ·Y2, fused into
# one 2-deep PRA (pinned bit-identical to the builtin by
# rust/tests/text_frontend.rs). The transposed read A[i1, i0] is in
# bounds only on square problems — the `requires` line declares that
# precondition, and the lint engine proves bounds-safety under it.

workload mvt
loop i0 in 0..N0
loop i1 in 0..N1
requires N0 == N1
tensor A[N0, N1]
tensor Y1[N1]
tensor Y2[N1]
tensor X1in[N0]
tensor X2in[N0]
tensor X1[N0]
tensor X2[N0]

propagate v1 = Y1[i1] along i0
propagate v2 = Y2[i1] along i0
stmt: m1[i0, i1] = A[i0, i1] * v1[i0, i1]
stmt: m2[i0, i1] = A[i1, i0] * v2[i0, i1]
reduce s1 = m1 along i1
reduce s2 = m2 along i1
stmt: X1[i0] = s1[i0, i1] + X1in[i0] if i1 >= N1 - 1
stmt: X2[i0] = s2[i0, i1] + X2in[i0] if i1 >= N1 - 1
