# ATAX (PolyBench): y = Aᵀ(A·x) as a two-phase workload — the phase
# blocks mirror the builtin's atax_p1/atax_p2 split (pinned
# bit-identical by rust/tests/text_frontend.rs). TMP produced by phase
# 1 re-enters as an input of phase 2.

workload atax

phase atax_p1 {
  loop i0 in 0..N0
  loop i1 in 0..N1
  tensor A[N0, N1]
  tensor X[N1]
  tensor TMP[N0]

  propagate xx = X[i1] along i0
  stmt: m[i0, i1] = A[i0, i1] * xx[i0, i1]
  reduce s = m along i1
  stmt: TMP[i0] = s[i0, i1] if i1 >= N1 - 1
}

phase atax_p2 {
  loop i0 in 0..N0
  loop i1 in 0..N1
  tensor A[N0, N1]
  tensor TMP[N0]
  tensor Y[N1]

  propagate tt = TMP[i0] along i1
  stmt: m[i0, i1] = A[i0, i1] * tt[i0, i1]
  reduce s = m along i0
  stmt: Y[i1] = s[i0, i1] if i0 >= N0 - 1
}
