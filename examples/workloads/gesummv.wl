# GESUMMV (PolyBench): Y = (A + B)·X — the paper's running example.
# Textual rendition of the builtin `gesummv` constructor; pinned
# bit-identical (fingerprint, statement count, DSE frontier) by
# rust/tests/text_frontend.rs. The sugar lines expand to the paper's
# S1–S11 exactly: propagate → S1/S2, the two products → S3/S4, each
# reduce → a three-statement accumulation chain (S5–S7, S8–S10).

workload gesummv
loop i0 in 0..N0
loop i1 in 0..N1
tensor A[N0, N1]
tensor B[N0, N1]
tensor X[N1]
tensor Y[N0]

propagate x = X[i1] along i0
stmt: a[i0, i1] = A[i0, i1] * x[i0, i1]
stmt: b[i0, i1] = B[i0, i1] * x[i0, i1]
reduce sA = a along i1
reduce sB = b along i1
stmt: Y[i0] = sA[i0, i1] + sB[i0, i1] if i1 >= N1 - 1
