# Elementwise 2-D sum C = A + B — a workload that exists only as text,
# no Rust constructor. Demonstrates the minimal shape of the format:
# loops, tensors, one statement; no propagation or reduction chains.
# Passes `lint --deny warnings` (CI parses and lints every file here).

workload axpy2d
loop i0 in 0..N0
loop i1 in 0..N1
tensor A[N0, N1]
tensor B[N0, N1]
tensor C[N0, N1]

stmt: C[i0, i1] = A[i0, i1] + B[i0, i1]
