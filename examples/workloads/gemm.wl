# GEMM (PolyBench): C = A·B over a 3-deep nest (row, col, reduction).
# Textual rendition of the builtin `gemm` constructor (pinned
# bit-identical by rust/tests/text_frontend.rs): A propagates along the
# column dimension i1, B along the row dimension i0, products
# accumulate along i2.

workload gemm
loop i0 in 0..N0
loop i1 in 0..N1
loop i2 in 0..N2
tensor A[N0, N2]
tensor B[N2, N1]
tensor C[N0, N1]

propagate a = A[i0, i2] along i1
propagate bb = B[i2, i1] along i0
stmt: m[i0, i1, i2] = a[i0, i1, i2] * bb[i0, i1, i2]
reduce s = m along i2
stmt: C[i0, i1] = s[i0, i1, i2] if i2 >= N2 - 1
