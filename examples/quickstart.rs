//! Quickstart: analyze the paper's running example (GESUMMV, Example 1–9)
//! symbolically and evaluate energy + latency at a concrete size — no
//! simulation, no artifacts needed.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::tiling::ArrayMapping;
use tcpa_energy::workloads::gesummv::gesummv;

fn main() {
    // The paper's configuration (Example 2): 2×2 PE array.
    let pra = gesummv();
    let mapping = ArrayMapping::new(vec![2, 2]);

    // One-time symbolic analysis: tiling, scheduling, classification,
    // parametric volume computation.
    let ana = SymbolicAnalysis::analyze(&pra, &mapping);
    println!(
        "symbolic analysis of `{}` on a 2x2 array: {:?}\n",
        pra.name, ana.analysis_time
    );

    // Full report: schedule vectors, per-statement volumes (Example 9
    // style case expressions) and energies.
    println!("{}", ana.report());

    // Instant evaluation at any loop bounds — here the paper's 4×5 example
    // (tile sizes follow the exact-cover rule p = ceil(N/t) = (2,3)).
    let params = ana.params_for(&[4, 5]);
    let energy = ana.energy_at(&params);
    let latency = ana.latency_at(&params);
    println!("\nN = 4x5  (params {params:?})");
    for (class, pj) in &energy.mem_pj {
        println!("  {class:4} {pj:>12.2} pJ");
    }
    println!("  comp {:>12.2} pJ", energy.compute_pj);
    println!("  E_tot = {:.2} pJ, L = {latency} cycles", energy.total);
    assert_eq!(latency, 16, "paper Example 3");

    // ... and at a size where simulation would take real time:
    let big = ana.params_for(&[4096, 4096]);
    let e_big = ana.energy_at(&big);
    println!(
        "\nN = 4096x4096: E_tot = {:.3e} pJ, L = {} cycles \
         (same one-time analysis, instant evaluation)",
        e_big.total,
        ana.latency_at(&big)
    );
}
