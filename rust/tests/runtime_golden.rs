//! Three-layer composition test: the AOT-compiled JAX/Pallas artifacts
//! (L2/L1), executed from Rust through PJRT (runtime), must numerically
//! agree with (a) the PRA interpreter and (b) the cycle-accurate
//! simulator's functional outputs — for every workload in the catalog.
//!
//! Requires `make artifacts` (skips with a message otherwise, so plain
//! `cargo test` stays green in a fresh checkout).

use std::path::Path;

use tcpa_energy::runtime::{catalog, Runtime};
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::{
    self, interpret_workload, workload_inputs, Tensor,
};

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping");
        None
    }
}

#[test]
fn pjrt_artifacts_match_interpreter_and_simulator() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new().expect("PJRT CPU client");
    if rt.is_stub() {
        eprintln!(
            "PJRT backend not built (stub runtime) — rebuild with \
             --features pjrt; skipping"
        );
        return;
    }
    let loaded = rt.load_dir(dir).expect("loading artifacts");
    assert_eq!(loaded.len(), 10, "all ten artifacts load");

    for spec in catalog() {
        let wl = workloads::by_name(spec.name).unwrap();
        // Exact-cover params at the artifact's lowered bounds, 2×2 array
        // (padded with t=1 for 3-deep phases).
        let params: Vec<Vec<i64>> = wl
            .phases
            .iter()
            .zip(spec.bounds)
            .map(|(ph, b)| {
                let mut t = vec![2, 2];
                while t.len() < ph.ndims {
                    t.push(1);
                }
                t.truncate(ph.ndims);
                ArrayMapping::new(t).params_for(b)
            })
            .collect();
        let env = workload_inputs(&wl, &params);

        // --- PJRT execution of the artifact ---
        let inputs: Vec<Tensor> =
            spec.inputs.iter().map(|n| env[*n].clone()).collect();
        let outs = rt
            .execute(spec.name, &inputs)
            .unwrap_or_else(|e| panic!("{}: {e:#}", spec.name));
        assert_eq!(outs.len(), spec.outputs.len(), "{}", spec.name);

        // --- interpreter golden ---
        let golden = interpret_workload(&wl, &params, &env);
        for (tensor_name, pjrt_out) in spec.outputs.iter().zip(&outs) {
            let want = &golden[*tensor_name];
            assert_eq!(
                pjrt_out.shape, want.shape,
                "{} output {tensor_name}",
                spec.name
            );
            assert!(
                pjrt_out.allclose(want, 1e-3, 1e-3),
                "{} output {tensor_name}: max diff {}",
                spec.name,
                pjrt_out.max_abs_diff(want)
            );
        }

        // --- simulator functional agreement (first phase) ---
        let phase = &wl.phases[0];
        let mut t = vec![2, 2];
        while t.len() < phase.ndims {
            t.push(1);
        }
        t.truncate(phase.ndims);
        let mapping = ArrayMapping::new(t.clone());
        let mut arch = ArchConfig::with_array(t);
        arch.regs.fd = 1 << 20;
        let tiled = tile_pra(phase, &mapping);
        let schedule = find_schedule(&tiled, 1).unwrap();
        let sim = simulate(phase, &arch, &schedule, &params[0], &env);
        assert!(sim.violations.is_empty(), "{}", spec.name);
        for (name, tens) in &sim.outputs {
            assert!(
                tens.allclose(&golden[name], 1e-3, 1e-3),
                "{} sim output {name} diverges",
                spec.name
            );
        }
    }
}
