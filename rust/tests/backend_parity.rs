//! Backend parity suite: the pluggable [`Backend`] descriptors must not
//! change a single bit of the paper's TCPA numbers, and the
//! cross-architecture pricing must come from *one* symbolic analysis.
//!
//! * The TCPA backend reproduces the native `energy_at` path (the
//!   pre-backend `Policy::Tcpa` fast path of the explorer) bit-for-bit,
//!   per class, across workloads, shapes and bounds.
//! * The Example-9 / Table-I energies of the paper come out exactly.
//! * One `SymbolicAnalysis` prices all four built-in backends without
//!   re-running the symbolic pass, with the documented energy ordering.
//! * The legacy `Policy` semantics survive the conversion to backends.

use tcpa_energy::analysis::{SymbolicAnalysis, WorkloadAnalysis};
use tcpa_energy::energy::{
    AccessClass, Backend, EnergyTable, MemoryClass, Policy,
};
use tcpa_energy::tiling::ArrayMapping;
use tcpa_energy::workloads;
use tcpa_energy::workloads::gesummv::gesummv;

#[test]
fn tcpa_backend_matches_native_path_bit_for_bit() {
    let tcpa = Backend::tcpa();
    for name in ["gesummv", "gemm", "bicg", "atax", "jacobi1d"] {
        let wl = workloads::by_name(name).unwrap();
        for array in [vec![1i64, 1], vec![2, 2], vec![4, 2]] {
            let ana = WorkloadAnalysis::analyze_uniform(&wl, &array);
            for n in [8i64, 16, 64] {
                let params: Vec<Vec<i64>> = ana
                    .phases
                    .iter()
                    .map(|ph| {
                        let b = tcpa_energy::tiling::pad_bounds(
                            &[n, n],
                            ph.tiled.pra.ndims,
                        );
                        ph.params_for(&b)
                    })
                    .collect();
                let native = ana.energy_at(&params);
                let routed = ana.energy_at_backend(&params, &tcpa);
                assert_eq!(
                    native.total.to_bits(),
                    routed.total.to_bits(),
                    "{name} {array:?} N={n}: total drifted"
                );
                assert_eq!(
                    native, routed,
                    "{name} {array:?} N={n}: breakdown drifted"
                );
                for (c, v) in &native.mem_pj {
                    assert_eq!(
                        v.to_bits(),
                        routed.mem_pj[c].to_bits(),
                        "{name} {array:?} N={n}: {c} drifted"
                    );
                }
                assert_eq!(
                    ana.counts_at(&params),
                    ana.counts_at_backend(&params, &tcpa),
                    "{name} {array:?} N={n}: counts drifted"
                );
            }
        }
    }
}

#[test]
fn example9_energies_reproduced_by_tcpa_backend() {
    // Paper Example 9: E(S7*1) = E(FD)+E(RD) = 0.47 pJ, E(S7*2) =
    // E(ID)+E(RD) = 0.36 pJ; S7's total contribution at N=(4,5),
    // p=(2,3) on a 2×2 array is 12·0.47 + 4·0.36 = 7.08 pJ.
    let ana =
        SymbolicAnalysis::analyze(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let tcpa = Backend::tcpa();
    let params = [4i64, 5, 2, 3];
    let s7: Vec<_> = ana
        .statements
        .iter()
        .filter(|s| s.base_name == "S7")
        .collect();
    assert_eq!(s7.len(), 2);
    let per_exec: Vec<f64> =
        s7.iter().map(|s| tcpa.stmt_energy(&s.profile)).collect();
    assert!((per_exec[0] - 0.47).abs() < 1e-12, "{per_exec:?}");
    assert!((per_exec[1] - 0.36).abs() < 1e-12, "{per_exec:?}");
    let contribution: f64 = s7
        .iter()
        .zip(&per_exec)
        .map(|(s, e)| s.volume.eval(&params) as f64 * e)
        .sum();
    assert!((contribution - 7.08).abs() < 1e-9, "{contribution}");
    // And the per-statement energies match the profile's own Table-I
    // pricing exactly.
    for s in &ana.statements {
        assert_eq!(
            tcpa.stmt_energy(&s.profile).to_bits(),
            s.profile.energy(&ana.table).to_bits(),
            "{}",
            s.name
        );
    }
}

#[test]
fn one_symbolic_analysis_prices_four_architectures() {
    // Acceptance: ≥ 4 built-in backends priced from one symbolic pass —
    // no re-analysis, just expression evaluation + routing.
    let ana =
        SymbolicAnalysis::analyze(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let params = ana.params_for(&[64, 64]);
    let backends = Backend::builtins();
    assert!(backends.len() >= 4);
    let totals: Vec<(String, f64)> = backends
        .iter()
        .map(|b| {
            (b.name().to_string(), ana.energy_at_backend(&params, b).total)
        })
        .collect();
    for (name, e) in &totals {
        assert!(e.is_finite() && *e > 0.0, "{name}: {e}");
    }
    let by = |n: &str| totals.iter().find(|(m, _)| m == n).unwrap().1;
    // Pointwise routing order ⇒ total order (strict: GESUMMV has FD and
    // ID traffic).
    assert!(by("tcpa") < by("systolic"));
    assert!(by("systolic") < by("cgra"));
    assert!(by("cgra") < by("gpu-sm"));
    // DRAM energy is a mapping property — identical across backends.
    let dram: Vec<u64> = backends
        .iter()
        .map(|b| {
            ana.energy_at_backend(&params, b).mem_pj[&MemoryClass::Dram]
                .to_bits()
        })
        .collect();
    assert!(dram.windows(2).all(|w| w[0] == w[1]), "{dram:?}");
}

#[test]
fn legacy_policy_semantics_survive_backend_conversion() {
    // The old `energy_at_with(params, policy, table)` accumulated
    // per-statement: Σ_q vol_q · E_q(policy). The backend path aggregates
    // counts first — same value, different float summation order — so
    // the parity bound here is relative, not bit-wise.
    let ana =
        SymbolicAnalysis::analyze(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let table = EnergyTable::table1_45nm();
    let params = ana.params_for(&[32, 32]);
    for policy in Policy::ALL {
        let backend = policy.backend(&table);
        let routed = ana.energy_at_backend(&params, &backend).total;
        // Reference: the pre-refactor per-statement formula.
        let reference: f64 = ana
            .statements
            .iter()
            .map(|s| {
                let vol = s.volume.eval(&params) as f64;
                let reads: f64 = s
                    .profile
                    .reads
                    .iter()
                    .map(|&r| policy.access_energy(r, &table))
                    .sum();
                let write = policy.access_energy(s.profile.write, &table);
                vol * (reads + table.op(s.profile.op) + write)
            })
            .sum();
        let rel = (routed - reference).abs() / reference.max(1e-12);
        assert!(
            rel < 1e-12,
            "{}: {routed} vs {reference} (rel {rel})",
            policy.label()
        );
    }
}

#[test]
fn custom_backend_is_a_plain_value() {
    // Pluggability: a user-defined architecture needs no enum variant —
    // just a descriptor. A register-poor tile whose FD spills to IOb
    // must price strictly between tcpa and gpu-sm.
    let ana =
        SymbolicAnalysis::analyze(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let params = ana.params_for(&[32, 32]);
    let custom = Backend::new("reg-poor", EnergyTable::table1_45nm())
        .with_route(
            AccessClass::Fd,
            &[MemoryClass::IOb, MemoryClass::IOb, MemoryClass::Rd],
        );
    let tcpa = ana.energy_at_backend(&params, &Backend::tcpa()).total;
    let mid = ana.energy_at_backend(&params, &custom).total;
    let gpu = ana.energy_at_backend(&params, &Backend::gpu_sm()).total;
    assert!(tcpa < mid, "{tcpa} vs {mid}");
    assert!(mid < gpu, "{mid} vs {gpu}");
}
