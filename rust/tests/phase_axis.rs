//! Properties of the per-phase heterogeneous mapping axis
//! (`DesignSpace::with_phase_shapes`):
//!
//! 1. The per-phase frontier weakly dominates the uniform frontier in
//!    every (bounds, backend) scenario — the sweep is a superset, so it
//!    can only improve.
//! 2. On GEMVER, composed with the schedule axis, a genuinely
//!    heterogeneous assignment reaches the frontier. The schedule axis
//!    matters: GEMVER's phases are structural transposes with one
//!    propagation and one accumulation stream each, so their orientation
//!    preferences under *optimal* schedules mirror (or tie) — while the
//!    default candidate-0 schedule's fixed lexicographic dimension order
//!    penalizes dim-1 tile crossings for every phase alike, aligning
//!    all preferences on one orientation.
//! 3. For phases with *opposite stream-count asymmetries* (a
//!    GESUMMV-like phase and its transpose), heterogeneity is strictly
//!    optimal in energy — the mechanism in its purest form, pinned on a
//!    purpose-built workload.
//! 4. `PhasePolicy::Uniform` (the default) reproduces the pre-axis
//!    sweep bit-for-bit, pinned by manual recomputation of every point
//!    from a fresh uniform analysis.
//! 5. Analysis work scales with distinct (phase, shape) pairs, never
//!    with the number of shape combinations.
//! 6. Sim differential: a heterogeneous assignment's explorer energy
//!    equals the per-phase symbolic totals, which in turn match the
//!    cycle-accurate simulator exactly (`validate_workload_mapped`).
//! 7. Exploration with the axis enabled is deterministic across worker
//!    counts.

use tcpa_energy::analysis::WorkloadAnalysis;
use tcpa_energy::coordinator::validate::validate_workload_mapped;
use tcpa_energy::dse::{
    explore, explore_with_cache, AnalysisCache, DesignSpace,
    ExploreConfig, PhasePolicy, PhaseShapes, SchedulePolicy,
};
use tcpa_energy::energy::Backend;
use tcpa_energy::pra::ir::{IndexMap, Lhs, Op, Operand};
use tcpa_energy::pra::{validate, Workload};
use tcpa_energy::workloads::{self, PraBuilder};

/// The comparison space the axis properties run on: GEMVER (3 phases)
/// over both 4-PE orientations plus the square, two bounds scenarios.
fn gemver_space() -> DesignSpace {
    DesignSpace::new()
        .with_arrays(vec![vec![1, 4], vec![4, 1], vec![2, 2]])
        .with_bounds_sweep(&[8, 12], 2)
}

#[test]
fn per_phase_frontier_weakly_dominates_uniform_per_scenario() {
    let wl = workloads::by_name("gemver").unwrap();
    let uniform = explore(&wl, &gemver_space(), &ExploreConfig::default());
    let per_phase = explore(
        &wl,
        &gemver_space().with_phase_shapes(PhasePolicy::PerPhase),
        &ExploreConfig::default(),
    );
    assert!(uniform.failures.is_empty(), "{:?}", uniform.failures);
    assert!(per_phase.failures.is_empty(), "{:?}", per_phase.failures);
    assert_eq!(uniform.points.len(), 3 * 2);
    assert_eq!(per_phase.points.len(), 27 * 2, "3 shapes ^ 3 phases");
    for ug in &uniform.groups {
        let pg = per_phase
            .groups
            .iter()
            .find(|g| g.bounds == ug.bounds && g.backend == ug.backend)
            .expect("scenario present in both sweeps");
        for &ui in &ug.frontier {
            let uo = uniform.points[ui].objectives().to_array();
            let covered = pg.frontier.iter().any(|&pi| {
                let po = per_phase.points[pi].objectives().to_array();
                po.iter().zip(&uo).all(|(p, u)| p <= u)
            });
            assert!(
                covered,
                "uniform frontier point {:?} ({:?}) has no weakly \
                 dominating counterpart under per-phase shapes",
                uniform.points[ui].point.array, ug.bounds
            );
        }
        // The uniform diagonal is enumerated, so the per-phase frontier
        // can never be worse in any scenario.
        assert!(!pg.frontier.is_empty());
    }
}

#[test]
fn heterogeneous_assignment_reaches_the_frontier_on_gemver() {
    // Per-phase shapes composed with the schedule axis: each (phase,
    // shape) pair is evaluated at its best feasible λ, which restores
    // the transpose symmetry between GEMVER's phase 2 (accumulates
    // along i0) and phase 3 (accumulates along i1). Their orientation
    // preferences then mirror — or tie exactly — and either way some
    // heterogeneous assignment is non-dominated: with mirrored strict
    // preferences the phase-wise argmin combination strictly beats both
    // uniform orientations, and with exact ties nothing dominates
    // anything, so heterogeneous combinations stand on the frontier
    // alongside the uniforms.
    let wl = workloads::by_name("gemver").unwrap();
    let space = DesignSpace::new()
        .with_arrays(vec![vec![1, 4], vec![4, 1]])
        .with_bounds(vec![8, 8])
        .with_phase_shapes(PhasePolicy::PerPhase)
        .with_schedules(SchedulePolicy::All);
    let res = explore(&wl, &space, &ExploreConfig::default());
    assert!(res.failures.is_empty(), "{:?}", res.failures);
    // All 2^3 shape combinations are present (× schedule candidates).
    let combos: std::collections::BTreeSet<String> = res
        .points
        .iter()
        .map(|p| p.point.phase_shapes.label())
        .collect();
    assert_eq!(combos.len(), 8, "2 shapes ^ 3 phases: {combos:?}");
    let hetero_on_frontier = res.frontier.iter().any(|&i| {
        res.points[i].point.phase_shapes.is_heterogeneous()
    });
    assert!(
        hetero_on_frontier,
        "a genuinely heterogeneous assignment must reach the frontier; \
         frontier: {:?}",
        res.frontier
            .iter()
            .map(|&i| {
                (
                    res.points[i].point.phase_shapes.label(),
                    res.points[i].energy_pj,
                    res.points[i].latency_cycles,
                )
            })
            .collect::<Vec<_>>()
    );
    // And the composed frontier weakly dominates the uniform sweep at
    // the same schedule policy.
    let uniform = explore(
        &wl,
        &DesignSpace::new()
            .with_arrays(vec![vec![1, 4], vec![4, 1]])
            .with_bounds(vec![8, 8])
            .with_schedules(SchedulePolicy::All),
        &ExploreConfig::default(),
    );
    for &ui in &uniform.frontier {
        let uo = uniform.points[ui].objectives().to_array();
        assert!(
            res.frontier.iter().any(|&pi| {
                let po = res.points[pi].objectives().to_array();
                po.iter().zip(&uo).all(|(p, u)| p <= u)
            }),
            "uniform frontier point must be weakly dominated"
        );
    }
}

/// A two-phase workload whose phases carry *opposite* stream-count
/// asymmetries: phase A propagates one value along `i0` and drives two
/// accumulation chains along `i1` (the GESUMMV shape); phase B is its
/// transpose. Splitting a dimension converts that dimension's streams
/// from FD to (Table-I-cheaper) ID transport, so phase A's energy
/// strictly prefers the orientation that splits `i1` (two streams
/// converted) while phase B strictly prefers the opposite — the uniform
/// sweep must pay the wrong orientation for one of them.
fn mirrored_asymmetric() -> Workload {
    let nd = 2;
    let mut a = PraBuilder::new("hetero_a", nd);
    a.tensor("A", &[0, 1])
        .tensor("B", &[0, 1])
        .tensor("X", &[1])
        .tensor("Y", &[0]);
    a.propagate("x", "X", IndexMap::select(&[1], nd), 0);
    a.stmt(
        Lhs::Var("pa".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("x", nd),
        ],
        vec![],
    );
    a.stmt(
        Lhs::Var("pb".into()),
        Op::Mul,
        vec![
            Operand::tensor("B", IndexMap::identity(2, nd)),
            Operand::var0("x", nd),
        ],
        vec![],
    );
    a.acc_chain("sa", "pa", 1);
    a.acc_chain("sb", "pb", 1);
    let top1 = a.eq_top(1);
    a.stmt(
        Lhs::Tensor { name: "Y".into(), map: IndexMap::select(&[0], nd) },
        Op::Add,
        vec![Operand::var0("sa", nd), Operand::var0("sb", nd)],
        top1,
    );
    let pa = a.build();
    assert!(validate(&pa).is_empty(), "{:?}", validate(&pa));

    let mut b = PraBuilder::new("hetero_b", nd);
    b.tensor("C", &[0, 1])
        .tensor("D", &[0, 1])
        .tensor("W", &[0])
        .tensor("Z", &[1]);
    b.propagate("w", "W", IndexMap::select(&[0], nd), 1);
    b.stmt(
        Lhs::Var("pc".into()),
        Op::Mul,
        vec![
            Operand::tensor("C", IndexMap::identity(2, nd)),
            Operand::var0("w", nd),
        ],
        vec![],
    );
    b.stmt(
        Lhs::Var("pd".into()),
        Op::Mul,
        vec![
            Operand::tensor("D", IndexMap::identity(2, nd)),
            Operand::var0("w", nd),
        ],
        vec![],
    );
    b.acc_chain("sc", "pc", 0);
    b.acc_chain("sd", "pd", 0);
    let top0 = b.eq_top(0);
    b.stmt(
        Lhs::Tensor { name: "Z".into(), map: IndexMap::select(&[1], nd) },
        Op::Add,
        vec![Operand::var0("sc", nd), Operand::var0("sd", nd)],
        top0,
    );
    let pb = b.build();
    assert!(validate(&pb).is_empty(), "{:?}", validate(&pb));

    Workload { name: "mirrored-asym".into(), phases: vec![pa, pb] }
}

#[test]
fn opposite_phase_asymmetries_make_heterogeneity_strictly_optimal() {
    let wl = mirrored_asymmetric();
    let shapes = [vec![1i64, 4], vec![4i64, 1]];
    let bounds = [8i64, 8];
    // Premise, computed not assumed: the phases' energy argmins over
    // the two orientations differ.
    let cache = AnalysisCache::new();
    let energy = |phase: usize, s: &[i64]| {
        let (ana, _) = cache.try_get_or_analyze_phase(&wl, phase, s);
        let ana = ana.expect("schedulable");
        let params = ana.params_for(&bounds);
        ana.energy_at(&params).total
    };
    let argmin = |phase: usize| {
        let (e0, e1) = (energy(phase, &shapes[0]), energy(phase, &shapes[1]));
        assert_ne!(
            e0, e1,
            "phase {phase}: opposite stream asymmetries must price the \
             orientations differently ({e0} vs {e1} pJ)"
        );
        usize::from(e1 < e0)
    };
    let (pref_a, pref_b) = (argmin(0), argmin(1));
    assert_ne!(
        pref_a, pref_b,
        "mirrored phases must prefer opposite orientations"
    );

    let space = DesignSpace::new()
        .with_arrays(shapes.to_vec())
        .with_bounds(bounds.to_vec())
        .with_phase_shapes(PhasePolicy::PerPhase);
    let res = explore_with_cache(
        &wl,
        &space,
        &ExploreConfig::default(),
        &cache,
    );
    assert!(res.failures.is_empty(), "{:?}", res.failures);
    assert_eq!(res.points.len(), 4);
    let best = PhaseShapes::PerPhase(vec![
        shapes[pref_a].clone(),
        shapes[pref_b].clone(),
    ]);
    let best_idx = res
        .points
        .iter()
        .position(|p| p.point.phase_shapes == best)
        .expect("argmin assignment enumerated");
    assert!(best.is_heterogeneous());
    // The phase-wise energy argmin is the unique total-energy minimum
    // (energies sum over phases), so nothing can dominate it …
    assert!(
        res.frontier.contains(&best_idx),
        "the heterogeneous energy minimum must be non-dominated"
    );
    // … and it strictly undercuts every uniform assignment.
    for p in &res.points {
        if p.point.phase_shapes.is_uniform() {
            assert!(
                res.points[best_idx].energy_pj < p.energy_pj,
                "hetero argmin must undercut uniform {} ({} vs {} pJ)",
                p.point.phase_shapes.label(),
                res.points[best_idx].energy_pj,
                p.energy_pj
            );
        }
    }
}

#[test]
fn uniform_policy_reproduces_pre_axis_sweep_bit_for_bit() {
    // Explicit Uniform changes nothing relative to the default space,
    // and every emitted point carries exactly the pre-axis arithmetic:
    // energy from a fresh uniform analysis' backend pricing, latency
    // from its embedded default schedules.
    let wl = workloads::by_name("atax").unwrap();
    let space = DesignSpace::new()
        .with_arrays(vec![vec![1, 4], vec![4, 1], vec![2, 2]])
        .with_bounds_sweep(&[8, 16], 2)
        .with_backends(vec![Backend::tcpa(), Backend::cgra()]);
    let implicit = explore(&wl, &space, &ExploreConfig::default());
    let explicit = explore(
        &wl,
        &space.clone().with_phase_shapes(PhasePolicy::Uniform),
        &ExploreConfig::default(),
    );
    assert_eq!(implicit.points.len(), explicit.points.len());
    for (a, b) in implicit.points.iter().zip(&explicit.points) {
        assert_eq!(a.point, b.point);
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.dram_pj.to_bits(), b.dram_pj.to_bits());
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.edp.to_bits(), b.edp.to_bits());
    }
    assert_eq!(implicit.frontier, explicit.frontier);
    assert_eq!(implicit.groups, explicit.groups);
    // Manual recomputation — the pre-axis explorer semantics.
    for p in &explicit.points {
        assert_eq!(p.point.phase_shapes, PhaseShapes::Uniform);
        let ana = WorkloadAnalysis::analyze_uniform(&wl, &p.point.array);
        let params: Vec<Vec<i64>> = ana
            .phases
            .iter()
            .map(|ph| {
                ph.params_for(&tcpa_energy::tiling::pad_bounds(
                    &p.point.bounds,
                    ph.tiled.pra.ndims,
                ))
            })
            .collect();
        let energy = ana.energy_at_backend(&params, &p.point.backend);
        assert_eq!(p.energy_pj.to_bits(), energy.total.to_bits());
        assert_eq!(p.latency_cycles, ana.latency_at(&params));
    }
}

#[test]
fn analysis_count_scales_with_phase_shape_pairs() {
    // 27 combinations per scenario, 2 scenarios — but exactly
    // 3 phases × 3 shapes = 9 symbolic analyses, each reused by every
    // combination containing it.
    let wl = workloads::by_name("gemver").unwrap();
    let cache = AnalysisCache::new();
    let res = explore_with_cache(
        &wl,
        &gemver_space().with_phase_shapes(PhasePolicy::PerPhase),
        &ExploreConfig::default(),
        &cache,
    );
    assert!(res.failures.is_empty(), "{:?}", res.failures);
    assert_eq!(res.points.len(), 54);
    let s = cache.stats();
    assert_eq!(s.entries, 9, "3 phases × 3 shapes");
    assert_eq!(s.misses, 9, "analysis count must not track combinations");
    // 3 phase lookups per base point; all but the 9 cold ones hit.
    assert_eq!(s.hits, 54 * 3 - 9);
}

#[test]
fn heterogeneous_energy_matches_simulator_exactly() {
    // The sim differential: phase-wise symbolic counts on heterogeneous
    // shapes match the cycle-accurate simulator exactly, and the
    // explorer's assembled totals are precisely those phase sums.
    let wl = workloads::by_name("gemver").unwrap();
    let shapes = vec![vec![2i64, 2], vec![1i64, 4], vec![4i64, 1]];
    let rows = validate_workload_mapped(&wl, &[8, 8], &shapes);
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert!(r.exact_match, "{}: {:?}", r.phase, r.counts);
        assert!(r.functional_ok, "{}: outputs diverge", r.phase);
    }
    let space = DesignSpace::new()
        .with_arrays(shapes.clone())
        .with_bounds(vec![8, 8])
        .with_phase_shapes(PhasePolicy::PerPhase);
    let res = explore(&wl, &space, &ExploreConfig::default());
    let point = res
        .points
        .iter()
        .find(|p| p.point.phase_shapes == PhaseShapes::PerPhase(shapes.clone()))
        .expect("the validated assignment is enumerated");
    let sym_total: f64 = rows.iter().map(|r| r.energy_sym_pj).sum();
    assert_eq!(
        point.energy_pj.to_bits(),
        sym_total.to_bits(),
        "explorer totals must be the exact per-phase sums"
    );
    let sim_total: f64 = rows.iter().map(|r| r.energy_sim_pj).sum();
    assert!(
        (point.energy_pj - sim_total).abs() <= 1e-6 * point.energy_pj,
        "symbolic {} vs simulated {} pJ",
        point.energy_pj,
        sim_total
    );
}

#[test]
fn per_phase_axis_deterministic_across_worker_counts() {
    let wl = workloads::by_name("gemver").unwrap();
    let space = gemver_space().with_phase_shapes(PhasePolicy::PerPhase);
    let a = explore(&wl, &space, &ExploreConfig { workers: 1 });
    let b = explore(&wl, &space, &ExploreConfig { workers: 4 });
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.point, y.point, "order must not depend on workers");
        assert_eq!(x.energy_pj.to_bits(), y.energy_pj.to_bits());
        assert_eq!(x.dram_pj.to_bits(), y.dram_pj.to_bits());
        assert_eq!(x.latency_cycles, y.latency_cycles);
        assert_eq!(x.edp.to_bits(), y.edp.to_bits());
    }
    assert_eq!(a.frontier, b.frontier);
    assert_eq!(a.groups, b.groups);
    assert_eq!(a.knee, b.knee);
}
