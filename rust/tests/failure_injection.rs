//! Failure injection: the framework must *report* broken configurations,
//! not silently produce numbers.

use tcpa_energy::runtime::Runtime;
use tcpa_energy::schedule::{find_schedule, ScheduleError};
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::{self, workload_inputs, Tensor};

/// Undersized feedback register files must be flagged: GEMM with a big
/// PE-local reduction needs deep FD FIFOs.
#[test]
fn undersized_fd_regfile_reported() {
    let wl = workloads::by_name("gemm").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![2, 2, 1]);
    let mut arch = ArchConfig::with_array(vec![2, 2, 1]);
    arch.regs.fd = 2; // far below the schedule distance of the chains
    let tiled = tile_pra(phase, &mapping);
    let schedule = find_schedule(&tiled, 1).unwrap();
    let params = mapping.params_for(&[8, 8, 8]);
    let env = workload_inputs(&wl, &[params.clone()]);
    let res = simulate(phase, &arch, &schedule, &params, &env);
    assert!(
        res.violations.iter().any(|v| v.contains("FD pressure")),
        "expected an FD-pressure violation, got {:?}",
        res.violations
    );
    // ... and a generously sized file is clean.
    arch.regs.fd = 1 << 20;
    let res2 = simulate(phase, &arch, &schedule, &params, &env);
    assert!(res2.violations.is_empty(), "{:?}", res2.violations);
}

/// A dependence set with no causal lexicographic order must be rejected
/// by the scheduler (not silently mis-scheduled).
#[test]
fn unschedulable_dependences_rejected() {
    let wl = workloads::twist_unschedulable();
    let tiled = tile_pra(&wl.phases[0], &ArrayMapping::new(vec![2, 2]));
    let err = find_schedule(&tiled, 1);
    assert!(
        matches!(err, Err(ScheduleError::NoValidPermutation(_))),
        "{err:?}"
    );
}

/// Runtime errors are descriptive: missing artifacts directory, unknown
/// model, and shape mismatches.
#[test]
fn runtime_error_paths() {
    let mut rt = Runtime::new().unwrap();
    // Missing manifest points the user at `make artifacts`.
    let err = rt
        .load_dir(std::path::Path::new("/nonexistent-dir"))
        .unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "{err:#}");
    // Unknown model.
    let err = rt.execute("ghost", &[]).unwrap_err();
    assert!(err.to_string().contains("not loaded"));
    // Shape mismatch (needs real artifacts and the real backend).
    let dir = std::path::Path::new("artifacts");
    if !rt.is_stub() && dir.join("manifest.txt").exists() {
        rt.load_dir(dir).unwrap();
        let bad = vec![Tensor::zeros(vec![3, 3]); 3];
        let err = rt.execute("gesummv", &bad).unwrap_err();
        assert!(
            err.to_string().contains("does not match artifact"),
            "{err:#}"
        );
        let err2 = rt.execute("gesummv", &[]).unwrap_err();
        assert!(err2.to_string().contains("expected"), "{err2:#}");
    }
}

/// Mappings with a rank different from the loop depth are a programmer
/// error and panic with a clear message.
#[test]
#[should_panic(expected = "mapping rank")]
fn rank_mismatch_panics() {
    let wl = workloads::by_name("gemm").unwrap();
    let _ = tile_pra(&wl.phases[0], &ArrayMapping::new(vec![2, 2]));
}

/// Zero/negative array extents are rejected at construction.
#[test]
#[should_panic(expected = "extents must be >= 1")]
fn bad_array_extent_panics() {
    let _ = ArrayMapping::new(vec![2, 0]);
}
