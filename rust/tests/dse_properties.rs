//! Property tests for the `dse` subsystem (via the in-crate
//! `proptest_lite` harness):
//!
//! 1. Pareto frontiers contain no dominated point, and every dropped
//!    point is dominated by some frontier member.
//! 2. For a workload whose dependence structure is symmetric under the
//!    dimension swap, transposed array shapes `(a,b)` / `(b,a)` yield
//!    bit-identical energy — the soundness condition behind
//!    `DesignSpace::with_symmetry_pruning`.
//! 3. Cached and uncached analyses agree bit-for-bit.
//! 4. Exploration results are deterministic across worker counts.
//! 5. Every built-in cross-architecture backend yields a sound
//!    per-scenario Pareto frontier (no dominated member, every dropped
//!    point dominated by a member, knee on the frontier).
//! 6. The schedule axis only improves frontiers: `--schedules all`
//!    weakly dominates the single-schedule frontier point-for-point at
//!    identical (bounds, backend) scenarios, and `--schedules first`
//!    reproduces the pre-axis per-point arithmetic bit-for-bit.

use tcpa_energy::analysis::WorkloadAnalysis;
use tcpa_energy::dse::{
    dominates, explore, pareto_frontier, AnalysisCache, DesignSpace,
    ExploreConfig, SchedulePolicy,
};
use tcpa_energy::energy::Backend;
use tcpa_energy::pra::ir::{IndexMap, Lhs, Op, Operand};
use tcpa_energy::pra::{validate, Workload};
use tcpa_energy::proptest_lite::{check, Rng};
use tcpa_energy::workloads::{self, PraBuilder};

#[test]
fn frontier_contains_no_dominated_point_random() {
    check(
        "pareto-no-dominated",
        0xD5E_0001,
        200,
        |r: &mut Rng| {
            let n = r.i64_in(1, 12) as usize;
            (0..n)
                .map(|_| {
                    // Small integer coordinates force plenty of ties and
                    // duplicates — the degenerate cases.
                    [
                        r.i64_in(0, 4) as f64,
                        r.i64_in(0, 4) as f64,
                        r.i64_in(0, 4) as f64,
                        r.i64_in(0, 4) as f64,
                    ]
                })
                .collect::<Vec<[f64; 4]>>()
        },
        |objs| {
            let frontier = pareto_frontier(objs);
            if frontier.is_empty() {
                return Err("frontier empty on non-empty input".into());
            }
            for &i in &frontier {
                if let Some(j) =
                    (0..objs.len()).find(|&j| dominates(&objs[j], &objs[i]))
                {
                    return Err(format!(
                        "frontier point {i} {:?} dominated by {j} {:?}",
                        objs[i], objs[j]
                    ));
                }
            }
            for i in 0..objs.len() {
                if !frontier.contains(&i)
                    && !frontier
                        .iter()
                        .any(|&f| dominates(&objs[f], &objs[i]))
                {
                    return Err(format!(
                        "dropped point {i} {:?} dominated by no frontier \
                         member",
                        objs[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// A 2-deep PRA that is its own mirror image under the dimension swap:
/// one propagation + product + accumulation pipeline along each axis.
/// (GESUMMV is *not* symmetric — one propagation along i0, two chains
/// along i1 — which is exactly why the pruning soundness property needs
/// a purpose-built workload.)
fn sym2d() -> Workload {
    let nd = 2;
    let mut b = PraBuilder::new("sym2d", nd);
    b.tensor("A", &[0, 1])
        .tensor("B", &[1, 0])
        .tensor("X", &[1])
        .tensor("Yv", &[0])
        .tensor("OutA", &[0])
        .tensor("OutB", &[1]);
    // Axis-0 pipeline: X propagates along i0, product, chain along i1.
    b.propagate("x", "X", IndexMap::select(&[1], nd), 0);
    // Axis-1 pipeline (mirror): Yv propagates along i1.
    b.propagate("y", "Yv", IndexMap::select(&[0], nd), 1);
    b.stmt(
        Lhs::Var("a".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("x", nd),
        ],
        vec![],
    );
    b.stmt(
        Lhs::Var("c".into()),
        Op::Mul,
        vec![
            Operand::tensor("B", IndexMap::identity(2, nd)),
            Operand::var0("y", nd),
        ],
        vec![],
    );
    b.acc_chain("sa", "a", 1);
    b.acc_chain("sc", "c", 0);
    let top1 = b.eq_top(1);
    b.stmt(
        Lhs::Tensor { name: "OutA".into(), map: IndexMap::select(&[0], nd) },
        Op::Copy,
        vec![Operand::var0("sa", nd)],
        top1,
    );
    let top0 = b.eq_top(0);
    b.stmt(
        Lhs::Tensor { name: "OutB".into(), map: IndexMap::select(&[1], nd) },
        Op::Copy,
        vec![Operand::var0("sc", nd)],
        top0,
    );
    let pra = b.build();
    assert!(validate(&pra).is_empty(), "{:?}", validate(&pra));
    Workload::single(pra)
}

#[test]
fn transposed_shapes_identical_energy_on_symmetric_workload() {
    let wl = sym2d();
    check(
        "symmetric-transpose-energy",
        0xD5E_0002,
        12,
        |r: &mut Rng| {
            let a = r.i64_in(1, 4);
            let b = r.i64_in(1, 4);
            let n = 4 * r.i64_in(1, 4);
            (a, b, n)
        },
        |&(a, b, n)| {
            let ana_ab = WorkloadAnalysis::analyze_uniform(&wl, &[a, b]);
            let ana_ba = WorkloadAnalysis::analyze_uniform(&wl, &[b, a]);
            let e_ab =
                ana_ab.energy_at(&[ana_ab.phases[0].params_for(&[n, n])]);
            let e_ba =
                ana_ba.energy_at(&[ana_ba.phases[0].params_for(&[n, n])]);
            if e_ab.total.to_bits() != e_ba.total.to_bits() {
                return Err(format!(
                    "({a},{b}) vs ({b},{a}) at N={n}: {} != {}",
                    e_ab.total, e_ba.total
                ));
            }
            if e_ab.mem_pj != e_ba.mem_pj {
                return Err(format!(
                    "per-class breakdown differs: {:?} vs {:?}",
                    e_ab.mem_pj, e_ba.mem_pj
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn symmetry_pruning_is_sound_on_symmetric_workload() {
    // With pruning on, each transposed pair collapses to one point; the
    // frontier loses no objective value because the mirror's energy is
    // identical (above) and its PE count trivially so.
    let wl = sym2d();
    let full = DesignSpace::new().with_arrays_2d(6).with_bounds(vec![8, 8]);
    let pruned = DesignSpace::new()
        .with_arrays_2d(6)
        .with_bounds(vec![8, 8])
        .with_symmetry_pruning();
    assert!(pruned.points().len() < full.points().len());
    let res_full = explore(&wl, &full, &ExploreConfig::default());
    let res_pruned = explore(&wl, &pruned, &ExploreConfig::default());
    let best_full = res_full
        .points
        .iter()
        .map(|p| p.energy_pj)
        .min_by(f64::total_cmp)
        .unwrap();
    let best_pruned = res_pruned
        .points
        .iter()
        .map(|p| p.energy_pj)
        .min_by(f64::total_cmp)
        .unwrap();
    assert_eq!(best_full.to_bits(), best_pruned.to_bits());
}

#[test]
fn cached_and_uncached_agree_bit_for_bit() {
    let wl = workloads::by_name("gesummv").unwrap();
    let cache = AnalysisCache::new();
    check(
        "cache-transparent",
        0xD5E_0003,
        10,
        |r: &mut Rng| {
            let t0 = r.i64_in(1, 3);
            let t1 = r.i64_in(1, 3);
            let n = 4 * r.i64_in(2, 6);
            (t0, t1, n)
        },
        |&(t0, t1, n)| {
            let (cached, _) = cache.get_or_analyze(&wl, &[t0, t1]);
            let fresh = WorkloadAnalysis::analyze_uniform(&wl, &[t0, t1]);
            let params = vec![cached.phases[0].params_for(&[n, n])];
            let (ec, ef) =
                (cached.energy_at(&params), fresh.energy_at(&params));
            if ec.total.to_bits() != ef.total.to_bits() || ec != ef {
                return Err(format!("energy differs: {ec:?} vs {ef:?}"));
            }
            if cached.counts_at(&params) != fresh.counts_at(&params) {
                return Err("counts differ".into());
            }
            if cached.latency_at(&params) != fresh.latency_at(&params) {
                return Err("latency differs".into());
            }
            Ok(())
        },
    );
    // Every shape was looked up once cold, rest of the runs were hits or
    // new shapes — all entries distinct.
    assert!(cache.stats().entries <= 9);
}

#[test]
fn builtin_backends_satisfy_frontier_soundness() {
    // The backend axis multiplies scenarios, not soundness bugs:
    // within every (bounds, backend) group the frontier must contain no
    // dominated point, every dropped point must be dominated by some
    // frontier member, and the knee must sit on the frontier.
    let wl = workloads::by_name("gesummv").unwrap();
    let space = DesignSpace::new()
        .with_arrays_2d(4)
        .with_bounds_sweep(&[8, 16], 2)
        .with_backends(Backend::builtins());
    let res = explore(&wl, &space, &ExploreConfig::default());
    assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
    // 2 bounds × 4 backends scenarios.
    assert_eq!(res.groups.len(), 8);
    for g in &res.groups {
        let members: Vec<usize> = res
            .points
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                p.point.bounds == g.bounds && p.point.backend == g.backend
            })
            .map(|(i, _)| i)
            .collect();
        assert!(!members.is_empty());
        assert!(!g.frontier.is_empty(), "{}: empty frontier", g.backend);
        let obj = |i: usize| res.points[i].objectives().to_array();
        for &i in &g.frontier {
            assert!(
                g.bounds == res.points[i].point.bounds
                    && g.backend == res.points[i].point.backend,
                "frontier member from another scenario"
            );
            assert!(
                !members.iter().any(|&j| dominates(&obj(j), &obj(i))),
                "{}: dominated point {i} on the frontier",
                g.backend
            );
        }
        for &i in &members {
            if !g.frontier.contains(&i) {
                assert!(
                    g.frontier.iter().any(|&f| dominates(&obj(f), &obj(i))),
                    "{}: dropped point {i} dominated by no frontier member",
                    g.backend
                );
            }
        }
        let knee = g.knee.expect("non-empty frontier has a knee");
        assert!(g.frontier.contains(&knee));
    }
}

/// The schedule-sweep spaces the axis properties below compare: the
/// canonical square mapping plus the column orientation (whose *swapped*
/// schedule routes GESUMMV's accumulation offsets off the mapped
/// dimension and genuinely wins — see explore.rs unit tests), two
/// bounds scenarios, two backends. Deliberately no row orientation: its
/// default schedule matches the column's swapped one at lower energy,
/// which would mask the non-default win this suite pins.
fn schedule_axis_space() -> DesignSpace {
    DesignSpace::new()
        .with_arrays(vec![vec![2, 2], vec![1, 4]])
        .with_bounds_sweep(&[8, 16], 2)
        .with_backends(vec![Backend::tcpa(), Backend::cgra()])
}

#[test]
fn schedules_all_weakly_dominates_single_schedule_frontier() {
    // For every frontier point of the single-schedule sweep there must
    // be a point on the all-schedules frontier of the *same* (bounds,
    // backend) scenario that is no worse in every objective — enlarging
    // the axis can only improve a frontier, never lose ground.
    let wl = workloads::by_name("gesummv").unwrap();
    let first = explore(
        &wl,
        &schedule_axis_space(),
        &ExploreConfig::default(),
    );
    let all = explore(
        &wl,
        &schedule_axis_space().with_schedules(SchedulePolicy::All),
        &ExploreConfig::default(),
    );
    assert!(first.failures.is_empty() && all.failures.is_empty());
    assert!(all.points.len() > first.points.len(), "axis must expand");
    for fg in &first.groups {
        let ag = all
            .groups
            .iter()
            .find(|g| g.bounds == fg.bounds && g.backend == fg.backend)
            .expect("scenario present in both sweeps");
        for &fi in &fg.frontier {
            let fo = first.points[fi].objectives().to_array();
            let weakly_dominated = ag.frontier.iter().any(|&ai| {
                let ao = all.points[ai].objectives().to_array();
                ao.iter().zip(&fo).all(|(a, f)| a <= f)
            });
            assert!(
                weakly_dominated,
                "single-schedule frontier point {:?} ({:?}, {}) has no \
                 weakly-dominating counterpart under --schedules all",
                first.points[fi].point.array,
                fg.bounds,
                fg.backend
            );
        }
    }
    // And strictly better somewhere: a linear shape whose best schedule
    // beats the default pick (see explore.rs unit tests).
    let improved = all.frontier.iter().any(|&ai| {
        let p = &all.points[ai];
        !p.point.schedule.is_default()
    });
    assert!(
        improved,
        "a non-default schedule should reach some frontier"
    );
}

#[test]
fn schedules_first_reproduces_single_schedule_arithmetic_bit_for_bit() {
    // The default policy *is* First; pin both that explicit First
    // changes nothing and that every emitted point carries exactly the
    // pre-axis arithmetic: energy via the cached analysis' backend
    // pricing, latency via the analysis' embedded default schedule.
    let wl = workloads::by_name("gesummv").unwrap();
    let space = schedule_axis_space();
    let implicit = explore(&wl, &space, &ExploreConfig::default());
    let explicit = explore(
        &wl,
        &schedule_axis_space().with_schedules(SchedulePolicy::First),
        &ExploreConfig::default(),
    );
    assert_eq!(implicit.points.len(), explicit.points.len());
    for (a, b) in implicit.points.iter().zip(&explicit.points) {
        assert_eq!(a.point, b.point);
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.latency_cycles, b.latency_cycles);
        assert_eq!(a.edp.to_bits(), b.edp.to_bits());
    }
    assert_eq!(implicit.frontier, explicit.frontier);
    assert_eq!(implicit.groups, explicit.groups);
    // Manual recomputation — the pre-axis explorer semantics.
    for p in &explicit.points {
        let ana =
            WorkloadAnalysis::analyze_uniform(&wl, &p.point.array);
        let params: Vec<Vec<i64>> = ana
            .phases
            .iter()
            .map(|ph| {
                ph.params_for(&tcpa_energy::tiling::pad_bounds(
                    &p.point.bounds,
                    ph.tiled.pra.ndims,
                ))
            })
            .collect();
        let energy = ana.energy_at_backend(&params, &p.point.backend);
        assert_eq!(p.energy_pj.to_bits(), energy.total.to_bits());
        assert_eq!(p.latency_cycles, ana.latency_at(&params));
    }
}

#[test]
fn schedule_axis_deterministic_across_worker_counts() {
    let wl = workloads::by_name("gesummv").unwrap();
    let space =
        schedule_axis_space().with_schedules(SchedulePolicy::All);
    let a = explore(&wl, &space, &ExploreConfig { workers: 1 });
    let b = explore(&wl, &space, &ExploreConfig { workers: 4 });
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.point, y.point);
        assert_eq!(x.schedule_label, y.schedule_label);
        assert_eq!(x.energy_pj.to_bits(), y.energy_pj.to_bits());
        assert_eq!(x.latency_cycles, y.latency_cycles);
    }
    assert_eq!(a.frontier, b.frontier);
    assert_eq!(a.groups, b.groups);
}

#[test]
fn exploration_deterministic_across_worker_counts() {
    let wl = workloads::by_name("gesummv").unwrap();
    let space = DesignSpace::new()
        .with_arrays_2d(6)
        .with_bounds_sweep(&[8, 16], 2);
    let a = explore(&wl, &space, &ExploreConfig { workers: 1 });
    let b = explore(&wl, &space, &ExploreConfig { workers: 4 });
    assert_eq!(a.points.len(), b.points.len());
    for (x, y) in a.points.iter().zip(&b.points) {
        assert_eq!(x.point, y.point, "order must not depend on workers");
        assert_eq!(x.energy_pj.to_bits(), y.energy_pj.to_bits());
        assert_eq!(x.dram_pj.to_bits(), y.dram_pj.to_bits());
        assert_eq!(x.latency_cycles, y.latency_cycles);
        assert_eq!(x.edp.to_bits(), y.edp.to_bits());
    }
    assert_eq!(a.frontier, b.frontier);
    assert_eq!(a.groups, b.groups);
    assert_eq!(a.knee, b.knee);
}
