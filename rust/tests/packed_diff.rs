//! Differential oracle for the packed polyhedral core (PR 3).
//!
//! The packed `Poly` (u64-packed exponents, sorted term vector, Horner
//! eval) and the interned `Guard` (sorted id vectors over the global
//! `ConstraintPool`) must be *observationally identical* to the previous
//! clone-heavy representations. Three layers of evidence:
//!
//! * a naive test-only reference `Poly` (the old `BTreeMap<Vec<u32>, i128>`
//!   representation) driven through random op sequences, with eval
//!   equality checked at random parameter points;
//! * guard algebra vs direct constraint-by-constraint semantics, plus
//!   feasibility soundness against grid enumeration;
//! * a `count_symbolic` regression over **every built-in workload**: the
//!   symbolic `GuardedSum::eval` must equal the concrete counter (the
//!   invariant the previous implementation was property-tested against,
//!   so agreement here pins the values bit-for-bit across the rewrite),
//!   and shared-feasibility-pool analyses must be bit-identical to
//!   private-pool ones.

use std::collections::BTreeMap;

use tcpa_energy::analysis::WorkloadAnalysis;
use tcpa_energy::polyhedral::{
    count_concrete, AffineExpr, Constraint, FeasPool, Guard, Poly,
};
use tcpa_energy::proptest_lite::{check, Rng};
use tcpa_energy::tiling::pad_array;
use tcpa_energy::workloads;

/// The previous `Poly` representation, reimplemented naively as the
/// reference oracle: exponent-vector keys in a `BTreeMap`,
/// clone-then-mutate arithmetic, per-term power chains in `eval`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct RefPoly {
    nparams: usize,
    terms: BTreeMap<Vec<u32>, i128>,
}

impl RefPoly {
    fn zero(nparams: usize) -> Self {
        RefPoly { nparams, terms: BTreeMap::new() }
    }

    fn from_affine(e: &AffineExpr) -> Self {
        let n = e.nparams();
        let mut p = Self::zero(n);
        if e.konst != 0 {
            p.terms.insert(vec![0; n], e.konst as i128);
        }
        for (i, &c) in e.coeffs.iter().enumerate() {
            if c != 0 {
                let mut ex = vec![0; n];
                ex[i] = 1;
                p.terms.insert(ex, c as i128);
            }
        }
        p
    }

    fn add_term(&mut self, expo: Vec<u32>, coeff: i128) {
        if coeff == 0 {
            return;
        }
        let entry = self.terms.entry(expo.clone()).or_insert(0);
        *entry += coeff;
        if *entry == 0 {
            self.terms.remove(&expo);
        }
    }

    fn add(&self, rhs: &Self) -> Self {
        let mut out = self.clone();
        for (e, &c) in &rhs.terms {
            out.add_term(e.clone(), c);
        }
        out
    }

    fn sub(&self, rhs: &Self) -> Self {
        let mut out = self.clone();
        for (e, &c) in &rhs.terms {
            out.add_term(e.clone(), -c);
        }
        out
    }

    fn mul(&self, rhs: &Self) -> Self {
        let mut out = Self::zero(self.nparams);
        for (ea, &ca) in &self.terms {
            for (eb, &cb) in &rhs.terms {
                let expo: Vec<u32> =
                    ea.iter().zip(eb).map(|(a, b)| a + b).collect();
                out.add_term(expo, ca * cb);
            }
        }
        out
    }

    fn scale(&self, c: i128) -> Self {
        let mut out = Self::zero(self.nparams);
        for (e, &v) in &self.terms {
            out.add_term(e.clone(), v * c);
        }
        out
    }

    fn eval(&self, params: &[i64]) -> i128 {
        let mut acc = 0i128;
        for (e, &c) in &self.terms {
            let mut t = c;
            for (i, &pow) in e.iter().enumerate() {
                for _ in 0..pow {
                    t *= params[i] as i128;
                }
            }
            acc += t;
        }
        acc
    }

    fn degree(&self) -> u32 {
        self.terms
            .keys()
            .map(|e| e.iter().sum::<u32>())
            .max()
            .unwrap_or(0)
    }

    fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }
}

const NP: usize = 4;

fn random_affine(rng: &mut Rng) -> AffineExpr {
    AffineExpr {
        coeffs: (0..NP).map(|_| rng.i64_in(-3, 3)).collect(),
        konst: rng.i64_in(-4, 4),
    }
}

fn random_point(rng: &mut Rng) -> Vec<i64> {
    (0..NP).map(|_| rng.i64_in(-5, 5)).collect()
}

#[derive(Debug, Clone)]
enum Op {
    Add(usize, usize),
    Sub(usize, usize),
    Mul(usize, usize),
    Scale(usize, i128),
}

#[test]
fn prop_packed_poly_matches_reference_on_random_op_sequences() {
    check(
        "packed-poly-diff",
        0x9ACC_ED01,
        120,
        |rng| {
            let seeds: Vec<AffineExpr> =
                (0..4).map(|_| random_affine(rng)).collect();
            let mut degrees: Vec<u32> = vec![1; 4];
            let mut ops = Vec::new();
            for _ in 0..8 {
                let i = rng.i64_in(0, degrees.len() as i64 - 1) as usize;
                let j = rng.i64_in(0, degrees.len() as i64 - 1) as usize;
                let op = match rng.i64_in(0, 3) {
                    0 => Op::Add(i, j),
                    1 => Op::Sub(i, j),
                    2 if degrees[i] + degrees[j] <= 6 => Op::Mul(i, j),
                    _ => Op::Scale(i, rng.i64_in(-3, 3) as i128),
                };
                degrees.push(match &op {
                    Op::Add(a, b) | Op::Sub(a, b) => {
                        degrees[*a].max(degrees[*b])
                    }
                    Op::Mul(a, b) => degrees[*a] + degrees[*b],
                    Op::Scale(a, _) => degrees[*a],
                });
                ops.push(op);
            }
            let points: Vec<Vec<i64>> =
                (0..3).map(|_| random_point(rng)).collect();
            (seeds, ops, points)
        },
        |(seeds, ops, points)| {
            let mut packed: Vec<Poly> =
                seeds.iter().map(Poly::from_affine).collect();
            let mut reference: Vec<RefPoly> =
                seeds.iter().map(RefPoly::from_affine).collect();
            for op in ops {
                let (p, r) = match *op {
                    Op::Add(i, j) => (
                        packed[i].add(&packed[j]),
                        reference[i].add(&reference[j]),
                    ),
                    Op::Sub(i, j) => (
                        packed[i].sub(&packed[j]),
                        reference[i].sub(&reference[j]),
                    ),
                    Op::Mul(i, j) => (
                        packed[i].mul(&packed[j]),
                        reference[i].mul(&reference[j]),
                    ),
                    Op::Scale(i, c) => {
                        (packed[i].scale(c), reference[i].scale(c))
                    }
                };
                packed.push(p);
                reference.push(r);
            }
            for (p, r) in packed.iter().zip(&reference) {
                if p.degree() != r.degree() {
                    return Err(format!(
                        "degree {} != reference {}",
                        p.degree(),
                        r.degree()
                    ));
                }
                if p.is_zero() != r.is_zero() {
                    return Err("is_zero disagrees".into());
                }
                // Same normal form: identical term multisets.
                let got: BTreeMap<Vec<u32>, i128> = p.terms().collect();
                if got != r.terms {
                    return Err(format!(
                        "terms {:?} != reference {:?}",
                        got, r.terms
                    ));
                }
                for pt in points {
                    if p.eval(pt) != r.eval(pt) {
                        return Err(format!(
                            "eval at {pt:?}: {} != {}",
                            p.eval(pt),
                            r.eval(pt)
                        ));
                    }
                }
            }
            // In-place ops agree with the functional ones.
            let a = &packed[packed.len() - 1];
            let b = &packed[packed.len() - 2];
            let mut x = a.clone();
            x.add_assign(b);
            if x != a.add(b) {
                return Err("add_assign != add".into());
            }
            x.sub_assign(b);
            if &x != a {
                return Err("sub_assign did not undo add_assign".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_interned_guard_matches_direct_semantics() {
    check(
        "interned-guard-diff",
        0x6A2D_0002,
        200,
        |rng| {
            let cs: Vec<Constraint> = (0..rng.i64_in(1, 4))
                .map(|_| Constraint::ge0(random_affine(rng)))
                .collect();
            let extra = Constraint::ge0(random_affine(rng));
            let points: Vec<Vec<i64>> =
                (0..4).map(|_| random_point(rng)).collect();
            (cs, extra, points)
        },
        |(cs, extra, points)| {
            let g = Guard::new(cs.clone());
            // holds == conjunction of constraint holds.
            for pt in points {
                let direct = cs.iter().all(|c| c.holds(pt));
                if g.holds(pt) != direct {
                    return Err(format!("holds at {pt:?} disagrees"));
                }
            }
            // Construction order cannot matter.
            let mut rev = cs.clone();
            rev.reverse();
            if Guard::new(rev) != g {
                return Err("order-sensitive normal form".into());
            }
            // `and` == rebuilding from the extended list.
            let mut ext = cs.clone();
            ext.push(extra.clone());
            if g.and(extra.clone()) != Guard::new(ext) {
                return Err("and != Guard::new of extended list".into());
            }
            // and_guard == new over the concatenation.
            let half = cs.len() / 2;
            let left = Guard::new(cs[..half].to_vec());
            let right = Guard::new(cs[half..].to_vec());
            if left.and_guard(&right) != g {
                return Err("and_guard != conjunction".into());
            }
            // Feasibility soundness: infeasible ⟹ no grid point satisfies.
            if !g.feasible() {
                for x in -6..=6 {
                    for y in -6..=6 {
                        let pt = vec![x, y, x - y, x + y];
                        if g.holds(&pt) {
                            return Err(format!(
                                "infeasible guard holds at {pt:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Array shape used per loop depth in the regression sweeps.
fn shape_for(ndims: usize) -> Vec<i64> {
    pad_array(&[2, 2], ndims)
}

#[test]
fn count_symbolic_matches_concrete_on_every_builtin_workload() {
    // The previous implementation satisfied symbolic == concrete at every
    // context point (tier-1 property suite); the packed rewrite must
    // produce the same exact i128 values, so agreement with the concrete
    // counter on a parameter sweep pins the rewrite bit-for-bit.
    for wl in workloads::all() {
        let ana = WorkloadAnalysis::analyze_uniform(
            &wl,
            &shape_for(wl.phases[0].ndims),
        );
        for (phase, sym) in wl.phases.iter().zip(&ana.phases) {
            let t = &sym.tiled.mapping.t;
            for (ts, st) in sym.tiled.statements.iter().zip(&sym.statements)
            {
                for n0 in [2i64, 5, 9] {
                    for n1 in [3i64, 7] {
                        let mut bounds = vec![n0, n1];
                        while bounds.len() < phase.ndims {
                            bounds.push(n1);
                        }
                        bounds.truncate(phase.ndims);
                        if matches!(wl.name.as_str(), "mvt" | "syrk") {
                            let m = bounds[0].max(bounds[1]);
                            bounds.fill(m);
                        }
                        let params = sym.params_for(&bounds);
                        assert_eq!(
                            st.volume.eval(&params),
                            count_concrete(&ts.space, t, &params),
                            "{}::{} at {params:?}",
                            wl.name,
                            st.name
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn shared_feasibility_pool_is_bit_transparent() {
    // Sharing one FeasPool across analyses (what the DSE cache does) must
    // not change a single piece, count, or energy bit.
    let pool = FeasPool::new();
    for name in ["gesummv", "atax", "gemm"] {
        let wl = workloads::by_name(name).unwrap();
        let shape = shape_for(wl.phases[0].ndims);
        let shared =
            WorkloadAnalysis::analyze_uniform_in(&wl, &shape, &pool, None);
        let private = WorkloadAnalysis::analyze_uniform(&wl, &shape);
        for (a, b) in shared.phases.iter().zip(&private.phases) {
            for (sa, sb) in a.statements.iter().zip(&b.statements) {
                assert_eq!(sa.volume, sb.volume, "{name}::{}", sa.name);
            }
        }
        let params: Vec<Vec<i64>> = shared
            .phases
            .iter()
            .map(|ph| ph.params_for(&vec![8i64; ph.tiled.pra.ndims]))
            .collect();
        assert_eq!(shared.counts_at(&params), private.counts_at(&params));
        assert_eq!(
            shared.energy_at(&params).total.to_bits(),
            private.energy_at(&params).total.to_bits()
        );
    }
    // The pool actually accumulated shared state.
    assert!(!pool.is_empty());
    assert!(pool.stats().hits + pool.stats().misses > 0);
}

#[test]
fn counts_at_equals_manual_concrete_aggregation() {
    // counts_at is pure integer aggregation over the packed volumes; it
    // must equal re-deriving every statement count with the concrete
    // counter (an independent code path that never touches Poly).
    let wl = workloads::by_name("gesummv").unwrap();
    let ana = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
    let sym = &ana.phases[0];
    for bounds in [[4i64, 5], [8, 8], [13, 9]] {
        let params = sym.params_for(&bounds);
        let from_expr = sym.counts_at(&params);
        let mut manual: i128 = 0;
        for (ts, st) in sym.tiled.statements.iter().zip(&sym.statements) {
            let c = count_concrete(&ts.space, &sym.tiled.mapping.t, &params);
            assert_eq!(st.volume.eval(&params), c, "{}", st.name);
            manual += c;
        }
        assert_eq!(from_expr.executions, manual, "bounds {bounds:?}");
    }
}
