//! Differential suite for the discrete-event simulation engine
//! (`sim::event`): the tick engine is the oracle.
//!
//! 1. **Full-grid parity** — for every built-in workload × array shape ×
//!    loop-bound vector × enumerated schedule candidate, the event
//!    engine's result is *bit-identical* to the tick engine's: counters,
//!    cycles, outputs, violations, per-PE stats, I/O stats, concurrency
//!    and the utilization float (compared by bits). The two engines
//!    share the execution core (`sim::exec`) and differ only in how
//!    events are produced, so any divergence is an ordering bug.
//! 2. **Per-phase chaining parity** — heterogeneous per-phase mappings
//!    (the DSE per-phase axis) with phase outputs fed forward, verified
//!    with the *event* engine's outputs driving the chain.
//! 3. **Scaling** — the event engine runs at bounds ≥ 100× the parity
//!    grids (800×800 where the grids stop at 8) and still reproduces
//!    the symbolic access counts and the Eq. 8 latency exactly. The
//!    tick engine is deliberately absent here: materializing and
//!    sorting the full iteration space is what the event engine exists
//!    to avoid.

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::schedule::{enumerate_schedules, find_schedule, latency};
use tcpa_energy::sim::{simulate_event, simulate_tick, ArchConfig, SimResult};
use tcpa_energy::tiling::{pad_array, pad_bounds, tile_pra, ArrayMapping};
use tcpa_energy::workloads::{self, workload_inputs};

/// Loop-bound vectors per workload (the `schedule_enum` grid);
/// `mvt`/`syrk` are square-only by repo convention.
fn bounds_for(wl_name: &str, ndims: usize) -> Vec<Vec<i64>> {
    let mut out = vec![
        pad_bounds(&[4, 4], ndims),
        pad_bounds(&[8, 8], ndims),
        pad_bounds(&[4, 9], ndims),
        pad_bounds(&[9, 4], ndims),
    ];
    if matches!(wl_name, "mvt" | "syrk") {
        for b in &mut out {
            let m = b.iter().copied().max().unwrap();
            b.fill(m);
        }
    }
    out
}

/// Bit-identical comparison: every observable of the two engines,
/// including the float utilization by bit pattern.
fn assert_identical(tag: &str, event: &SimResult, tick: &SimResult) {
    assert_eq!(event.counters, tick.counters, "{tag}: counters");
    assert_eq!(event.cycles, tick.cycles, "{tag}: cycles");
    assert_eq!(event.outputs, tick.outputs, "{tag}: outputs");
    assert_eq!(event.violations, tick.violations, "{tag}: violations");
    assert_eq!(event.stats.pe, tick.stats.pe, "{tag}: pe stats");
    assert_eq!(event.stats.io, tick.stats.io, "{tag}: io stats");
    assert_eq!(event.stats.max_hop, tick.stats.max_hop, "{tag}: max_hop");
    assert_eq!(
        event.stats.max_concurrency, tick.stats.max_concurrency,
        "{tag}: max_concurrency"
    );
    assert_eq!(
        event.stats.fd_pressure, tick.stats.fd_pressure,
        "{tag}: fd_pressure"
    );
    assert_eq!(
        event.stats.utilization.to_bits(),
        tick.stats.utilization.to_bits(),
        "{tag}: utilization bits"
    );
}

#[test]
fn event_engine_matches_tick_engine_on_the_full_grid() {
    for wl in workloads::all() {
        for shape in [vec![2i64, 2], vec![1, 4], vec![4, 1], vec![3, 2]] {
            for base in bounds_for(&wl.name, 2) {
                // Per-phase parameters under one shared shape/bounds
                // seed, padded to each phase's depth.
                let params_all: Vec<Vec<i64>> = wl
                    .phases
                    .iter()
                    .map(|ph| {
                        let b = pad_bounds(&base, ph.ndims);
                        let t = pad_array(&shape, ph.ndims);
                        ArrayMapping::new(t).params_for(&b)
                    })
                    .collect();
                let mut env = workload_inputs(&wl, &params_all);
                for (phase, params) in wl.phases.iter().zip(&params_all) {
                    let t = pad_array(&shape, phase.ndims);
                    let mut arch = ArchConfig::with_array(t.clone());
                    arch.regs.fd = 1 << 20; // pressure is a separate axis
                    let tiled = tile_pra(phase, &arch.mapping);
                    for (ci, s) in
                        enumerate_schedules(&tiled, arch.pi, None)
                            .iter()
                            .enumerate()
                    {
                        let tag = format!(
                            "{} t={t:?} bounds={base:?} candidate {ci} \
                             (perm {:?})",
                            phase.name, s.perm
                        );
                        let tick =
                            simulate_tick(phase, &arch, s, params, &env);
                        let event =
                            simulate_event(phase, &arch, s, params, &env);
                        assert_identical(&tag, &event, &tick);
                    }
                    // Later phases consume earlier phases' outputs.
                    let s = find_schedule(&tiled, arch.pi).unwrap();
                    let res = simulate_tick(phase, &arch, &s, params, &env);
                    for (name, tens) in res.outputs {
                        env.insert(name, tens);
                    }
                }
            }
        }
    }
}

#[test]
fn per_phase_mappings_chain_identically() {
    // The DSE per-phase axis: each phase on its own shape, with the
    // *event* engine's outputs driving the chain — parity must hold on
    // the chained inputs, not just on phase 0.
    let wl = workloads::by_name("atax").unwrap();
    assert!(wl.phases.len() >= 2, "atax is the multi-phase exemplar");
    let shapes: Vec<Vec<i64>> = vec![vec![1, 2], vec![2, 1]];
    let params_all: Vec<Vec<i64>> = wl
        .phases
        .iter()
        .enumerate()
        .map(|(i, ph)| {
            let b = pad_bounds(&[8, 8], ph.ndims);
            let t = pad_array(&shapes[i % shapes.len()], ph.ndims);
            ArrayMapping::new(t).params_for(&b)
        })
        .collect();
    let mut env = workload_inputs(&wl, &params_all);
    for (i, (phase, params)) in
        wl.phases.iter().zip(&params_all).enumerate()
    {
        let t = pad_array(&shapes[i % shapes.len()], phase.ndims);
        let mut arch = ArchConfig::with_array(t.clone());
        arch.regs.fd = 1 << 20;
        let tiled = tile_pra(phase, &arch.mapping);
        let s = find_schedule(&tiled, arch.pi).unwrap();
        let tick = simulate_tick(phase, &arch, &s, params, &env);
        let event = simulate_event(phase, &arch, &s, params, &env);
        assert_identical(
            &format!("{} phase {i} t={t:?}", phase.name),
            &event,
            &tick,
        );
        for (name, tens) in event.outputs {
            env.insert(name, tens);
        }
    }
}

#[test]
fn event_engine_scales_to_hundredfold_bounds() {
    // 800×800 gesummv on a 2×2 array: 640k iterations, ≥ 100× the
    // parity grids above. The event engine alone runs it, and both the
    // §V-A observable (symbolic access counts) and the Eq. 8 latency
    // hold exactly — the frontier verification pass
    // (`dse --sim-verify-frontier`) relies on exactly this.
    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let bounds = vec![800i64, 800];
    let mut arch = ArchConfig::with_array(vec![2, 2]);
    arch.regs.fd = 1 << 20;
    let params = arch.mapping.params_for(&bounds);
    let env = workload_inputs(&wl, &[params.clone()]);
    let mapping = arch.mapping.clone();
    let tiled = tile_pra(phase, &mapping);
    let s = find_schedule(&tiled, arch.pi).unwrap();

    let res = simulate_event(phase, &arch, &s, &params, &env);

    assert!(res.violations.is_empty(), "{:?}", res.violations);
    let ana = SymbolicAnalysis::analyze(phase, &mapping);
    let diff = res.counters.diff_symbolic(&ana.counts_at(&params));
    assert!(diff.is_empty(), "{diff:#?}");
    assert_eq!(res.cycles, latency(&s, &tiled, &params), "Eq. 8 latency");
    // Iteration volume really is ≥ 100× the grid tests' largest (81).
    let total: i64 = res.stats.pe.iter().map(|p| p.iterations).sum();
    assert_eq!(total, 800 * 800);
}
