//! Every numbered example of the paper, reproduced end-to-end through the
//! public API (the per-experiment index EX2/EX3/EX9 of DESIGN.md §5).

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::energy::{EnergyTable, MemoryClass};
use tcpa_energy::schedule::{find_schedule, latency};
use tcpa_energy::tiling::{tile_pra, ArrayMapping, TiledStmt};
use tcpa_energy::workloads::gesummv::gesummv;

/// Example 1: the GESUMMV PRA has the paper's 11 statements with the
/// paper's operation split (Example 4: C = {S3,S4,S6,S9,S11}).
#[test]
fn example1_and_4_statement_structure() {
    let pra = gesummv();
    assert_eq!(pra.statements.len(), 11);
    let c: Vec<&str> = pra
        .statements
        .iter()
        .filter(|s| !s.is_memory())
        .map(|s| s.name.as_str())
        .collect();
    assert_eq!(c, ["S3", "S4", "S6", "S9", "S11"]);
}

/// Example 2: tiling 4×5 onto a 2×2 array with 2×3 tiles; S7 splits into
/// γ = (0,0) and γ = (0,−1), the latter with d* = (0, 1−p1, 0, 1).
#[test]
fn example2_gamma_decomposition() {
    let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let s7: Vec<&TiledStmt> = tiled
        .statements
        .iter()
        .filter(|s| s.base_name == "S7")
        .collect();
    assert_eq!(s7.len(), 2);
    let inter = s7.iter().find(|s| s.is_inter_tile()).unwrap();
    assert_eq!(inter.gamma, Some(vec![0, -1]));
    assert_eq!(inter.dk, vec![0, 1]);
    // d_J = (0, 1 − p1): at p1 = 3 the intra displacement is (0, −2).
    let params = [4i64, 5, 2, 3];
    let dj: Vec<i64> = inter.dj.iter().map(|e| e.eval(&params)).collect();
    assert_eq!(dj, vec![0, 1 - 3]);
    let intra = s7.iter().find(|s| !s.is_inter_tile()).unwrap();
    let dj0: Vec<i64> = intra.dj.iter().map(|e| e.eval(&params)).collect();
    assert_eq!(dj0, vec![0, 1]);
}

/// Example 3: λ^J = (1, p0), λ^K = (p0, p0(p1−1)+1), L_c = 4, and the
/// global latency L = 16 at N = 4×5, p = (2,3), t = (2,2), π = 1.
#[test]
fn example3_schedule_and_latency() {
    let tiled = tile_pra(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let s = find_schedule(&tiled, 1).unwrap();
    let params = [4i64, 5, 2, 3];
    assert_eq!(s.lambda_j_at(&params), vec![1, 2]);
    assert_eq!(s.lambda_k_at(&params), vec![2, 5]);
    assert_eq!(s.lc, 4);
    assert_eq!(latency(&s, &tiled, &params), 16);
    // The paper's decomposition: 5 (intra) + 7 (inter) + 4 (L_c).
    let lj = s.lambda_j_at(&params);
    let lk = s.lambda_k_at(&params);
    assert_eq!(lj[0] * (2 - 1) + lj[1] * (3 - 1), 5);
    assert_eq!(lk[0] * (2 - 1) + lk[1] * (2 - 1), 7);
}

/// Examples 5–8: the access-location classification table `L(x)`.
#[test]
fn examples5_to_8_access_classification() {
    use tcpa_energy::energy::{AccessClass, AccessProfile};
    let pra = gesummv();
    let tiled = tile_pra(&pra, &ArrayMapping::new(vec![2, 2]));
    let profile = |base: &str, inter: bool| -> AccessProfile {
        let ts = tiled
            .statements
            .iter()
            .find(|s| s.base_name == base && s.is_inter_tile() == inter)
            .unwrap();
        AccessProfile::of(&pra.statements[ts.stmt_index], ts)
    };
    // Example 5: inputs A, B, X stream DRAM → IOb → ID; output Y streams
    // OD → IOb → DRAM.
    assert_eq!(profile("S1", false).reads, vec![AccessClass::InputStream]);
    assert_eq!(profile("S11", false).write, AccessClass::OutputStream);
    // Example 6: S5/S8 are RD-local.
    assert_eq!(profile("S5", false).reads, vec![AccessClass::Rd]);
    assert_eq!(profile("S5", false).write, AccessClass::Rd);
    // Example 7: intra-tile transports (S2, S7, S10) read FD.
    for s in ["S2", "S7", "S10"] {
        assert_eq!(profile(s, false).reads, vec![AccessClass::Fd], "{s}");
    }
    // Example 8: inter-tile variants read ID.
    for s in ["S2", "S7", "S10"] {
        assert_eq!(profile(s, true).reads, vec![AccessClass::Id], "{s}");
    }
}

/// Example 9: Vol(S7*1) = 12, Vol(S7*2) = 4 at the example configuration;
/// statement energies 0.47 / 0.36 pJ; total S7 contribution 7.08 pJ. Also
/// checks the paper's printed chamber polynomials at points in other
/// chambers.
#[test]
fn example9_symbolic_volumes_and_energy() {
    let ana =
        SymbolicAnalysis::analyze(&gesummv(), &ArrayMapping::new(vec![2, 2]));
    let t = EnergyTable::table1_45nm();
    let params = [4i64, 5, 2, 3];
    let s7_1 = ana
        .statements
        .iter()
        .find(|s| s.base_name == "S7" && !s.inter_tile)
        .unwrap();
    let s7_2 = ana
        .statements
        .iter()
        .find(|s| s.base_name == "S7" && s.inter_tile)
        .unwrap();
    assert_eq!(s7_1.volume.eval(&params), 12);
    assert_eq!(s7_2.volume.eval(&params), 4);
    assert!((s7_1.profile.energy(&t) - 0.47).abs() < 1e-12);
    assert!((s7_2.profile.energy(&t) - 0.36).abs() < 1e-12);
    let contribution: f64 = 12.0 * 0.47 + 4.0 * 0.36;
    assert!((contribution - 7.08).abs() < 1e-12);

    // Paper chamber 1 of vol(S7*1): 0<p0 ∧ 2p0<N0 ∧ p1≥2 ∧ 2p1<N1 →
    // 4·p0·(p1−1).
    let chk =
        |n0: i64, n1: i64, p0: i64, p1: i64| s7_1.volume.eval(&[n0, n1, p0, p1]);
    assert_eq!(chk(8, 10, 2, 3), 4 * 2 * (3 - 1));
    assert_eq!(chk(10, 12, 3, 4), 4 * 3 * (4 - 1));
    // Chamber 2: 2p0 ≥ N0 → 2·N0·(p1−1).
    assert_eq!(chk(3, 10, 2, 3), 2 * 3 * (3 - 1));
    // Chamber 3: 2p1 ≥ N1 ∧ p1 ≤ N1−2 → (2N1−4)·p0.
    assert_eq!(chk(8, 6, 2, 4), (2 * 6 - 4) * 2);
    // Chamber 4: both saturated → N0(N1−2).
    assert_eq!(chk(3, 6, 2, 4), 3 * (6 - 2));
    // vol(S7*2) chambers: 2p0 < N0 → 2p0; else N0.
    let chk2 =
        |n0: i64, n1: i64, p0: i64, p1: i64| s7_2.volume.eval(&[n0, n1, p0, p1]);
    assert_eq!(chk2(8, 10, 2, 3), 2 * 2);
    assert_eq!(chk2(3, 10, 2, 3), 3);
}

/// Table I: the 45 nm energy numbers used throughout.
#[test]
fn table1_energies() {
    let t = EnergyTable::table1_45nm();
    let expect = [
        (MemoryClass::Rd, 0.12),
        (MemoryClass::Fd, 0.35),
        (MemoryClass::Id, 0.24),
        (MemoryClass::Od, 0.12),
        (MemoryClass::IOb, 16.0),
        (MemoryClass::Dram, 1280.0),
    ];
    for (c, e) in expect {
        assert_eq!(t.access(c), e, "{c}");
    }
    assert_eq!(t.add_pj, 0.36);
    assert_eq!(t.mul_pj, 1.24);
}

/// Footnote 1: symbolic analysis stays tractable for large arrays — a
/// 50×50-processor unfolding completes well inside the paper's "order of
/// 1 minute" (per-statement version benchmarked in volume_counting).
#[test]
fn footnote1_50x50_array_tractable() {
    use std::time::Instant;
    let t0 = Instant::now();
    let ana = SymbolicAnalysis::analyze(
        &gesummv(),
        &ArrayMapping::new(vec![50, 50]),
    );
    let took = t0.elapsed();
    assert!(
        took.as_secs() < 60,
        "50x50 symbolic analysis took {took:?} (paper: ~1 minute)"
    );
    // And evaluation still works at scale.
    let params = ana.params_for(&[200, 200]);
    let c = ana.counts_at(&params);
    assert!(c.executions > 0);
}
