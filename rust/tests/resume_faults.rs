//! Fault-injection matrix for interruptible, resumable DSE sweeps.
//!
//! Each test interrupts a checkpointed `dse` sweep with one injected
//! fault (worker kill, journal write failure, mid-record truncation,
//! checksum corruption, deadline firing), resumes it in a fresh
//! process, and proves the resumed run reproduces the uninterrupted
//! sweep's report **bit-for-bit**. The injection hooks are the
//! `TCPA_DSE_FAULT_*` environment variables read by
//! `dse::FaultPlan::from_env` — deterministic (they fire at fixed
//! committed-point counts), so every run of this suite exercises the
//! same interleaving.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

const BIN: &str = env!("CARGO_BIN_EXE_tcpa-energy");

const KILL_AFTER: &str = "TCPA_DSE_FAULT_KILL_AFTER";
const DEADLINE_AFTER: &str = "TCPA_DSE_FAULT_DEADLINE_AFTER";
const JOURNAL_WRITE: &str = "TCPA_DSE_FAULT_JOURNAL_WRITE";
const JOURNAL_BATCH: &str = "TCPA_DSE_JOURNAL_BATCH";

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("tcpa-resume-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Run `tcpa-energy dse --workload gesummv --bounds 8,8 --max-pes 4
/// --workers 2 <extra>` with the given env hooks.
fn dse(extra: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut cmd = Command::new(BIN);
    cmd.args([
        "dse", "--workload", "gesummv", "--bounds", "8,8", "--max-pes",
        "4", "--workers", "2",
    ]);
    cmd.args(extra);
    // Never inherit hooks from the harness environment.
    for k in [KILL_AFTER, DEADLINE_AFTER, JOURNAL_WRITE, JOURNAL_BATCH] {
        cmd.env_remove(k);
    }
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.output().expect("spawn tcpa-energy")
}

/// The three report files `--out` writes, as raw bytes.
fn report_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    ["dse_gesummv_points.csv", "dse_gesummv_frontier.csv",
     "dse_gesummv_frontier.md"]
        .iter()
        .map(|f| (f.to_string(), std::fs::read(dir.join(f)).unwrap()))
        .collect()
}

/// Uninterrupted sweep into `dir`; returns its report bytes.
fn baseline(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let out = dse(&["--out", dir.to_str().unwrap()], &[]);
    assert!(out.status.success(), "baseline failed: {out:?}");
    report_bytes(dir)
}

fn assert_reports_identical(
    base: &[(String, Vec<u8>)],
    dir: &Path,
    what: &str,
) {
    for ((name, want), (_, got)) in
        base.iter().zip(report_bytes(dir).iter())
    {
        assert_eq!(
            want, got,
            "{what}: {name} must be bit-identical to the \
             uninterrupted sweep"
        );
    }
}

#[test]
fn worker_kill_then_resume_reproduces_the_frontier() {
    let dir = tmp_dir("kill");
    let base = baseline(&dir.join("base"));
    let journal = dir.join("sweep.journal");
    let j = journal.to_str().unwrap();
    // Kill the process (abort, no cleanup) after 3 committed points.
    let killed = dse(
        &["--checkpoint", j],
        &[(KILL_AFTER, "3"), (JOURNAL_BATCH, "1")],
    );
    assert!(
        !killed.status.success(),
        "the injected kill must tear the process down"
    );
    assert!(journal.exists(), "the journal survives the kill");
    // Resume in a fresh process: replay the journal, finish the rest.
    let out_dir = dir.join("resumed");
    let resumed = dse(
        &["--checkpoint", j, "--resume", "--out",
          out_dir.to_str().unwrap()],
        &[],
    );
    assert!(resumed.status.success(), "{resumed:?}");
    let stdout = String::from_utf8_lossy(&resumed.stdout);
    assert!(
        stdout.contains("3 replayed from journal"),
        "resume must replay the committed prefix: {stdout}"
    );
    assert_reports_identical(&base, &out_dir, "kill+resume");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn journal_write_failure_degrades_to_an_unjournaled_sweep() {
    let dir = tmp_dir("wfail");
    let base = baseline(&dir.join("base"));
    let journal = dir.join("sweep.journal");
    let out_dir = dir.join("out");
    let out = dse(
        &["--checkpoint", journal.to_str().unwrap(), "--out",
          out_dir.to_str().unwrap()],
        &[(JOURNAL_WRITE, "1"), (JOURNAL_BATCH, "1")],
    );
    assert!(
        out.status.success(),
        "a failing journal must not fail the sweep: {out:?}"
    );
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("journal write failed"),
        "the degradation must be loud: {stderr}"
    );
    assert!(!journal.exists(), "no torn journal file is left behind");
    assert_reports_identical(&base, &out_dir, "journal-write failure");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_tail_resumes_to_the_identical_frontier() {
    let dir = tmp_dir("truncate");
    let base = baseline(&dir.join("base"));
    let journal = dir.join("sweep.journal");
    let j = journal.to_str().unwrap();
    // Full checkpointed run, then tear 10 bytes off the journal tail —
    // a mid-record truncation, as a crash during a batch write would
    // leave (the writer goes through tmp+rename, so this simulates
    // filesystem-level damage, the worst case).
    assert!(dse(&["--checkpoint", j], &[]).status.success());
    let bytes = std::fs::read(&journal).unwrap();
    assert!(bytes.len() > 10);
    std::fs::write(&journal, &bytes[..bytes.len() - 10]).unwrap();
    let out_dir = dir.join("resumed");
    let resumed = dse(
        &["--checkpoint", j, "--resume", "--out",
          out_dir.to_str().unwrap()],
        &[],
    );
    assert!(resumed.status.success(), "{resumed:?}");
    let stderr = String::from_utf8_lossy(&resumed.stderr);
    assert!(
        stderr.contains("truncated"),
        "dropping the torn tail must warn: {stderr}"
    );
    assert_reports_identical(&base, &out_dir, "truncated tail");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checksum_corrupt_record_is_skipped_and_recomputed() {
    let dir = tmp_dir("corrupt");
    let base = baseline(&dir.join("base"));
    let journal = dir.join("sweep.journal");
    let j = journal.to_str().unwrap();
    assert!(dse(&["--checkpoint", j], &[]).status.success());
    // Flip the last checksum character of the first record line.
    let text = std::fs::read_to_string(&journal).unwrap();
    let mut lines: Vec<String> =
        text.lines().map(str::to_string).collect();
    let rec = lines
        .iter_mut()
        .find(|l| l.starts_with("r "))
        .expect("journal has records");
    let last = rec.pop().unwrap();
    rec.push(if last == '0' { '1' } else { '0' });
    std::fs::write(&journal, lines.join("\n") + "\n").unwrap();
    let out_dir = dir.join("resumed");
    let resumed = dse(
        &["--checkpoint", j, "--resume", "--out",
          out_dir.to_str().unwrap()],
        &[],
    );
    assert!(resumed.status.success(), "{resumed:?}");
    let stderr = String::from_utf8_lossy(&resumed.stderr);
    assert!(
        stderr.contains("corrupt"),
        "skipping a corrupt record must warn: {stderr}"
    );
    assert_reports_identical(&base, &out_dir, "checksum corruption");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn deadline_cancellation_reports_partial_and_resumes_bit_for_bit() {
    let dir = tmp_dir("deadline");
    let base = baseline(&dir.join("base"));
    let journal = dir.join("sweep.journal");
    let j = journal.to_str().unwrap();
    // The injected hook fires the (armed) deadline after exactly 3
    // committed points — deterministic, unlike a real clock.
    let cancelled = dse(
        &["--checkpoint", j, "--deadline", "3600"],
        &[(DEADLINE_AFTER, "3"), (JOURNAL_BATCH, "1")],
    );
    assert_eq!(
        cancelled.status.code(),
        Some(3),
        "cancelled sweeps exit with the documented partial code: \
         {cancelled:?}"
    );
    let stdout = String::from_utf8_lossy(&cancelled.stdout);
    assert!(
        stdout.contains("partial (3/"),
        "the frontier must be marked partial: {stdout}"
    );
    assert!(
        stdout.contains("deadline exceeded"),
        "the cancellation reason must be named: {stdout}"
    );
    let out_dir = dir.join("resumed");
    let resumed = dse(
        &["--checkpoint", j, "--resume", "--out",
          out_dir.to_str().unwrap()],
        &[],
    );
    assert!(resumed.status.success(), "{resumed:?}");
    assert_reports_identical(&base, &out_dir, "deadline+resume");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stale_journal_is_rejected_with_a_distinct_error() {
    let dir = tmp_dir("stale");
    let journal = dir.join("sweep.journal");
    let j = journal.to_str().unwrap();
    // Journal a sweep at one bounds vector, then try to resume a
    // sweep over different bounds: the space fingerprint differs and
    // replaying would silently mix incompatible results.
    let mut first = Command::new(BIN);
    first.args([
        "dse", "--workload", "gesummv", "--bounds", "16,16",
        "--max-pes", "4", "--checkpoint", j,
    ]);
    assert!(first.output().unwrap().status.success());
    let clash = dse(&["--checkpoint", j, "--resume"], &[]);
    assert_eq!(
        clash.status.code(),
        Some(2),
        "a stale journal is a hard error: {clash:?}"
    );
    let stderr = String::from_utf8_lossy(&clash.stderr);
    assert!(stderr.contains("stale"), "{stderr}");
    assert!(
        journal.exists(),
        "a stale (but intact) journal is left in place for the user"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
