//! Contract tests for the textual workload frontend
//! (`tcpa_energy::workloads::text`).
//!
//! Three layers of contract:
//!
//! 1. **Pinned corpus** — the textual renditions under
//!    `examples/workloads/` lower to workloads *bit-identical* to their
//!    Rust builtin constructors: same fingerprint (the cache key — so
//!    parsed inputs share memoized and disk-cached analyses), same
//!    statement counts, and the same DSE Pareto frontier.
//! 2. **Round-trip** — every builtin rendered to text re-parses to the
//!    identical fingerprint, pinning the renderer and the parser to the
//!    same IR encoding.
//! 3. **Adversarial corpus** — malformed input fails with a
//!    line/column-anchored diagnostic whose message prefix is stable
//!    (scripts may grep it), and never panics.

use tcpa_energy::dse::{explore, workload_fingerprint, DesignSpace, ExploreConfig};
use tcpa_energy::lint::{lint_workload, LintOptions};
use tcpa_energy::workloads::{self, text};

fn corpus_path(file: &str) -> String {
    format!(
        "{}/../examples/workloads/{file}",
        env!("CARGO_MANIFEST_DIR")
    )
}

fn parse_corpus(file: &str) -> tcpa_energy::pra::Workload {
    let src = std::fs::read_to_string(corpus_path(file))
        .unwrap_or_else(|e| panic!("reading {file}: {e}"));
    text::parse_workload(&src)
        .unwrap_or_else(|e| panic!("parsing {file}: {e}"))
}

/// The corpus files that mirror a builtin constructor, pinned
/// bit-identical: equal fingerprints mean equal `Debug` encodings of
/// the whole IR — names, statements, access maps, guards, requires.
#[test]
fn corpus_files_are_bit_identical_to_their_builtins() {
    for file in ["gesummv.wl", "gemm.wl", "atax.wl", "mvt.wl"] {
        let parsed = parse_corpus(file);
        let builtin = workloads::by_name(&parsed.name)
            .unwrap_or_else(|| panic!("{file} names no builtin"));
        assert_eq!(
            parsed.phases.len(),
            builtin.phases.len(),
            "{file}: phase count"
        );
        for (p, b) in parsed.phases.iter().zip(&builtin.phases) {
            assert_eq!(p.name, b.name, "{file}: phase name");
            assert_eq!(
                p.statements.len(),
                b.statements.len(),
                "{file}: statement count in {}",
                p.name
            );
        }
        assert_eq!(
            workload_fingerprint(&parsed),
            workload_fingerprint(&builtin),
            "{file}: fingerprint differs from builtin `{}`",
            parsed.name
        );
    }
}

/// Every file in the corpus — including the text-only ones with no
/// builtin twin — parses and survives the strictest lint gate. CI runs
/// the same sweep through the CLI; this is the in-tree witness.
#[test]
fn whole_corpus_is_lint_clean_under_deny_warnings() {
    let dir = corpus_path("");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("corpus dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("wl") {
            continue;
        }
        seen += 1;
        let src = std::fs::read_to_string(&path).unwrap();
        let wl = text::parse_workload(&src)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        for rep in lint_workload(&wl, &LintOptions::default()) {
            assert!(
                rep.is_clean(true),
                "{} phase {} must pass --deny warnings:\n{}",
                path.display(),
                rep.pra,
                rep.render()
            );
        }
    }
    assert!(seen >= 5, "corpus unexpectedly small: {seen} files");
}

/// The acceptance bit: a DSE sweep over the parsed file and over the
/// builtin produce the same frontier, point for point — identical
/// energy, latency, PEs and schedule labels in the same order. The
/// parsed run additionally proves schedule causality per candidate
/// (the untrusted-input hardening the CLI switches on for
/// `--workload-file`) without perturbing the result.
#[test]
fn dse_frontier_of_parsed_gesummv_matches_builtin() {
    let parsed = parse_corpus("gesummv.wl");
    let builtin = workloads::by_name("gesummv").unwrap();
    let space = DesignSpace::new()
        .with_arrays_2d(4)
        .with_bounds(vec![8, 8]);
    let cfg = ExploreConfig { workers: 0 };
    let res_b = explore(&builtin, &space, &cfg);
    let res_p = explore(
        &parsed,
        &space.clone().with_schedule_verification(),
        &cfg,
    );
    assert!(res_b.failures.is_empty(), "{:?}", res_b.failures);
    assert!(res_p.failures.is_empty(), "{:?}", res_p.failures);
    assert_eq!(res_p.frontier, res_b.frontier, "frontier indices");
    assert_eq!(res_p.points.len(), res_b.points.len());
    for (p, b) in res_p.points.iter().zip(&res_b.points) {
        assert_eq!(
            format!("{:?}", p.point),
            format!("{:?}", b.point),
            "design point"
        );
        assert_eq!(p.schedule_label, b.schedule_label);
        assert_eq!(p.pes, b.pes);
        assert_eq!(p.energy_pj, b.energy_pj);
        assert_eq!(p.latency_cycles, b.latency_cycles);
        assert_eq!(p.edp, b.edp);
    }
}

/// Renderer ↔ parser closure over the whole builtin registry, plus the
/// unschedulable counterexample fixture (structure the lint gate
/// rejects must still round-trip — the frontend reports, it does not
/// silently repair).
#[test]
fn every_builtin_round_trips_through_text() {
    let mut wls = workloads::all();
    wls.push(workloads::twist_unschedulable());
    for wl in wls {
        let src = text::render_workload(&wl);
        let back = text::parse_workload(&src).unwrap_or_else(|e| {
            panic!("{} failed to re-parse: {e}\n--- rendered:\n{src}", wl.name)
        });
        assert_eq!(
            workload_fingerprint(&back),
            workload_fingerprint(&wl),
            "{} round-trip fingerprint\n--- rendered:\n{src}",
            wl.name
        );
    }
}

/// One adversarial input per documented diagnostic family: the error is
/// anchored at the exact line and column, and its message prefix is
/// stable.
#[test]
fn adversarial_corpus_pins_positions_and_message_prefixes() {
    // (source, line, col, expected message prefix)
    let cases: &[(&str, usize, usize, &str)] = &[
        // Unknown parameter: M is neither a loop bound nor declared.
        (
            "workload w\nloop i0 in 0..N0\ntensor T[N0]\n\
             stmt: T[i0] = T[i0 + M]\n",
            4,
            1,
            "unknown parameter `M`",
        ),
        // Non-affine loop bound.
        (
            "workload w\nloop i0 in 0..N0\nloop i1 in 0..N1*N1\n",
            3,
            17,
            "non-affine expression",
        ),
        // Rank mismatch: T is rank 1, accessed rank 2.
        (
            "workload w\nloop i0 in 0..N0\nloop i1 in 0..N1\n\
             tensor T[N0]\nstmt: T[i0] = T[i0, i1]\n",
            5,
            15,
            "rank mismatch: tensor `T`",
        ),
        // Duplicate statement name.
        (
            "workload w\nloop i0 in 0..N0\ntensor T[N0]\n\
             stmt S1: T[i0] = T[i0]\nstmt S1: a[i0] = T[i0]\n",
            5,
            6,
            "duplicate statement name `S1`",
        ),
        // Dangling dependence: `z` is read but never defined.
        (
            "workload w\nloop i0 in 0..N0\ntensor T[N0]\n\
             stmt: T[i0] = z[i0]\n",
            4,
            15,
            "dangling dependence: variable `z`",
        ),
        // Unterminated phase block.
        (
            "workload w\nphase p1 {\n  loop i0 in 0..N0\n",
            2,
            10,
            "unterminated phase block `p1`",
        ),
    ];
    for (src, line, col, prefix) in cases {
        let e = text::parse_workload(src)
            .expect_err(&format!("must reject:\n{src}"));
        assert!(
            e.message.starts_with(prefix),
            "message {:?} should start with {prefix:?} for:\n{src}",
            e.message
        );
        assert_eq!(
            (e.line, e.col),
            (*line, *col),
            "position of {prefix:?} in:\n{src}\ngot: {e}"
        );
    }
}
