//! Soundness and sim-differential suite for the schedule-vector
//! enumerator (`schedule::enumerate_schedules`), the DSE schedule axis'
//! foundation:
//!
//! 1. **Constraint soundness** — every enumerated schedule of every
//!    built-in workload passes `Schedule::verify` at a grid of sampled
//!    parameter points (several bounds × array shapes × π).
//! 2. **Default containment** — `find_schedule`'s pick is always
//!    candidate 0 of the enumeration (same permutation, same evaluated
//!    λ^J/λ^K), so `--schedules first` can never diverge from the
//!    single-schedule explorer.
//! 3. **Determinism** — repeated enumeration (including from concurrent
//!    threads, the explorer's worker setting) yields identical candidate
//!    sequences.
//! 4. **Sim differential** — extending the symbolic==concrete oracle of
//!    `tests/packed_diff.rs` to the schedule axis: for small concrete
//!    bounds, *each* enumerated schedule drives the cycle-accurate `sim`
//!    engine with zero causality violations, its symbolic latency (Eq. 8)
//!    equals the simulated makespan exactly, the rectangular-span start
//!    time `λ^J·(p−1) + λ^K·(t−1)` anchors the cycle count, counts stay
//!    schedule-invariant (equal to the symbolic volumes), and functional
//!    outputs match the lexicographic interpreter.

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::schedule::{enumerate_schedules, find_schedule, latency};
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{
    pad_array, pad_bounds, tile_pra, ArrayMapping,
};
use tcpa_energy::workloads::{self, interpret, workload_inputs};

/// Array shapes exercised per loop depth: the canonical 2×2-style
/// mapping plus linear and rectangular orientations (deeper dimensions
/// stay PE-local, the `analyze_uniform` convention).
fn shapes_for(ndims: usize) -> Vec<Vec<i64>> {
    let base: Vec<Vec<i64>> =
        vec![vec![2, 2], vec![1, 4], vec![4, 1], vec![3, 2]];
    base.into_iter().map(|t| pad_array(&t, ndims)).collect()
}

/// Loop-bound vectors per depth (padded with the last entry, the CLI
/// convention). Kept ≥ 4 so every shape above fits and tiles are
/// non-degenerate; `mvt`/`syrk` are square-only (the convention the
/// validation and property suites follow), so rectangles collapse to
/// their larger square for them.
fn bounds_for(wl_name: &str, ndims: usize) -> Vec<Vec<i64>> {
    let mut out = vec![
        pad_bounds(&[4, 4], ndims),
        pad_bounds(&[8, 8], ndims),
        pad_bounds(&[4, 9], ndims),
        pad_bounds(&[9, 4], ndims),
    ];
    if matches!(wl_name, "mvt" | "syrk") {
        for b in &mut out {
            let m = b.iter().copied().max().unwrap();
            b.fill(m);
        }
    }
    out
}

#[test]
fn every_enumerated_schedule_verifies_on_every_builtin_workload() {
    for wl in workloads::all() {
        for phase in &wl.phases {
            for shape in shapes_for(phase.ndims) {
                let mapping = ArrayMapping::new(shape.clone());
                let tiled = tile_pra(phase, &mapping);
                for pi in [1i64, 3] {
                    let all = enumerate_schedules(&tiled, pi, None);
                    assert!(
                        !all.is_empty(),
                        "{}: no candidates on {shape:?}",
                        phase.name
                    );
                    for bounds in bounds_for(&wl.name, phase.ndims) {
                        let params = mapping.params_for(&bounds);
                        for (ci, s) in all.iter().enumerate() {
                            let v = s.verify(&tiled, &params);
                            assert!(
                                v.is_empty(),
                                "{} t={shape:?} π={pi} candidate {ci} \
                                 (perm {:?}) violates causality at \
                                 {params:?}: {v:?}",
                                phase.name,
                                s.perm
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn every_enumerated_schedule_verifies_symbolically() {
    // The all-parameter analogue of the grid test above: no candidate
    // relies on the sampled grid being too small to expose it (the
    // adversarial-λ^K gap closed by `Schedule::verify_symbolic`).
    for wl in workloads::all() {
        for phase in &wl.phases {
            for shape in shapes_for(phase.ndims) {
                let mapping = ArrayMapping::new(shape.clone());
                let tiled = tile_pra(phase, &mapping);
                for pi in [1i64, 3] {
                    for (ci, s) in
                        enumerate_schedules(&tiled, pi, None).iter().enumerate()
                    {
                        let v = s.verify_symbolic(&tiled);
                        assert!(
                            v.is_empty(),
                            "{} t={shape:?} π={pi} candidate {ci} \
                             (perm {:?}): {v:?}",
                            phase.name,
                            s.perm
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn find_schedule_pick_is_candidate_zero_everywhere() {
    for wl in workloads::all() {
        for phase in &wl.phases {
            for shape in shapes_for(phase.ndims) {
                let mapping = ArrayMapping::new(shape.clone());
                let tiled = tile_pra(phase, &mapping);
                let first = find_schedule(&tiled, 1)
                    .unwrap_or_else(|e| {
                        panic!("{} on {shape:?}: {e}", phase.name)
                    });
                let all = enumerate_schedules(&tiled, 1, None);
                let c0 = &all[0];
                assert_eq!(c0.perm, first.perm, "{}", phase.name);
                assert_eq!(c0.pi, first.pi);
                assert_eq!(c0.lc, first.lc);
                // Same evaluated vectors at a sample of points — the
                // observable identity the DSE `first` policy relies on.
                for bounds in bounds_for(&wl.name, phase.ndims) {
                    let params = mapping.params_for(&bounds);
                    assert_eq!(
                        c0.lambda_j_at(&params),
                        first.lambda_j_at(&params)
                    );
                    assert_eq!(
                        c0.lambda_k_at(&params),
                        first.lambda_k_at(&params)
                    );
                    assert_eq!(
                        latency(c0, &tiled, &params),
                        latency(&first, &tiled, &params)
                    );
                }
            }
        }
    }
}

/// (perm, λ^J, λ^K) evaluated at one parameter point — the observable
/// identity of one candidate in the determinism checks below.
type CandidatePrint = (Vec<usize>, Vec<i128>, Vec<i128>);

#[test]
fn enumeration_is_deterministic_across_runs_and_threads() {
    let wl = workloads::by_name("gemm").unwrap();
    let phase = &wl.phases[0];
    let tiled = tile_pra(phase, &ArrayMapping::new(vec![2, 2, 1]));
    let fingerprint = |tiled: &tcpa_energy::tiling::TiledPra| -> Vec<CandidatePrint> {
        let params = [8i64, 8, 8, 4, 4, 8];
        enumerate_schedules(tiled, 1, None)
            .into_iter()
            .map(|s| {
                (
                    s.perm.clone(),
                    s.lambda_j_at(&params),
                    s.lambda_k_at(&params),
                )
            })
            .collect()
    };
    let reference = fingerprint(&tiled);
    assert!(!reference.is_empty());
    // Repeated runs.
    assert_eq!(fingerprint(&tiled), reference);
    // Concurrent enumeration (the explorer calls this from its worker
    // pool): every thread must observe the identical sequence.
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| scope.spawn(|| fingerprint(&tiled)))
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), reference);
        }
    });
}

#[test]
fn sim_differential_validates_every_candidate_on_every_workload() {
    for wl in workloads::all() {
        // Small concrete bounds keep the Θ(iterations) simulation cheap;
        // jacobi1d wants a wider space dimension (its boundary stencil
        // needs the room — same sizing the figures pipeline uses).
        let base: Vec<i64> = match wl.name.as_str() {
            "jacobi1d" => vec![4, 12],
            _ => vec![8, 8],
        };
        let params_all: Vec<Vec<i64>> = wl
            .phases
            .iter()
            .map(|ph| {
                let b = pad_bounds(&base, ph.ndims);
                let t = pad_array(&[2, 2], ph.ndims);
                ArrayMapping::new(t).params_for(&b)
            })
            .collect();
        let mut env = workload_inputs(&wl, &params_all);
        for (phase, params) in wl.phases.iter().zip(&params_all) {
            let t = pad_array(&[2, 2], phase.ndims);
            let mapping = ArrayMapping::new(t.clone());
            let ana = SymbolicAnalysis::analyze(phase, &mapping);
            let sym = ana.counts_at(params);
            let golden = interpret(phase, params, &env);
            let mut arch = ArchConfig::with_array(t.clone());
            arch.regs.fd = 1 << 20; // pressure is a separate concern
            let tiled = tile_pra(phase, &mapping);
            let all = enumerate_schedules(&tiled, arch.pi, None);
            assert!(!all.is_empty(), "{}", phase.name);
            for (ci, s) in all.iter().enumerate() {
                let tag = format!(
                    "{} candidate {ci} (perm {:?})",
                    phase.name, s.perm
                );
                let res = simulate(phase, &arch, s, params, &env);
                // Dynamic causality: no operand may be read before its
                // producing iteration started — the ground truth the
                // symbolic constraints stand in for.
                assert!(
                    res.violations.is_empty(),
                    "{tag}: {:?}",
                    res.violations
                );
                // Symbolic latency == simulated makespan, exactly.
                let l_sym = latency(s, &tiled, params);
                assert_eq!(res.cycles, l_sym, "{tag}: latency");
                // Start-time anchor: the final iteration of the
                // rectangular schedule starts at span = L − L_c.
                let jmax: Vec<i64> = (0..phase.ndims)
                    .map(|l| params[phase.space.p_index(l)] - 1)
                    .collect();
                let kmax: Vec<i64> =
                    mapping.t.iter().map(|&x| x - 1).collect();
                assert_eq!(
                    s.start_time(&jmax, &kmax, params) + s.lc as i128,
                    res.cycles as i128,
                    "{tag}: start-time span"
                );
                // Counts are schedule-invariant and exactly symbolic.
                let diff = res.counters.diff_symbolic(&sym);
                assert!(diff.is_empty(), "{tag}: {diff:#?}");
                // Functional ground truth.
                for (name, tens) in &res.outputs {
                    assert!(
                        tens.allclose(&golden[name], 1e-4, 1e-4),
                        "{tag}: output {name} diverges"
                    );
                }
            }
            // Later phases consume earlier phases' outputs: feed the
            // interpreter's (schedule-independent) values forward.
            for (name, tens) in golden {
                env.insert(name, tens);
            }
        }
    }
}
