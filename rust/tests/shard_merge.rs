//! Sharded-sweep partition and merge acceptance suite.
//!
//! `--shard i/n` must slice the canonical enumeration into a true
//! partition — every point owned by exactly one shard, no overlap —
//! and `dse merge` must fold the per-shard journals into a report
//! **byte-identical** to the unsharded run, failing loudly (naming the
//! offending shard, field, or file) on a missing, duplicated, stale,
//! or unfinished shard. Like the fault-injection suite, these tests
//! drive the real binary: the property pinned is the end-to-end
//! artifact a CI pipeline diffs.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

use tcpa_energy::dse::Shard;

const BIN: &str = env!("CARGO_BIN_EXE_tcpa-energy");

const KILL_AFTER: &str = "TCPA_DSE_FAULT_KILL_AFTER";
const JOURNAL_BATCH: &str = "TCPA_DSE_JOURNAL_BATCH";

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("tcpa-shard-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Run `tcpa-energy dse --workload gesummv --bounds 8,8 --max-pes 4
/// --workers 2 <extra>` — an 8-point canonical enumeration — with the
/// given env hooks.
fn dse(extra: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut cmd = Command::new(BIN);
    cmd.args([
        "dse", "--workload", "gesummv", "--bounds", "8,8", "--max-pes",
        "4", "--workers", "2",
    ]);
    cmd.args(extra);
    // Never inherit hooks from the harness environment.
    for k in [KILL_AFTER, JOURNAL_BATCH] {
        cmd.env_remove(k);
    }
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.output().expect("spawn tcpa-energy")
}

/// `dse merge` over the same space, folding `journals`.
fn merge(journals: &[&str], out: Option<&Path>) -> Output {
    let mut cmd = Command::new(BIN);
    cmd.args([
        "dse", "merge", "--workload", "gesummv", "--bounds", "8,8",
        "--max-pes", "4",
    ]);
    let list = journals.join(",");
    cmd.args(["--shards", &list]);
    if let Some(dir) = out {
        cmd.args(["--out", dir.to_str().unwrap()]);
    }
    cmd.output().expect("spawn tcpa-energy dse merge")
}

/// The three report files `--out` writes, as raw bytes.
fn report_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
    ["dse_gesummv_points.csv", "dse_gesummv_frontier.csv",
     "dse_gesummv_frontier.md"]
        .iter()
        .map(|f| (f.to_string(), std::fs::read(dir.join(f)).unwrap()))
        .collect()
}

fn assert_reports_identical(
    base: &[(String, Vec<u8>)],
    dir: &Path,
    what: &str,
) {
    for ((name, want), (_, got)) in
        base.iter().zip(report_bytes(dir).iter())
    {
        assert_eq!(
            want, got,
            "{what}: {name} must be byte-identical to the unsharded \
             sweep"
        );
    }
}

/// Data rows (header stripped) of one run's points CSV.
fn point_rows(dir: &Path) -> Vec<String> {
    let text =
        std::fs::read_to_string(dir.join("dse_gesummv_points.csv"))
            .unwrap();
    text.lines().skip(1).map(str::to_string).collect()
}

/// Run all `n` shards, journaling under `dir`; returns the journal
/// paths in shard order.
fn run_shards(dir: &Path, n: usize, with_out: bool) -> Vec<PathBuf> {
    (1..=n)
        .map(|i| {
            let j = dir.join(format!("shard{i}.journal"));
            let sh = format!("{i}/{n}");
            let mut extra: Vec<String> = vec![
                "--shard".into(),
                sh.clone(),
                "--checkpoint".into(),
                j.to_str().unwrap().into(),
            ];
            if with_out {
                extra.extend([
                    "--out".into(),
                    dir.join(format!("out{i}")).to_str().unwrap().into(),
                ]);
            }
            let extra_refs: Vec<&str> =
                extra.iter().map(String::as_str).collect();
            let out = dse(&extra_refs, &[]);
            assert!(out.status.success(), "shard {sh} failed: {out:?}");
            j
        })
        .collect()
}

#[test]
fn shard_slices_partition_the_enumeration_for_several_n() {
    // Library-level invariant first: round-robin ownership is a true
    // partition for any n — exactly one owner per index.
    for n in [1usize, 2, 3, 5, 8, 11] {
        for idx in 0..16usize {
            let owners: Vec<usize> = (1..=n)
                .filter(|&i| Shard { index: i, count: n }.owns(idx))
                .collect();
            assert_eq!(
                owners,
                vec![Shard::owner_of(idx, n).index],
                "point {idx} must have exactly one owner of {n} shards"
            );
        }
    }
    // End-to-end: the union of the shard-local point CSVs is exactly
    // the unsharded point CSV, with no row appearing in two shards.
    let dir = tmp_dir("partition");
    let base_dir = dir.join("base");
    assert!(dse(&["--out", base_dir.to_str().unwrap()], &[])
        .status
        .success());
    let all_rows = point_rows(&base_dir);
    assert_eq!(
        all_rows.len(),
        8,
        "gesummv 8,8 max-pes 4 enumerates 8 points"
    );
    for n in [2usize, 3] {
        let sub = dir.join(format!("n{n}"));
        std::fs::create_dir_all(&sub).unwrap();
        run_shards(&sub, n, true);
        let mut union: Vec<String> = Vec::new();
        for i in 1..=n {
            let rows = point_rows(&sub.join(format!("out{i}")));
            for r in &rows {
                assert!(
                    !union.contains(r),
                    "row owned by two shards of {n}: {r}"
                );
            }
            union.extend(rows);
        }
        let mut want = all_rows.clone();
        want.sort();
        union.sort();
        assert_eq!(
            union, want,
            "the union of {n} shard slices must cover the space"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn three_way_merge_is_byte_identical_to_the_unsharded_run() {
    let dir = tmp_dir("merge");
    let base_dir = dir.join("base");
    assert!(dse(&["--out", base_dir.to_str().unwrap()], &[])
        .status
        .success());
    let base = report_bytes(&base_dir);
    let journals = run_shards(&dir, 3, false);
    let refs: Vec<&str> =
        journals.iter().map(|j| j.to_str().unwrap()).collect();
    let merged_dir = dir.join("merged");
    let out = merge(&refs, Some(&merged_dir));
    assert!(out.status.success(), "merge failed: {out:?}");
    assert_reports_identical(&base, &merged_dir, "3-way merge");
    // Order independence: shards fold identically in any order.
    let rev: Vec<&str> = refs.iter().rev().copied().collect();
    let rev_dir = dir.join("merged-rev");
    assert!(merge(&rev, Some(&rev_dir)).status.success());
    assert_reports_identical(&base, &rev_dir, "reversed-order merge");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn merge_failures_are_loud_and_name_the_offender() {
    let dir = tmp_dir("offender");
    let journals = run_shards(&dir, 3, false);
    let refs: Vec<&str> =
        journals.iter().map(|j| j.to_str().unwrap()).collect();

    // Missing shard: only 2 of 3 journals given.
    let out = merge(&[refs[0], refs[2]], None);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("2 of 3"), "{err}");
    assert!(err.contains("2/3"), "missing shard must be named: {err}");

    // Duplicate shard: 1/3 given twice, both paths named.
    let out = merge(&[refs[0], refs[1], refs[0]], None);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("duplicate shard 1/3"), "{err}");
    assert!(err.contains("shard1.journal"), "{err}");

    // Stale shard: a journal written over different bounds — the
    // fingerprint mismatch and the file are named.
    let stale = dir.join("stale.journal");
    let mut cmd = Command::new(BIN);
    cmd.args([
        "dse", "--workload", "gesummv", "--bounds", "16,16",
        "--max-pes", "4", "--shard", "2/3", "--checkpoint",
        stale.to_str().unwrap(),
    ]);
    assert!(cmd.output().unwrap().status.success());
    let out = merge(&[refs[0], stale.to_str().unwrap(), refs[2]], None);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("stale"), "{err}");
    assert!(err.contains("stale.journal"), "{err}");

    // Unfinished shard: tear the records off shard 2's journal — the
    // first unowned point names the owning shard, its journal file,
    // and the recovery (--resume).
    let text = std::fs::read_to_string(&journals[1]).unwrap();
    let header_only: String =
        text.lines().take(6).map(|l| format!("{l}\n")).collect();
    std::fs::write(&journals[1], header_only).unwrap();
    let out = merge(&refs, None);
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let err = String::from_utf8_lossy(&out.stderr).to_string();
    assert!(err.contains("incomplete merge"), "{err}");
    assert!(err.contains("2/3"), "{err}");
    assert!(err.contains("shard2.journal"), "{err}");
    assert!(err.contains("--resume"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn killed_shard_resumes_and_still_merges_byte_identical() {
    let dir = tmp_dir("interop");
    let base_dir = dir.join("base");
    assert!(dse(&["--out", base_dir.to_str().unwrap()], &[])
        .status
        .success());
    let base = report_bytes(&base_dir);
    // Shards 1 and 3 complete; shard 2 is killed after its first
    // committed point, then resumed in a fresh process — the sharded
    // and interruptible machineries must compose.
    let j: Vec<PathBuf> = (1..=3)
        .map(|i| dir.join(format!("shard{i}.journal")))
        .collect();
    for i in [1usize, 3] {
        let sh = format!("{i}/3");
        let out = dse(
            &["--shard", &sh, "--checkpoint",
              j[i - 1].to_str().unwrap()],
            &[],
        );
        assert!(out.status.success(), "shard {sh}: {out:?}");
    }
    let killed = dse(
        &["--shard", "2/3", "--checkpoint", j[1].to_str().unwrap()],
        &[(KILL_AFTER, "1"), (JOURNAL_BATCH, "1")],
    );
    assert!(!killed.status.success(), "the kill must fire: {killed:?}");
    assert!(j[1].exists(), "the shard journal survives the kill");
    let resumed = dse(
        &["--shard", "2/3", "--checkpoint", j[1].to_str().unwrap(),
          "--resume"],
        &[],
    );
    assert!(resumed.status.success(), "{resumed:?}");
    let stdout = String::from_utf8_lossy(&resumed.stdout);
    assert!(
        stdout.contains("1 replayed from journal"),
        "the resume must replay the committed prefix: {stdout}"
    );
    let refs: Vec<&str> =
        j.iter().map(|p| p.to_str().unwrap()).collect();
    let merged_dir = dir.join("merged");
    let out = merge(&refs, Some(&merged_dir));
    assert!(out.status.success(), "merge failed: {out:?}");
    assert_reports_identical(&base, &merged_dir, "kill+resume+merge");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resuming_under_a_different_shard_flag_is_a_stale_journal() {
    let dir = tmp_dir("stale-shard");
    let j = dir.join("sweep.journal");
    let js = j.to_str().unwrap().to_string();
    assert!(dse(&["--shard", "1/3", "--checkpoint", &js], &[])
        .status
        .success());
    // The journal is fingerprint-locked to its slice: replaying shard
    // 1's records into shard 2's sweep would silently mis-assign
    // points, so it must be rejected as stale, naming the field.
    let clash =
        dse(&["--shard", "2/3", "--checkpoint", &js, "--resume"], &[]);
    assert_eq!(clash.status.code(), Some(2), "{clash:?}");
    let err = String::from_utf8_lossy(&clash.stderr).to_string();
    assert!(err.contains("stale"), "{err}");
    assert!(err.contains("shard"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
