//! §V-A cross-validation — the paper's headline correctness claim:
//! "The analytically derived access counts and obtained total energy
//! values match the simulation results exactly."
//!
//! For every benchmark workload, several problem sizes, and several array
//! shapes, this test checks that
//!
//! 1. the symbolic counts (one-time analysis, O(1) evaluation) equal the
//!    cycle-accurate simulator's counters **exactly**, per memory class;
//! 2. the implied total energies agree to floating-point round-off;
//! 3. the simulator's functional outputs equal the lexicographic
//!    interpreter's (the in-crate golden model);
//! 4. the simulation runs without causality/pressure violations.

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::sim::{simulate, ArchConfig};
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads::{self, interpret, workload_inputs};

/// Problem sizes per workload (kept modest: the simulator is Θ(N·
/// statements); symbolic analysis is size-independent).
fn sizes_for(name: &str) -> Vec<Vec<i64>> {
    match name {
        // (time, space) for the stencil; N1 ≥ 3 required.
        "jacobi1d" => vec![vec![3, 8], vec![4, 12], vec![6, 10]],
        // square-only workloads
        "mvt" | "syrk" => vec![vec![6, 6], vec![8, 8], vec![12, 12]],
        _ => vec![vec![4, 5], vec![8, 8], vec![12, 10]],
    }
}

/// Array shapes to validate on (per loop depth).
fn arrays_for(ndims: usize) -> Vec<Vec<i64>> {
    match ndims {
        2 => vec![vec![2, 2], vec![4, 2], vec![1, 3]],
        3 => vec![vec![2, 2, 1], vec![4, 2, 1]],
        _ => vec![vec![2; ndims]],
    }
}

/// Extend a base size vector to a phase's loop depth.
fn phase_bounds(base: &[i64], ndims: usize) -> Vec<i64> {
    let mut b = base.to_vec();
    while b.len() < ndims {
        b.push(*base.last().unwrap());
    }
    b.truncate(ndims);
    b
}

#[test]
fn symbolic_matches_simulation_exactly_all_benchmarks() {
    let mut validated = 0usize;
    for wl in workloads::all() {
        for base in sizes_for(&wl.name) {
            for array in arrays_for(wl.phases[0].ndims) {
                // Per-phase params and mappings.
                let mut env =
                    workload_inputs(&wl, &phase_params(&wl, &base, &array));
                let params_all = phase_params(&wl, &base, &array);
                for (phase, params) in wl.phases.iter().zip(&params_all) {
                    let mut t = array.clone();
                    while t.len() < phase.ndims {
                        t.push(1);
                    }
                    t.truncate(phase.ndims);
                    let mapping = ArrayMapping::new(t.clone());
                    // --- symbolic ---
                    let ana = SymbolicAnalysis::analyze(phase, &mapping);
                    let sym = ana.counts_at(params);
                    // --- simulation ---
                    let mut arch = ArchConfig::with_array(t);
                    arch.regs.fd = 1 << 20; // pressure checked separately
                    let tiled = tile_pra(phase, &mapping);
                    let schedule = find_schedule(&tiled, 1).unwrap();
                    let res =
                        simulate(phase, &arch, &schedule, params, &env);
                    assert!(
                        res.violations.is_empty(),
                        "{} {base:?} {array:?}: {:?}",
                        phase.name,
                        res.violations
                    );
                    // 1. exact count match
                    let diff = res.counters.diff_symbolic(&sym);
                    assert!(
                        diff.is_empty(),
                        "{} N={base:?} t={array:?} params={params:?}: \
                         {diff:#?}",
                        phase.name
                    );
                    // 2. energy agreement
                    let e_sym = ana.energy_at(params).total;
                    let e_sim = res.counters.energy_pj(&ana.table);
                    assert!(
                        (e_sym - e_sim).abs() <= 1e-9 * e_sym.abs().max(1.0),
                        "{}: energy {e_sym} vs {e_sim}",
                        phase.name
                    );
                    // 3. functional agreement with the interpreter
                    let golden = interpret(phase, params, &env);
                    for (name, tens) in &res.outputs {
                        assert!(
                            tens.allclose(&golden[name], 1e-4, 1e-4),
                            "{}: output {name} diverges (max diff {})",
                            phase.name,
                            tens.max_abs_diff(&golden[name])
                        );
                    }
                    // chain outputs into the next phase's inputs
                    for (name, tens) in res.outputs {
                        env.insert(name, tens);
                    }
                    validated += 1;
                }
            }
        }
    }
    // 8 workloads × ≥3 sizes × ≥1 arrays × phases — make sure the loop
    // actually exercised a meaningful matrix.
    assert!(validated >= 60, "only {validated} configurations validated");
}

/// Per-phase parameter vectors under the exact-cover sizing rule.
fn phase_params(
    wl: &tcpa_energy::pra::Workload,
    base: &[i64],
    array: &[i64],
) -> Vec<Vec<i64>> {
    wl.phases
        .iter()
        .map(|phase| {
            let bounds = phase_bounds(base, phase.ndims);
            let mut t = array.to_vec();
            while t.len() < phase.ndims {
                t.push(1);
            }
            t.truncate(phase.ndims);
            ArrayMapping::new(t).params_for(&bounds)
        })
        .collect()
}

#[test]
fn latency_formula_matches_simulated_makespan() {
    // Eq. 8 vs the engine's cycle counter, across sizes and arrays.
    for wl in workloads::all() {
        let base = sizes_for(&wl.name)[0].clone();
        for array in arrays_for(wl.phases[0].ndims) {
            for (phase, params) in
                wl.phases.iter().zip(phase_params(&wl, &base, &array))
            {
                let mut t = array.clone();
                while t.len() < phase.ndims {
                    t.push(1);
                }
                t.truncate(phase.ndims);
                let mapping = ArrayMapping::new(t.clone());
                let ana = SymbolicAnalysis::analyze(phase, &mapping);
                let mut arch = ArchConfig::with_array(t);
                arch.regs.fd = 1 << 20;
                let tiled = tile_pra(phase, &mapping);
                let schedule = find_schedule(&tiled, 1).unwrap();
                let env = workload_inputs(&wl, &phase_params(&wl, &base, &array));
                // Phases beyond the first may need produced tensors; only
                // check single-phase workloads and first phases here.
                if !env_has_all_inputs(phase, &env) {
                    continue;
                }
                let res = simulate(phase, &arch, &schedule, &params, &env);
                assert_eq!(
                    res.cycles,
                    ana.latency_at(&params),
                    "{} t={:?}",
                    phase.name,
                    array
                );
            }
        }
    }
}

fn env_has_all_inputs(
    pra: &tcpa_energy::pra::Pra,
    env: &tcpa_energy::workloads::TensorEnv,
) -> bool {
    use tcpa_energy::pra::classify::{classify, VarClass};
    classify(pra)
        .iter()
        .filter(|(_, c)| **c == VarClass::Input)
        .all(|(n, _)| env.contains_key(n))
}
