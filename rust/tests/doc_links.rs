//! Link integrity over the repository's Markdown files: every relative
//! link target (`[text](path)`) must exist on disk, so README /
//! ARCHITECTURE cross-references never rot silently. External links
//! (`http(s)://`, `mailto:`), pure anchors (`#...`) and anything inside
//! fenced code blocks are ignored. CI runs this as the "Markdown link
//! integrity" step; it also rides along in every plain `cargo test`.

use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // rust/ is the manifest dir; the Markdown lives one level up.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf()
}

/// Every `.md` file under `dir`, skipping VCS and build output.
fn md_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries {
        let entry = entry.expect("readable dir entry");
        let name = entry.file_name().to_string_lossy().into_owned();
        let path = entry.path();
        if path.is_dir() {
            if name.starts_with('.') || name == "target" {
                continue;
            }
            md_files(&path, out);
        } else if name.ends_with(".md") {
            out.push(path);
        }
    }
}

/// Relative link targets of one Markdown document with their line
/// numbers, fenced code blocks stripped first.
fn relative_links(content: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    // The open fence's marker, so a ``` fence only closes on ``` and a
    // ~~~ fence only on ~~~ — mixed styles (e.g. showing a literal ```
    // inside a ~~~ block) must not desynchronize the scanner.
    let mut fence: Option<&str> = None;
    for (lineno, line) in content.lines().enumerate() {
        let trimmed = line.trim_start();
        let marker = ["```", "~~~"]
            .into_iter()
            .find(|m| trimmed.starts_with(m));
        match (fence, marker) {
            (None, Some(m)) => {
                fence = Some(m);
                continue;
            }
            (Some(open), Some(m)) if open == m => {
                fence = None;
                continue;
            }
            _ => {}
        }
        if fence.is_some() {
            continue;
        }
        let mut rest = line;
        while let Some(start) = rest.find("](") {
            let tail = &rest[start + 2..];
            let Some(end) = tail.find(')') else { break };
            let target = tail[..end].trim();
            rest = &tail[end + 1..];
            if target.is_empty()
                || target.contains("://")
                || target.starts_with('#')
                || target.starts_with("mailto:")
                || target.contains(char::is_whitespace)
            {
                continue;
            }
            // Drop any fragment: `docs/ARCHITECTURE.md#layout`.
            let path_part =
                target.split_once('#').map_or(target, |(p, _)| p);
            if !path_part.is_empty() {
                out.push((lineno + 1, path_part.to_string()));
            }
        }
    }
    out
}

#[test]
fn every_relative_markdown_link_resolves() {
    let root = repo_root();
    let mut files = Vec::new();
    md_files(&root, &mut files);
    assert!(
        files.iter().any(|f| f.ends_with("README.md")),
        "README.md must exist at the repository root"
    );
    assert!(
        files.iter().any(|f| f.ends_with("ARCHITECTURE.md")),
        "docs/ARCHITECTURE.md must exist"
    );
    let mut broken = Vec::new();
    for file in &files {
        let content = std::fs::read_to_string(file).expect("readable md");
        let base = file.parent().expect("md file has a dir");
        for (line, target) in relative_links(&content) {
            if !base.join(&target).exists() {
                broken.push(format!(
                    "{}:{line}: dead link -> {target}",
                    file.display()
                ));
            }
        }
    }
    assert!(broken.is_empty(), "dead relative links:\n{}", broken.join("\n"));
}

#[test]
fn link_scanner_understands_markdown() {
    let doc = "\
see [guide](docs/ARCHITECTURE.md#map) and [web](https://example.org)\n\
```bash\n\
echo [not a link](nope.md)\n\
```\n\
[anchor](#local) [rel](../README.md) [mail](mailto:x@y.z)\n";
    let links = relative_links(doc);
    assert_eq!(
        links,
        vec![
            (1, "docs/ARCHITECTURE.md".to_string()),
            (5, "../README.md".to_string()),
        ]
    );
    // Mixed fence styles stay synchronized: a literal ``` shown inside
    // a ~~~ fence neither closes it nor exposes the fenced link.
    let mixed = "~~~\n```\n[inside](dead.md)\n~~~\n[after](../README.md)\n";
    assert_eq!(
        relative_links(mixed),
        vec![(5, "../README.md".to_string())]
    );
}
