//! Adversarial lint corpus: one deliberately broken workload per
//! documented lint code, each asserting that lint rejects it with
//! exactly that code — plus the clean sweep over every builtin.
//!
//! The corpus is the contract behind the stable code table in
//! `tcpa_energy::lint`: a code is only "documented" if a workload in
//! here provably triggers it.

use tcpa_energy::lint::{lint_pra, LintCode, LintOptions, Severity};
use tcpa_energy::polyhedral::ParamSpace;
use tcpa_energy::pra::{
    CondConstraint, IndexMap, Lhs, Op, Operand, Pra, Statement, TensorDecl,
    TensorDim,
};

/// Minimal valid scaffold: one rank-1 tensor `T` of extent `N0`.
fn base(nd: usize) -> Pra {
    Pra {
        name: "corpus".into(),
        ndims: nd,
        space: ParamSpace::loop_nest(nd),
        statements: vec![],
        tensors: vec![TensorDecl {
            name: "T".into(),
            shape: vec![TensorDim::Param(0)],
        }],
        requires: vec![],
    }
}

fn copy_stmt(
    name: &str,
    lhs: Lhs,
    args: Vec<Operand>,
    cond: Vec<CondConstraint>,
) -> Statement {
    Statement { name: name.into(), lhs, op: Op::Copy, args, cond }
}

/// Assert the exact code fires, and that the report's severity gating
/// matches the code table.
fn assert_code(pra: &Pra, opts: &LintOptions, code: LintCode) {
    let rep = lint_pra(pra, opts);
    assert!(
        rep.findings.iter().any(|f| f.code == code),
        "expected {code} in report for {}:\n{}",
        pra.name,
        rep.render()
    );
    match code.severity() {
        Severity::Deny => assert!(rep.has_deny(), "{code} must deny"),
        Severity::Warn => {
            assert!(!rep.is_clean(true), "{code} must fail --deny warnings")
        }
    }
}

#[test]
fn l001_duplicate_statement_name() {
    let mut pra = base(1);
    let s = copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::tensor("T", IndexMap::identity(1, 1))],
        vec![],
    );
    pra.statements.push(s.clone());
    pra.statements.push(s);
    assert_code(&pra, &LintOptions::default(), LintCode::L001);
}

#[test]
fn l002_arity_mismatch() {
    let mut pra = base(1);
    pra.statements.push(Statement {
        name: "S1".into(),
        lhs: Lhs::Var("a".into()),
        op: Op::Add, // needs 2 args
        args: vec![Operand::tensor("T", IndexMap::identity(1, 1))],
        cond: vec![],
    });
    assert_code(&pra, &LintOptions::default(), LintCode::L002);
}

#[test]
fn l003_wrong_rank_access() {
    // Rank-2 access to the rank-1 tensor T.
    let mut pra = base(2);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::tensor("T", IndexMap::identity(2, 2))],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L003);
}

#[test]
fn l004_wrong_dependence_vector_length() {
    let mut pra = base(2);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        // 1-entry dependence vector in a 2-deep nest.
        vec![Operand::var("a", vec![1])],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L004);
}

#[test]
fn l005_undefined_variable() {
    let mut pra = base(1);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::var0("ghost", 1)],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L005);
}

#[test]
fn l006_non_lex_positive_dependence() {
    let mut pra = base(2);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::var("a", vec![-1, 0])],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L006);
}

#[test]
fn l007_double_self_read_reduction() {
    let mut pra = base(1);
    pra.statements.push(Statement {
        name: "S1".into(),
        lhs: Lhs::Var("a".into()),
        op: Op::Add,
        args: vec![
            Operand::var("a", vec![1]),
            Operand::var("a", vec![1]),
        ],
        cond: vec![],
    });
    assert_code(&pra, &LintOptions::default(), LintCode::L007);
}

#[test]
fn l008_unused_iteration_dimension() {
    let mut pra = base(2);
    // Only i0 is ever used; i1 exists to replicate work.
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Tensor { name: "T".into(), map: IndexMap::select(&[0], 2) },
        vec![Operand::tensor("T", IndexMap::select(&[0], 2))],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L008);
}

#[test]
fn l009_dead_tensor() {
    let mut pra = base(1);
    pra.tensors.push(TensorDecl {
        name: "Unused".into(),
        shape: vec![TensorDim::Param(0)],
    });
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Tensor { name: "T".into(), map: IndexMap::identity(1, 1) },
        vec![Operand::tensor("T", IndexMap::identity(1, 1))],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L009);
}

#[test]
fn l010_dead_statement() {
    let mut pra = base(1);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::tensor("T", IndexMap::identity(1, 1))],
        vec![],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L010);
}

#[test]
fn l100_symbolically_provable_oob_access() {
    // T[i0 + 1] over 0 ≤ i0 < N0 against extent N0: out of bounds at
    // the top iteration for EVERY parameter value — but no concrete
    // sampling is involved; the violation polyhedron
    // {0 ≤ i0 ≤ N0−1 ∧ i0+1 ≥ N0} is non-empty symbolically.
    let mut pra = base(1);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::tensor(
            "T",
            IndexMap::identity(1, 1).with_offset(vec![1]),
        )],
        vec![],
    ));
    let rep = lint_pra(&pra, &LintOptions::default());
    assert_code(&pra, &LintOptions::default(), LintCode::L100);
    // The finding anchors to the statement.
    assert!(rep
        .findings
        .iter()
        .any(|f| f.code == LintCode::L100
            && f.statement.as_deref() == Some("S1")));
}

#[test]
fn l101_inconsistent_dependence_vector() {
    // Producer covers only i0 = 0, but the consumer's dependence vector
    // reaches back one step from every i0 ≥ 1 — reads at i0 ≥ 2 land
    // where no producer was active.
    let nd = 1;
    let np = 2;
    let mut pra = base(nd);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Var("a".into()),
        vec![Operand::tensor("T", IndexMap::identity(1, nd))],
        vec![
            CondConstraint::ge_const(0, 0, nd, np),
            CondConstraint::le_const(0, 0, nd, np),
        ],
    ));
    pra.statements.push(copy_stmt(
        "S2",
        Lhs::Var("b".into()),
        vec![Operand::var("a", vec![1])],
        vec![CondConstraint::ge_const(0, 1, nd, np)],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L101);
}

#[test]
fn l102_unreachable_statement() {
    let nd = 1;
    let np = 2;
    let mut pra = base(nd);
    pra.statements.push(copy_stmt(
        "S1",
        Lhs::Tensor { name: "T".into(), map: IndexMap::identity(1, nd) },
        vec![Operand::tensor("T", IndexMap::identity(1, nd))],
        // i0 ≥ 2 ∧ i0 ≤ 1: empty for every N0.
        vec![
            CondConstraint::ge_const(0, 2, nd, np),
            CondConstraint::le_const(0, 1, nd, np),
        ],
    ));
    assert_code(&pra, &LintOptions::default(), LintCode::L102);
}

#[test]
fn l200_acausal_schedule() {
    // The shared counterexample fixture: dependence vectors (1,−1) and
    // (−1,1) admit no causal lexicographic order, so the mapping pass
    // must reject every array shape.
    let wl = tcpa_energy::workloads::twist_unschedulable();
    let opts = LintOptions {
        array: Some(vec![2, 2]),
        ..LintOptions::default()
    };
    assert_code(&wl.phases[0], &opts, LintCode::L200);
}

#[test]
fn l201_write_write_conflict() {
    let mut pra = base(1);
    for name in ["S1", "S2"] {
        pra.statements.push(copy_stmt(
            name,
            Lhs::Var("a".into()),
            vec![Operand::tensor("T", IndexMap::identity(1, 1))],
            vec![],
        ));
    }
    let opts = LintOptions {
        array: Some(vec![2]),
        ..LintOptions::default()
    };
    assert_code(&pra, &opts, LintCode::L201);
}

#[test]
fn l202_fd_pressure_over_budget() {
    let wl = tcpa_energy::workloads::by_name("gemm").unwrap();
    let opts = LintOptions {
        array: Some(vec![2, 2]),
        fd_budget: 0,
        ..LintOptions::default()
    };
    assert_code(&wl.phases[0], &opts, LintCode::L202);
}

/// The clean sweep: every builtin workload, on a representative array
/// shape with the first (candidate-0) schedule, has no deny-level
/// finding — all three passes running. Warnings are allowed (the `L202`
/// FD ladder legitimately advises on deep kernels at large tile sizes);
/// deny findings are not.
#[test]
fn clean_sweep_all_builtins_all_passes() {
    for wl in tcpa_energy::workloads::all() {
        for phase in &wl.phases {
            let shape: Vec<i64> = match phase.ndims {
                2 => vec![2, 2],
                3 => vec![2, 2, 1],
                n => vec![2; n],
            };
            let opts = LintOptions {
                array: Some(shape.clone()),
                ..LintOptions::default()
            };
            let rep = lint_pra(phase, &opts);
            assert!(
                rep.passes.iter().all(|p| p.ran),
                "{} / {}: every pass must run, got {:?}",
                wl.name,
                phase.name,
                rep.passes
            );
            assert!(
                !rep.has_deny(),
                "{} / {} at {shape:?} must be deny-clean:\n{}",
                wl.name,
                phase.name,
                rep.render()
            );
        }
    }
}

/// Without a mapping, builtins are fully clean — not even warnings.
#[test]
fn clean_sweep_without_mapping_is_warning_free() {
    for wl in tcpa_energy::workloads::all() {
        for rep in
            tcpa_energy::lint::lint_workload(&wl, &LintOptions::default())
        {
            assert!(
                rep.is_clean(true),
                "{}:\n{}",
                rep.pra,
                rep.render()
            );
        }
    }
}
