//! Differential oracle suite for `dse --strategy beam`.
//!
//! The exhaustive enumeration is the ground truth; the beam is a
//! heuristic that must (a) reproduce the oracle **point-for-point**
//! whenever its budget covers the reachable space, (b) never lose the
//! energy optimum and stay within a bounded knee regret under tight
//! budgets, and (c) be bit-for-bit deterministic regardless of worker
//! count or repetition. All three properties are pinned here on every
//! builtin workload over small spaces — the same differential
//! discipline the resume suite applies to journals.

use tcpa_energy::dse::{
    explore, DesignSpace, ExploreConfig, ExploreResult, PhasePolicy,
    Strategy,
};
use tcpa_energy::workloads;

/// A small space every builtin fits: 2-D shapes up to 4 PEs, one
/// bounds vector (padded per phase by the CLI convention).
fn small_space() -> DesignSpace {
    DesignSpace::new().with_arrays_2d(4).with_bounds(vec![8, 8])
}

/// Stable identity of a result, excluding the timing-volatile fields
/// (`analysis_ms`, `cache_hit`): every point's full configuration and
/// exact objective bits, plus the frontier/knee structure.
fn fingerprint(res: &ExploreResult) -> Vec<String> {
    let mut out: Vec<String> = res
        .points
        .iter()
        .map(|p| {
            format!(
                "{:?} {:?}",
                p.point,
                p.objectives().to_array().map(f64::to_bits)
            )
        })
        .collect();
    out.push(format!(
        "frontier {:?} knee {:?} groups {}",
        res.frontier,
        res.knee,
        res.groups.len()
    ));
    out
}

#[test]
fn full_budget_beam_matches_the_exhaustive_oracle_on_every_builtin() {
    for wl in workloads::all() {
        for per_phase in [false, true] {
            let policy = if per_phase {
                PhasePolicy::PerPhase
            } else {
                PhasePolicy::Uniform
            };
            let base = small_space().with_phase_shapes(policy);
            let oracle = explore(&wl, &base, &ExploreConfig::serial());
            let beam = explore(
                &wl,
                &base
                    .clone()
                    .with_strategy(Strategy::beam_with_budget(4, 1 << 20)),
                &ExploreConfig::serial(),
            );
            assert_eq!(
                fingerprint(&beam),
                fingerprint(&oracle),
                "{} (per_phase={per_phase}): a beam whose budget covers \
                 the whole space must equal the exhaustive oracle \
                 point-for-point",
                wl.name
            );
        }
    }
}

#[test]
fn full_budget_beam_matches_the_oracle_under_symmetry_pruning() {
    // The beam canonicalizes transposition-symmetric states; the
    // quotient walk must still reproduce the pruned oracle exactly.
    for name in ["gesummv", "atax", "gemver"] {
        let wl = workloads::by_name(name).unwrap();
        for per_phase in [false, true] {
            let policy = if per_phase {
                PhasePolicy::PerPhase
            } else {
                PhasePolicy::Uniform
            };
            let base = small_space()
                .with_phase_shapes(policy)
                .with_symmetry_pruning();
            let oracle = explore(&wl, &base, &ExploreConfig::serial());
            let beam = explore(
                &wl,
                &base
                    .clone()
                    .with_strategy(Strategy::beam_with_budget(4, 1 << 20)),
                &ExploreConfig::serial(),
            );
            assert_eq!(
                fingerprint(&beam),
                fingerprint(&oracle),
                "{name} (per_phase={per_phase}, pruned): beam must \
                 equal the symmetric-pruned oracle"
            );
        }
    }
}

#[test]
fn tight_budget_beam_pins_the_energy_minimum_and_bounds_knee_regret() {
    // gemver, per-phase: 8 shapes ^ 3 phases = 512 combinations; the
    // budget below visits well under half of them.
    let wl = workloads::by_name("gemver").unwrap();
    let base = DesignSpace::new()
        .with_arrays_2d(4)
        .with_bounds(vec![12, 12])
        .with_phase_shapes(PhasePolicy::PerPhase);
    let oracle = explore(&wl, &base, &ExploreConfig::serial());
    let beam = explore(
        &wl,
        &base.clone().with_strategy(Strategy::beam_with_budget(8, 160)),
        &ExploreConfig::serial(),
    );
    assert!(
        beam.points.len() < oracle.points.len(),
        "the tight budget must actually prune ({} of {})",
        beam.points.len(),
        oracle.points.len()
    );
    let min_e = |r: &ExploreResult| {
        r.points
            .iter()
            .map(|p| p.energy_pj)
            .fold(f64::INFINITY, f64::min)
    };
    // Phase energies are separable, so the per-phase argmin vector is
    // the exact global energy optimum — and the beam seeds it: the
    // heuristic can never lose the energy-optimal point, however
    // tight the budget.
    assert_eq!(
        min_e(&beam).to_bits(),
        min_e(&oracle).to_bits(),
        "the seeded energy argmin must survive any budget"
    );
    // Knee regret: the beam's knee stays within 5% energy of the
    // oracle's knee (the acceptance bound for heuristic sweeps).
    let knee_e = |r: &ExploreResult| {
        r.points[r.knee.expect("single-scenario knee")].energy_pj
    };
    assert!(
        knee_e(&beam) <= 1.05 * knee_e(&oracle),
        "beam knee {} pJ vs oracle knee {} pJ exceeds the 1.05x \
         regret bound",
        knee_e(&beam),
        knee_e(&oracle)
    );
}

#[test]
fn tight_budget_beam_is_deterministic_across_workers_and_repeats() {
    // Way under full coverage, so the beam genuinely chooses what to
    // visit — and must choose identically every time, at any worker
    // count (the walk itself is serial and cache-seeded; workers only
    // re-evaluate the emitted points).
    let wl = workloads::by_name("gemver").unwrap();
    let space = DesignSpace::new()
        .with_arrays_2d(4)
        .with_bounds(vec![8, 8])
        .with_phase_shapes(PhasePolicy::PerPhase)
        .with_strategy(Strategy::beam_with_budget(2, 24));
    let runs: Vec<Vec<String>> = [1usize, 4, 1, 4]
        .iter()
        .map(|&w| {
            fingerprint(&explore(&wl, &space, &ExploreConfig {
                workers: w,
            }))
        })
        .collect();
    for r in &runs[1..] {
        assert_eq!(
            &runs[0], r,
            "a tight beam may miss points, but must miss the same \
             points every run, at any worker count"
        );
    }
}
