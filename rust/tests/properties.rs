//! Randomized property tests (proptest_lite) over the core invariants:
//!
//! * the symbolic counter equals the concrete counter equals brute-force
//!   enumeration on randomized tiled spaces and parameters;
//! * guard/chamber algebra invariants (negation complement, feasibility
//!   monotonicity);
//! * coordinator invariants: schedule causality holds wherever volumes are
//!   non-zero; energy decomposes over statements; analysis evaluation is
//!   deterministic.

use tcpa_energy::analysis::SymbolicAnalysis;
use tcpa_energy::polyhedral::{
    count_bruteforce, count_concrete, count_symbolic, AffineExpr, Constraint,
    Guard, ParamSpace, SymbolicOptions, TiledSet,
};
use tcpa_energy::proptest_lite::{check, Rng};
use tcpa_energy::schedule::find_schedule;
use tcpa_energy::tiling::{tile_pra, ArrayMapping};
use tcpa_energy::workloads;

/// Build a randomized 2-D tiled space: base space plus a random shifted
/// membership and/or a random global condition.
fn random_space(rng: &mut Rng, t: &[i64]) -> TiledSet {
    let sp = ParamSpace::loop_nest(2);
    let np = sp.len();
    let p_idx = [sp.p_index(0), sp.p_index(1)];
    let mut set = TiledSet::universe(2, np);
    for l in 0..2 {
        set.add_tile_bounds(l, p_idx[l]);
        set.add_array_bounds(l, t[l]);
        let mut a = [0i64; 2];
        a[l] = 1;
        set.add_global_affine(&a, AffineExpr::zero(np), &p_idx);
        let mut an = [0i64; 2];
        an[l] = -1;
        set.add_global_affine(
            &an,
            AffineExpr::param(np, sp.n_index(l)).plus(-1),
            &p_idx,
        );
    }
    // Random extras.
    if rng.i64_in(0, 1) == 1 {
        // condition i_l >= c
        let l = rng.i64_in(0, 1) as usize;
        let c = rng.i64_in(0, 2);
        let mut a = [0i64; 2];
        a[l] = 1;
        set.add_global_affine(&a, AffineExpr::constant(np, -c), &p_idx);
    }
    if rng.i64_in(0, 1) == 1 {
        // shifted membership j_l - (d + γ p_l) ∈ J
        let l = rng.i64_in(0, 1) as usize;
        let d = rng.i64_in(-1, 1);
        let gamma = if d > 0 {
            -rng.i64_in(0, 1)
        } else if d < 0 {
            rng.i64_in(0, 1)
        } else {
            0
        };
        let off = AffineExpr::param_scaled(np, p_idx[l], gamma, d);
        set.add_shifted_tile_membership(l, off, p_idx[l]);
    }
    set
}

fn context2() -> Guard {
    let sp = ParamSpace::loop_nest(2);
    let np = sp.len();
    let one = AffineExpr::constant(np, 1);
    let mut cs = Vec::new();
    for l in 0..2 {
        let n = AffineExpr::param(np, sp.n_index(l));
        let p = AffineExpr::param(np, sp.p_index(l));
        cs.push(Constraint::ge(&n, &one));
        cs.push(Constraint::ge(&p, &one));
        cs.push(Constraint::le(&p, &n));
    }
    Guard::new(cs)
}

#[test]
fn prop_symbolic_equals_concrete_equals_bruteforce() {
    let ctx = context2();
    check(
        "count-agreement",
        0xC0FFEE,
        60,
        |rng| {
            let t = vec![rng.i64_in(1, 3), rng.i64_in(1, 3)];
            let set = random_space(rng, &t);
            let n0 = rng.i64_in(1, 7);
            let n1 = rng.i64_in(1, 7);
            let p0 = rng.i64_in(1, n0);
            let p1 = rng.i64_in(1, n1);
            (t, set, [n0, n1, p0, p1])
        },
        |(t, set, params)| {
            let sym = count_symbolic(set, t, &ctx, &SymbolicOptions::default());
            let s = sym.eval(params);
            let c = count_concrete(set, t, params);
            let b = count_bruteforce(set, t, params);
            if s != c || c != b {
                return Err(format!("symbolic {s}, concrete {c}, brute {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_constraint_negation_is_complement() {
    check(
        "negation-complement",
        7,
        200,
        |rng| {
            let coeffs = vec![
                rng.i64_in(-3, 3),
                rng.i64_in(-3, 3),
                rng.i64_in(-3, 3),
                rng.i64_in(-3, 3),
            ];
            let konst = rng.i64_in(-5, 5);
            let point = vec![
                rng.i64_in(-4, 4),
                rng.i64_in(-4, 4),
                rng.i64_in(-4, 4),
                rng.i64_in(-4, 4),
            ];
            (AffineExpr { coeffs, konst }, point)
        },
        |(expr, point)| {
            let c = Constraint::ge0(expr.clone());
            let n = c.negated();
            if c.holds(point) == n.holds(point) {
                return Err(format!(
                    "c and ¬c agree at {point:?}: {} {}",
                    c.holds(point),
                    n.holds(point)
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_guard_and_is_intersection() {
    check(
        "guard-and",
        99,
        150,
        |rng| {
            let mk = |rng: &mut Rng| AffineExpr {
                coeffs: vec![
                    rng.i64_in(-2, 2),
                    rng.i64_in(-2, 2),
                    rng.i64_in(-2, 2),
                    rng.i64_in(-2, 2),
                ],
                konst: rng.i64_in(-4, 4),
            };
            let a = Constraint::ge0(mk(rng));
            let b = Constraint::ge0(mk(rng));
            let point = vec![
                rng.i64_in(-4, 4),
                rng.i64_in(-4, 4),
                rng.i64_in(-4, 4),
                rng.i64_in(-4, 4),
            ];
            (a, b, point)
        },
        |(a, b, point)| {
            let g = Guard::new(vec![a.clone()]).and(b.clone());
            let expect = a.holds(point) && b.holds(point);
            if g.holds(point) != expect {
                return Err("conjunction semantics broken".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_schedule_causality_where_volumes_nonzero() {
    // For random workloads / arrays / sizes: the found schedule satisfies
    // every causality constraint whose variant actually executes.
    let wls = workloads::all();
    check(
        "schedule-causality",
        0xBADC0DE,
        40,
        |rng| {
            let wl = rng.choose(&wls).clone();
            let pi = rng.i64_in(1, 3);
            let n0 = rng.i64_in(2, 10);
            let n1 = rng.i64_in(3, 10);
            let t0 = rng.i64_in(1, 3);
            let t1 = rng.i64_in(1, 3);
            (wl, pi, n0, n1, t0, t1)
        },
        |(wl, pi, n0, n1, t0, t1)| {
            for phase in &wl.phases {
                let mut t = vec![*t0, *t1];
                while t.len() < phase.ndims {
                    t.push(1);
                }
                t.truncate(phase.ndims);
                let mapping = ArrayMapping::new(t);
                let tiled = tile_pra(phase, &mapping);
                let schedule = find_schedule(&tiled, *pi)
                    .map_err(|e| format!("{}: {e}", phase.name))?;
                let mut bounds = vec![*n0, *n1];
                while bounds.len() < phase.ndims {
                    bounds.push(*n1);
                }
                bounds.truncate(phase.ndims);
                // square-only workloads
                if matches!(wl.name.as_str(), "mvt" | "syrk") {
                    let m = bounds[0].max(bounds[1]);
                    bounds[0] = m;
                    bounds[1] = m;
                }
                let params = mapping.params_for(&bounds);
                let v = schedule.verify(&tiled, &params);
                if !v.is_empty() {
                    return Err(format!("{}: {v:?}", phase.name));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_energy_decomposes_over_statements() {
    // E_tot == Σ_q Vol_q · E_q for random configurations (Eq. 11 as an
    // invariant of the evaluator).
    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    check(
        "energy-decomposition",
        0xE4E,
        30,
        |rng| {
            let t0 = rng.i64_in(1, 4);
            let t1 = rng.i64_in(1, 4);
            let n0 = rng.i64_in(2, 20);
            let n1 = rng.i64_in(2, 20);
            (t0, t1, n0, n1)
        },
        |&(t0, t1, n0, n1)| {
            let mapping = ArrayMapping::new(vec![t0, t1]);
            let ana = SymbolicAnalysis::analyze(phase, &mapping);
            let params = mapping.params_for(&[n0, n1]);
            let total = ana.energy_at(&params).total;
            let manual: f64 = ana
                .statements
                .iter()
                .map(|s| {
                    s.volume.eval(&params) as f64 * s.profile.energy(&ana.table)
                })
                .sum();
            if (total - manual).abs() > 1e-6 * manual.abs().max(1.0) {
                return Err(format!("E_tot {total} != Σ {manual}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_evaluation_deterministic() {
    let wl = workloads::by_name("bicg").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![3, 2]);
    let ana = SymbolicAnalysis::analyze(phase, &mapping);
    let ana2 = SymbolicAnalysis::analyze(phase, &mapping);
    check(
        "evaluation-deterministic",
        5,
        50,
        |rng| {
            let n0 = rng.i64_in(3, 30);
            let n1 = rng.i64_in(2, 30);
            mapping.params_for(&[n0, n1])
        },
        |params| {
            let a = ana.counts_at(params);
            let b = ana2.counts_at(params);
            if a != b {
                return Err("two analyses disagree".into());
            }
            if ana.energy_at(params).total != ana.energy_at(params).total {
                return Err("re-evaluation differs".into());
            }
            Ok(())
        },
    );
}
