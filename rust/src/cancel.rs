//! Cooperative cancellation for long-running sweeps.
//!
//! A [`CancelToken`] is a cloneable handle shared between the party
//! that requests a stop (CLI signal handler, deadline watchdog, a
//! future `dse serve` request scope) and the workers that honor it.
//! Cancellation is *cooperative*: nothing is killed, workers observe
//! [`CancelToken::cancelled`] between design points and inside the
//! Fourier–Motzkin feasibility loop, finish or abandon the point at
//! hand, and drain.
//!
//! Three sources can trip a token, and the *first* one wins (a
//! deadline expiring while a SIGINT drain is in progress must not
//! relabel the interrupt):
//!
//! - [`CancelToken::cancel`] / [`CancelToken::cancel_with`] —
//!   programmatic (tests, fault injection, a serving layer).
//! - [`CancelToken::set_deadline_in`] — a wall-clock budget
//!   (`dse --deadline SECS`), checked lazily on every
//!   [`CancelToken::cancelled`] call.
//! - [`CancelToken::watch_sigint`] — Ctrl-C. The handler only sets an
//!   atomic flag (async-signal-safe); a second Ctrl-C exits
//!   immediately with the conventional `130` for users who insist.
//!
//! The token lives at the crate root (not under `dse`) because the
//! polyhedral core honors it too — `polyhedral::symbolic` checks a
//! thread-local guard seeded from this token so a pathological FM
//! blow-up cannot wedge a worker past its per-point timeout.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// Why a run stopped early. Ordered by precedence of *arrival*, not
/// severity: whichever source trips the token first is the reason the
/// partial report carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelReason {
    /// `cancel()` was called programmatically.
    Explicit,
    /// The wall-clock budget (`--deadline`) expired.
    Deadline,
    /// SIGINT (Ctrl-C) was received.
    Interrupt,
}

impl CancelReason {
    /// Human-readable label used in partial-frontier reports and the
    /// CLI summary line.
    pub fn label(self) -> &'static str {
        match self {
            CancelReason::Explicit => "cancelled",
            CancelReason::Deadline => "deadline exceeded",
            CancelReason::Interrupt => "interrupted (SIGINT)",
        }
    }

    fn code(self) -> u8 {
        match self {
            CancelReason::Explicit => 1,
            CancelReason::Deadline => 2,
            CancelReason::Interrupt => 3,
        }
    }

    fn from_code(code: u8) -> Option<Self> {
        match code {
            1 => Some(CancelReason::Explicit),
            2 => Some(CancelReason::Deadline),
            3 => Some(CancelReason::Interrupt),
            _ => None,
        }
    }
}

#[derive(Debug, Default)]
struct Inner {
    /// The cancelled bit; once set it never clears.
    flag: AtomicBool,
    /// `CancelReason::code`, 0 while untripped. First writer wins via
    /// compare-exchange.
    reason: AtomicU8,
    /// Wall-clock budget; set at most once.
    deadline: OnceLock<Instant>,
    /// Whether `cancelled()` should consult the process-wide SIGINT
    /// flag. Opt-in so library embedders are unaffected.
    watch_sigint: AtomicBool,
}

/// Cloneable cooperative-cancellation handle; all clones share state.
/// `Default` yields a token that never trips on its own.
#[derive(Debug, Clone, Default)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl CancelToken {
    /// A fresh, untripped token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Trip the token programmatically ([`CancelReason::Explicit`]).
    pub fn cancel(&self) {
        self.cancel_with(CancelReason::Explicit);
    }

    /// Trip the token with an explicit reason. The first reason to
    /// arrive sticks; later calls only (re)assert the flag.
    pub fn cancel_with(&self, reason: CancelReason) {
        let _ = self.inner.reason.compare_exchange(
            0,
            reason.code(),
            Ordering::AcqRel,
            Ordering::Acquire,
        );
        self.inner.flag.store(true, Ordering::Release);
    }

    /// Arm a wall-clock budget: `cancelled()` reports
    /// [`CancelReason::Deadline`] once `timeout` has elapsed from now.
    /// Only the first call takes effect.
    pub fn set_deadline_in(&self, timeout: Duration) {
        let _ = self.inner.deadline.set(Instant::now() + timeout);
    }

    /// The absolute deadline, if one was armed.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.deadline.get().copied()
    }

    /// Install the process-wide SIGINT handler (idempotent) and make
    /// this token observe it.
    pub fn watch_sigint(&self) {
        sigint::install();
        self.inner.watch_sigint.store(true, Ordering::Release);
    }

    /// Has the token tripped? Checks the explicit flag, then lazily
    /// consults the SIGINT flag and the armed deadline, latching
    /// whichever fired so every later call agrees on the reason.
    pub fn cancelled(&self) -> Option<CancelReason> {
        if !self.inner.flag.load(Ordering::Acquire) {
            if self.inner.watch_sigint.load(Ordering::Acquire)
                && sigint::seen()
            {
                self.cancel_with(CancelReason::Interrupt);
            } else if let Some(&at) = self.inner.deadline.get() {
                if Instant::now() >= at {
                    self.cancel_with(CancelReason::Deadline);
                }
            }
        }
        if self.inner.flag.load(Ordering::Acquire) {
            CancelReason::from_code(self.inner.reason.load(Ordering::Acquire))
        } else {
            None
        }
    }

    /// `cancelled().is_some()` without the reason.
    pub fn is_cancelled(&self) -> bool {
        self.cancelled().is_some()
    }

    /// Flag-only fast path: has some party already *latched* the
    /// token? Unlike [`CancelToken::cancelled`] this never consults
    /// the clock or the SIGINT flag — it is a single relaxed atomic
    /// load, cheap enough for the innermost Fourier–Motzkin loop to
    /// call on every iteration (with the full check amortized to every
    /// Nth call; see `polyhedral::symbolic::check_point_guard`).
    pub fn tripped(&self) -> bool {
        self.inner.flag.load(Ordering::Relaxed)
    }
}

#[cfg(unix)]
mod sigint {
    //! Dependency-free SIGINT latch: `libc::signal` declared by hand
    //! (the vendor tree is empty), handler body restricted to
    //! async-signal-safe operations (one atomic swap, `_exit`).

    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Once;

    static SEEN: AtomicBool = AtomicBool::new(false);
    static INSTALL: Once = Once::new();

    const SIGINT: i32 = 2;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        fn _exit(code: i32) -> !;
    }

    extern "C" fn on_sigint(_sig: i32) {
        if SEEN.swap(true, Ordering::SeqCst) {
            // Second Ctrl-C: the user insists; conventional 128+2.
            unsafe { _exit(130) };
        }
    }

    pub fn install() {
        INSTALL.call_once(|| unsafe {
            signal(SIGINT, on_sigint);
        });
    }

    pub fn seen() -> bool {
        SEEN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    //! No-op fallback: tokens still honor explicit cancellation and
    //! deadlines; Ctrl-C falls back to the platform default.
    pub fn install() {}
    pub fn seen() -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_token_never_trips() {
        let t = CancelToken::new();
        assert_eq!(t.cancelled(), None);
        assert!(!t.is_cancelled());
        assert!(!t.tripped());
    }

    #[test]
    fn clones_share_state_and_first_reason_wins() {
        let t = CancelToken::new();
        let u = t.clone();
        u.cancel_with(CancelReason::Deadline);
        assert!(t.tripped(), "flag-only fast path sees the latch");
        assert_eq!(t.cancelled(), Some(CancelReason::Deadline));
        // A later, different reason does not overwrite the first.
        t.cancel_with(CancelReason::Interrupt);
        assert_eq!(t.cancelled(), Some(CancelReason::Deadline));
        assert_eq!(u.cancelled(), Some(CancelReason::Deadline));
    }

    #[test]
    fn expired_deadline_trips_with_deadline_reason() {
        let t = CancelToken::new();
        t.set_deadline_in(Duration::ZERO);
        assert_eq!(t.cancelled(), Some(CancelReason::Deadline));
        assert!(t.deadline().is_some());
    }

    #[test]
    fn future_deadline_does_not_trip_early() {
        let t = CancelToken::new();
        t.set_deadline_in(Duration::from_secs(3600));
        assert_eq!(t.cancelled(), None);
        // Only the first deadline call takes effect.
        t.set_deadline_in(Duration::ZERO);
        assert_eq!(t.cancelled(), None);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(CancelReason::Explicit.label(), "cancelled");
        assert_eq!(CancelReason::Deadline.label(), "deadline exceeded");
        assert_eq!(
            CancelReason::Interrupt.label(),
            "interrupted (SIGINT)"
        );
    }
}
