//! Human-readable reports of a symbolic analysis: per-statement symbolic
//! volumes in the paper's Example-9 case style, per-statement energies, and
//! the schedule vectors.

use std::fmt::Write as _;

use super::SymbolicAnalysis;

impl SymbolicAnalysis {
    /// Render the full symbolic analysis: statement table with volumes as
    /// disjoint case expressions (where tractable) and per-execution
    /// energies.
    pub fn report(&self) -> String {
        let sp = &self.tiled.pra.space;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "# Symbolic energy analysis: {} on {:?} array",
            self.tiled.pra.name, self.tiled.mapping.t
        );
        let _ = writeln!(
            out,
            "\nparameters: {:?}\ncontext: {}\n",
            sp.names(),
            self.tiled.context.display(sp)
        );
        let _ = writeln!(out, "## Schedule (π = {})", self.schedule.pi);
        let _ = writeln!(
            out,
            "intra-tile order (fastest first): {:?}",
            self.schedule.perm
        );
        for (l, lj) in self.schedule.lambda_j.iter().enumerate() {
            let _ = writeln!(out, "  λJ[{l}] = {}", lj.display(sp));
        }
        for (l, cands) in self.schedule.lambda_k.iter().enumerate() {
            let s: Vec<String> =
                cands.iter().map(|c| format!("{}", c.display(sp))).collect();
            let _ = writeln!(out, "  λK[{l}] = max(0, {})", s.join(", "));
        }
        let _ = writeln!(out, "  L_c = {}", self.schedule.lc);
        let _ = writeln!(out, "\n## Statements");
        for s in &self.statements {
            let kind = if s.profile.op.is_copy() { "mem " } else { "comp" };
            let e = s.profile.energy(&self.table);
            let _ = writeln!(
                out,
                "\n### {} [{kind}] E/exec = {:.2} pJ  reads={:?} write={:?}",
                s.name, e, s.profile.reads, s.profile.write
            );
            match s.volume.disjointify(&self.tiled.context, 64) {
                Some(pw) if pw.len() <= 12 => {
                    let _ = writeln!(out, "Vol = {}", pw.display(sp));
                }
                _ => {
                    let _ = writeln!(
                        out,
                        "Vol = Σ of {} guarded pieces (case form too large \
                         to print)",
                        s.volume.pieces.len()
                    );
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use crate::analysis::SymbolicAnalysis;
    use crate::tiling::ArrayMapping;
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn report_renders_paper_artifacts() {
        let ana = SymbolicAnalysis::analyze(
            &gesummv(),
            &ArrayMapping::new(vec![2, 2]),
        );
        let rep = ana.report();
        assert!(rep.contains("gesummv"));
        assert!(rep.contains("S7*1") || rep.contains("S7*2"));
        assert!(rep.contains("0.47 pJ")); // Example 9 FD+RD energy
        assert!(rep.contains("0.36 pJ")); // Example 9 ID+RD energy
        assert!(rep.contains("L_c = 4")); // Example 3
    }
}
