//! The paper's contribution: end-to-end **symbolic energy analysis** of a
//! loop nest mapped onto a processor array (§IV).
//!
//! [`SymbolicAnalysis::analyze`] runs *once* per (PRA, array mapping):
//! tiling (Eq. 5–7), scheduling (§III-D), access classification (Eq. 9/10)
//! and symbolic volume computation (Eq. 12/13) — producing, for every
//! tiled statement variant, a parametric piecewise-polynomial volume and a
//! constant per-execution energy. Evaluating total energy (Eq. 11), access
//! counts, or latency (Eq. 8) at concrete loop bounds is then just
//! plugging numbers into the stored expressions — the O(1)-per-query
//! scalability the paper demonstrates in Fig. 4.

pub mod evaluate;
pub mod report;

pub use evaluate::{
    counts_at_backend_phases, energy_at_backend_phases, latency_at_phases,
    CountsBreakdown, EnergyBreakdown,
};

use std::collections::HashMap;
use std::sync::OnceLock;
use std::time::Instant;

use crate::energy::{AccessProfile, EnergyTable};
use crate::polyhedral::{
    count_symbolic_in, FeasPool, GuardedSum, SymbolicOptions,
};
use crate::pra::{Pra, Workload};
use crate::schedule::{find_schedule, Schedule};
use crate::tiling::{tile_pra, ArrayMapping, TiledPra};

/// Precomputed symbolic volumes keyed by tiled-statement name — the
/// payload the persistent analysis cache (`dse::persist`) restores so a
/// warm start skips the lattice-point counting entirely. Entries that are
/// missing or fail the parameter-count sanity check are recomputed.
pub type PresetVolumes = HashMap<String, GuardedSum>;

/// One analyzed statement variant: symbolic volume + access profile.
#[derive(Debug, Clone)]
pub struct StmtAnalysis {
    /// Display name, e.g. `"S7*2"`.
    pub name: String,
    /// Originating statement name, e.g. `"S7"`.
    pub base_name: String,
    /// Symbolic execution count (piecewise polynomial in `(N, p)`).
    pub volume: GuardedSum,
    /// Per-execution access/energy profile.
    pub profile: AccessProfile,
    /// True for tile-crossing variants.
    pub inter_tile: bool,
}

/// The one-time symbolic analysis of one PRA phase on one array mapping.
#[derive(Debug, Clone)]
pub struct SymbolicAnalysis {
    pub tiled: TiledPra,
    pub schedule: Schedule,
    pub statements: Vec<StmtAnalysis>,
    pub table: EnergyTable,
    /// Wall-clock duration of the symbolic pass (for Fig. 4).
    pub analysis_time: std::time::Duration,
    /// Lazily memoized *full* schedule-candidate enumeration, so a
    /// cached analysis shared across design points (the DSE explorer
    /// holds these behind `Arc`) enumerates once per (workload, shape)
    /// instead of once per bounds/tile/backend variant. Cloning the
    /// analysis clones the memo's current contents.
    schedule_memo: OnceLock<Vec<Schedule>>,
    /// Lazily memoized symbolic causality proof of the embedded default
    /// schedule ([`Schedule::verify_symbolic`]; empty = proved for all
    /// parameter values). Untrusted-input paths (`--workload-file`)
    /// consult this before trusting a mapping; builtins skip it.
    default_proof_memo: OnceLock<Vec<String>>,
    /// Lazily memoized causality proofs for *every* enumerated schedule
    /// candidate, index-aligned with the full
    /// [`Self::enumerate_schedules`] list.
    candidate_proof_memo: OnceLock<Vec<Vec<String>>>,
}

impl SymbolicAnalysis {
    /// Run the one-time symbolic pass.
    pub fn analyze(pra: &Pra, mapping: &ArrayMapping) -> Self {
        Self::analyze_with(pra, mapping, &EnergyTable::default(), 1)
    }

    /// As [`Self::analyze`] with an explicit energy table and initiation
    /// interval (private single-use feasibility pool).
    pub fn analyze_with(
        pra: &Pra,
        mapping: &ArrayMapping,
        table: &EnergyTable,
        pi: i64,
    ) -> Self {
        Self::analyze_in(pra, mapping, table, pi, &FeasPool::new(), None)
    }

    /// The full-control entry point: `feas` shares one Fourier–Motzkin
    /// memo table per parameter context across every statement of this
    /// analysis — and, when the caller passes a long-lived pool (the DSE
    /// cache does), across analyses and design points. `preset` supplies
    /// previously computed volumes by statement name; missing entries
    /// (or entries whose parameter count disagrees) are recomputed.
    ///
    /// The *only* validation applied to a preset entry is the parameter
    /// count — every array shape of one workload shares it, so a volume
    /// computed for a different mapping would be accepted silently. The
    /// caller owns the cache-key discipline: presets must come from an
    /// analysis of the *same* `(pra, mapping)` pair (the persistent
    /// `dse::persist::DiskCache` keys its files by exactly that).
    pub fn analyze_in(
        pra: &Pra,
        mapping: &ArrayMapping,
        table: &EnergyTable,
        pi: i64,
        feas: &FeasPool,
        preset: Option<&PresetVolumes>,
    ) -> Self {
        let start = Instant::now();
        let tiled = tile_pra(pra, mapping);
        let schedule = find_schedule(&tiled, pi)
            .expect("no feasible LSGP schedule for this PRA");
        let opts = SymbolicOptions::default();
        let ctx = feas.ctx_for(&tiled.context);
        let statements: Vec<StmtAnalysis> = tiled
            .statements
            .iter()
            .map(|ts| {
                let volume = preset
                    .and_then(|m| m.get(&ts.name))
                    .filter(|v| v.nparams() == ts.space.nparams)
                    .cloned()
                    .unwrap_or_else(|| {
                        count_symbolic_in(&ts.space, &mapping.t, &ctx, &opts)
                    });
                let profile =
                    AccessProfile::of(&pra.statements[ts.stmt_index], ts);
                StmtAnalysis {
                    name: ts.name.clone(),
                    base_name: ts.base_name.clone(),
                    volume,
                    profile,
                    inter_tile: ts.is_inter_tile(),
                }
            })
            .collect();
        SymbolicAnalysis {
            tiled,
            schedule,
            statements,
            table: table.clone(),
            analysis_time: start.elapsed(),
            schedule_memo: OnceLock::new(),
            default_proof_memo: OnceLock::new(),
            candidate_proof_memo: OnceLock::new(),
        }
    }

    /// The concrete parameter vector `(N…, p…)` for loop bounds `n` under
    /// the exact-cover sizing rule `p_ℓ = ⌈N_ℓ/t_ℓ⌉`.
    pub fn params_for(&self, n: &[i64]) -> Vec<i64> {
        self.tiled.mapping.params_for(n)
    }

    /// All feasible schedule candidates of this analysis' tiled mapping
    /// at its initiation interval, capped at `limit` (`None` = all).
    /// Candidate 0 is always [`Self::analyze`]'s embedded default
    /// ([`crate::schedule::find_schedule`]'s pick); the symbolic volumes
    /// — and therefore counts and energies — are shared by every
    /// candidate, only latency varies
    /// ([`SymbolicAnalysis::latency_at_with`]).
    ///
    /// The full enumeration is memoized alongside the analysis (the
    /// candidate set depends only on the tiled mapping and π, both fixed
    /// here), so DSE sweeps that revisit one cached analysis across many
    /// bounds/tile/backend variants enumerate once per (workload, shape);
    /// a `limit` merely slices the memoized list — enumeration order is
    /// deterministic, so the prefix equals a capped enumeration.
    pub fn enumerate_schedules(&self, limit: Option<usize>) -> Vec<Schedule> {
        let all = self.schedule_memo.get_or_init(|| {
            crate::schedule::enumerate_schedules(
                &self.tiled,
                self.schedule.pi,
                None,
            )
        });
        match limit {
            Some(n) => all.iter().take(n).cloned().collect(),
            None => all.clone(),
        }
    }

    /// Has [`Self::enumerate_schedules`] populated its memo yet? (Test
    /// and diagnostics hook — the memo itself is an implementation
    /// detail.)
    pub fn schedules_memoized(&self) -> bool {
        self.schedule_memo.get().is_some()
    }

    /// Symbolic causality proof of the embedded default schedule:
    /// empty = proved for all parameter values, otherwise the list of
    /// unprovable constraints ([`Schedule::verify_symbolic`]). Memoized
    /// alongside the analysis, so a cached analysis shared across
    /// design points proves its default schedule once.
    pub fn verify_default_schedule(&self) -> &[String] {
        self.default_proof_memo
            .get_or_init(|| self.schedule.verify_symbolic(&self.tiled))
    }

    /// Causality proofs for every enumerated schedule candidate,
    /// index-aligned with the full (uncapped)
    /// [`Self::enumerate_schedules`] list; an empty inner list means
    /// that candidate is proved for all parameter values. A capped
    /// enumeration is a prefix of the memo, so callers index by
    /// candidate position.
    pub fn verify_enumerated_schedules(&self) -> &[Vec<String>] {
        self.candidate_proof_memo.get_or_init(|| {
            self.enumerate_schedules(None)
                .iter()
                .map(|s| s.verify_symbolic(&self.tiled))
                .collect()
        })
    }
}

/// Multi-phase workload analysis: one [`SymbolicAnalysis`] per phase.
#[derive(Debug, Clone)]
pub struct WorkloadAnalysis {
    pub name: String,
    pub phases: Vec<SymbolicAnalysis>,
}

impl WorkloadAnalysis {
    /// Analyze all phases of a workload on per-phase array mappings.
    pub fn analyze(wl: &Workload, mappings: &[ArrayMapping]) -> Self {
        Self::analyze_pooled(wl, mappings, &FeasPool::new(), None)
    }

    /// As [`Self::analyze`] with a shared feasibility pool and optional
    /// per-phase preset volumes (indexed like `wl.phases`).
    pub fn analyze_pooled(
        wl: &Workload,
        mappings: &[ArrayMapping],
        feas: &FeasPool,
        preset: Option<&[PresetVolumes]>,
    ) -> Self {
        assert_eq!(wl.phases.len(), mappings.len());
        if let Some(pre) = preset {
            assert_eq!(pre.len(), wl.phases.len());
        }
        WorkloadAnalysis {
            name: wl.name.clone(),
            phases: wl
                .phases
                .iter()
                .zip(mappings)
                .enumerate()
                .map(|(i, (p, m))| {
                    SymbolicAnalysis::analyze_in(
                        p,
                        m,
                        &EnergyTable::default(),
                        1,
                        feas,
                        preset.map(|pre| &pre[i]),
                    )
                })
                .collect(),
        }
    }

    /// Analyze with the same array shape for every phase (extended by
    /// `t = 1` on unmapped dimensions of deeper nests).
    pub fn analyze_uniform(wl: &Workload, array: &[i64]) -> Self {
        Self::analyze_uniform_in(wl, array, &FeasPool::new(), None)
    }

    /// As [`Self::analyze_uniform`] with a shared feasibility pool and
    /// optional preset volumes — the DSE cache's entry point.
    pub fn analyze_uniform_in(
        wl: &Workload,
        array: &[i64],
        feas: &FeasPool,
        preset: Option<&[PresetVolumes]>,
    ) -> Self {
        let mappings: Vec<ArrayMapping> = wl
            .phases
            .iter()
            .map(|p| ArrayMapping::new(crate::tiling::pad_array(array, p.ndims)))
            .collect();
        Self::analyze_pooled(wl, &mappings, feas, preset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn example9_contribution_7_08_pj() {
        // Paper Example 9: Vol(S7*1)·E + Vol(S7*2)·E = 12·0.47 + 4·0.36
        // = 7.08 pJ at N=(4,5), p=(2,3) on a 2×2 array.
        let ana = SymbolicAnalysis::analyze(
            &gesummv(),
            &ArrayMapping::new(vec![2, 2]),
        );
        let params = [4i64, 5, 2, 3];
        let s7: Vec<&StmtAnalysis> = ana
            .statements
            .iter()
            .filter(|s| s.base_name == "S7")
            .collect();
        assert_eq!(s7.len(), 2);
        let contribution: f64 = s7
            .iter()
            .map(|s| {
                s.volume.eval(&params) as f64 * s.profile.energy(&ana.table)
            })
            .sum();
        assert!(
            (contribution - 7.08).abs() < 1e-9,
            "S7 contribution = {contribution}"
        );
    }

    #[test]
    fn schedule_enumeration_is_memoized_and_cap_slices_the_memo() {
        let ana = SymbolicAnalysis::analyze(
            &gesummv(),
            &ArrayMapping::new(vec![1, 4]),
        );
        assert!(!ana.schedules_memoized());
        // A capped request still fills the full memo (enumeration is
        // cheap, bounded by ndims! permutations) and returns its prefix.
        let one = ana.enumerate_schedules(Some(1));
        assert_eq!(one.len(), 1);
        assert!(ana.schedules_memoized());
        let all = ana.enumerate_schedules(None);
        assert!(all.len() >= 2, "1×4 GESUMMV has two causal orders");
        // Memoized results equal a fresh enumeration, candidate by
        // candidate (permutation identity is what distinguishes them).
        let fresh = crate::schedule::enumerate_schedules(
            &ana.tiled,
            ana.schedule.pi,
            None,
        );
        assert_eq!(all.len(), fresh.len());
        for (a, b) in all.iter().zip(&fresh) {
            assert_eq!(a.perm, b.perm);
            assert_eq!(a.lc, b.lc);
        }
        assert_eq!(one[0].perm, all[0].perm, "cap = prefix of the memo");
    }

    #[test]
    fn analysis_is_reusable_across_params() {
        // One analysis, many evaluations — the core scalability claim.
        let ana = SymbolicAnalysis::analyze(
            &gesummv(),
            &ArrayMapping::new(vec![2, 2]),
        );
        for h in 1..6 {
            let params = ana.params_for(&[4 * h, 5 * h]);
            let e = ana.energy_at(&params);
            assert!(e.total > 0.0);
        }
    }
}
