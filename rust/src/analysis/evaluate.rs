//! Concrete-parameter evaluation of a [`SymbolicAnalysis`]: total energy
//! (Eq. 11) with per-memory-class breakdown, access/operation counts,
//! latency (Eq. 8), and cross-architecture pricing via
//! [`crate::energy::Backend`] descriptors.
//!
//! Every query walks the stored packed piecewise polynomials
//! (`GuardedSum::eval`: one shared constraint-pool view per sum, Horner
//! evaluation per piece) — O(#pieces) per statement, independent of the
//! iteration-space volume. All count aggregation is exact `i128`
//! arithmetic; floats only appear at the final pricing step, so counts —
//! and therefore energies — are bit-for-bit reproducible regardless of
//! piece ordering or cache warmth.

use std::collections::BTreeMap;

use crate::energy::{Backend, MemoryClass};
use crate::schedule::latency;

use super::{SymbolicAnalysis, WorkloadAnalysis};

/// Access/operation counts at one parameter point.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CountsBreakdown {
    /// Memory accesses by class.
    pub mem: BTreeMap<MemoryClass, i128>,
    /// Adder activations.
    pub adds: i128,
    /// Multiplier activations.
    pub muls: i128,
    /// Total statement executions.
    pub executions: i128,
}

impl CountsBreakdown {
    /// Merge another breakdown into this one.
    pub fn merge(&mut self, other: &CountsBreakdown) {
        for (&c, &v) in &other.mem {
            *self.mem.entry(c).or_insert(0) += v;
        }
        self.adds += other.adds;
        self.muls += other.muls;
        self.executions += other.executions;
    }
}

/// Energy at one parameter point, by contribution (pJ).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// Memory-access energy per class.
    pub mem_pj: BTreeMap<MemoryClass, f64>,
    /// Arithmetic energy.
    pub compute_pj: f64,
    /// `E_tot` of Eq. 11.
    pub total: f64,
}

impl EnergyBreakdown {
    /// Merge another breakdown into this one.
    pub fn merge(&mut self, other: &EnergyBreakdown) {
        for (&c, &v) in &other.mem_pj {
            *self.mem_pj.entry(c).or_insert(0.0) += v;
        }
        self.compute_pj += other.compute_pj;
        self.total += other.total;
    }
}

impl SymbolicAnalysis {
    /// Access/operation counts at concrete parameters — O(#pieces), not
    /// O(#iterations).
    pub fn counts_at(&self, params: &[i64]) -> CountsBreakdown {
        let mut out = CountsBreakdown::default();
        for s in &self.statements {
            let vol = s.volume.eval(params);
            if vol == 0 {
                continue;
            }
            out.executions += vol;
            for (&c, &n) in &s.profile.mem_counts {
                *out.mem.entry(c).or_insert(0) += vol * n as i128;
            }
            out.adds += vol * s.profile.op_counts.0 as i128;
            out.muls += vol * s.profile.op_counts.1 as i128;
        }
        out
    }

    /// Total energy `E_tot` (Eq. 11) with per-class breakdown, in pJ.
    pub fn energy_at(&self, params: &[i64]) -> EnergyBreakdown {
        let counts = self.counts_at(params);
        self.price(&counts, &self.table)
    }

    /// Access/operation counts at concrete parameters with every access
    /// routed through `backend` — the *same* symbolic volumes, a
    /// different register hierarchy (the §VI "comparison with other loop
    /// nest accelerator architectures" use case; see `energy::backend`).
    pub fn counts_at_backend(
        &self,
        params: &[i64],
        backend: &Backend,
    ) -> CountsBreakdown {
        let mut out = CountsBreakdown::default();
        for s in &self.statements {
            let vol = s.volume.eval(params);
            if vol == 0 {
                continue;
            }
            out.executions += vol;
            // Route each access straight into the aggregate map — no
            // per-statement scratch map on this per-query hot path. The
            // multiset equals `vol × route_counts(profile)` (exact
            // integer arithmetic), so identity routing stays bitwise
            // equal to [`Self::counts_at`].
            for r in s
                .profile
                .reads
                .iter()
                .chain(std::iter::once(&s.profile.write))
            {
                for &c in backend.route(*r) {
                    *out.mem.entry(c).or_insert(0) += vol;
                }
            }
            out.adds += vol * s.profile.op_counts.0 as i128;
            out.muls += vol * s.profile.op_counts.1 as i128;
        }
        out
    }

    /// Total energy `E_tot` under an alternative architecture
    /// [`Backend`] — same symbolic volumes, different routing and energy
    /// table. For [`Backend::tcpa`] this is bit-for-bit identical to
    /// [`Self::energy_at`] (identical counts, identical summation
    /// order, identical Table-I values).
    pub fn energy_at_backend(
        &self,
        params: &[i64],
        backend: &Backend,
    ) -> EnergyBreakdown {
        let counts = self.counts_at_backend(params, backend);
        self.price(&counts, &backend.table)
    }

    /// Price a counts breakdown against an energy table (the shared
    /// arithmetic of [`Self::energy_at`] and [`Self::energy_at_backend`],
    /// kept in one place so the two paths cannot drift bit-wise).
    fn price(
        &self,
        counts: &CountsBreakdown,
        table: &crate::energy::EnergyTable,
    ) -> EnergyBreakdown {
        let mut out = EnergyBreakdown::default();
        for (&c, &n) in &counts.mem {
            let e = n as f64 * table.access(c);
            out.mem_pj.insert(c, e);
            out.total += e;
        }
        out.compute_pj = counts.adds as f64 * table.add_pj
            + counts.muls as f64 * table.mul_pj;
        out.total += out.compute_pj;
        out
    }

    /// Global latency `L` (Eq. 8) in cycles at concrete parameters,
    /// under the analysis' default schedule ([`find_schedule`]'s pick).
    ///
    /// [`find_schedule`]: crate::schedule::find_schedule
    pub fn latency_at(&self, params: &[i64]) -> i64 {
        latency(&self.schedule, &self.tiled, params)
    }

    /// Global latency under an *alternative* schedule of the same tiled
    /// mapping (one of [`SymbolicAnalysis::enumerate_schedules`]'s
    /// candidates). Counts and energies are schedule-invariant — the
    /// symbolic volumes depend only on the tiling — so swapping the
    /// schedule re-prices latency alone; this is what lets the DSE
    /// explorer sweep λ candidates against one shared analysis.
    pub fn latency_at_with(
        &self,
        schedule: &crate::schedule::Schedule,
        params: &[i64],
    ) -> i64 {
        latency(schedule, &self.tiled, params)
    }

    /// Energy-delay product in pJ·cycles (a derived DSE metric).
    pub fn edp_at(&self, params: &[i64]) -> f64 {
        self.energy_at(params).total * self.latency_at(params) as f64
    }
}

/// Pair each phase analysis with its parameter vector, panicking — not
/// silently truncating, as a bare `zip` would — when the lengths
/// disagree: a dropped phase would quietly omit a whole phase's counts,
/// energy or latency from the totals.
fn zip_phases<'a, 'b>(
    phases: impl IntoIterator<Item = &'a SymbolicAnalysis>,
    params: &'b [Vec<i64>],
) -> impl Iterator<Item = (&'a SymbolicAnalysis, &'b Vec<i64>)> {
    let mut phases = phases.into_iter();
    let mut n = 0usize;
    std::iter::from_fn(move || match (phases.next(), params.get(n)) {
        (Some(ph), Some(p)) => {
            n += 1;
            Some((ph, p))
        }
        (None, None) => None,
        (Some(_), None) => {
            panic!("more phase analyses than parameter vectors ({n} params)")
        }
        (None, Some(_)) => panic!(
            "more parameter vectors ({}) than phase analyses ({n})",
            params.len()
        ),
    })
}

/// Counts summed over an explicit sequence of per-phase analyses, each
/// paired with its own parameter vector and routed through `backend` —
/// the shared aggregation behind [`WorkloadAnalysis::counts_at_backend`]
/// *and* the DSE explorer's per-phase heterogeneous mappings, where every
/// phase was analyzed on its own array shape
/// (`dse::DesignSpace::with_phase_shapes`) and no single
/// [`WorkloadAnalysis`] exists. Phases execute back to back, so counts
/// sum; a `phases`/`params` length mismatch panics.
pub fn counts_at_backend_phases<'a>(
    phases: impl IntoIterator<Item = &'a SymbolicAnalysis>,
    params: &[Vec<i64>],
    backend: &Backend,
) -> CountsBreakdown {
    let mut out = CountsBreakdown::default();
    for (ph, p) in zip_phases(phases, params) {
        out.merge(&ph.counts_at_backend(p, backend));
    }
    out
}

/// Energy summed over an explicit sequence of per-phase analyses under
/// `backend` (see [`counts_at_backend_phases`]); merge order is the
/// phase order, so uniform assignments stay bit-for-bit identical to
/// [`WorkloadAnalysis::energy_at_backend`] — which delegates here.
pub fn energy_at_backend_phases<'a>(
    phases: impl IntoIterator<Item = &'a SymbolicAnalysis>,
    params: &[Vec<i64>],
    backend: &Backend,
) -> EnergyBreakdown {
    let mut out = EnergyBreakdown::default();
    for (ph, p) in zip_phases(phases, params) {
        out.merge(&ph.energy_at_backend(p, backend));
    }
    out
}

/// Latency summed over an explicit sequence of per-phase analyses
/// (phases execute back to back; see [`counts_at_backend_phases`]).
/// [`WorkloadAnalysis::latency_at`] delegates here.
pub fn latency_at_phases<'a>(
    phases: impl IntoIterator<Item = &'a SymbolicAnalysis>,
    params: &[Vec<i64>],
) -> i64 {
    zip_phases(phases, params)
        .map(|(ph, p)| ph.latency_at(p))
        .sum()
}

impl WorkloadAnalysis {
    /// Counts summed over phases; `params` per phase.
    pub fn counts_at(&self, params: &[Vec<i64>]) -> CountsBreakdown {
        assert_eq!(params.len(), self.phases.len());
        let mut out = CountsBreakdown::default();
        for (ph, p) in self.phases.iter().zip(params) {
            out.merge(&ph.counts_at(p));
        }
        out
    }

    /// Energy summed over phases.
    pub fn energy_at(&self, params: &[Vec<i64>]) -> EnergyBreakdown {
        assert_eq!(params.len(), self.phases.len());
        let mut out = EnergyBreakdown::default();
        for (ph, p) in self.phases.iter().zip(params) {
            out.merge(&ph.energy_at(p));
        }
        out
    }

    /// Counts summed over phases, routed through `backend`.
    pub fn counts_at_backend(
        &self,
        params: &[Vec<i64>],
        backend: &Backend,
    ) -> CountsBreakdown {
        assert_eq!(params.len(), self.phases.len());
        counts_at_backend_phases(&self.phases, params, backend)
    }

    /// Energy summed over phases under an alternative [`Backend`] — one
    /// symbolic analysis, many architectures.
    pub fn energy_at_backend(
        &self,
        params: &[Vec<i64>],
        backend: &Backend,
    ) -> EnergyBreakdown {
        assert_eq!(params.len(), self.phases.len());
        energy_at_backend_phases(&self.phases, params, backend)
    }

    /// Latency summed over phases (phases execute back to back).
    pub fn latency_at(&self, params: &[Vec<i64>]) -> i64 {
        assert_eq!(params.len(), self.phases.len());
        latency_at_phases(&self.phases, params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::SymbolicAnalysis;
    use crate::tiling::ArrayMapping;
    use crate::workloads::gesummv::gesummv;

    fn ana22() -> SymbolicAnalysis {
        SymbolicAnalysis::analyze(&gesummv(), &ArrayMapping::new(vec![2, 2]))
    }

    #[test]
    fn gesummv_counts_hand_checked() {
        // N=(4,5), p=(2,3), 2×2 array — hand-derived exact counts.
        let ana = ana22();
        let params = [4i64, 5, 2, 3];
        let c = ana.counts_at(&params);
        // DRAM: A reads (20) + B reads (20) + X reads at i0=0 (5)
        //       + Y writes at i1=4 (4) = 49.
        assert_eq!(c.mem[&MemoryClass::Dram], 49);
        assert_eq!(c.mem[&MemoryClass::IOb], 49);
        // muls: S3 + S4 = 40; adds: S6 (16) + S9 (16) + S11 (4) = 36.
        assert_eq!(c.muls, 40);
        assert_eq!(c.adds, 36);
        // FD reads: intra-tile transports of S2 (x: i0>0 intra rows:
        // vol 10... see sim cross-check) + S7 + S10.
        assert!(c.mem[&MemoryClass::Fd] > 0);
        assert!(c.executions > 0);
    }

    #[test]
    fn energy_breakdown_sums_to_total() {
        let ana = ana22();
        let params = [4i64, 5, 2, 3];
        let e = ana.energy_at(&params);
        let sum: f64 = e.mem_pj.values().sum::<f64>() + e.compute_pj;
        assert!((sum - e.total).abs() < 1e-9);
        // DRAM dominates at small sizes (Fig. 5's small-N regime).
        assert!(e.mem_pj[&MemoryClass::Dram] > 0.5 * e.total);
    }

    #[test]
    fn counts_scale_quadratically() {
        // GESUMMV volume is N0·N1: DRAM count ratio between N and 2N ≈ 4.
        let ana = ana22();
        let c1 = ana.counts_at(&ana.params_for(&[16, 16]));
        let c2 = ana.counts_at(&ana.params_for(&[32, 32]));
        let ratio = c2.mem[&MemoryClass::Dram] as f64
            / c1.mem[&MemoryClass::Dram] as f64;
        assert!((ratio - 4.0).abs() < 0.3, "ratio = {ratio}");
    }

    #[test]
    fn tcpa_backend_bit_identical_to_native_path() {
        let ana = ana22();
        let tcpa = Backend::tcpa();
        for n in [[4i64, 5], [16, 16], [40, 24]] {
            let params = ana.params_for(&n);
            let native = ana.energy_at(&params);
            let routed = ana.energy_at_backend(&params, &tcpa);
            assert_eq!(native.total.to_bits(), routed.total.to_bits());
            assert_eq!(native, routed);
            assert_eq!(
                ana.counts_at(&params),
                ana.counts_at_backend(&params, &tcpa)
            );
        }
    }

    #[test]
    fn one_analysis_prices_every_builtin_backend() {
        // The §VI claim: the symbolic pass ran once (in ana22); pricing
        // four architectures is pure expression evaluation.
        let ana = ana22();
        let params = ana.params_for(&[16, 16]);
        let total = |name: &str| {
            ana.energy_at_backend(&params, &Backend::by_name(name).unwrap())
                .total
        };
        let (tcpa, systolic, cgra, gpu) = (
            total("tcpa"),
            total("systolic"),
            total("cgra"),
            total("gpu-sm"),
        );
        // GESUMMV has FD and ID traffic, so the pointwise access-energy
        // chain becomes strict on totals.
        assert!(tcpa < systolic, "{tcpa} vs {systolic}");
        assert!(systolic < cgra, "{systolic} vs {cgra}");
        assert!(cgra < gpu, "{cgra} vs {gpu}");
    }

    #[test]
    fn phase_merge_matches_workload_aggregation_and_sums_heterogeneous() {
        // Uniform delegation: WorkloadAnalysis methods and the free
        // functions are the same arithmetic, bit for bit.
        let wl = crate::workloads::by_name("atax").unwrap();
        let ana = crate::analysis::WorkloadAnalysis::analyze_uniform(
            &wl,
            &[2, 2],
        );
        let params: Vec<Vec<i64>> =
            ana.phases.iter().map(|ph| ph.params_for(&[8, 8])).collect();
        let be = Backend::tcpa();
        let merged = super::energy_at_backend_phases(&ana.phases, &params, &be);
        let whole = ana.energy_at_backend(&params, &be);
        assert_eq!(merged.total.to_bits(), whole.total.to_bits());
        assert_eq!(merged, whole);
        assert_eq!(
            super::latency_at_phases(&ana.phases, &params),
            ana.latency_at(&params)
        );
        // Heterogeneous: each phase analyzed on its own shape; totals are
        // exactly the per-phase sums (phases run back to back).
        let p1 = crate::analysis::SymbolicAnalysis::analyze(
            &wl.phases[0],
            &ArrayMapping::new(vec![1, 4]),
        );
        let p2 = crate::analysis::SymbolicAnalysis::analyze(
            &wl.phases[1],
            &ArrayMapping::new(vec![4, 1]),
        );
        let hp = vec![p1.params_for(&[8, 8]), p2.params_for(&[8, 8])];
        let phases = [&p1, &p2];
        let e = super::energy_at_backend_phases(
            phases.iter().copied(),
            &hp,
            &be,
        );
        let want = p1.energy_at_backend(&hp[0], &be).total
            + p2.energy_at_backend(&hp[1], &be).total;
        assert_eq!(e.total.to_bits(), want.to_bits());
        let c = super::counts_at_backend_phases(
            phases.iter().copied(),
            &hp,
            &be,
        );
        let mut manual = p1.counts_at_backend(&hp[0], &be);
        manual.merge(&p2.counts_at_backend(&hp[1], &be));
        assert_eq!(c, manual);
        assert_eq!(
            super::latency_at_phases(phases.iter().copied(), &hp),
            p1.latency_at(&hp[0]) + p2.latency_at(&hp[1])
        );
    }

    #[test]
    #[should_panic(expected = "parameter vectors")]
    fn phase_merge_rejects_length_mismatch() {
        // A bare zip would silently drop the unmatched phase and return
        // a total missing a whole phase's latency.
        let ana = ana22();
        let params: Vec<Vec<i64>> = Vec::new();
        let _ = super::latency_at_phases(std::iter::once(&ana), &params);
    }

    #[test]
    fn edp_positive_and_monotone() {
        let ana = ana22();
        let a = ana.edp_at(&ana.params_for(&[8, 8]));
        let b = ana.edp_at(&ana.params_for(&[16, 16]));
        assert!(b > a && a > 0.0);
    }
}
