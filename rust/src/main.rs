//! `tcpa-energy` — symbolic polyhedral energy analysis for nested loop
//! programs on processor arrays. See `tcpa-energy --help` / README.md.

use tcpa_energy::coordinator::run_cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!(
            "tcpa-energy — symbolic energy analysis for loop nests on \
             processor arrays\n\n\
             USAGE:\n  tcpa-energy list\n  \
             tcpa-energy analyze  --workload NAME --array TxT \
             [--bounds N,N] [--report]\n  \
             tcpa-energy simulate --workload NAME --array TxT --bounds N,N\n  \
             tcpa-energy validate [--workload NAME] [--bounds N,N] \
             [--array TxT]\n  \
             tcpa-energy dse      --workload NAME --bounds N,N \
             [--max-pes P] [--arrays 1d|2d]\n                       \
             [--bounds-sweep N,N,..] [--tile-scales K,K] \
             [--policies all|tcpa,no-fd,no-reuse]\n                       \
             [--prune-symmetric] [--workers W] [--out DIR]\n                       \
             [--checkpoint FILE] [--resume] [--deadline SECS]\n                       \
             [--point-timeout SECS] [--progress]\n                       \
             [--strategy exhaustive|beam[:W]] [--shard i/n]\n  \
             tcpa-energy dse merge <same space flags> \
             --shards a.journal,b.journal,..\n  \
             tcpa-energy figures  [--out DIR] [--quick]\n  \
             tcpa-energy lint     --workload NAME | --workload-file F | \
             --all-builtins\n                       \
             [--array TxT] [--pi N] \
             [--json] [--json-out FILE] [--deny warnings]\n\n\
             analyze/simulate/dse/lint also accept --workload-file F.wl — \
             a textual\nloop-nest description (grammar in README.md) \
             instead of a builtin name.\nParsed files are untrusted: \
             malformed input fails with file:line:col\ndiagnostics, and \
             every parsed workload passes the lint deny gate plus\n\
             symbolic schedule-causality proofs.\n\n\
             `analyze`, `simulate` and `dse` lint their workload first; \
             deny-level\nfindings abort the run (bypass with --no-lint).\n\n\
             Long sweeps: --checkpoint journals completed points, \
             --resume replays them\nbit-for-bit, --deadline/--point-timeout \
             bound the clock, Ctrl-C drains and\nflushes. `dse` exit \
             codes: 0 ok, 1 all points failed, 2 error, 3 partial\n\
             (cancelled; frontier marked `partial (k/n points)`).\n\n\
             Scaling: --strategy beam[:W] searches the shape axis with a \
             deterministic\nPareto beam (exhaustive stays the oracle); \
             --shard i/n sweeps the i-th\nround-robin slice of the \
             enumeration, and `dse merge --shards ...` folds\nfinished \
             shard journals into a report byte-identical to the unsharded \
             run."
        );
        return;
    }
    match run_cli(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}
