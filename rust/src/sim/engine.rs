//! The cycle-accurate tick simulation engine and the engine dispatch.
//!
//! [`simulate_tick`] executes a tiled + scheduled loop nest on the modeled
//! PE array: every iteration `(j, k)` fires at its schedule time
//! `λ^J·j + λ^K·k` on PE `k`; its statements execute in intra-iteration
//! topological order, moving real `f32` values through the register
//! hierarchy. Every operand access is classified **geometrically** (from
//! the source iteration's tile, not from the analysis' γ-decomposition)
//! and counted — making the exact-match comparison against the symbolic
//! counts a genuine two-sided validation.
//!
//! This engine materializes *every* iteration up front and sorts the full
//! event list — Θ(#iterations · #statements) time and Θ(#iterations)
//! memory with a global `O(E log E)` sort. That is the scaling the
//! symbolic analysis removes (Fig. 4 of the paper), and the reason it
//! stays the **small-bounds oracle**: the discrete-event engine
//! ([`super::event`]) produces bit-identical results without the global
//! sort and is the one to use at large bounds. [`simulate`] dispatches on
//! [`super::arch::EngineKind`].
//!
//! §Perf: the inner loop runs on a *precompiled* statement form
//! (`sim::exec::ExecStmt`) with name→index resolution, pre-evaluated
//! condition constants, flat-index value stores, and zero per-access
//! allocation — see EXPERIMENTS.md §Perf for the before/after numbers.

use crate::polyhedral::k_grid;
use crate::pra::Pra;
use crate::schedule::Schedule;
use crate::workloads::tensor::TensorEnv;

use super::arch::{ArchConfig, EngineKind};
use super::counters::AccessCounters;
use super::exec;
use super::stats::SimStats;

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Access/operation counters (the §V-A validation observable).
    pub counters: AccessCounters,
    /// Produced output tensors (functional observable).
    pub outputs: TensorEnv,
    /// Total cycles: full rectangular schedule span + critical chain `L_c`
    /// (Eq. 8 — the global controller runs the whole tile schedule;
    /// iterations outside `I` are predicated off but occupy their slot).
    pub cycles: i64,
    /// Per-PE / interconnect / buffer statistics.
    pub stats: SimStats,
    /// Dynamic check failures (schedule causality, register pressure).
    /// Empty on a healthy run.
    pub violations: Vec<String>,
}

/// Narrow i128 schedule vectors for iteration enumeration. Schedule
/// arithmetic is i128 (entries can exceed `i64` at symbolic-scale
/// parameters); the simulators enumerate iterations, so their parameters
/// are small by construction and the narrowing is checked, not lossy.
pub(super) fn narrow_lambda(v: Vec<i128>) -> Vec<i64> {
    v.into_iter()
        .map(|x| {
            i64::try_from(x)
                .expect("schedule vector overflows i64 in simulation")
        })
        .collect()
}

/// Run the cycle-accurate simulation with the engine selected by
/// `arch.engine` ([`EngineKind::Tick`] by default; both engines are
/// bit-identical in every observable — see `tests/event_sim_diff.rs`).
///
/// `params` is the full `(N…, p…)` vector; `inputs` must contain every
/// input tensor of the PRA.
pub fn simulate(
    pra: &Pra,
    arch: &ArchConfig,
    schedule: &Schedule,
    params: &[i64],
    inputs: &TensorEnv,
) -> SimResult {
    match arch.engine {
        EngineKind::Tick => simulate_tick(pra, arch, schedule, params, inputs),
        EngineKind::Event => {
            super::event::simulate_event(pra, arch, schedule, params, inputs)
        }
    }
}

/// Run the exhaustive tick engine (see module docs): materialize every
/// iteration's `(start, pe, i)` event, sort by `(start, pe)`, fire in
/// order.
pub fn simulate_tick(
    pra: &Pra,
    arch: &ArchConfig,
    schedule: &Schedule,
    params: &[i64],
    inputs: &TensorEnv,
) -> SimResult {
    let n = pra.ndims;
    let t = &arch.mapping.t;
    let bounds: Vec<i64> =
        (0..n).map(|l| params[pra.space.n_index(l)]).collect();
    let p: Vec<i64> = (0..n).map(|l| params[pra.space.p_index(l)]).collect();
    let lj = narrow_lambda(schedule.lambda_j_at(params));
    let lk = narrow_lambda(schedule.lambda_k_at(params));

    let (prog, outputs) = exec::compile(pra, params, inputs);
    let mut st =
        exec::RunState::new(&prog, arch, bounds.clone(), p.clone(), outputs);

    // ---- enumerate iterations with start times -------------------------
    // event = (start, pe_flat, i)
    let kcells = k_grid(t);
    let mut events: Vec<(i64, usize, Vec<i64>)> = Vec::new();
    for (pe_flat, k) in kcells.iter().enumerate() {
        let mut j = vec![0i64; n];
        'tile: loop {
            let i: Vec<i64> = (0..n).map(|l| j[l] + p[l] * k[l]).collect();
            if i.iter().zip(&bounds).all(|(&x, &b)| x < b) {
                let start: i64 =
                    (0..n).map(|l| lj[l] * j[l] + lk[l] * k[l]).sum();
                events.push((start, pe_flat, i));
            }
            for d in (0..n).rev() {
                j[d] += 1;
                if j[d] < p[d] {
                    continue 'tile;
                }
                j[d] = 0;
                if d == 0 {
                    break 'tile;
                }
            }
        }
    }
    events.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

    // full rectangular schedule span (Eq. 8 without L_c)
    let span = exec::rect_span(&lj, &lk, &p, t);
    let mut starts_per_cycle: Vec<i32> = vec![0; (span + 1) as usize];
    let mut max_start = 0i64;
    for (start, pe, i) in &events {
        max_start = max_start.max(*start);
        starts_per_cycle[*start as usize] += 1;
        exec::fire(&prog, &mut st, arch, *start, *pe, &kcells[*pe], i);
        st.commit_streams();
    }

    debug_assert!(max_start <= span);
    let cycles = span + schedule.lc;
    let max_concurrency =
        starts_per_cycle.iter().copied().max().unwrap_or(0) as i64;
    exec::finalize(&prog, st, arch, &lj, cycles, max_concurrency)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::find_schedule;
    use crate::tiling::tile_pra;
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    fn run_gesummv(n0: i64, n1: i64) -> (SimResult, TensorEnv, Vec<i64>) {
        let pra = gesummv();
        let arch = ArchConfig::with_array(vec![2, 2]);
        let tiled = tile_pra(&pra, &arch.mapping);
        let schedule = find_schedule(&tiled, arch.pi).unwrap();
        let params = arch.mapping.params_for(&[n0, n1]);
        let inputs = synth_inputs(&[
            ("A".into(), vec![n0, n1]),
            ("B".into(), vec![n0, n1]),
            ("X".into(), vec![n1]),
        ]);
        let res = simulate(&pra, &arch, &schedule, &params, &inputs);
        (res, inputs, params)
    }

    #[test]
    fn simulation_is_clean_and_functional() {
        let (res, inputs, params) = run_gesummv(4, 5);
        assert!(res.violations.is_empty(), "{:?}", res.violations);
        // functional outputs match the lexicographic interpreter exactly
        let golden = interpret(&gesummv(), &params, &inputs);
        assert_eq!(res.outputs["Y"].shape, golden["Y"].shape);
        assert!(res.outputs["Y"].allclose(&golden["Y"], 1e-5, 1e-5));
    }

    #[test]
    fn example3_cycle_count() {
        // Paper Example 3: L = 16 for N=(4,5), p=(2,3), t=(2,2).
        let (res, _, _) = run_gesummv(4, 5);
        assert_eq!(res.cycles, 16);
    }

    #[test]
    fn counts_match_symbolic_exactly() {
        use crate::analysis::SymbolicAnalysis;
        let (res, _, params) = run_gesummv(4, 5);
        let ana = SymbolicAnalysis::analyze(
            &gesummv(),
            &crate::tiling::ArrayMapping::new(vec![2, 2]),
        );
        let sym = ana.counts_at(&params);
        let diff = res.counters.diff_symbolic(&sym);
        assert!(diff.is_empty(), "mismatches: {diff:?}");
    }

    #[test]
    fn utilization_and_stats_sane() {
        let (res, _, _) = run_gesummv(8, 8);
        assert!(res.stats.utilization > 0.0 && res.stats.utilization <= 1.0);
        assert_eq!(res.stats.max_hop, 1, "neighbour-to-neighbour only");
        assert_eq!(res.stats.pe.len(), 4);
        let total: i64 = res.stats.pe.iter().map(|p| p.iterations).sum();
        assert_eq!(total, 64);
        assert!(res.stats.max_concurrency <= 4);
    }

    #[test]
    fn per_tensor_io_traffic() {
        let (res, _, _) = run_gesummv(4, 5);
        // A and B read once per iteration (20 each); X once per column at
        // i0 = 0 (5); Y written once per row (4).
        assert_eq!(res.stats.io.per_tensor_in["A"], 20);
        assert_eq!(res.stats.io.per_tensor_in["B"], 20);
        assert_eq!(res.stats.io.per_tensor_in["X"], 5);
        assert_eq!(res.stats.io.per_tensor_out["Y"], 4);
    }

    #[test]
    fn dispatch_selects_the_event_engine() {
        // `simulate` with `engine: Event` must agree with the tick
        // default in every observable (full parity in
        // tests/event_sim_diff.rs — this pins only the dispatch).
        let pra = gesummv();
        let mut arch = ArchConfig::with_array(vec![2, 2]);
        let tiled = tile_pra(&pra, &arch.mapping);
        let schedule = find_schedule(&tiled, arch.pi).unwrap();
        let params = arch.mapping.params_for(&[4, 5]);
        let inputs = synth_inputs(&[
            ("A".into(), vec![4, 5]),
            ("B".into(), vec![4, 5]),
            ("X".into(), vec![5]),
        ]);
        let tick = simulate(&pra, &arch, &schedule, &params, &inputs);
        arch.engine = EngineKind::Event;
        let event = simulate(&pra, &arch, &schedule, &params, &inputs);
        assert_eq!(event.counters, tick.counters);
        assert_eq!(event.cycles, tick.cycles);
        assert_eq!(event.outputs, tick.outputs);
        assert_eq!(event.violations, tick.violations);
    }
}
