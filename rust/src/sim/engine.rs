//! The cycle-accurate simulation engine.
//!
//! Executes a tiled + scheduled loop nest on the modeled PE array: every
//! iteration `(j, k)` fires at its schedule time `λ^J·j + λ^K·k` on PE `k`;
//! its statements execute in intra-iteration topological order, moving real
//! `f32` values through the register hierarchy. Every operand access is
//! classified **geometrically** (from the source iteration's tile, not from
//! the analysis' γ-decomposition) and counted — making the exact-match
//! comparison against the symbolic counts a genuine two-sided validation.
//!
//! Simulation cost is Θ(#iterations · #statements): this is the scaling the
//! symbolic analysis removes (Fig. 4 of the paper).
//!
//! §Perf: the inner loop runs on a *precompiled* statement form
//! ([`ExecStmt`]) with name→index resolution, pre-evaluated condition
//! constants, flat-index value stores, and zero per-access allocation —
//! see EXPERIMENTS.md §Perf for the before/after numbers.

use std::collections::BTreeMap;

use crate::energy::MemoryClass;
use crate::polyhedral::k_grid;
use crate::pra::{Lhs, Op, Operand, Pra, Rdg};
use crate::schedule::Schedule;
use crate::workloads::tensor::{Tensor, TensorEnv};

use super::arch::ArchConfig;
use super::counters::AccessCounters;
use super::stats::{IoStats, PeStats, SimStats};

/// Result of a simulation run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Access/operation counters (the §V-A validation observable).
    pub counters: AccessCounters,
    /// Produced output tensors (functional observable).
    pub outputs: TensorEnv,
    /// Total cycles: full rectangular schedule span + critical chain `L_c`
    /// (Eq. 8 — the global controller runs the whole tile schedule;
    /// iterations outside `I` are predicated off but occupy their slot).
    pub cycles: i64,
    /// Per-PE / interconnect / buffer statistics.
    pub stats: SimStats,
    /// Dynamic check failures (schedule causality, register pressure).
    /// Empty on a healthy run.
    pub violations: Vec<String>,
}

/// Precompiled operand.
enum ExecArg {
    /// Input tensor read: resolved tensor index + affine map.
    Tensor { tidx: usize, rows: Vec<Vec<i64>>, offset: Vec<i64> },
    /// Intra-iteration variable read (RD).
    VarZero { vidx: usize },
    /// Dependence-carrying variable read (FD/ID by geometry).
    VarDep { vidx: usize, dep: Vec<i64> },
}

/// Precompiled left-hand side.
enum ExecLhs {
    Var { vidx: usize },
    Tensor { oidx: usize, rows: Vec<Vec<i64>>, offset: Vec<i64> },
}

/// Precompiled statement: conditions with parameter constants already
/// folded, operands resolved to indices.
struct ExecStmt {
    qi: usize,
    /// `Σ a·i + c ≥ 0` per condition.
    conds: Vec<(Vec<i64>, i64)>,
    op: Op,
    adds: u32,
    muls: u32,
    args: Vec<ExecArg>,
    lhs: ExecLhs,
}

#[inline]
fn apply_map(rows: &[Vec<i64>], offset: &[i64], i: &[i64], out: &mut Vec<i64>) {
    out.clear();
    for (row, off) in rows.iter().zip(offset) {
        let mut v = *off;
        for (a, x) in row.iter().zip(i) {
            v += a * x;
        }
        out.push(v);
    }
}

/// Run the cycle-accurate simulation.
///
/// `params` is the full `(N…, p…)` vector; `inputs` must contain every
/// input tensor of the PRA.
pub fn simulate(
    pra: &Pra,
    arch: &ArchConfig,
    schedule: &Schedule,
    params: &[i64],
    inputs: &TensorEnv,
) -> SimResult {
    let n = pra.ndims;
    let t = &arch.mapping.t;
    let bounds: Vec<i64> =
        (0..n).map(|l| params[pra.space.n_index(l)]).collect();
    let p: Vec<i64> = (0..n).map(|l| params[pra.space.p_index(l)]).collect();
    // Schedule vectors are i128 (they can exceed i64 at symbolic-scale
    // parameters); the simulator enumerates iterations, so its parameters
    // are small by construction and the narrowing is checked, not lossy.
    let narrow = |v: Vec<i128>| -> Vec<i64> {
        v.into_iter()
            .map(|x| {
                i64::try_from(x)
                    .expect("schedule vector overflows i64 in simulation")
            })
            .collect()
    };
    let lj = narrow(schedule.lambda_j_at(params));
    let lk = narrow(schedule.lambda_k_at(params));

    let rdg = Rdg::build(pra);
    let order = rdg
        .intra_iteration_order(pra.statements.len())
        .expect("PRA has an intra-iteration dependence cycle");

    // ---- precompile statements (name → index, fold parameters) ---------
    let mut var_names: Vec<&str> = Vec::new();
    let var_idx = |name: &str, names: &[&str]| -> usize {
        // (resolved against pra's statement LHS set built below)
        names.iter().position(|&x| x == name).unwrap_or_else(|| {
            panic!("unknown var {name}")
        })
    };
    for s in &pra.statements {
        if let Lhs::Var(v) = &s.lhs {
            if !var_names.iter().any(|&x| x == v.as_str()) {
                var_names.push(v);
            }
        }
    }
    let in_names: Vec<&String> = inputs.keys().collect();
    let in_tensors: Vec<&Tensor> = inputs.values().collect();
    let mut out_names: Vec<String> = Vec::new();
    let mut outputs_vec: Vec<Tensor> = Vec::new();
    for s in &pra.statements {
        if let Lhs::Tensor { name, .. } = &s.lhs {
            if !out_names.contains(name) {
                let decl = pra.tensor(name).expect("undeclared output");
                out_names.push(name.clone());
                outputs_vec.push(Tensor::zeros(decl.concrete_shape(params)));
            }
        }
    }
    let exec: Vec<ExecStmt> = order
        .iter()
        .map(|&qi| {
            let s = &pra.statements[qi];
            let conds = s
                .cond
                .iter()
                .map(|c| (c.a.clone(), c.konst.eval(params)))
                .collect();
            let args = s
                .args
                .iter()
                .map(|a| match a {
                    Operand::Tensor { name, map } => ExecArg::Tensor {
                        tidx: in_names
                            .iter()
                            .position(|x| x.as_str() == name)
                            .unwrap_or_else(|| {
                                panic!("missing input {name}")
                            }),
                        rows: map.rows.clone(),
                        offset: map.offset.clone(),
                    },
                    Operand::Var { name, dep } => {
                        let vidx = var_idx(name, &var_names);
                        if dep.iter().all(|&d| d == 0) {
                            ExecArg::VarZero { vidx }
                        } else {
                            ExecArg::VarDep { vidx, dep: dep.clone() }
                        }
                    }
                })
                .collect();
            let lhs = match &s.lhs {
                Lhs::Var(name) => {
                    ExecLhs::Var { vidx: var_idx(name, &var_names) }
                }
                Lhs::Tensor { name, map } => ExecLhs::Tensor {
                    oidx: out_names.iter().position(|x| x == name).unwrap(),
                    rows: map.rows.clone(),
                    offset: map.offset.clone(),
                },
            };
            let (adds, muls) =
                crate::energy::EnergyTable::op_activations(s.op);
            ExecStmt { qi, conds, op: s.op, adds, muls, args, lhs }
        })
        .collect();

    // ---- dense value stores (flat-indexed over the iteration space) ----
    let iter_total: usize = bounds.iter().product::<i64>() as usize;
    let mut var_data: Vec<Vec<f32>> =
        vec![vec![0.0; iter_total]; var_names.len()];
    let mut var_written: Vec<Vec<bool>> =
        vec![vec![false; iter_total]; var_names.len()];
    // start time per flat iteration index (for causality checks)
    let mut start_by_flat: Vec<i64> = vec![i64::MIN; iter_total];
    let flat_of = |i: &[i64]| -> Option<usize> {
        let mut off: i64 = 0;
        for (&x, &b) in i.iter().zip(&bounds) {
            if x < 0 || x >= b {
                return None;
            }
            off = off * b + x;
        }
        Some(off as usize)
    };

    // ---- enumerate iterations with start times -------------------------
    // event = (start, pe_flat, k grid index, i)
    let kcells = k_grid(t);
    let mut events: Vec<(i64, usize, usize, Vec<i64>)> = Vec::new();
    for (pe_flat, k) in kcells.iter().enumerate() {
        let mut j = vec![0i64; n];
        'tile: loop {
            let i: Vec<i64> = (0..n).map(|l| j[l] + p[l] * k[l]).collect();
            if i.iter().zip(&bounds).all(|(&x, &b)| x < b) {
                let start: i64 =
                    (0..n).map(|l| lj[l] * j[l] + lk[l] * k[l]).sum();
                events.push((start, pe_flat, pe_flat, i));
            }
            for d in (0..n).rev() {
                j[d] += 1;
                if j[d] < p[d] {
                    continue 'tile;
                }
                j[d] = 0;
                if d == 0 {
                    break 'tile;
                }
            }
        }
    }
    events.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));

    // ---- state ----------------------------------------------------------
    let num_pes = arch.num_pes() as usize;
    let mut counters = AccessCounters::default();
    // flat per-class counters folded into the BTreeMap at the end
    let mut mem = [0i128; 6]; // RD FD ID OD IOb DR in MemoryClass::ALL order
    const RD: usize = 0;
    const FD: usize = 1;
    const ID: usize = 2;
    const OD: usize = 3;
    const IOB: usize = 4;
    const DR: usize = 5;
    let mut pe_stats = vec![PeStats::default(); num_pes];
    let mut per_tensor_in: Vec<i64> = vec![0; in_names.len()];
    let mut per_tensor_out: Vec<i64> = vec![0; out_names.len()];
    let mut io = IoStats::default();
    let mut violations: Vec<String> = Vec::new();
    let mut max_hop = 0i64;
    let mut last_start_per_pe = vec![i64::MIN; num_pes];
    let mut max_start = 0i64;
    // full rectangular schedule span (Eq. 8 without L_c)
    let span: i64 = (0..n)
        .map(|l| lj[l] * (p[l] - 1) + lk[l] * (t[l] - 1))
        .sum();
    let mut starts_per_cycle: Vec<i32> = vec![0; (span + 1) as usize];

    let mut argbuf: Vec<f32> = Vec::with_capacity(3);
    let mut idxbuf: Vec<i64> = Vec::with_capacity(4);
    let mut srcbuf: Vec<i64> = vec![0; n];
    for (start, pe, _, i) in &events {
        let iflat = flat_of(i).expect("event inside iteration space");
        start_by_flat[iflat] = *start;
        max_start = max_start.max(*start);
        starts_per_cycle[*start as usize] += 1;
        if last_start_per_pe[*pe] != i64::MIN
            && start - last_start_per_pe[*pe] < arch.pi
        {
            violations.push(format!(
                "PE {pe}: iterations {} cycles apart (π = {})",
                start - last_start_per_pe[*pe],
                arch.pi
            ));
        }
        last_start_per_pe[*pe] = *start;
        let ps = &mut pe_stats[*pe];
        ps.iterations += 1;
        ps.first_cycle = ps.first_cycle.min(*start);
        ps.last_cycle = ps.last_cycle.max(*start);
        let k = &kcells[*pe];

        'stmts: for es in &exec {
            // condition check (constants pre-folded)
            for (a, c) in &es.conds {
                let mut v = *c;
                for (av, xv) in a.iter().zip(i) {
                    v += av * xv;
                }
                if v < 0 {
                    continue 'stmts;
                }
            }
            counters.executions += 1;
            argbuf.clear();
            for arg in &es.args {
                let v = match arg {
                    ExecArg::Tensor { tidx, rows, offset } => {
                        mem[DR] += 1;
                        mem[IOB] += 1;
                        mem[ID] += 1;
                        io.elements_in += 1;
                        per_tensor_in[*tidx] += 1;
                        apply_map(rows, offset, i, &mut idxbuf);
                        in_tensors[*tidx].get(&idxbuf)
                    }
                    ExecArg::VarZero { vidx } => {
                        mem[RD] += 1;
                        pe_stats[*pe].rd_reads += 1;
                        debug_assert!(var_written[*vidx][iflat]);
                        var_data[*vidx][iflat]
                    }
                    ExecArg::VarDep { vidx, dep } => {
                        for l in 0..n {
                            srcbuf[l] = i[l] - dep[l];
                        }
                        // geometric classification by source tile
                        let mut same_tile = true;
                        let mut hop = 0i64;
                        for l in 0..n {
                            let kt = srcbuf[l].div_euclid(p[l]);
                            if kt != k[l] {
                                same_tile = false;
                                hop += (kt - k[l]).abs();
                            }
                        }
                        if same_tile {
                            mem[FD] += 1;
                            pe_stats[*pe].fd_reads += 1;
                        } else {
                            mem[ID] += 1;
                            pe_stats[*pe].id_reads += 1;
                            max_hop = max_hop.max(hop);
                        }
                        match flat_of(&srcbuf) {
                            Some(soff) if var_written[*vidx][soff] => {
                                // dynamic causality check
                                let ss = start_by_flat[soff];
                                if ss != i64::MIN && ss >= *start {
                                    violations.push(format!(
                                        "{}@{i:?}: source {srcbuf:?} starts \
                                         at {ss} >= {start}",
                                        pra.statements[es.qi].name
                                    ));
                                }
                                var_data[*vidx][soff]
                            }
                            _ => {
                                violations.push(format!(
                                    "{}@{i:?}: read of {}[{srcbuf:?}] \
                                     before definition",
                                    pra.statements[es.qi].name,
                                    var_names[*vidx]
                                ));
                                0.0
                            }
                        }
                    }
                };
                argbuf.push(v);
            }
            counters.adds += es.adds as i128;
            counters.muls += es.muls as i128;
            let value = es.op.apply(&argbuf);
            match &es.lhs {
                ExecLhs::Var { vidx } => {
                    mem[RD] += 1;
                    pe_stats[*pe].rd_writes += 1;
                    var_data[*vidx][iflat] = value;
                    var_written[*vidx][iflat] = true;
                }
                ExecLhs::Tensor { oidx, rows, offset } => {
                    mem[OD] += 1;
                    mem[IOB] += 1;
                    mem[DR] += 1;
                    io.elements_out += 1;
                    per_tensor_out[*oidx] += 1;
                    apply_map(rows, offset, i, &mut idxbuf);
                    outputs_vec[*oidx].set(&idxbuf, value);
                }
            }
        }
    }

    // fold flat counters into the public map
    for (slot, &class) in MemoryClass::ALL.iter().enumerate() {
        if mem[slot] != 0 {
            counters.touch_n(class, mem[slot]);
        }
    }
    for (name, cnt) in in_names.iter().zip(&per_tensor_in) {
        if *cnt > 0 {
            io.per_tensor_in.insert((*name).clone(), *cnt);
        }
    }
    for (name, cnt) in out_names.iter().zip(&per_tensor_out) {
        if *cnt > 0 {
            io.per_tensor_out.insert(name.clone(), *cnt);
        }
    }
    let outputs: TensorEnv = out_names
        .into_iter()
        .zip(outputs_vec)
        .collect::<BTreeMap<_, _>>();

    // ---- static FD-pressure check (FIFO depth = schedule distance) -----
    let mut fd_pressure = 0i64;
    for s in &pra.statements {
        for arg in &s.args {
            if let Operand::Var { dep, .. } = arg {
                if dep.iter().any(|&d| d != 0) {
                    let dist: i64 = dep
                        .iter()
                        .zip(&lj)
                        .map(|(&d, &l)| d * l)
                        .sum::<i64>()
                        / arch.pi.max(1);
                    fd_pressure += dist.max(0);
                }
            }
        }
    }
    if fd_pressure > arch.regs.fd as i64 {
        violations.push(format!(
            "FD pressure {fd_pressure} exceeds register file size {}",
            arch.regs.fd
        ));
    }

    debug_assert!(max_start <= span);
    let cycles = span + schedule.lc;
    let max_concurrency =
        starts_per_cycle.iter().copied().max().unwrap_or(0) as i64;
    let total_iters: i128 =
        pe_stats.iter().map(|s| s.iterations as i128).sum();
    let utilization = if cycles > 0 {
        total_iters as f64 / (cycles as f64 * num_pes as f64)
    } else {
        0.0
    };
    io.max_per_cycle = {
        let max_stream_args = pra
            .statements
            .iter()
            .map(|s| {
                s.args
                    .iter()
                    .filter(|a| matches!(a, Operand::Tensor { .. }))
                    .count()
            })
            .max()
            .unwrap_or(0);
        max_concurrency as usize * max_stream_args
    };
    let stats = SimStats {
        pe: pe_stats,
        io,
        max_hop,
        max_concurrency,
        utilization,
        fd_pressure,
    };
    SimResult { counters, outputs, cycles, stats, violations }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::find_schedule;
    use crate::tiling::tile_pra;
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    fn run_gesummv(n0: i64, n1: i64) -> (SimResult, TensorEnv, Vec<i64>) {
        let pra = gesummv();
        let arch = ArchConfig::with_array(vec![2, 2]);
        let tiled = tile_pra(&pra, &arch.mapping);
        let schedule = find_schedule(&tiled, arch.pi).unwrap();
        let params = arch.mapping.params_for(&[n0, n1]);
        let inputs = synth_inputs(&[
            ("A".into(), vec![n0, n1]),
            ("B".into(), vec![n0, n1]),
            ("X".into(), vec![n1]),
        ]);
        let res = simulate(&pra, &arch, &schedule, &params, &inputs);
        (res, inputs, params)
    }

    #[test]
    fn simulation_is_clean_and_functional() {
        let (res, inputs, params) = run_gesummv(4, 5);
        assert!(res.violations.is_empty(), "{:?}", res.violations);
        // functional outputs match the lexicographic interpreter exactly
        let golden = interpret(&gesummv(), &params, &inputs);
        assert_eq!(res.outputs["Y"].shape, golden["Y"].shape);
        assert!(res.outputs["Y"].allclose(&golden["Y"], 1e-5, 1e-5));
    }

    #[test]
    fn example3_cycle_count() {
        // Paper Example 3: L = 16 for N=(4,5), p=(2,3), t=(2,2).
        let (res, _, _) = run_gesummv(4, 5);
        assert_eq!(res.cycles, 16);
    }

    #[test]
    fn counts_match_symbolic_exactly() {
        use crate::analysis::SymbolicAnalysis;
        let (res, _, params) = run_gesummv(4, 5);
        let ana = SymbolicAnalysis::analyze(
            &gesummv(),
            &crate::tiling::ArrayMapping::new(vec![2, 2]),
        );
        let sym = ana.counts_at(&params);
        let diff = res.counters.diff_symbolic(&sym);
        assert!(diff.is_empty(), "mismatches: {diff:?}");
    }

    #[test]
    fn utilization_and_stats_sane() {
        let (res, _, _) = run_gesummv(8, 8);
        assert!(res.stats.utilization > 0.0 && res.stats.utilization <= 1.0);
        assert_eq!(res.stats.max_hop, 1, "neighbour-to-neighbour only");
        assert_eq!(res.stats.pe.len(), 4);
        let total: i64 = res.stats.pe.iter().map(|p| p.iterations).sum();
        assert_eq!(total, 64);
        assert!(res.stats.max_concurrency <= 4);
    }

    #[test]
    fn per_tensor_io_traffic() {
        let (res, _, _) = run_gesummv(4, 5);
        // A and B read once per iteration (20 each); X once per column at
        // i0 = 0 (5); Y written once per row (4).
        assert_eq!(res.stats.io.per_tensor_in["A"], 20);
        assert_eq!(res.stats.io.per_tensor_in["B"], 20);
        assert_eq!(res.stats.io.per_tensor_in["X"], 5);
        assert_eq!(res.stats.io.per_tensor_out["Y"], 4);
    }
}
