//! The discrete-event simulation engine.
//!
//! PEs *sleep* between their scheduled start times `λ^J·j + λ^K·k`: the
//! engine never visits an idle cycle. Each PE keeps at most one pending
//! iteration-fire event in the [`super::queue::TimeQueue`]; popping a
//! fire executes the iteration through the shared execution core
//! (`sim::exec`), posts same-cycle stream-arrival / drain events for its
//! DRAM traffic, and schedules the PE's next in-bounds iteration. Cost is
//! `O(#statements + log #PEs)` per *executed* iteration — independent of
//! the loop bounds and of the schedule span, unlike the tick engine's
//! global materialize-and-sort.
//!
//! ## Bit-identical parity with the tick engine
//!
//! The tick engine fires events in stable `(start, pe)` order, where the
//! per-PE insertion order is the lexicographic `j`-odometer. This engine
//! reproduces that order exactly:
//!
//! * `λ^J·j` is injective on the tile `[0, p)` (it is a π-scaled
//!   mixed-radix encoding along the schedule permutation), so one PE
//!   never has two iterations at the same start time — the per-PE order
//!   is fully determined by sorting the shared [`tile_order`] walk, and
//!   the *stable* sort preserves the odometer order as its (vacuous)
//!   tie-break, matching the tick engine's stable global sort.
//! * Across PEs, same-cycle fires pop in PE-index order (the queue's
//!   `key`), which is exactly the tick engine's `(start, pe)` sort key.
//! * `tile_order` is `k`-independent (`start = λ^J·j + λ^K·k` separates),
//!   so all PEs share one sorted walk and per-PE out-of-bounds skipping
//!   is a cursor advance, never a re-sort.

use crate::polyhedral::k_grid;
use crate::pra::Pra;
use crate::schedule::Schedule;
use crate::workloads::tensor::TensorEnv;

use super::super::arch::ArchConfig;
use super::super::engine::{narrow_lambda, SimResult};
use super::super::exec;
use super::queue::TimeQueue;

/// Typed simulation events. Fires carry the PE whose cursor names the
/// iteration; stream events carry the tensor lane they account.
enum Event {
    /// A PE wakes up and executes its next scheduled iteration.
    Fire { pe: usize },
    /// One element of input tensor `tidx` arrives from DRAM through the
    /// I/O buffers (posted at the consuming iteration's cycle).
    Arrival { tidx: usize },
    /// One element of output tensor `oidx` drains to DRAM.
    Drain { oidx: usize },
}

/// Queue keys: fires use the PE index (the tick engine's tie-break);
/// stream events sort after every same-cycle fire.
const STREAM_KEY: u64 = 1 << 32;

/// The shared intra-tile walk: every `j ∈ [0, p)` with its intra-tile
/// start offset `λ^J·j`, stably sorted by that offset. `k`-independent,
/// so one walk serves every PE.
fn tile_order(n: usize, p: &[i64], lj: &[i64]) -> Vec<(i64, Vec<i64>)> {
    let cells: usize = p.iter().product::<i64>() as usize;
    let mut order: Vec<(i64, Vec<i64>)> = Vec::with_capacity(cells);
    let mut j = vec![0i64; n];
    'tile: loop {
        let jstart: i64 = lj.iter().zip(&j).map(|(l, x)| l * x).sum();
        order.push((jstart, j.clone()));
        for d in (0..n).rev() {
            j[d] += 1;
            if j[d] < p[d] {
                continue 'tile;
            }
            j[d] = 0;
            if d == 0 {
                break 'tile;
            }
        }
    }
    order.sort_by_key(|e| e.0); // stable: odometer order breaks ties
    order
}

/// Advance a PE's cursor to its next in-bounds tile cell (`i = j + p∘k`
/// inside the loop bounds), starting at `idx`. Each cell is visited at
/// most once per PE over the whole run, so skipping is amortized O(1).
fn advance(
    order: &[(i64, Vec<i64>)],
    k: &[i64],
    p: &[i64],
    bounds: &[i64],
    mut idx: usize,
) -> Option<usize> {
    while idx < order.len() {
        let j = &order[idx].1;
        let inside = j
            .iter()
            .zip(p)
            .zip(k)
            .zip(bounds)
            .all(|(((jl, pl), kl), bl)| jl + pl * kl < *bl);
        if inside {
            return Some(idx);
        }
        idx += 1;
    }
    None
}

/// Run the discrete-event engine (see module docs). Same contract and
/// bit-identical observables as [`crate::sim::simulate_tick`].
pub fn simulate_event(
    pra: &Pra,
    arch: &ArchConfig,
    schedule: &Schedule,
    params: &[i64],
    inputs: &TensorEnv,
) -> SimResult {
    let n = pra.ndims;
    let t = &arch.mapping.t;
    let bounds: Vec<i64> =
        (0..n).map(|l| params[pra.space.n_index(l)]).collect();
    let p: Vec<i64> = (0..n).map(|l| params[pra.space.p_index(l)]).collect();
    let lj = narrow_lambda(schedule.lambda_j_at(params));
    let lk = narrow_lambda(schedule.lambda_k_at(params));

    let (prog, outputs) = exec::compile(pra, params, inputs);
    let mut st =
        exec::RunState::new(&prog, arch, bounds.clone(), p.clone(), outputs);

    let order = tile_order(n, &p, &lj);
    let kcells = k_grid(t);
    let kstart: Vec<i64> = kcells
        .iter()
        .map(|k| lk.iter().zip(k).map(|(l, x)| l * x).sum())
        .collect();

    // Seed: one pending fire per PE with any in-bounds work.
    let num_pes = kcells.len();
    let mut cursor = vec![0usize; num_pes];
    let mut q: TimeQueue<Event> = TimeQueue::new();
    for pe in 0..num_pes {
        match advance(&order, &kcells[pe], &p, &bounds, 0) {
            Some(idx) => {
                cursor[pe] = idx;
                q.push(
                    kstart[pe] + order[idx].0,
                    pe as u64,
                    Event::Fire { pe },
                );
            }
            None => cursor[pe] = order.len(),
        }
    }

    // Concurrency by run-length counting: fires pop in non-decreasing
    // time (queue invariant 2), so a span-sized histogram — which would
    // reintroduce Θ(span) cost at exactly the large bounds this engine
    // exists for — is unnecessary.
    let mut cur_time = i64::MIN;
    let mut cur_run = 0i64;
    let mut max_concurrency = 0i64;
    let mut max_start = 0i64;
    let mut ibuf = vec![0i64; n];

    while let Some((time, ev)) = q.pop() {
        match ev {
            Event::Fire { pe } => {
                let (jstart, j) = &order[cursor[pe]];
                debug_assert_eq!(kstart[pe] + jstart, time);
                let k = &kcells[pe];
                ibuf.clear();
                for ((jl, pl), kl) in j.iter().zip(&p).zip(k) {
                    ibuf.push(jl + pl * kl);
                }
                exec::fire(&prog, &mut st, arch, time, pe, k, &ibuf);
                max_start = max_start.max(time);
                if time != cur_time {
                    cur_time = time;
                    cur_run = 0;
                }
                cur_run += 1;
                max_concurrency = max_concurrency.max(cur_run);
                // Same-cycle stream events for this fire's DRAM traffic.
                for &tidx in &st.stream_in {
                    q.push(
                        time,
                        STREAM_KEY + 2 * tidx as u64,
                        Event::Arrival { tidx },
                    );
                }
                for &oidx in &st.stream_out {
                    q.push(
                        time,
                        STREAM_KEY + 2 * oidx as u64 + 1,
                        Event::Drain { oidx },
                    );
                }
                st.stream_in.clear();
                st.stream_out.clear();
                // Put this PE back to sleep until its next iteration.
                match advance(&order, k, &p, &bounds, cursor[pe] + 1) {
                    Some(idx) => {
                        cursor[pe] = idx;
                        q.push(
                            kstart[pe] + order[idx].0,
                            pe as u64,
                            Event::Fire { pe },
                        );
                    }
                    None => cursor[pe] = order.len(),
                }
            }
            Event::Arrival { tidx } => st.stream_arrive(tidx),
            Event::Drain { oidx } => st.stream_drain(oidx),
        }
    }

    let span = exec::rect_span(&lj, &lk, &p, t);
    debug_assert!(max_start <= span);
    let cycles = span + schedule.lc;
    exec::finalize(&prog, st, arch, &lj, cycles, max_concurrency)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{enumerate_schedules, find_schedule};
    use crate::sim::simulate_tick;
    use crate::tiling::tile_pra;
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::tensor::synth_inputs;

    fn assert_identical(a: &SimResult, b: &SimResult) {
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.outputs, b.outputs);
        assert_eq!(a.violations, b.violations);
        assert_eq!(a.stats.pe, b.stats.pe);
        assert_eq!(a.stats.io, b.stats.io);
        assert_eq!(a.stats.max_hop, b.stats.max_hop);
        assert_eq!(a.stats.max_concurrency, b.stats.max_concurrency);
        assert_eq!(a.stats.fd_pressure, b.stats.fd_pressure);
        assert_eq!(
            a.stats.utilization.to_bits(),
            b.stats.utilization.to_bits()
        );
    }

    #[test]
    fn gesummv_parity_with_tick_engine() {
        // Ragged bounds (5×7 on 2×2 ⇒ p = (3,4), partial edge tiles)
        // exercise the cursor's out-of-bounds skipping.
        let pra = gesummv();
        let arch = ArchConfig::with_array(vec![2, 2]);
        let tiled = tile_pra(&pra, &arch.mapping);
        for bounds in [[4i64, 5], [5, 7], [8, 8]] {
            let params = arch.mapping.params_for(&bounds);
            let inputs = synth_inputs(&[
                ("A".into(), bounds.to_vec()),
                ("B".into(), bounds.to_vec()),
                ("X".into(), vec![bounds[1]]),
            ]);
            for s in enumerate_schedules(&tiled, arch.pi, None) {
                let tick = simulate_tick(&pra, &arch, &s, &params, &inputs);
                let event =
                    simulate_event(&pra, &arch, &s, &params, &inputs);
                assert_identical(&event, &tick);
            }
        }
    }

    #[test]
    fn tile_order_is_injective_and_sorted() {
        let pra = gesummv();
        let arch = ArchConfig::with_array(vec![2, 2]);
        let tiled = tile_pra(&pra, &arch.mapping);
        let s = find_schedule(&tiled, arch.pi).unwrap();
        let params = arch.mapping.params_for(&[9, 7]);
        let p: Vec<i64> =
            (0..2).map(|l| params[pra.space.p_index(l)]).collect();
        let lj = narrow_lambda(s.lambda_j_at(&params));
        let order = tile_order(2, &p, &lj);
        assert_eq!(order.len(), (p[0] * p[1]) as usize);
        // Start offsets strictly increase: λ^J·j is injective on [0, p),
        // the property the parity argument rests on.
        for w in order.windows(2) {
            assert!(w[0].0 < w[1].0, "{:?} !< {:?}", w[0], w[1]);
        }
    }
}
