//! The time-ordered event queue: a hand-rolled binary min-heap keyed by
//! `(time, key, seq)`.
//!
//! Invariants the engine relies on:
//!
//! 1. **Deterministic total order.** Entries pop in ascending `time`;
//!    ties break on the caller-supplied `key` (the event's identity — PE
//!    index for fires, stream lane for arrivals/drains) and then on
//!    insertion order (`seq`). No two pops are ever order-ambiguous, so a
//!    simulation run is a pure function of its inputs.
//! 2. **Monotone pops.** [`TimeQueue::pop`] never returns a time earlier
//!    than a previously popped one *provided* callers only push at or
//!    after the current time — the discrete-event contract. The engine
//!    exploits this to compute concurrency by run-length counting instead
//!    of a span-sized histogram.
//! 3. **No capacity coupling to model time.** Memory is proportional to
//!    the number of *pending* events (≤ one fire per PE + in-flight
//!    stream events), never to the schedule span — idle cycles cost
//!    nothing, which is the point of the event-driven engine.

/// One pending entry.
struct Entry<T> {
    time: i64,
    key: u64,
    seq: u64,
    item: T,
}

impl<T> Entry<T> {
    #[inline]
    fn rank(&self) -> (i64, u64, u64) {
        (self.time, self.key, self.seq)
    }
}

/// A deterministic binary min-heap of timed events.
pub struct TimeQueue<T> {
    heap: Vec<Entry<T>>,
    seq: u64,
}

impl<T> Default for TimeQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TimeQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        TimeQueue { heap: Vec::new(), seq: 0 }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no events are pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `item` at `time`. `key` breaks same-time ties
    /// deterministically (lower keys pop first); insertion order breaks
    /// exact `(time, key)` collisions.
    pub fn push(&mut self, time: i64, key: u64, item: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, key, seq, item });
        self.sift_up(self.heap.len() - 1);
    }

    /// Remove and return the earliest event as `(time, item)`.
    pub fn pop(&mut self) -> Option<(i64, T)> {
        if self.heap.is_empty() {
            return None;
        }
        let last = self.heap.len() - 1;
        self.heap.swap(0, last);
        let e = self.heap.pop().expect("non-empty heap");
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        Some((e.time, e.item))
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<i64> {
        self.heap.first().map(|e| e.time)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].rank() < self.heap[parent].rank() {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && self.heap[l].rank() < self.heap[smallest].rank() {
                smallest = l;
            }
            if r < n && self.heap[r].rank() < self.heap[smallest].rank() {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = TimeQueue::new();
        for (t, v) in [(5i64, "e"), (1, "a"), (3, "c"), (2, "b"), (4, "d")] {
            q.push(t, 0, v);
        }
        assert_eq!(q.len(), 5);
        assert_eq!(q.peek_time(), Some(1));
        let popped: Vec<(i64, &str)> =
            std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            popped,
            vec![(1, "a"), (2, "b"), (3, "c"), (4, "d"), (5, "e")]
        );
        assert!(q.is_empty());
    }

    #[test]
    fn same_time_breaks_on_key_then_insertion() {
        let mut q = TimeQueue::new();
        q.push(7, 2, "key2-first");
        q.push(7, 1, "key1");
        q.push(7, 2, "key2-second");
        q.push(6, 9, "earlier");
        let popped: Vec<&str> =
            std::iter::from_fn(|| q.pop()).map(|(_, v)| v).collect();
        assert_eq!(
            popped,
            vec!["earlier", "key1", "key2-first", "key2-second"]
        );
    }

    #[test]
    fn interleaved_push_pop_stays_sorted() {
        let mut q = TimeQueue::new();
        q.push(10, 0, 10);
        q.push(2, 0, 2);
        assert_eq!(q.pop(), Some((2, 2)));
        // pushes at the current time are allowed (stream events fire in
        // the same cycle as their producing iteration)
        q.push(2, 1, 22);
        q.push(5, 0, 5);
        assert_eq!(q.pop(), Some((2, 22)));
        assert_eq!(q.pop(), Some((5, 5)));
        assert_eq!(q.pop(), Some((10, 10)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn large_random_like_sequence_is_totally_ordered() {
        // Deterministic pseudo-random times via an LCG; the queue must
        // produce a non-decreasing time sequence over many entries.
        let mut q = TimeQueue::new();
        let mut x: u64 = 0x243f6a8885a308d3;
        for i in 0..1000u64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            q.push((x >> 40) as i64, i % 7, i);
        }
        let mut last = i64::MIN;
        let mut count = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
            count += 1;
        }
        assert_eq!(count, 1000);
    }
}
