//! Discrete-event simulation of the PE array.
//!
//! The tick engine ([`crate::sim::simulate_tick`]) materializes and sorts
//! every iteration of the tile schedule — Θ(#iterations) memory and a
//! global `O(E log E)` sort, which confines differential validation to
//! toy bounds. This subsystem replaces the global sort with a
//! time-ordered event queue ([`queue::TimeQueue`]) in which PEs *sleep*
//! between their scheduled start times `λ^J·j + λ^K·k` and idle cycles
//! are never visited. Both engines share one execution core
//! (`sim::exec`), so every observable — `AccessCounters`, `cycles`,
//! output tensors, violation reports, per-PE stats — is bit-identical by
//! construction; `tests/event_sim_diff.rs` enforces this over the full
//! differential grid.
//!
//! Select the engine with [`crate::sim::EngineKind`] on
//! `ArchConfig::engine`; `dse --sim-verify-frontier` uses the event
//! engine to re-simulate Pareto-frontier points at full design bounds.

mod engine;
pub mod queue;

pub use engine::simulate_event;
pub use queue::TimeQueue;
