//! Access counters tracked during simulation, and their comparison with
//! the symbolic analysis (the §V-A validation: "the analytically derived
//! access counts … match the simulation results exactly").

use std::collections::BTreeMap;

use crate::analysis::CountsBreakdown;
use crate::energy::{EnergyTable, MemoryClass};

/// Raw event counters of a simulation run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AccessCounters {
    /// Memory accesses by class.
    pub mem: BTreeMap<MemoryClass, i128>,
    /// Adder activations.
    pub adds: i128,
    /// Multiplier activations.
    pub muls: i128,
    /// Statement executions.
    pub executions: i128,
}

impl AccessCounters {
    /// Count one access.
    pub fn touch(&mut self, class: MemoryClass) {
        *self.mem.entry(class).or_insert(0) += 1;
    }

    /// Count `n` accesses.
    pub fn touch_n(&mut self, class: MemoryClass, n: i128) {
        *self.mem.entry(class).or_insert(0) += n;
    }

    /// Merge another counter set.
    pub fn merge(&mut self, other: &AccessCounters) {
        for (&c, &v) in &other.mem {
            self.touch_n(c, v);
        }
        self.adds += other.adds;
        self.muls += other.muls;
        self.executions += other.executions;
    }

    /// Energy implied by the counters (the simulation-side `E_tot`).
    pub fn energy_pj(&self, table: &EnergyTable) -> f64 {
        let mem: f64 = self
            .mem
            .iter()
            .map(|(&c, &n)| n as f64 * table.access(c))
            .sum();
        mem + self.adds as f64 * table.add_pj + self.muls as f64 * table.mul_pj
    }

    /// Field-by-field comparison with a symbolic [`CountsBreakdown`].
    /// Returns human-readable mismatches (empty = exact match).
    pub fn diff_symbolic(&self, sym: &CountsBreakdown) -> Vec<String> {
        let mut out = Vec::new();
        for &c in &MemoryClass::ALL {
            let a = self.mem.get(&c).copied().unwrap_or(0);
            let b = sym.mem.get(&c).copied().unwrap_or(0);
            if a != b {
                out.push(format!("{c}: simulated {a} != symbolic {b}"));
            }
        }
        if self.adds != sym.adds {
            out.push(format!("adds: simulated {} != symbolic {}", self.adds, sym.adds));
        }
        if self.muls != sym.muls {
            out.push(format!("muls: simulated {} != symbolic {}", self.muls, sym.muls));
        }
        if self.executions != sym.executions {
            out.push(format!(
                "executions: simulated {} != symbolic {}",
                self.executions, sym.executions
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_and_merge() {
        let mut a = AccessCounters::default();
        a.touch(MemoryClass::Rd);
        a.touch(MemoryClass::Rd);
        a.touch_n(MemoryClass::Dram, 5);
        a.adds = 3;
        let mut b = AccessCounters::default();
        b.touch(MemoryClass::Rd);
        b.muls = 2;
        a.merge(&b);
        assert_eq!(a.mem[&MemoryClass::Rd], 3);
        assert_eq!(a.mem[&MemoryClass::Dram], 5);
        assert_eq!(a.adds, 3);
        assert_eq!(a.muls, 2);
    }

    #[test]
    fn energy_accounting() {
        let t = EnergyTable::table1_45nm();
        let mut a = AccessCounters::default();
        a.touch_n(MemoryClass::Fd, 12);
        a.touch_n(MemoryClass::Id, 4);
        a.touch_n(MemoryClass::Rd, 16);
        // Example 9 contribution: 12·0.35 + 4·0.24 + 16·0.12 = 7.08
        assert!((a.energy_pj(&t) - 7.08).abs() < 1e-9);
    }

    #[test]
    fn diff_reports_mismatches() {
        let mut a = AccessCounters::default();
        a.touch(MemoryClass::Rd);
        let sym = CountsBreakdown::default();
        let d = a.diff_symbolic(&sym);
        assert_eq!(d.len(), 1);
        assert!(d[0].contains("RD"));
        // and an exact match is silent
        let b = AccessCounters::default();
        assert!(b.diff_symbolic(&sym).is_empty());
    }
}
