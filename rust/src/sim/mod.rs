//! Cycle-accurate TCPA simulator — the paper's validation baseline (§V-A).
//!
//! Executes a tiled + scheduled loop nest on a modeled PE array with real
//! data values, counting every memory access by class and every operation.
//! Its cost grows with the iteration-space volume — exactly the scaling the
//! symbolic analysis (Fig. 4) removes — and its counts must equal the
//! symbolic counts **exactly**.

pub mod arch;
pub mod counters;
pub mod engine;
pub mod event;
mod exec;
pub mod stats;

pub use arch::{ArchConfig, EngineKind, FuLatencies, RegFileSizes};
pub use counters::AccessCounters;
pub use engine::{simulate, simulate_tick, SimResult};
pub use event::simulate_event;
pub use stats::{IoStats, PeStats, SimStats};
