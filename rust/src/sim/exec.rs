//! Shared execution core of the two simulation engines.
//!
//! The exhaustive tick engine ([`super::engine::simulate_tick`]) and the
//! discrete-event engine ([`super::event::simulate_event`]) execute the
//! *same* precompiled statement program ([`Program`]) with the same
//! per-iteration firing semantics and accounting ([`fire`] on a shared
//! [`RunState`]); they differ only in how the stream of
//! `(start, pe, iteration)` fire events is produced — global
//! materialize-and-sort versus a time-ordered event queue. Keeping every
//! observable side effect here makes the engine differential
//! (`tests/event_sim_diff.rs`) a test of exactly the scheduling logic.
//!
//! I/O streaming is decoupled from firing: a fire records which tensor
//! elements arrived from / drained to DRAM in [`RunState::stream_in`] /
//! [`RunState::stream_out`], and the engine decides *when* to account
//! them — immediately ([`RunState::commit_streams`], the tick engine) or
//! via stream-arrival / drain events popped from the time queue
//! ([`RunState::stream_arrive`] / [`RunState::stream_drain`], the event
//! engine). Both paths are pure sums at the same timestamp, so totals are
//! identical by construction.

use std::collections::BTreeMap;

use crate::energy::MemoryClass;
use crate::pra::{Lhs, Op, Operand, Pra, Rdg};
use crate::workloads::tensor::{Tensor, TensorEnv};

use super::arch::ArchConfig;
use super::counters::AccessCounters;
use super::engine::SimResult;
use super::stats::{IoStats, PeStats, SimStats};

/// Precompiled operand.
pub(super) enum ExecArg {
    /// Input tensor read: resolved tensor index + affine map.
    Tensor { tidx: usize, rows: Vec<Vec<i64>>, offset: Vec<i64> },
    /// Intra-iteration variable read (RD).
    VarZero { vidx: usize },
    /// Dependence-carrying variable read (FD/ID by geometry).
    VarDep { vidx: usize, dep: Vec<i64> },
}

/// Precompiled left-hand side.
pub(super) enum ExecLhs {
    Var { vidx: usize },
    Tensor { oidx: usize, rows: Vec<Vec<i64>>, offset: Vec<i64> },
}

/// Precompiled statement: conditions with parameter constants already
/// folded, operands resolved to indices.
pub(super) struct ExecStmt {
    pub qi: usize,
    /// `Σ a·i + c ≥ 0` per condition.
    pub conds: Vec<(Vec<i64>, i64)>,
    pub op: Op,
    pub adds: u32,
    pub muls: u32,
    pub args: Vec<ExecArg>,
    pub lhs: ExecLhs,
}

#[inline]
pub(super) fn apply_map(
    rows: &[Vec<i64>],
    offset: &[i64],
    i: &[i64],
    out: &mut Vec<i64>,
) {
    out.clear();
    for (row, off) in rows.iter().zip(offset) {
        let mut v = *off;
        for (a, x) in row.iter().zip(i) {
            v += a * x;
        }
        out.push(v);
    }
}

/// The precompiled program: statements in intra-iteration topological
/// order plus the resolved name tables.
pub(super) struct Program<'a> {
    pub pra: &'a Pra,
    pub exec: Vec<ExecStmt>,
    pub var_names: Vec<&'a str>,
    pub in_names: Vec<&'a String>,
    pub in_tensors: Vec<&'a Tensor>,
    pub out_names: Vec<String>,
}

/// Precompile a PRA for execution at `params` (name → index resolution,
/// parameter folding) and allocate the zeroed output tensors.
pub(super) fn compile<'a>(
    pra: &'a Pra,
    params: &[i64],
    inputs: &'a TensorEnv,
) -> (Program<'a>, Vec<Tensor>) {
    let rdg = Rdg::build(pra);
    let order = rdg
        .intra_iteration_order(pra.statements.len())
        .expect("PRA has an intra-iteration dependence cycle");

    let mut var_names: Vec<&str> = Vec::new();
    let var_idx = |name: &str, names: &[&str]| -> usize {
        names
            .iter()
            .position(|&x| x == name)
            .unwrap_or_else(|| panic!("unknown var {name}"))
    };
    for s in &pra.statements {
        if let Lhs::Var(v) = &s.lhs {
            if !var_names.iter().any(|&x| x == v.as_str()) {
                var_names.push(v);
            }
        }
    }
    let in_names: Vec<&String> = inputs.keys().collect();
    let in_tensors: Vec<&Tensor> = inputs.values().collect();
    let mut out_names: Vec<String> = Vec::new();
    let mut outputs: Vec<Tensor> = Vec::new();
    for s in &pra.statements {
        if let Lhs::Tensor { name, .. } = &s.lhs {
            if !out_names.contains(name) {
                let decl = pra.tensor(name).expect("undeclared output");
                out_names.push(name.clone());
                outputs.push(Tensor::zeros(decl.concrete_shape(params)));
            }
        }
    }
    let exec: Vec<ExecStmt> = order
        .iter()
        .map(|&qi| {
            let s = &pra.statements[qi];
            let conds = s
                .cond
                .iter()
                .map(|c| (c.a.clone(), c.konst.eval(params)))
                .collect();
            let args = s
                .args
                .iter()
                .map(|a| match a {
                    Operand::Tensor { name, map } => ExecArg::Tensor {
                        tidx: in_names
                            .iter()
                            .position(|x| x.as_str() == name)
                            .unwrap_or_else(|| {
                                panic!("missing input {name}")
                            }),
                        rows: map.rows.clone(),
                        offset: map.offset.clone(),
                    },
                    Operand::Var { name, dep } => {
                        let vidx = var_idx(name, &var_names);
                        if dep.iter().all(|&d| d == 0) {
                            ExecArg::VarZero { vidx }
                        } else {
                            ExecArg::VarDep { vidx, dep: dep.clone() }
                        }
                    }
                })
                .collect();
            let lhs = match &s.lhs {
                Lhs::Var(name) => {
                    ExecLhs::Var { vidx: var_idx(name, &var_names) }
                }
                Lhs::Tensor { name, map } => ExecLhs::Tensor {
                    oidx: out_names.iter().position(|x| x == name).unwrap(),
                    rows: map.rows.clone(),
                    offset: map.offset.clone(),
                },
            };
            let (adds, muls) =
                crate::energy::EnergyTable::op_activations(s.op);
            ExecStmt { qi, conds, op: s.op, adds, muls, args, lhs }
        })
        .collect();
    (Program { pra, exec, var_names, in_names, in_tensors, out_names }, outputs)
}

/// Flat per-class counter slots, folded into the public `BTreeMap` by
/// [`finalize`] (in `MemoryClass::ALL` order).
pub(super) const RD: usize = 0;
pub(super) const FD: usize = 1;
pub(super) const ID: usize = 2;
pub(super) const OD: usize = 3;
pub(super) const IOB: usize = 4;
pub(super) const DR: usize = 5;

/// All mutable state of a simulation run: value stores, counters,
/// statistics, violations, and scratch buffers. Engine-agnostic — every
/// observable a [`SimResult`] reports lives here (except the cycle count
/// and concurrency profile, which each engine derives from its own event
/// ordering).
pub(super) struct RunState {
    n: usize,
    bounds: Vec<i64>,
    p: Vec<i64>,
    pub mem: [i128; 6],
    pub counters: AccessCounters,
    pub pe_stats: Vec<PeStats>,
    pub per_tensor_in: Vec<i64>,
    pub per_tensor_out: Vec<i64>,
    pub io: IoStats,
    pub violations: Vec<String>,
    pub max_hop: i64,
    pub last_start_per_pe: Vec<i64>,
    pub outputs: Vec<Tensor>,
    /// Tensor input indices streamed in by the most recent [`fire`].
    pub stream_in: Vec<usize>,
    /// Output tensor indices streamed out by the most recent [`fire`].
    pub stream_out: Vec<usize>,
    var_data: Vec<Vec<f32>>,
    var_written: Vec<Vec<bool>>,
    start_by_flat: Vec<i64>,
    argbuf: Vec<f32>,
    idxbuf: Vec<i64>,
    srcbuf: Vec<i64>,
}

impl RunState {
    pub(super) fn new(
        prog: &Program,
        arch: &ArchConfig,
        bounds: Vec<i64>,
        p: Vec<i64>,
        outputs: Vec<Tensor>,
    ) -> RunState {
        let n = bounds.len();
        let iter_total: usize = bounds.iter().product::<i64>() as usize;
        let num_pes = arch.num_pes() as usize;
        RunState {
            n,
            bounds,
            p,
            mem: [0; 6],
            counters: AccessCounters::default(),
            pe_stats: vec![PeStats::default(); num_pes],
            per_tensor_in: vec![0; prog.in_names.len()],
            per_tensor_out: vec![0; prog.out_names.len()],
            io: IoStats::default(),
            violations: Vec::new(),
            max_hop: 0,
            last_start_per_pe: vec![i64::MIN; num_pes],
            outputs,
            stream_in: Vec::with_capacity(4),
            stream_out: Vec::with_capacity(2),
            var_data: vec![vec![0.0; iter_total]; prog.var_names.len()],
            var_written: vec![vec![false; iter_total]; prog.var_names.len()],
            start_by_flat: vec![i64::MIN; iter_total],
            argbuf: Vec::with_capacity(3),
            idxbuf: Vec::with_capacity(4),
            srcbuf: vec![0; n],
        }
    }

    fn flat_of(&self, i: &[i64]) -> Option<usize> {
        let mut off: i64 = 0;
        for (&x, &b) in i.iter().zip(&self.bounds) {
            if x < 0 || x >= b {
                return None;
            }
            off = off * b + x;
        }
        Some(off as usize)
    }

    /// Account the most recent fire's tensor traffic immediately (the
    /// tick engine's in-line streaming path).
    pub(super) fn commit_streams(&mut self) {
        let RunState {
            stream_in, stream_out, io, per_tensor_in, per_tensor_out, ..
        } = self;
        for &t in stream_in.iter() {
            io.elements_in += 1;
            per_tensor_in[t] += 1;
        }
        for &o in stream_out.iter() {
            io.elements_out += 1;
            per_tensor_out[o] += 1;
        }
        stream_in.clear();
        stream_out.clear();
    }

    /// One element of input tensor `tidx` arrived from DRAM (the event
    /// engine's stream-arrival handler).
    pub(super) fn stream_arrive(&mut self, tidx: usize) {
        self.io.elements_in += 1;
        self.per_tensor_in[tidx] += 1;
    }

    /// One element of output tensor `oidx` drained to DRAM (the event
    /// engine's drain handler).
    pub(super) fn stream_drain(&mut self, oidx: usize) {
        self.io.elements_out += 1;
        self.per_tensor_out[oidx] += 1;
    }
}

/// Fire iteration `i` on PE `pe` (tile cell `k`) at schedule time
/// `start`: π-spacing check, then every statement in topological order —
/// condition predication, operand reads with geometric FD/ID
/// classification and causality checks, the operation, and the
/// register/tensor write-back. Tensor traffic is recorded in
/// `stream_in`/`stream_out` for the engine to account (see module docs).
pub(super) fn fire(
    prog: &Program,
    st: &mut RunState,
    arch: &ArchConfig,
    start: i64,
    pe: usize,
    k: &[i64],
    i: &[i64],
) {
    let n = st.n;
    let iflat = st.flat_of(i).expect("event inside iteration space");
    st.start_by_flat[iflat] = start;
    st.stream_in.clear();
    st.stream_out.clear();
    if st.last_start_per_pe[pe] != i64::MIN
        && start - st.last_start_per_pe[pe] < arch.pi
    {
        st.violations.push(format!(
            "PE {pe}: iterations {} cycles apart (π = {})",
            start - st.last_start_per_pe[pe],
            arch.pi
        ));
    }
    st.last_start_per_pe[pe] = start;
    let ps = &mut st.pe_stats[pe];
    ps.iterations += 1;
    ps.first_cycle = ps.first_cycle.min(start);
    ps.last_cycle = ps.last_cycle.max(start);

    'stmts: for es in &prog.exec {
        // condition check (constants pre-folded)
        for (a, c) in &es.conds {
            let mut v = *c;
            for (av, xv) in a.iter().zip(i) {
                v += av * xv;
            }
            if v < 0 {
                continue 'stmts;
            }
        }
        st.counters.executions += 1;
        st.argbuf.clear();
        for arg in &es.args {
            let v = match arg {
                ExecArg::Tensor { tidx, rows, offset } => {
                    st.mem[DR] += 1;
                    st.mem[IOB] += 1;
                    st.mem[ID] += 1;
                    st.stream_in.push(*tidx);
                    apply_map(rows, offset, i, &mut st.idxbuf);
                    prog.in_tensors[*tidx].get(&st.idxbuf)
                }
                ExecArg::VarZero { vidx } => {
                    st.mem[RD] += 1;
                    st.pe_stats[pe].rd_reads += 1;
                    debug_assert!(st.var_written[*vidx][iflat]);
                    st.var_data[*vidx][iflat]
                }
                ExecArg::VarDep { vidx, dep } => {
                    for l in 0..n {
                        st.srcbuf[l] = i[l] - dep[l];
                    }
                    // geometric classification by source tile
                    let mut same_tile = true;
                    let mut hop = 0i64;
                    for l in 0..n {
                        let kt = st.srcbuf[l].div_euclid(st.p[l]);
                        if kt != k[l] {
                            same_tile = false;
                            hop += (kt - k[l]).abs();
                        }
                    }
                    if same_tile {
                        st.mem[FD] += 1;
                        st.pe_stats[pe].fd_reads += 1;
                    } else {
                        st.mem[ID] += 1;
                        st.pe_stats[pe].id_reads += 1;
                        st.max_hop = st.max_hop.max(hop);
                    }
                    match st.flat_of(&st.srcbuf) {
                        Some(soff) if st.var_written[*vidx][soff] => {
                            // dynamic causality check
                            let ss = st.start_by_flat[soff];
                            if ss != i64::MIN && ss >= start {
                                st.violations.push(format!(
                                    "{}@{i:?}: source {:?} starts \
                                     at {ss} >= {start}",
                                    prog.pra.statements[es.qi].name,
                                    st.srcbuf
                                ));
                            }
                            st.var_data[*vidx][soff]
                        }
                        _ => {
                            st.violations.push(format!(
                                "{}@{i:?}: read of {}[{:?}] \
                                 before definition",
                                prog.pra.statements[es.qi].name,
                                prog.var_names[*vidx],
                                st.srcbuf
                            ));
                            0.0
                        }
                    }
                }
            };
            st.argbuf.push(v);
        }
        st.counters.adds += es.adds as i128;
        st.counters.muls += es.muls as i128;
        let value = es.op.apply(&st.argbuf);
        match &es.lhs {
            ExecLhs::Var { vidx } => {
                st.mem[RD] += 1;
                st.pe_stats[pe].rd_writes += 1;
                st.var_data[*vidx][iflat] = value;
                st.var_written[*vidx][iflat] = true;
            }
            ExecLhs::Tensor { oidx, rows, offset } => {
                st.mem[OD] += 1;
                st.mem[IOB] += 1;
                st.mem[DR] += 1;
                st.stream_out.push(*oidx);
                apply_map(rows, offset, i, &mut st.idxbuf);
                st.outputs[*oidx].set(&st.idxbuf, value);
            }
        }
    }
}

/// The full rectangular schedule span `λ^J·(p−1) + λ^K·(t−1)` (Eq. 8
/// without `L_c`) — both engines' cycle anchor.
pub(super) fn rect_span(lj: &[i64], lk: &[i64], p: &[i64], t: &[i64]) -> i64 {
    (0..p.len()).map(|l| lj[l] * (p[l] - 1) + lk[l] * (t[l] - 1)).sum()
}

/// Fold the run state into a [`SimResult`]: public counter map,
/// per-tensor traffic, static FD-pressure check, utilization and
/// streaming high-water derived from `max_concurrency`.
pub(super) fn finalize(
    prog: &Program,
    mut st: RunState,
    arch: &ArchConfig,
    lj: &[i64],
    cycles: i64,
    max_concurrency: i64,
) -> SimResult {
    debug_assert!(
        st.stream_in.is_empty() && st.stream_out.is_empty(),
        "engine finished with unaccounted stream traffic"
    );
    for (slot, &class) in MemoryClass::ALL.iter().enumerate() {
        if st.mem[slot] != 0 {
            st.counters.touch_n(class, st.mem[slot]);
        }
    }
    for (name, cnt) in prog.in_names.iter().zip(&st.per_tensor_in) {
        if *cnt > 0 {
            st.io.per_tensor_in.insert((*name).clone(), *cnt);
        }
    }
    for (name, cnt) in prog.out_names.iter().zip(&st.per_tensor_out) {
        if *cnt > 0 {
            st.io.per_tensor_out.insert(name.clone(), *cnt);
        }
    }
    let outputs: TensorEnv = prog
        .out_names
        .iter()
        .cloned()
        .zip(st.outputs)
        .collect::<BTreeMap<_, _>>();

    // ---- static FD-pressure check (FIFO depth = schedule distance) -----
    let mut fd_pressure = 0i64;
    for s in &prog.pra.statements {
        for arg in &s.args {
            if let Operand::Var { dep, .. } = arg {
                if dep.iter().any(|&d| d != 0) {
                    let dist: i64 = dep
                        .iter()
                        .zip(lj)
                        .map(|(&d, &l)| d * l)
                        .sum::<i64>()
                        / arch.pi.max(1);
                    fd_pressure += dist.max(0);
                }
            }
        }
    }
    if fd_pressure > arch.regs.fd as i64 {
        st.violations.push(format!(
            "FD pressure {fd_pressure} exceeds register file size {}",
            arch.regs.fd
        ));
    }

    let num_pes = arch.num_pes() as usize;
    let total_iters: i128 =
        st.pe_stats.iter().map(|s| s.iterations as i128).sum();
    let utilization = if cycles > 0 {
        total_iters as f64 / (cycles as f64 * num_pes as f64)
    } else {
        0.0
    };
    st.io.max_per_cycle = {
        let max_stream_args = prog
            .pra
            .statements
            .iter()
            .map(|s| {
                s.args
                    .iter()
                    .filter(|a| matches!(a, Operand::Tensor { .. }))
                    .count()
            })
            .max()
            .unwrap_or(0);
        max_concurrency as usize * max_stream_args
    };
    let stats = SimStats {
        pe: st.pe_stats,
        io: st.io,
        max_hop: st.max_hop,
        max_concurrency,
        utilization,
        fd_pressure,
    };
    SimResult {
        counters: st.counters,
        outputs,
        cycles,
        stats,
        violations: st.violations,
    }
}
