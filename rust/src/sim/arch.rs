//! Architecture description of the simulated TCPA — the Rust mirror of the
//! XML-based architectural description driving the authors' simulator
//! (§V-A). Captures the array geometry, per-PE register-file sizes,
//! functional-unit latencies, and I/O buffer capacities.

use crate::tiling::ArrayMapping;

/// Functional-unit latencies in cycles (`w_q` of Eq. 8; the paper's
/// examples use 1 for every operation).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FuLatencies {
    pub add: i64,
    pub mul: i64,
    pub copy: i64,
}

impl Default for FuLatencies {
    fn default() -> Self {
        FuLatencies { add: 1, mul: 1, copy: 1 }
    }
}

/// Register-file sizes per PE (entries per class). The simulator tracks
/// high-water occupancy against these and reports pressure violations —
/// mapping decisions that exceed physical register files would not be
/// realizable on the modeled TCPA.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegFileSizes {
    /// General-purpose registers (RD).
    pub rd: usize,
    /// Feedback registers (FD) — sized like the ALPACA-class arrays'
    /// feedback FIFOs.
    pub fd: usize,
    /// Input registers (ID).
    pub id: usize,
    /// Output registers (OD).
    pub od: usize,
}

impl Default for RegFileSizes {
    fn default() -> Self {
        RegFileSizes { rd: 16, fd: 64, id: 8, od: 8 }
    }
}

/// Which simulation engine `sim::simulate` dispatches to. Both produce
/// bit-identical observables (`tests/event_sim_diff.rs`); they differ
/// only in scaling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Materialize-and-sort oracle: Θ(#iterations) memory, global event
    /// sort. Trustworthy by its simplicity — the small-bounds reference.
    #[default]
    Tick,
    /// Discrete-event engine (`sim::event`): PEs sleep between scheduled
    /// start times, idle cycles are skipped, per-iteration cost is
    /// bounds-independent. The one to use at large bounds.
    Event,
}

/// Full architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchConfig {
    /// Array geometry: tiles per loop dimension.
    pub mapping: ArrayMapping,
    pub regs: RegFileSizes,
    pub fu: FuLatencies,
    /// I/O buffer capacity per array border, in elements.
    pub iob_capacity: usize,
    /// Initiation interval the PEs are modulo-scheduled for.
    pub pi: i64,
    /// Simulation engine selection (default [`EngineKind::Tick`]).
    pub engine: EngineKind,
}

impl ArchConfig {
    /// An array of the given shape with default PE parameters.
    pub fn with_array(t: Vec<i64>) -> Self {
        ArchConfig {
            mapping: ArrayMapping::new(t),
            regs: RegFileSizes::default(),
            fu: FuLatencies::default(),
            iob_capacity: 16 * 1024,
            pi: 1,
            engine: EngineKind::default(),
        }
    }

    /// The paper's evaluation target: an 8×8 PE grid (for 2-deep nests;
    /// deeper nests keep extra dimensions PE-local).
    pub fn array_8x8_for(ndims: usize) -> Self {
        let mut t = vec![8, 8];
        while t.len() < ndims {
            t.push(1);
        }
        t.truncate(ndims);
        Self::with_array(t)
    }

    /// Number of PEs.
    pub fn num_pes(&self) -> i64 {
        self.mapping.num_pes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sizes() {
        let a = ArchConfig::with_array(vec![2, 2]);
        assert_eq!(a.num_pes(), 4);
        assert_eq!(a.regs.rd, 16);
        assert_eq!(a.fu.mul, 1);
        assert_eq!(a.pi, 1);
        // the tick oracle stays the default engine
        assert_eq!(a.engine, EngineKind::Tick);
    }

    #[test]
    fn array_8x8_pads_depth() {
        let a = ArchConfig::array_8x8_for(3);
        assert_eq!(a.mapping.t, vec![8, 8, 1]);
        assert_eq!(a.num_pes(), 64);
        let b = ArchConfig::array_8x8_for(2);
        assert_eq!(b.mapping.t, vec![8, 8]);
    }
}
