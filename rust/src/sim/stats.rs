//! Per-component statistics gathered by the simulation engine: PE activity,
//! interconnect hop distances, and I/O buffer traffic.

use std::collections::BTreeMap;

/// Per-PE activity counters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PeStats {
    /// Iterations executed on this PE.
    pub iterations: i64,
    /// First / last cycle with an iteration start.
    pub first_cycle: i64,
    pub last_cycle: i64,
    /// Register-file activity.
    pub rd_reads: i64,
    pub rd_writes: i64,
    pub fd_reads: i64,
    pub id_reads: i64,
}

impl Default for PeStats {
    fn default() -> Self {
        PeStats {
            iterations: 0,
            first_cycle: i64::MAX,
            last_cycle: i64::MIN,
            rd_reads: 0,
            rd_writes: 0,
            fd_reads: 0,
            id_reads: 0,
        }
    }
}

/// I/O buffer / DMA traffic counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IoStats {
    /// Elements streamed in from DRAM (through the I/O buffers).
    pub elements_in: i64,
    /// Elements streamed out to DRAM.
    pub elements_out: i64,
    /// Per-tensor traffic.
    pub per_tensor_in: BTreeMap<String, i64>,
    pub per_tensor_out: BTreeMap<String, i64>,
    /// Streaming high-water estimate (elements per cycle).
    pub max_per_cycle: usize,
}

/// Aggregate simulation statistics.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub pe: Vec<PeStats>,
    pub io: IoStats,
    /// Longest interconnect hop observed (Manhattan distance between
    /// producer and consumer tiles); 1 on a healthy neighbour-connected
    /// mapping.
    pub max_hop: i64,
    /// Maximum number of PEs starting an iteration in the same cycle.
    pub max_concurrency: i64,
    /// Fraction of PE·cycles doing useful work.
    pub utilization: f64,
    /// Static feedback-register (FIFO) demand per PE.
    pub fd_pressure: i64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults() {
        let p = PeStats::default();
        assert_eq!(p.iterations, 0);
        assert!(p.first_cycle > p.last_cycle); // sentinel until first event
        let s = SimStats::default();
        assert_eq!(s.max_hop, 0);
        assert_eq!(s.io.elements_in, 0);
    }
}
