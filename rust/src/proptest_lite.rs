//! Minimal property-testing harness (the offline vendor tree has no
//! proptest). A seeded xorshift generator drives randomized cases; on
//! failure the seed and the first failing case are reported so runs
//! reproduce exactly.

/// Deterministic xorshift64* PRNG.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    /// Seeded construction (seed 0 is remapped: xorshift state must be
    /// non-zero).
    pub fn new(seed: u64) -> Self {
        Rng(if seed == 0 { 0x9e3779b97f4a7c15 } else { seed })
    }

    /// Next raw value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.next_u64() % items.len() as u64) as usize]
    }
}

/// Run `cases` randomized property cases. `gen` draws an input from the
/// RNG; `prop` returns `Err(description)` on failure. Panics with the
/// seed, case index, and debug-rendered input of the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name} failed (seed {seed}, case {case}):\n\
                 input: {input:?}\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..10 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.i64_in(-3, 5);
            assert!((-3..=5).contains(&v));
        }
        let pick = *r.choose(&[1, 2, 3]);
        assert!([1, 2, 3].contains(&pick));
    }

    #[test]
    fn check_passes_good_property() {
        check(
            "sum-commutes",
            1,
            100,
            |r| (r.i64_in(0, 9), r.i64_in(0, 9)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property bad failed")]
    fn check_reports_failure() {
        check(
            "bad",
            1,
            10,
            |r| r.i64_in(0, 9),
            |&v| {
                if v < 100 {
                    Err(format!("v = {v}"))
                } else {
                    Ok(())
                }
            },
        );
    }
}
