//! Checkpoint journal: crash-tolerant persistence of completed
//! design points.
//!
//! A sweep with `--checkpoint FILE` records every *committed* point —
//! its full candidate list or its failure message — in a small
//! line-oriented text file, flushed in batches through the same
//! tmp-file + atomic-rename discipline as [`crate::dse::persist`]. A
//! later `--resume` run replays the journal **bit-for-bit** (all
//! `f64`s travel as `to_bits()` hex, so replayed energies and EDPs
//! are exactly the originals; see [`ReplayedCandidate`] for the two
//! volatile timing fields that are deliberately excluded) and
//! evaluates only the remainder.
//!
//! Robustness contract, in decreasing order of trust:
//!
//! - **Stale journal** (header parses but its workload fingerprint,
//!   space fingerprint or point count disagree with the resuming
//!   sweep): rejected **loudly** with the mismatching field named.
//!   Replaying points of an edited workload would silently fabricate
//!   a frontier; the file is left in place for inspection.
//! - **Corrupt header** (magic or fields don't scan): the file is
//!   quarantined to `FILE.corrupt` — never silently ignored, never
//!   replayed — and the error says so.
//! - **Corrupt record** (checksum mismatch): that single point is
//!   skipped with a warning and re-evaluated; its neighbors replay.
//! - **Truncated tail** (the crash landed mid-write): the partial
//!   line is dropped with a warning; every complete record replays.
//!
//! The header binds a journal to one `(workload, space)` pair via
//! [`crate::dse::cache::workload_fingerprint`] and
//! [`space_fingerprint`]; record indices are positions in the
//! deterministic `DesignSpace` enumeration, which is what makes
//! replay-by-index sound.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::dse::cache::workload_fingerprint;
use crate::dse::explore::EvaluatedPoint;
use crate::dse::space::{DesignPoint, DesignSpace, ScheduleChoice, Shard};
use crate::pra::Workload;

/// First line of every journal; bump the version on format changes so
/// old files are quarantined, not misparsed. v2 added the `shard`
/// header line.
pub const MAGIC: &str = "tcpa-dse-journal v2";

/// Deterministic structural fingerprint of a [`DesignSpace`] — the
/// same derive-`Debug`-and-hash idiom as
/// [`crate::dse::cache::workload_fingerprint`], and like it **not**
/// stable across compiler releases; ideal for "is this the same
/// space?" checks within one binary, which is all resume needs.
pub fn space_fingerprint(space: &DesignSpace) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h = DefaultHasher::new();
    format!("{space:?}").hash(&mut h);
    h.finish()
}

/// The identity block at the top of a journal file. A resume run
/// recomputes its own header and requires an exact match before
/// replaying anything.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalHeader {
    /// Raw workload name (display only; the fingerprint is the check).
    pub workload: String,
    /// `workload_fingerprint` of the sweep's workload.
    pub workload_fp: u64,
    /// [`space_fingerprint`] of the sweep's design space.
    pub space_fp: u64,
    /// Total number of enumerated design points across **all** shards
    /// (`k/n` denominators and the record-index upper bound; record
    /// indices are always global).
    pub points: usize,
    /// Which slice of the enumeration this journal owns (`1/1` for an
    /// unsharded sweep). Bound into the header so a shard journal can
    /// never be resumed — or merged — as a different shard.
    pub shard: Shard,
}

impl JournalHeader {
    /// The header binding `(wl, space)` with `points` enumerated
    /// design points, for an unsharded sweep.
    pub fn new(wl: &Workload, space: &DesignSpace, points: usize) -> Self {
        JournalHeader {
            workload: wl.name.clone(),
            workload_fp: workload_fingerprint(wl),
            space_fp: space_fingerprint(space),
            points,
            shard: Shard::solo(),
        }
    }

    /// The same header bound to one shard of the enumeration.
    pub fn with_shard(mut self, shard: Shard) -> Self {
        self.shard = shard;
        self
    }

    fn render(&self) -> String {
        format!(
            "{MAGIC}\nworkload {}\nworkload_fp {:016x}\n\
             space_fp {:016x}\npoints {}\nshard {}\n",
            self.workload,
            self.workload_fp,
            self.space_fp,
            self.points,
            self.shard.label()
        )
    }

    /// Parse the six header lines; `None` means *corrupt* (the
    /// caller quarantines), not *stale* (that is a field-level
    /// mismatch diagnosed separately).
    fn parse(lines: &mut std::str::Lines) -> Option<Self> {
        if lines.next()? != MAGIC {
            return None;
        }
        let workload = lines.next()?.strip_prefix("workload ")?.to_string();
        let workload_fp = u64::from_str_radix(
            lines.next()?.strip_prefix("workload_fp ")?,
            16,
        )
        .ok()?;
        let space_fp = u64::from_str_radix(
            lines.next()?.strip_prefix("space_fp ")?,
            16,
        )
        .ok()?;
        let points: usize =
            lines.next()?.strip_prefix("points ")?.parse().ok()?;
        let shard =
            Shard::parse(lines.next()?.strip_prefix("shard ")?).ok()?;
        Some(JournalHeader { workload, workload_fp, space_fp, points, shard })
    }

    /// First field (name, value-in-file, value-expected) that
    /// disagrees with `expected`, for the loud stale-journal error.
    fn mismatch(
        &self,
        expected: &JournalHeader,
    ) -> Option<(&'static str, String, String)> {
        if self.workload_fp != expected.workload_fp {
            Some((
                "workload_fp",
                format!("{:016x}", self.workload_fp),
                format!("{:016x}", expected.workload_fp),
            ))
        } else if self.space_fp != expected.space_fp {
            Some((
                "space_fp",
                format!("{:016x}", self.space_fp),
                format!("{:016x}", expected.space_fp),
            ))
        } else if self.points != expected.points {
            Some((
                "points",
                self.points.to_string(),
                expected.points.to_string(),
            ))
        } else if self.shard != expected.shard {
            Some((
                "shard",
                self.shard.label(),
                expected.shard.label(),
            ))
        } else if self.workload != expected.workload {
            Some((
                "workload",
                self.workload.clone(),
                expected.workload.clone(),
            ))
        } else {
            None
        }
    }
}

/// One schedule candidate of a completed point, with every *stable*
/// field an [`EvaluatedPoint`] carries beyond the design point itself.
/// `f64`s round-trip through `to_bits`, so replay is bit-for-bit.
///
/// The two volatile fields — `analysis_ms` and `cache_hit` — are
/// deliberately **not** journalled: they are wall-clock noise that
/// would make the journal bytes depend on worker count and machine
/// load (the explorer pins that a cancelled serial run and a
/// cancelled 4-worker run flush *identical* journals), and no report
/// emits them. Replay restores them as `0.0` / `true`: a replayed
/// point genuinely cost no analysis time this run.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayedCandidate {
    /// Which enumerated schedule the candidate used.
    pub schedule: ScheduleChoice,
    /// Display label of the schedule (stored, not recomputed, so a
    /// future label tweak cannot desync replayed reports).
    pub schedule_label: String,
    /// Provisioned PE count.
    pub pes: i64,
    /// Total energy \[pJ\].
    pub energy_pj: f64,
    /// DRAM share of the energy \[pJ\].
    pub dram_pj: f64,
    /// Latency \[cycles\].
    pub latency_cycles: i64,
    /// Energy–delay product.
    pub edp: f64,
}

impl ReplayedCandidate {
    /// Capture the journalled fields of one evaluated candidate.
    pub fn of(ep: &EvaluatedPoint) -> Self {
        ReplayedCandidate {
            schedule: ep.point.schedule.clone(),
            schedule_label: ep.schedule_label.clone(),
            pes: ep.pes,
            energy_pj: ep.energy_pj,
            dram_pj: ep.dram_pj,
            latency_cycles: ep.latency_cycles,
            edp: ep.edp,
        }
    }

    /// Reconstruct the [`EvaluatedPoint`]: the re-enumerated `base`
    /// design point (identical by the fingerprint check) with the
    /// journalled schedule choice and metrics restored.
    pub fn to_evaluated(&self, base: &DesignPoint) -> EvaluatedPoint {
        let mut point = base.clone();
        point.schedule = self.schedule.clone();
        EvaluatedPoint {
            point,
            schedule_label: self.schedule_label.clone(),
            pes: self.pes,
            energy_pj: self.energy_pj,
            dram_pj: self.dram_pj,
            latency_cycles: self.latency_cycles,
            edp: self.edp,
            analysis_ms: 0.0,
            cache_hit: true,
        }
    }
}

/// The journalled outcome of one design point: every schedule
/// candidate it produced, or the failure message the sweep reported.
/// Failures are journalled too — resuming must not retry a
/// deterministic failure, and the failure list is part of the report.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalRecord {
    /// The point evaluated; all candidates in enumeration order.
    Ok(Vec<ReplayedCandidate>),
    /// The point failed with this message.
    Fail(String),
}

/// Result of [`load`]: nothing to replay, or the surviving records.
#[derive(Debug)]
pub enum JournalLoad {
    /// No journal file exists (fresh sweep, or a corrupt one was just
    /// quarantined by an earlier run).
    Absent,
    /// A valid journal for this exact `(workload, space)`.
    Replayed {
        /// Surviving records by design-point index.
        records: BTreeMap<usize, JournalRecord>,
        /// Per-record recovery notes (corrupt record skipped,
        /// truncated tail dropped, out-of-range index ignored).
        warnings: Vec<String>,
    },
}

/// Load and verify the journal at `path` against `expected`.
///
/// Errors are *loud* conditions the caller must surface: a stale
/// header (file left in place, mismatching field named) or a corrupt
/// header (file quarantined to `path.corrupt`). Per-record damage is
/// not an error — survivors replay and the damage is reported in
/// [`JournalLoad::Replayed`]'s `warnings`.
pub fn load(
    path: &Path,
    expected: &JournalHeader,
) -> Result<JournalLoad, String> {
    let content = match std::fs::read_to_string(path) {
        Ok(c) => c,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(JournalLoad::Absent)
        }
        Err(e) => {
            return Err(format!(
                "cannot read checkpoint journal {}: {e}",
                path.display()
            ))
        }
    };
    let mut lines = content.lines();
    let Some(header) = JournalHeader::parse(&mut lines) else {
        let to = quarantine(path);
        return Err(format!(
            "checkpoint journal {} has a corrupt header; {to}",
            path.display()
        ));
    };
    if let Some((field, found, want)) = header.mismatch(expected) {
        return Err(format!(
            "checkpoint journal {} is stale: {field} is {found} but this \
             sweep has {want} (the workload or design space changed since \
             the journal was written); delete the file or pass a fresh \
             --checkpoint path",
            path.display()
        ));
    }
    let mut records = BTreeMap::new();
    let mut warnings = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        match parse_record(line) {
            Some((idx, rec)) if idx < expected.points => {
                records.insert(idx, rec);
            }
            Some((idx, _)) => warnings.push(format!(
                "checkpoint journal {}: record for point {idx} is beyond \
                 the {}-point space; ignored",
                path.display(),
                expected.points
            )),
            None => warnings.push(format!(
                "checkpoint journal {}: dropped a corrupt or truncated \
                 record line ({} bytes); the point will be re-evaluated",
                path.display(),
                line.len()
            )),
        }
    }
    Ok(JournalLoad::Replayed { records, warnings })
}

/// Load one shard's journal for `dse merge`: like [`load`], but the
/// file's own shard identity is *returned* rather than required to
/// match (the merger collects shards it has not seen yet), and a
/// missing or corrupt file is a hard error — a merge must never
/// silently fabricate a complete report from a partial input. Nothing
/// is quarantined: merge inputs belong to other runs.
pub fn load_shard(
    path: &Path,
    expected: &JournalHeader,
) -> Result<(Shard, BTreeMap<usize, JournalRecord>, Vec<String>), String> {
    let content = std::fs::read_to_string(path).map_err(|e| {
        format!("cannot read shard journal {}: {e}", path.display())
    })?;
    let mut lines = content.lines();
    let Some(header) = JournalHeader::parse(&mut lines) else {
        return Err(format!(
            "shard journal {} has a corrupt header",
            path.display()
        ));
    };
    // Validate everything *except* the shard identity: build the
    // expectation for whatever shard the file claims to be, then run
    // the usual field-by-field staleness check.
    let want = expected.clone().with_shard(header.shard);
    if let Some((field, found, expect)) = header.mismatch(&want) {
        return Err(format!(
            "shard journal {} is stale: {field} is {found} but this merge \
             expects {expect} (was the journal written with the same \
             workload and dse flags?)",
            path.display()
        ));
    }
    let mut records = BTreeMap::new();
    let mut warnings = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        match parse_record(line) {
            Some((idx, rec)) if idx < expected.points => {
                records.insert(idx, rec);
            }
            Some((idx, _)) => warnings.push(format!(
                "shard journal {}: record for point {idx} is beyond the \
                 {}-point space; ignored",
                path.display(),
                expected.points
            )),
            None => warnings.push(format!(
                "shard journal {}: dropped a corrupt or truncated record \
                 line ({} bytes)",
                path.display(),
                line.len()
            )),
        }
    }
    Ok((header.shard, records, warnings))
}

/// Rename a damaged journal to `<path>.corrupt` so it is preserved
/// for inspection but never re-read. Returns a human-readable note.
fn quarantine(path: &Path) -> String {
    let to = PathBuf::from(format!("{}.corrupt", path.display()));
    match std::fs::rename(path, &to) {
        Ok(()) => format!("quarantined to {}", to.display()),
        Err(e) => format!(
            "quarantine to {} failed ({e}); delete the file by hand",
            to.display()
        ),
    }
}

/// Batched journal writer. Records accumulate in memory (keyed and
/// re-rendered deterministically, so serial and parallel sweeps that
/// commit the same prefix flush byte-identical files) and every
/// `batch` appends — or an explicit [`JournalWriter::flush`] — rewrite
/// the file through a `*.tmp<pid>` sibling and an atomic rename. A
/// reader therefore never observes a torn file, and an interrupted
/// write leaves only a temp that the next [`JournalWriter::create`]
/// reaps.
#[derive(Debug)]
pub struct JournalWriter {
    path: PathBuf,
    header: JournalHeader,
    records: BTreeMap<usize, String>,
    batch: usize,
    pending: usize,
    fail_flush: bool,
}

impl JournalWriter {
    /// A writer for `path`, reaping any `path.tmp<digits>` orphans an
    /// interrupted predecessor left behind. Nothing is written until
    /// the first flush. `batch == 0` clamps to 1 (flush every point).
    pub fn create(
        path: impl Into<PathBuf>,
        header: &JournalHeader,
        batch: usize,
    ) -> Self {
        let path = path.into();
        reap_orphan_temps(&path);
        JournalWriter {
            path,
            header: header.clone(),
            records: BTreeMap::new(),
            batch: batch.max(1),
            pending: 0,
            fail_flush: false,
        }
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Fault injection: make every subsequent flush fail without
    /// touching the filesystem (`TCPA_DSE_FAULT_JOURNAL_WRITE`).
    pub fn set_fail_flush(&mut self, fail: bool) {
        self.fail_flush = fail;
    }

    /// Record the outcome of point `idx`; flushes when the batch
    /// fills. A failed flush keeps the record buffered — the journal
    /// is advisory, and a later flush retries the whole state.
    pub fn append(
        &mut self,
        idx: usize,
        rec: &JournalRecord,
    ) -> Result<(), String> {
        self.records.insert(idx, render_record(idx, rec));
        self.pending += 1;
        if self.pending >= self.batch {
            self.flush()
        } else {
            Ok(())
        }
    }

    /// Rewrite the journal file with everything recorded so far.
    pub fn flush(&mut self) -> Result<(), String> {
        if self.fail_flush {
            return Err("injected journal write failure \
                        (TCPA_DSE_FAULT_JOURNAL_WRITE)"
                .to_string());
        }
        let mut body = self.header.render();
        for line in self.records.values() {
            body.push_str(line);
            body.push('\n');
        }
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| {
                    format!("create {}: {e}", dir.display())
                })?;
            }
        }
        let tmp = PathBuf::from(format!(
            "{}.tmp{}",
            self.path.display(),
            std::process::id()
        ));
        std::fs::write(&tmp, &body)
            .map_err(|e| format!("write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path).map_err(|e| {
            format!(
                "rename {} -> {}: {e}",
                tmp.display(),
                self.path.display()
            )
        })?;
        self.pending = 0;
        Ok(())
    }
}

/// Remove `<journal>.tmp<digits>` siblings — rename sources whose
/// writer died mid-flush. Only the exact naming of
/// [`JournalWriter::flush`] is touched.
fn reap_orphan_temps(path: &Path) {
    let Some(dir) = path.parent() else { return };
    let dir = if dir.as_os_str().is_empty() {
        Path::new(".")
    } else {
        dir
    };
    let Some(stem) = path.file_name().map(|n| n.to_string_lossy()) else {
        return;
    };
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(rest) = name.strip_prefix(stem.as_ref()) else {
            continue;
        };
        let Some(pid) = rest.strip_prefix(".tmp") else { continue };
        if !pid.is_empty() && pid.bytes().all(|b| b.is_ascii_digit()) {
            let _ = std::fs::remove_file(entry.path());
        }
    }
}

// ---- record line format -------------------------------------------------
//
//   r <idx> ok <ncand> {<sched> <label> <pes> <e> <d> <lat> <edp>}*
//       c <fnv64>
//   r <idx> fail <escaped message> c <fnv64>
//
// where <sched> is `first` or `i<comma-joined indices>`, <label> and
// the failure message are whitespace-escaped single tokens, every f64
// is its to_bits() as 16 hex digits, and <fnv64> is FNV-1a 64 of the
// record body (everything before " c ").

fn render_record(idx: usize, rec: &JournalRecord) -> String {
    let mut s = String::new();
    match rec {
        JournalRecord::Ok(cands) => {
            let _ = write!(s, "r {idx} ok {}", cands.len());
            for c in cands {
                let sched = match &c.schedule {
                    ScheduleChoice::First => "first".to_string(),
                    ScheduleChoice::Indices(ix) => format!(
                        "i{}",
                        ix.iter()
                            .map(|x| x.to_string())
                            .collect::<Vec<_>>()
                            .join(",")
                    ),
                };
                let _ = write!(
                    s,
                    " {sched} {} {} {:016x} {:016x} {} {:016x}",
                    escape(&c.schedule_label),
                    c.pes,
                    c.energy_pj.to_bits(),
                    c.dram_pj.to_bits(),
                    c.latency_cycles,
                    c.edp.to_bits()
                );
            }
        }
        JournalRecord::Fail(msg) => {
            let _ = write!(s, "r {idx} fail {}", escape(msg));
        }
    }
    let sum = fnv1a64(&s);
    let _ = write!(s, " c {sum:016x}");
    s
}

fn parse_record(line: &str) -> Option<(usize, JournalRecord)> {
    let (body, sum) = line.rsplit_once(" c ")?;
    if u64::from_str_radix(sum, 16).ok()? != fnv1a64(body) {
        return None;
    }
    let rest = body.strip_prefix("r ")?;
    let (idx, rest) = rest.split_once(' ')?;
    let idx: usize = idx.parse().ok()?;
    if let Some(msg) = rest.strip_prefix("fail ") {
        return Some((idx, JournalRecord::Fail(unescape(msg)?)));
    }
    let counted = rest.strip_prefix("ok ")?;
    let mut tok = counted.split(' ');
    let ncand: usize = tok.next()?.parse().ok()?;
    let mut cands = Vec::with_capacity(ncand);
    for _ in 0..ncand {
        let sched = tok.next()?;
        let schedule = if sched == "first" {
            ScheduleChoice::First
        } else {
            let ix = sched.strip_prefix('i')?;
            let ix: Vec<usize> = if ix.is_empty() {
                Vec::new()
            } else {
                ix.split(',')
                    .map(|x| x.parse().ok())
                    .collect::<Option<_>>()?
            };
            ScheduleChoice::Indices(ix)
        };
        cands.push(ReplayedCandidate {
            schedule,
            schedule_label: unescape(tok.next()?)?,
            pes: tok.next()?.parse().ok()?,
            energy_pj: f64::from_bits(
                u64::from_str_radix(tok.next()?, 16).ok()?,
            ),
            dram_pj: f64::from_bits(
                u64::from_str_radix(tok.next()?, 16).ok()?,
            ),
            latency_cycles: tok.next()?.parse().ok()?,
            edp: f64::from_bits(u64::from_str_radix(tok.next()?, 16).ok()?),
        });
    }
    if tok.next().is_some() {
        return None;
    }
    Some((idx, JournalRecord::Ok(cands)))
}

/// Escape a string into a single whitespace-free token: `\\` for a
/// backslash, `\n` for a newline, `\s` for a space, `\z` for the
/// empty string (a record field must occupy a token).
fn escape(s: &str) -> String {
    if s.is_empty() {
        return "\\z".to_string();
    }
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            ' ' => out.push_str("\\s"),
            c => out.push(c),
        }
    }
    out
}

fn unescape(s: &str) -> Option<String> {
    if s == "\\z" {
        return Some(String::new());
    }
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(ch) = chars.next() {
        if ch != '\\' {
            out.push(ch);
            continue;
        }
        match chars.next()? {
            '\\' => out.push('\\'),
            'n' => out.push('\n'),
            's' => out.push(' '),
            _ => return None,
        }
    }
    Some(out)
}

/// FNV-1a 64: tiny, dependency-free, and plenty to catch torn or
/// bit-rotted record lines (this is corruption *detection*, not
/// authentication).
fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tcpa-journal-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn small_setup() -> (Workload, DesignSpace, Vec<DesignPoint>) {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1], vec![2, 2]])
            .with_bounds(vec![8, 8]);
        let points = space.points();
        (wl, space, points)
    }

    fn sample_records(points: &[DesignPoint]) -> Vec<(usize, JournalRecord)> {
        let cand = |sched: ScheduleChoice, e: f64| ReplayedCandidate {
            schedule: sched,
            schedule_label: "first".to_string(),
            pes: 4,
            energy_pj: e,
            dram_pj: e * 0.25,
            latency_cycles: 123,
            edp: e * 123.0,
        };
        assert!(points.len() >= 3, "space must have a few points");
        vec![
            (
                0,
                JournalRecord::Ok(vec![
                    cand(ScheduleChoice::First, 0.1 + 0.2),
                    cand(
                        ScheduleChoice::Indices(vec![1, 0]),
                        f64::MIN_POSITIVE,
                    ),
                ]),
            ),
            (
                1,
                JournalRecord::Fail(
                    "evaluation panicked: index 3\\4 out of bounds\n(second \
                     line)"
                        .to_string(),
                ),
            ),
            (2, JournalRecord::Ok(vec![cand(ScheduleChoice::First, -1e300)])),
        ]
    }

    #[test]
    fn journal_round_trips_bit_for_bit() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("sweep.journal");
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        let recs = sample_records(&points);
        let mut w = JournalWriter::create(&path, &header, 2);
        for (idx, rec) in &recs {
            w.append(*idx, rec).unwrap();
        }
        w.flush().unwrap();
        match load(&path, &header).unwrap() {
            JournalLoad::Replayed { records, warnings } => {
                assert!(warnings.is_empty(), "{warnings:?}");
                assert_eq!(records.len(), recs.len());
                for (idx, rec) in &recs {
                    assert_eq!(records.get(idx), Some(rec), "point {idx}");
                }
            }
            JournalLoad::Absent => panic!("journal was just written"),
        }
        // A replayed candidate restores the original EvaluatedPoint
        // exactly, including the schedule choice on the base point.
        let JournalRecord::Ok(cands) = &recs[0].1 else { unreachable!() };
        let ep = cands[1].to_evaluated(&points[0]);
        assert_eq!(
            ep.point.schedule,
            ScheduleChoice::Indices(vec![1, 0])
        );
        assert_eq!(ep.energy_pj.to_bits(), f64::MIN_POSITIVE.to_bits());
        assert_eq!(ep.analysis_ms, 0.0, "replay costs no analysis time");
        assert!(ep.cache_hit, "a replayed point is a cache hit");
        assert_eq!(ReplayedCandidate::of(&ep), cands[1]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_journal_is_absent_and_batching_defers_writes() {
        let dir = tmp_dir("absent");
        let path = dir.join("sweep.journal");
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        assert!(matches!(
            load(&path, &header).unwrap(),
            JournalLoad::Absent
        ));
        let recs = sample_records(&points);
        let mut w = JournalWriter::create(&path, &header, 64);
        w.append(recs[0].0, &recs[0].1).unwrap();
        assert!(!path.exists(), "batch of 64 defers the first write");
        w.flush().unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_header_is_rejected_loudly_and_left_in_place() {
        let dir = tmp_dir("stale");
        let path = dir.join("sweep.journal");
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        let mut w = JournalWriter::create(&path, &header, 1);
        w.flush().unwrap();
        // Same workload, different space: the space_fp must be named.
        let other = DesignSpace::new()
            .with_arrays(vec![vec![4, 4]])
            .with_bounds(vec![16, 16]);
        let expected = JournalHeader::new(&wl, &other, points.len());
        let err = load(&path, &expected).unwrap_err();
        assert!(err.contains("stale"), "{err}");
        assert!(err.contains("space_fp"), "{err}");
        assert!(path.exists(), "stale journals are kept for inspection");
        // A different workload is caught by its fingerprint.
        let gemm = workloads::by_name("gemm").unwrap();
        let expected = JournalHeader::new(&gemm, &space, points.len());
        let err = load(&path, &expected).unwrap_err();
        assert!(err.contains("workload_fp"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_header_is_quarantined_not_replayed() {
        let dir = tmp_dir("quarantine");
        let path = dir.join("sweep.journal");
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        std::fs::write(&path, "not a journal at all\n").unwrap();
        let err = load(&path, &header).unwrap_err();
        assert!(err.contains("corrupt header"), "{err}");
        assert!(err.contains("quarantined"), "{err}");
        let corrupt = PathBuf::from(format!("{}.corrupt", path.display()));
        assert!(corrupt.exists(), "file moved aside for inspection");
        assert!(!path.exists());
        // The rerun then starts fresh instead of failing forever.
        assert!(matches!(
            load(&path, &header).unwrap(),
            JournalLoad::Absent
        ));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_tail_recovers_every_complete_record() {
        let dir = tmp_dir("truncate");
        let path = dir.join("sweep.journal");
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        let recs = sample_records(&points);
        let mut w = JournalWriter::create(&path, &header, 1);
        for (idx, rec) in &recs {
            w.append(*idx, rec).unwrap();
        }
        // Chop the file mid-way through the final record line, the
        // signature of a crash during a non-atomic write (or a torn
        // copy of the journal itself).
        let content = std::fs::read_to_string(&path).unwrap();
        let cut = content.trim_end().len() - 7;
        std::fs::write(&path, &content[..cut]).unwrap();
        match load(&path, &header).unwrap() {
            JournalLoad::Replayed { records, warnings } => {
                assert_eq!(records.len(), recs.len() - 1);
                assert!(records.contains_key(&0));
                assert!(records.contains_key(&1));
                assert!(!records.contains_key(&2), "tail record dropped");
                assert_eq!(warnings.len(), 1, "{warnings:?}");
                assert!(
                    warnings[0].contains("truncated"),
                    "{warnings:?}"
                );
            }
            JournalLoad::Absent => panic!("header is intact"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_corrupt_record_is_skipped_with_warning() {
        let dir = tmp_dir("corrupt-record");
        let path = dir.join("sweep.journal");
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        let recs = sample_records(&points);
        let mut w = JournalWriter::create(&path, &header, 1);
        for (idx, rec) in &recs {
            w.append(*idx, rec).unwrap();
        }
        // Flip one metric byte inside record 0's body; its checksum
        // no longer matches, so exactly that point is re-evaluated.
        let content = std::fs::read_to_string(&path).unwrap();
        let line = content
            .lines()
            .find(|l| l.starts_with("r 0 "))
            .unwrap()
            .to_string();
        let bad = if line.contains('7') {
            line.replacen('7', "8", 1)
        } else {
            line.replacen('0', "9", 1)
        };
        std::fs::write(&path, content.replace(&line, &bad)).unwrap();
        match load(&path, &header).unwrap() {
            JournalLoad::Replayed { records, warnings } => {
                assert!(!records.contains_key(&0), "corrupt record gone");
                assert!(records.contains_key(&1));
                assert!(records.contains_key(&2));
                assert_eq!(warnings.len(), 1, "{warnings:?}");
            }
            JournalLoad::Absent => panic!("header is intact"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn create_reaps_only_our_orphan_temps() {
        let dir = tmp_dir("reap");
        let path = dir.join("sweep.journal");
        let orphan = dir.join("sweep.journal.tmp4242");
        let foreign = dir.join("other.tmp12");
        let suffixed = dir.join("sweep.journal.tmpX");
        std::fs::write(&orphan, "interrupted flush").unwrap();
        std::fs::write(&foreign, "another tool's temp").unwrap();
        std::fs::write(&suffixed, "not our pid naming").unwrap();
        let (wl, space, points) = small_setup();
        let header = JournalHeader::new(&wl, &space, points.len());
        let _w = JournalWriter::create(&path, &header, 1);
        assert!(!orphan.exists(), "our orphan temp is reaped");
        assert!(foreign.exists(), "foreign temps are kept");
        assert!(suffixed.exists(), "non-digit suffixes are kept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn shard_identity_binds_into_the_header() {
        let dir = tmp_dir("shard-header");
        let path = dir.join("shard2.journal");
        let (wl, space, points) = small_setup();
        let shard = Shard::parse("2/3").unwrap();
        let header =
            JournalHeader::new(&wl, &space, points.len()).with_shard(shard);
        let recs = sample_records(&points);
        let mut w = JournalWriter::create(&path, &header, 1);
        for (idx, rec) in &recs {
            w.append(*idx, rec).unwrap();
        }
        // Resuming as the same shard replays; resuming unsharded (or
        // as a different shard) is stale with the shard field named.
        match load(&path, &header).unwrap() {
            JournalLoad::Replayed { records, .. } => {
                assert_eq!(records.len(), recs.len());
            }
            JournalLoad::Absent => panic!("journal was just written"),
        }
        let solo = JournalHeader::new(&wl, &space, points.len());
        let err = load(&path, &solo).unwrap_err();
        assert!(err.contains("stale"), "{err}");
        assert!(err.contains("shard"), "{err}");
        assert!(err.contains("2/3"), "{err}");
        // The merge loader returns the file's shard instead.
        let (got, records, warnings) = load_shard(&path, &solo).unwrap();
        assert_eq!(got, shard);
        assert_eq!(records.len(), recs.len());
        assert!(warnings.is_empty(), "{warnings:?}");
        // ...but still rejects a journal from another space, naming
        // the field and the file.
        let other = DesignSpace::new()
            .with_arrays(vec![vec![4, 4]])
            .with_bounds(vec![16, 16]);
        let expected = JournalHeader::new(&wl, &other, points.len());
        let err = load_shard(&path, &expected).unwrap_err();
        assert!(err.contains("space_fp"), "{err}");
        assert!(err.contains("shard2.journal"), "{err}");
        // A missing merge input is a hard error, not Absent.
        let gone = dir.join("nope.journal");
        let err = load_shard(&gone, &solo).unwrap_err();
        assert!(err.contains("cannot read"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprints_separate_spaces_and_escaping_round_trips() {
        let (_, space, _) = small_setup();
        let other = space.clone().with_bounds(vec![16, 16]);
        assert_ne!(space_fingerprint(&space), space_fingerprint(&other));
        assert_eq!(space_fingerprint(&space), space_fingerprint(&space));
        for s in ["", " ", "a b", "a\\b", "line\nbreak", "\\z", "\\"] {
            assert_eq!(
                unescape(&escape(s)).as_deref(),
                Some(s),
                "escape round trip of {s:?}"
            );
        }
        assert_eq!(unescape("\\q"), None, "unknown escape is corrupt");
    }
}
