//! Multi-objective selection: non-dominated (Pareto) frontiers over
//! (energy, latency, PE count, DRAM traffic) and knee-point picking.
//!
//! All comparisons go through `f64::total_cmp`, and NaN objectives are
//! mapped to `+∞` before comparison — a degenerate design point can never
//! panic the sweep (the `partial_cmp(..).unwrap()` hazard of the old
//! EDP sort) nor sneak onto the frontier.
//!
//! With the schedule axis (`DesignSpace::with_schedules`) latency is a
//! genuinely explored objective: candidates of one shape agree in
//! energy, PEs and DRAM and differ **only** in latency, so dominance
//! alone keeps exactly the fastest schedule(s) of each shape — ties all
//! survive (equal vectors dominate neither way), which preserves the
//! determinism guarantees of the explorer's enumeration order.
//!
//! With the per-phase shape axis (`DesignSpace::with_phase_shapes`) all
//! assignments of one (bounds, backend) scenario compete directly: a
//! heterogeneous assignment and the uniform diagonal are just points
//! with different objective vectors. Because the per-phase sweep is a
//! superset of the uniform one, its frontier weakly dominates the
//! uniform frontier per scenario — and a heterogeneous assignment whose
//! phases each take their energy-preferred orientation is the unique
//! energy minimum at its PE budget, so nothing can dominate it off the
//! frontier (the phase-shapes column in `report::frontier` is where it
//! shows up).

/// Number of objectives tracked per design point.
pub const NUM_OBJECTIVES: usize = 4;

/// The minimized objective vector of one design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objectives {
    /// Total energy `E_tot` in pJ.
    pub energy_pj: f64,
    /// Global latency in cycles.
    pub latency_cycles: f64,
    /// PEs used (silicon-area proxy).
    pub pes: f64,
    /// DRAM energy in pJ (off-chip-bandwidth proxy).
    pub dram_pj: f64,
}

impl Objectives {
    /// As a fixed-size vector, NaN replaced by `+∞` (minimization: a NaN
    /// objective makes the point worst-possible in that dimension).
    pub fn to_array(self) -> [f64; NUM_OBJECTIVES] {
        let s = |x: f64| if x.is_nan() { f64::INFINITY } else { x };
        [
            s(self.energy_pj),
            s(self.latency_cycles),
            s(self.pes),
            s(self.dram_pj),
        ]
    }
}

/// Does `a` dominate `b` — no worse in every objective, strictly better
/// in at least one? (Minimization.)
pub fn dominates(
    a: &[f64; NUM_OBJECTIVES],
    b: &[f64; NUM_OBJECTIVES],
) -> bool {
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b) {
        match x.total_cmp(y) {
            std::cmp::Ordering::Greater => return false,
            std::cmp::Ordering::Less => strictly_better = true,
            std::cmp::Ordering::Equal => {}
        }
    }
    strictly_better
}

/// Indices of the non-dominated points of `objs`, in input order.
/// Duplicate objective vectors all stay on the frontier (they dominate
/// nothing among themselves).
pub fn pareto_frontier(objs: &[[f64; NUM_OBJECTIVES]]) -> Vec<usize> {
    (0..objs.len())
        .filter(|&i| !objs.iter().any(|other| dominates(other, &objs[i])))
        .collect()
}

/// Knee point of a frontier: each objective is min–max normalized over
/// the given vectors, and the point closest (Euclidean) to the ideal
/// corner wins. Returns an index into `objs`, `None` when empty. Ties
/// break toward the lower index (deterministic).
pub fn knee_point(objs: &[[f64; NUM_OBJECTIVES]]) -> Option<usize> {
    if objs.is_empty() {
        return None;
    }
    let mut lo = [f64::INFINITY; NUM_OBJECTIVES];
    let mut hi = [f64::NEG_INFINITY; NUM_OBJECTIVES];
    for o in objs {
        for d in 0..NUM_OBJECTIVES {
            lo[d] = lo[d].min(o[d]);
            hi[d] = hi[d].max(o[d]);
        }
    }
    let dist = |o: &[f64; NUM_OBJECTIVES]| -> f64 {
        let mut sum = 0.0;
        for d in 0..NUM_OBJECTIVES {
            let range = hi[d] - lo[d];
            if range > 0.0 && range.is_finite() {
                let z = (o[d] - lo[d]) / range;
                sum += z * z;
            }
        }
        sum
    };
    (0..objs.len()).min_by(|&a, &b| dist(&objs[a]).total_cmp(&dist(&objs[b])))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn o(e: f64, l: f64, p: f64, d: f64) -> [f64; NUM_OBJECTIVES] {
        [e, l, p, d]
    }

    #[test]
    fn dominance_basics() {
        let a = o(1.0, 1.0, 1.0, 1.0);
        let b = o(2.0, 1.0, 1.0, 1.0);
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        // Equal vectors dominate neither way.
        let a2 = a;
        assert!(!dominates(&a, &a2));
        // Trade-off: incomparable.
        let c = o(0.5, 2.0, 1.0, 1.0);
        assert!(!dominates(&a, &c));
        assert!(!dominates(&c, &a));
    }

    #[test]
    fn frontier_drops_dominated() {
        let objs = vec![
            o(1.0, 4.0, 1.0, 1.0), // frontier (best energy)
            o(4.0, 1.0, 1.0, 1.0), // frontier (best latency)
            o(3.0, 3.0, 1.0, 1.0), // frontier (trade-off)
            o(4.0, 4.0, 1.0, 1.0), // dominated by all three
        ];
        assert_eq!(pareto_frontier(&objs), vec![0, 1, 2]);
    }

    #[test]
    fn nan_point_never_survives_against_finite() {
        let objs = vec![
            Objectives {
                energy_pj: f64::NAN,
                latency_cycles: 1.0,
                pes: 1.0,
                dram_pj: 1.0,
            }
            .to_array(),
            o(1.0, 1.0, 1.0, 1.0),
        ];
        // NaN → +∞ in one objective, equal elsewhere: dominated.
        assert_eq!(pareto_frontier(&objs), vec![1]);
    }

    #[test]
    fn schedule_variants_resolve_to_fastest_only() {
        // Schedule candidates of one shape: identical energy/PEs/DRAM,
        // latency varies. The frontier must keep exactly the fastest —
        // and keep *all* exact ties, so enumeration order (not float
        // luck) decides what the reports show.
        let objs = vec![
            o(5.0, 40.0, 4.0, 2.0), // default schedule, slow
            o(5.0, 16.0, 4.0, 2.0), // swapped schedule, fast
            o(5.0, 16.0, 4.0, 2.0), // distinct candidate, tied latency
        ];
        assert_eq!(pareto_frontier(&objs), vec![1, 2]);
    }

    #[test]
    fn phase_assignments_compete_and_hetero_minimum_survives() {
        // Per-phase assignments at one PE budget: total energy is the
        // per-phase sum, so the assignment giving each phase its
        // preferred orientation (index 2) is the strict energy minimum
        // and must survive; the strictly worse uniform assignments are
        // dominated away, while a latency trade-off (index 3) coexists.
        let objs = vec![
            o(9.0, 20.0, 4.0, 2.0), // uniform A|A
            o(8.0, 20.0, 4.0, 2.0), // uniform B|B
            o(6.0, 20.0, 4.0, 2.0), // hetero A|B: both phases happy
            o(8.5, 10.0, 4.0, 2.0), // hetero B|A: slower phases, faster λ
        ];
        assert_eq!(pareto_frontier(&objs), vec![2, 3]);
    }

    #[test]
    fn knee_prefers_balanced_point() {
        let objs = vec![
            o(0.0, 10.0, 0.0, 0.0),
            o(1.0, 1.0, 0.0, 0.0), // near-ideal in both active dims
            o(10.0, 0.0, 0.0, 0.0),
        ];
        assert_eq!(knee_point(&objs), Some(1));
        assert_eq!(knee_point(&[]), None);
        // Single point is its own knee.
        assert_eq!(knee_point(&objs[..1]), Some(0));
    }
}
