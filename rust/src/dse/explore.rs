//! The parallel explorer: evaluate every [`DesignPoint`] of a
//! [`DesignSpace`] on a `std::thread` worker pool.
//!
//! Work distribution is a channel-backed queue (an `mpsc` receiver behind
//! a mutex that workers pop from), results flow back over a second
//! channel tagged with the point's enumeration index, and the final
//! vector is stitched together in that index order — so the output is
//! byte-identical whether the sweep ran on 1 worker or 32.
//!
//! Per point, the expensive symbolic pass is fetched from (or inserted
//! into) the shared [`AnalysisCache`]; evaluating energy / latency /
//! counts at the point's bounds, tile scale and energy backend is then
//! just expression evaluation — microseconds, which is what makes wide
//! multi-axis sweeps tractable at all. Cold analyses within one sweep
//! additionally share the cache's Fourier–Motzkin feasibility pool, so a
//! guard proven (in)feasible for one design point is never re-proven for
//! another point with the same parameter context.
//!
//! The **schedule axis** ([`DesignSpace::with_schedules`]) is expanded
//! here rather than in [`DesignSpace::points`]: how many feasible
//! `(permutation, λ^J, λ^K)` candidates a point has depends on the
//! workload's dependence structure. Symbolic volumes are
//! schedule-invariant, so every candidate shares the shape's one cached
//! analysis — energy is priced once, and each candidate re-evaluates
//! latency alone (`SymbolicAnalysis::latency_at_with`). Candidates of
//! one base point compete inside the same (bounds, backend) scenario:
//! a slower schedule at identical energy/PEs/DRAM is dominated away,
//! which is how `--schedules all` can only improve the frontier.
//!
//! The **per-phase shape axis** ([`DesignSpace::with_phase_shapes`]) is
//! resolved here for the same reason: its extent depends on the
//! workload's phase count. Under [`PhasePolicy::PerPhase`] the explorer
//! enumerates [`DesignSpace::phase_points`] — every shape combination
//! across the phases — and assembles each point's totals from
//! *single-phase* analyses cached per (workload, phase, shape)
//! ([`AnalysisCache::try_get_or_analyze_phase_keyed`]): the
//! `shapes^phases` combinatorial sweep re-prices sums of per-phase
//! expressions, while analysis work stays proportional to the distinct
//! (phase, shape) pairs. Combinations compete inside their (bounds,
//! backend) scenario, so a heterogeneous assignment survives exactly
//! when no uniform (or other) assignment matches it everywhere — which
//! is how `--phase-shapes per-phase` can only improve the frontier.
//!
//! **Interruption and resume** ([`explore_controlled`]): the collector
//! runs *inside* the worker scope and commits results strictly in
//! enumeration order through a reorder buffer; each committed point is
//! appended to the optional checkpoint journal
//! ([`super::journal`]) and reported through the progress callback.
//! When the [`CancelToken`] trips (SIGINT, `--deadline`, or a caller),
//! the commit cursor *freezes*: whatever contiguous prefix of the
//! enumeration was committed is exactly what the journal and the
//! partial [`ExploreResult`] contain — which is why a cancelled serial
//! run and a cancelled 32-worker run flush byte-identical journals,
//! and why resuming from any of them reproduces the uninterrupted
//! frontier bit-for-bit. Workers observe the token between points, and
//! a thread-local [`PointGuard`] threads it (plus the per-point
//! timeout) into the Fourier–Motzkin feasibility loop so a single
//! pathological point cannot wedge a worker. A cancelled in-flight
//! point unwinds with [`POINT_CANCELLED_PANIC`]; the cache memoizes
//! that as a failure for its shape — harmless for the run at hand (it
//! is ending, and the result is discarded uncommitted), but an
//! in-memory [`AnalysisCache`] that survived a cancellation should not
//! be handed to a fresh sweep: the interrupted shapes stay memoized as
//! failures. Resuming in a new process (the CLI path) is unaffected.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::analysis::{
    energy_at_backend_phases, latency_at_phases, SymbolicAnalysis,
    WorkloadAnalysis,
};
use crate::cancel::{CancelReason, CancelToken};
use crate::energy::{Backend, MemoryClass};
use crate::polyhedral::{set_point_guard, PointGuard, POINT_CANCELLED_PANIC};
use crate::pra::Workload;
use crate::tiling::pad_bounds;

use super::cache::{
    panic_message, phase_fingerprint, workload_fingerprint, AnalysisCache,
    CacheStats,
};
use super::journal::{
    self, JournalHeader, JournalLoad, JournalRecord, JournalWriter,
    ReplayedCandidate,
};
use super::pareto::{knee_point, pareto_frontier, Objectives};
use super::space::{
    DesignPoint, DesignSpace, PhasePolicy, PhaseShapes, ScheduleChoice,
    SchedulePolicy, Shard,
};
use super::strategy::Strategy;

/// Explorer knobs.
#[derive(Debug, Clone, Default)]
pub struct ExploreConfig {
    /// Worker threads; `0` = one per available CPU.
    pub workers: usize,
}

impl ExploreConfig {
    /// A serial (single-worker) configuration.
    pub fn serial() -> Self {
        ExploreConfig { workers: 1 }
    }

    fn effective_workers(&self, jobs: usize) -> usize {
        let auto = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        let w = if self.workers == 0 { auto() } else { self.workers };
        w.clamp(1, jobs.max(1))
    }
}

/// Kill the sweep's process after `N` committed points
/// (`std::process::abort` right after a journal flush) — the
/// crash-recovery fixture of `tests/resume_faults.rs`.
pub const FAULT_KILL_AFTER_ENV: &str = "TCPA_DSE_FAULT_KILL_AFTER";
/// Trip the cancel token with [`CancelReason::Deadline`] after `N`
/// committed points — a deterministic stand-in for a wall-clock
/// deadline, so tests can pin *exactly* which prefix survives.
pub const FAULT_DEADLINE_AFTER_ENV: &str = "TCPA_DSE_FAULT_DEADLINE_AFTER";
/// Any value: make every journal flush fail without touching the
/// filesystem — the sweep must complete and only warn.
pub const FAULT_JOURNAL_WRITE_ENV: &str = "TCPA_DSE_FAULT_JOURNAL_WRITE";
/// Override the journal flush batch size (default 32). `1` flushes
/// every point — what the crash-recovery tests use so an aborted
/// process leaves a maximal journal.
pub const JOURNAL_BATCH_ENV: &str = "TCPA_DSE_JOURNAL_BATCH";

/// Deterministic fault injection, in the style of
/// `TCPA_SIM_VERIFY_FORCE_DIVERGE`: inert by default, armed through
/// environment hooks (or directly, in unit tests) so the resume
/// machinery can be exercised end-to-end through the real binary.
/// Counters trigger on **newly committed** points only — replayed
/// records don't count, so `--resume` under the same hooks makes
/// progress instead of re-dying at the same index.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Cancel (as if `--deadline` expired) after this many commits.
    pub deadline_after_points: Option<usize>,
    /// Abort the process after this many commits (journal flushed
    /// first — the crash the journal is designed to survive is the
    /// *uncontrolled* one, injected right after the flush).
    pub kill_after_points: Option<usize>,
    /// Fail every journal flush.
    pub fail_journal_flush: bool,
    /// Journal flush batch size override.
    pub journal_batch: Option<usize>,
}

impl FaultPlan {
    /// Read the `TCPA_DSE_FAULT_*` / `TCPA_DSE_JOURNAL_BATCH` hooks.
    /// Unparsable values are ignored (inert), like the sim-verify
    /// hooks.
    pub fn from_env() -> Self {
        let count = |key: &str| {
            std::env::var(key).ok().and_then(|v| v.parse::<usize>().ok())
        };
        FaultPlan {
            deadline_after_points: count(FAULT_DEADLINE_AFTER_ENV),
            kill_after_points: count(FAULT_KILL_AFTER_ENV),
            fail_journal_flush: std::env::var(FAULT_JOURNAL_WRITE_ENV)
                .is_ok(),
            journal_batch: count(JOURNAL_BATCH_ENV),
        }
    }
}

/// Runtime controls of one [`explore_controlled`] call: cancellation,
/// per-point timeout, checkpoint journal, progress reporting and
/// fault injection. `Default` is a fully inert control block —
/// [`explore_with_cache`] passes exactly that, so the uncontrolled
/// entry points stay bit-identical to the pre-robustness explorer.
#[derive(Default)]
pub struct ExploreControl {
    /// Cooperative stop: checked between points by the workers and
    /// the commit loop, and inside the symbolic core via the
    /// per-point guard. Arm deadlines / SIGINT on this token.
    pub cancel: CancelToken,
    /// Per-point wall-clock budget: a point whose *cold* symbolic
    /// analysis exceeds it unwinds and is recorded as a failure
    /// (cache hits never consult it — they do no symbolic work).
    pub point_timeout: Option<Duration>,
    /// Journal file (`dse --checkpoint FILE`).
    pub checkpoint: Option<PathBuf>,
    /// Replay completed points from `checkpoint` before evaluating
    /// (`dse --resume`).
    pub resume: bool,
    /// Called with `(completed, total)` once before evaluation starts
    /// (counting replayed points) and after every commit. Must be
    /// cheap; runs on the collector thread.
    #[allow(clippy::type_complexity)]
    pub progress: Option<Box<dyn Fn(usize, usize) + Send + Sync>>,
    /// Deterministic fault injection (tests; inert by default).
    pub faults: FaultPlan,
    /// Which slice of the enumeration this run owns (`dse --shard
    /// i/n`; defaults to the whole space). Lives in the control block,
    /// not the [`DesignSpace`]: sharding changes who evaluates a
    /// point, never which points exist, so every shard shares one
    /// space fingerprint and the shard identity is bound into the
    /// journal header as its own field.
    pub shard: Shard,
}

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct EvaluatedPoint {
    /// The configuration that was evaluated.
    pub point: DesignPoint,
    /// Human-readable schedule description: the per-phase intra-tile
    /// dimension orders (fastest first), phases joined by `|` — e.g.
    /// `j0j1` or `j0j1j2|j1j0`. Distinct schedule candidates of one
    /// shape always render distinctly.
    pub schedule_label: String,
    /// PEs used.
    pub pes: i64,
    /// Total energy `E_tot` in pJ.
    pub energy_pj: f64,
    /// DRAM share of the energy, in pJ.
    pub dram_pj: f64,
    /// Global latency in cycles.
    pub latency_cycles: i64,
    /// Energy-delay product (derived scalar, pJ·cycles).
    pub edp: f64,
    /// Wall time spent obtaining the symbolic analysis for this point —
    /// near zero on a cache hit.
    pub analysis_ms: f64,
    /// Whether the symbolic analysis came from the cache.
    pub cache_hit: bool,
}

impl EvaluatedPoint {
    /// The minimized objective vector (energy, latency, PEs, DRAM).
    pub fn objectives(&self) -> Objectives {
        Objectives {
            energy_pj: self.energy_pj,
            latency_cycles: self.latency_cycles as f64,
            pes: self.pes as f64,
            dram_pj: self.dram_pj,
        }
    }
}

/// The Pareto frontier of one *scenario* — one (bounds, backend) pair.
/// Dominance is only meaningful between points solving the same problem
/// under the same energy interpretation: pooling scenarios would let the
/// smallest bounds (cheaper in every objective) dominate every larger
/// size, and the TCPA backend dominate every pricier architecture.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierGroup {
    /// Loop bounds of this scenario.
    pub bounds: Vec<i64>,
    /// Energy backend of this scenario.
    pub backend: Backend,
    /// Indices into [`ExploreResult::points`] of the non-dominated
    /// points, in enumeration order.
    pub frontier: Vec<usize>,
    /// Index into [`ExploreResult::points`] of this frontier's knee.
    pub knee: Option<usize>,
}

/// Outcome of one exploration.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    /// Workload name.
    pub workload: String,
    /// Every surviving point, in deterministic space-enumeration order.
    pub points: Vec<EvaluatedPoint>,
    /// One Pareto frontier per (bounds, backend) scenario, in first-seen
    /// order.
    pub groups: Vec<FrontierGroup>,
    /// Union of all per-scenario frontiers (sorted indices into
    /// [`Self::points`]) — for a single-scenario space this *is* the
    /// frontier.
    pub frontier: Vec<usize>,
    /// Knee of the frontier when the space has exactly one scenario;
    /// `None` otherwise (each [`FrontierGroup`] carries its own knee).
    pub knee: Option<usize>,
    /// Points dropped because their analysis or evaluation failed
    /// (infeasible schedule etc.), with the failure message — reported,
    /// never silently absorbed into `points`. In enumeration order.
    pub failures: Vec<(DesignPoint, String)>,
    /// Cache counters at the end of the run.
    pub cache: CacheStats,
    /// Wall-clock time of the whole exploration.
    pub wall: Duration,
    /// Event-engine verification verdicts for frontier points, keyed by
    /// index into [`Self::points`]. Empty unless
    /// [`super::verify::sim_verify_frontier`] ran
    /// (`dse --sim-verify-frontier`).
    pub sim_verify: std::collections::BTreeMap<usize, super::verify::SimVerify>,
    /// Design points with a known outcome (evaluated, failed, or
    /// replayed from the journal). Equals [`Self::total`] on an
    /// uncancelled run.
    pub completed: usize,
    /// Total enumerated design points of this sweep.
    pub total: usize,
    /// How many of [`Self::completed`] were replayed from the journal
    /// rather than evaluated this run.
    pub replayed: usize,
    /// Why the sweep stopped early; `None` on a complete run (a
    /// deadline expiring *after* the last commit is still complete —
    /// nothing was lost).
    pub cancelled: Option<CancelReason>,
    /// Non-fatal incidents: journal records dropped on load, journal
    /// write failures. The sweep's numbers are unaffected; callers
    /// should surface these to the user.
    pub warnings: Vec<String>,
    /// How the enumeration was produced (provenance for the report
    /// header; [`Strategy::Exhaustive`] for merged shard results).
    pub strategy: Strategy,
    /// The shard this run evaluated, when it was one slice of a
    /// sharded sweep (`None` for unsharded runs and merged results).
    pub shard: Option<Shard>,
}

impl ExploreResult {
    /// The frontier, resolved to points (enumeration order).
    pub fn frontier_points(&self) -> Vec<&EvaluatedPoint> {
        self.frontier.iter().map(|&i| &self.points[i]).collect()
    }

    /// The knee point, resolved.
    pub fn knee_point(&self) -> Option<&EvaluatedPoint> {
        self.knee.map(|i| &self.points[i])
    }

    /// Points sorted by EDP (NaN-safe total order), best first — the old
    /// single-scalar ranking, kept as a convenience view.
    pub fn by_edp(&self) -> Vec<&EvaluatedPoint> {
        let mut v: Vec<&EvaluatedPoint> = self.points.iter().collect();
        v.sort_by(|a, b| a.edp.total_cmp(&b.edp));
        v
    }
}

/// Per-phase parameter vectors `(N…, p…)` for `point` against the
/// resolved phase analyses (uniform or heterogeneous). Shared with the
/// frontier verification pass (`super::verify`), which must reconstruct
/// exactly the parameters the sweep evaluated.
pub(crate) fn phase_params(
    phases: &[&SymbolicAnalysis],
    point: &DesignPoint,
) -> Vec<Vec<i64>> {
    phases
        .iter()
        .map(|ph| {
            let b = pad_bounds(&point.bounds, ph.tiled.pra.ndims);
            if point.tile_scale == 1 {
                ph.params_for(&b)
            } else {
                // Oversized tiles: p_ℓ = min(N_ℓ, k·⌈N_ℓ/t_ℓ⌉) stays
                // inside the analysis context 1 ≤ p_ℓ ≤ N_ℓ while
                // covering the iteration space. `tile_sizes` is the
                // exact-cover authority `params_for` also uses.
                let exact = ph.tiled.mapping.tile_sizes(&b);
                let mut v = b.clone();
                for (l, &n) in b.iter().enumerate() {
                    v.push(
                        (point.tile_scale * exact[l]).min(n).max(exact[l]),
                    );
                }
                v
            }
        })
        .collect()
}

/// Evaluate one design point against the (cached) symbolic analyses,
/// expanded into one [`EvaluatedPoint`] per schedule candidate according
/// to `policy`. `Err` carries the analysis failure message (memoized by
/// the cache, so a bad shape fails once and cheaply thereafter).
///
/// A uniform point resolves to the one whole-workload cached analysis of
/// its `array`; a per-phase point resolves each phase's shape to its own
/// cached single-phase analysis (`phase_fps` are the precomputed
/// [`phase_fingerprint`]s, indexed like `wl.phases`) — every shape
/// combination reuses the per-(phase, shape) entries. Either way the
/// evaluation below runs over the same resolved `&[&SymbolicAnalysis]`
/// slice through the same arithmetic
/// (`analysis::energy_at_backend_phases` & friends, which the uniform
/// `WorkloadAnalysis` methods delegate to), so uniform points stay
/// bit-for-bit identical to the pre-axis explorer.
///
/// Energy, DRAM traffic and PEs are schedule-invariant and computed once
/// per base point; only latency (and therefore EDP) is re-evaluated per
/// candidate — the structural cheapness that makes the schedule a free
/// axis on top of the cached analyses.
///
/// `pub(crate)` so [`super::strategy::beam_points`] prices candidate
/// states through the *same* arithmetic and cache: a beam-visited
/// point re-evaluated by the explorer is a cache hit with bit-identical
/// objectives.
pub(crate) fn evaluate(
    wl: &Workload,
    fingerprint: u64,
    phase_fps: &[u64],
    point: &DesignPoint,
    cache: &AnalysisCache,
    policy: SchedulePolicy,
    verify: bool,
) -> Result<Vec<EvaluatedPoint>, String> {
    let t0 = Instant::now();
    // Keep-alives for the Arc'd analyses the `phases` slice borrows.
    let uniform_ana: Option<std::sync::Arc<WorkloadAnalysis>>;
    let mut phase_anas: Vec<std::sync::Arc<SymbolicAnalysis>> = Vec::new();
    let cache_hit = match &point.phase_shapes {
        PhaseShapes::Uniform => {
            let (ana, hit) =
                cache.try_get_or_analyze_keyed(wl, fingerprint, &point.array);
            uniform_ana = Some(ana?);
            hit
        }
        PhaseShapes::PerPhase(shapes) => {
            assert_eq!(
                shapes.len(),
                wl.phases.len(),
                "one shape per phase of {}",
                wl.name
            );
            uniform_ana = None;
            let mut all_hit = true;
            for (i, shape) in shapes.iter().enumerate() {
                let (ana, hit) = cache.try_get_or_analyze_phase_keyed(
                    wl,
                    phase_fps[i],
                    i,
                    shape,
                );
                all_hit &= hit;
                phase_anas.push(ana?);
            }
            all_hit
        }
    };
    let phases: Vec<&SymbolicAnalysis> = match &uniform_ana {
        Some(ana) => ana.phases.iter().collect(),
        None => phase_anas.iter().map(|a| &**a).collect(),
    };
    let analysis_ms = t0.elapsed().as_secs_f64() * 1e3;
    let params = phase_params(&phases, point);
    // One symbolic analysis per phase, any architecture: routing +
    // pricing through the point's backend. For the TCPA backend this is
    // bit-identical to the pre-backend `energy_at` fast path (see
    // `analysis::evaluate`).
    let energy =
        energy_at_backend_phases(phases.iter().copied(), &params, &point.backend);
    let dram_pj = energy
        .mem_pj
        .get(&MemoryClass::Dram)
        .copied()
        .unwrap_or(0.0);
    let with_latency = |latency_cycles: i64,
                        schedule: ScheduleChoice,
                        schedule_label: String| {
        EvaluatedPoint {
            point: DesignPoint { schedule, ..point.clone() },
            schedule_label,
            pes: point.pes(),
            energy_pj: energy.total,
            dram_pj,
            latency_cycles,
            edp: energy.total * latency_cycles as f64,
            analysis_ms,
            cache_hit,
        }
    };
    if policy == SchedulePolicy::First {
        // The pre-axis path: each phase's embedded default schedule, no
        // enumeration — `--schedules first` stays bit-identical to the
        // single-schedule explorer.
        if verify {
            // Untrusted-input hardening: the default schedule must carry
            // a symbolic causality proof, not just the constructive
            // argument from `find_schedule`. Memoized per analysis, so
            // the sweep pays for each (phase, shape) once.
            for ph in &phases {
                let fails = ph.verify_default_schedule();
                if !fails.is_empty() {
                    return Err(format!(
                        "schedule causality proof failed for phase `{}` \
                         (pi={}, schedule {}): {}",
                        ph.tiled.pra.name,
                        ph.schedule.pi,
                        ph.schedule.perm_label(),
                        fails.join("; "),
                    ));
                }
            }
        }
        let latency_cycles = latency_at_phases(phases.iter().copied(), &params);
        let label = phases
            .iter()
            .map(|ph| ph.schedule.perm_label())
            .collect::<Vec<_>>()
            .join("|");
        return Ok(vec![with_latency(
            latency_cycles,
            ScheduleChoice::First,
            label,
        )]);
    }
    // Enumerate per phase (candidate 0 always exists: the analysis
    // succeeded, so find_schedule's pick did), then walk the per-phase
    // cross product in lexicographic index order — deterministic, last
    // phase fastest.
    let cands: Vec<Vec<crate::schedule::Schedule>> = phases
        .iter()
        .map(|ph| ph.enumerate_schedules(policy.per_phase_cap()))
        .collect();
    let counts: Vec<usize> = cands.iter().map(Vec::len).collect();
    debug_assert!(counts.iter().all(|&c| c >= 1));
    if verify {
        // Untrusted-input hardening: prove causality symbolically for
        // every candidate offered to the cross product. The capped
        // enumeration is a prefix of the full memoized one, so the
        // index-aligned proof list covers it. Memoized per analysis —
        // each (phase, shape) proves its candidates once per sweep.
        for (ph, phase_cands) in phases.iter().zip(&cands) {
            let proofs = ph.verify_enumerated_schedules();
            for (ci, (cand, fails)) in
                phase_cands.iter().zip(proofs).enumerate()
            {
                if !fails.is_empty() {
                    return Err(format!(
                        "schedule causality proof failed for phase `{}` \
                         candidate #{ci} (pi={}, schedule {}): {}",
                        ph.tiled.pra.name,
                        cand.pi,
                        cand.perm_label(),
                        fails.join("; "),
                    ));
                }
            }
        }
    }
    // Each (phase, candidate) latency once — the combos below only sum
    // table entries (Σ cᵢ evaluations instead of Π cᵢ · phases).
    let lat: Vec<Vec<i64>> = phases
        .iter()
        .zip(&params)
        .zip(&cands)
        .map(|((ph, p), phase_cands)| {
            phase_cands
                .iter()
                .map(|s| ph.latency_at_with(s, p))
                .collect()
        })
        .collect();
    let total: usize = counts.iter().product();
    let mut out = Vec::with_capacity(total);
    for flat in 0..total {
        let mut rem = flat;
        let mut idx = vec![0usize; counts.len()];
        for d in (0..counts.len()).rev() {
            idx[d] = rem % counts[d];
            rem /= counts[d];
        }
        let latency_cycles: i64 = idx
            .iter()
            .enumerate()
            .map(|(phase, &ci)| lat[phase][ci])
            .sum();
        let label = idx
            .iter()
            .enumerate()
            .map(|(phase, &ci)| cands[phase][ci].perm_label())
            .collect::<Vec<_>>()
            .join("|");
        out.push(with_latency(
            latency_cycles,
            ScheduleChoice::Indices(idx),
            label,
        ));
    }
    Ok(out)
}

/// Explore `space` for `wl` with a private, single-use cache.
pub fn explore(
    wl: &Workload,
    space: &DesignSpace,
    cfg: &ExploreConfig,
) -> ExploreResult {
    explore_with_cache(wl, space, cfg, &AnalysisCache::new())
}

/// Explore `space` for `wl`, sharing `cache` with (and warming it for)
/// other sweeps — the bounds-sweep fast path. Runs uncontrolled: no
/// cancellation, journal, timeout or faults
/// ([`ExploreControl::default`]), bit-identical to the pre-robustness
/// explorer.
pub fn explore_with_cache(
    wl: &Workload,
    space: &DesignSpace,
    cfg: &ExploreConfig,
    cache: &AnalysisCache,
) -> ExploreResult {
    explore_controlled(wl, space, cfg, cache, &ExploreControl::default())
        .expect("uncontrolled exploration cannot fail")
}

/// The controlled explorer — everything [`explore_with_cache`] does,
/// plus cooperative cancellation, per-point timeouts,
/// checkpoint/resume and fault injection per `ctl`. This is the
/// explorer-as-a-library shape `dse serve` and `dse --shard` sit on.
///
/// `Err` is reserved for *setup* refusals — a stale or corrupt
/// checkpoint journal ([`super::journal::load`]), or `resume` without
/// a checkpoint path. Once evaluation starts every problem is in the
/// result itself: point failures in [`ExploreResult::failures`],
/// interruption in [`ExploreResult::cancelled`], non-fatal incidents
/// in [`ExploreResult::warnings`].
pub fn explore_controlled(
    wl: &Workload,
    space: &DesignSpace,
    cfg: &ExploreConfig,
    cache: &AnalysisCache,
    ctl: &ExploreControl,
) -> Result<ExploreResult, String> {
    fn warn_once(warnings: &mut Vec<String>, warned: &mut bool, e: String) {
        if !*warned {
            warnings.push(format!(
                "checkpoint journal write failed: {e}; the sweep \
                 continues without durable checkpoints"
            ));
            *warned = true;
        }
    }

    let t0 = Instant::now();
    let policy = space.schedules;
    let verify = space.verify_schedules;
    // One IR walk for the whole sweep, not one per design point.
    let fingerprint = workload_fingerprint(wl);
    let phase_fps: Vec<u64> =
        wl.phases.iter().map(phase_fingerprint).collect();
    // The per-phase axis needs the workload's phase count, which the
    // space cannot know — resolve the base-point enumeration here.
    // Under `Strategy::Beam` the enumeration is the beam's visited
    // set re-emitted in canonical order (a subsequence of the
    // exhaustive list); journal indices, shard ownership and report
    // order are all positions in whichever enumeration the strategy
    // produced, and the strategy is part of the space fingerprint.
    let points = match &space.strategy {
        Strategy::Exhaustive => match space.phase_policy {
            PhasePolicy::Uniform => space.points(),
            PhasePolicy::PerPhase => space.phase_points(wl.phases.len()),
        },
        Strategy::Beam { .. } => super::strategy::beam_points(
            wl, fingerprint, &phase_fps, space, cache,
        ),
    };
    let n = points.len();
    // Shard-local workload: the indices this run owns. Everything the
    // user observes — progress, completed/total, the partial report —
    // is in terms of the owned slice; record indices stay global so
    // shard journals merge.
    let n_owned = (0..n).filter(|&i| ctl.shard.owns(i)).count();

    let mut warnings: Vec<String> = Vec::new();
    let mut journal_warned = false;
    // Resume: load the replayable prefix. Stale/corrupt journals are
    // loud errors (see `journal::load`); per-record damage degrades
    // to warnings and re-evaluation.
    let header = ctl.checkpoint.as_ref().map(|_| {
        JournalHeader::new(wl, space, n).with_shard(ctl.shard)
    });
    let mut replayed: BTreeMap<usize, JournalRecord> = BTreeMap::new();
    if ctl.resume {
        let (Some(path), Some(h)) = (&ctl.checkpoint, &header) else {
            return Err(
                "resume requires a checkpoint journal path".to_string()
            );
        };
        match journal::load(path, h)? {
            JournalLoad::Absent => {}
            JournalLoad::Replayed { records, warnings: w } => {
                warnings.extend(w);
                replayed = records;
            }
        }
    }
    // Open the journal writer (reaping orphan temps) and flush
    // immediately: the rewrite re-seeds the replayed records — healing
    // any truncated tail — and stamps a fresh run's header on disk
    // before evaluation can crash.
    let mut writer = match (&ctl.checkpoint, &header) {
        (Some(path), Some(h)) => {
            let batch = ctl.faults.journal_batch.unwrap_or(32);
            let mut w = JournalWriter::create(path, h, batch);
            w.set_fail_flush(ctl.faults.fail_journal_flush);
            Some(w)
        }
        _ => None,
    };
    if let Some(w) = writer.as_mut() {
        let mut seed = Ok(());
        for (idx, rec) in &replayed {
            if let Err(e) = w.append(*idx, rec) {
                seed = Err(e);
            }
        }
        if let Err(e) = w.flush() {
            seed = Err(e);
        }
        if let Err(e) = seed {
            warn_once(&mut warnings, &mut journal_warned, e);
        }
    }

    // Job queue: a channel pre-filled with every not-yet-replayed
    // (index, point), its receiver shared behind a mutex so idle
    // workers steal the next job.
    let jobs: Vec<(usize, DesignPoint)> = points
        .iter()
        .enumerate()
        .filter(|(i, _)| ctl.shard.owns(*i) && !replayed.contains_key(i))
        .map(|(i, p)| (i, p.clone()))
        .collect();
    let workers = cfg.effective_workers(jobs.len());
    if let Some(p) = &ctl.progress {
        p(replayed.len(), n_owned);
    }
    let (jtx, jrx) = mpsc::channel::<(usize, DesignPoint)>();
    for job in jobs {
        jtx.send(job).expect("queue send");
    }
    drop(jtx);
    let jrx = Mutex::new(jrx);

    // One base point expands into one evaluated point per schedule
    // candidate (exactly one under `SchedulePolicy::First`).
    enum Outcome {
        Ok(Vec<EvaluatedPoint>),
        Fail(DesignPoint, String),
        // The worker abandoned the point because the token tripped
        // (pre-check, or the guard unwound the symbolic pass).
        Aborted,
    }
    let (rtx, rrx) = mpsc::channel::<(usize, Outcome)>();

    let mut slots: Vec<Vec<EvaluatedPoint>> = vec![Vec::new(); n];
    let mut failed: Vec<(usize, DesignPoint, String)> = Vec::new();
    let mut committed = 0usize;

    std::thread::scope(|s| {
        for _ in 0..workers {
            let rtx = rtx.clone();
            let jrx = &jrx;
            let phase_fps = &phase_fps;
            let cancel = ctl.cancel.clone();
            let point_timeout = ctl.point_timeout;
            s.spawn(move || loop {
                // Pop under the lock, evaluate outside it.
                let job = { jrx.lock().unwrap().recv() };
                let Ok((idx, point)) = job else { break };
                // Between-points cancellation: drain the queue fast,
                // reporting each skipped point as aborted.
                if cancel.is_cancelled() {
                    let _ = rtx.send((idx, Outcome::Aborted));
                    continue;
                }
                // The thread-local guard threads the token and the
                // per-point timeout into the symbolic core (the FM
                // feasibility loop polls it) for this point only.
                set_point_guard(Some(PointGuard::new(
                    cancel.clone(),
                    point_timeout,
                )));
                let eval = catch_unwind(AssertUnwindSafe(|| {
                    evaluate(
                        wl, fingerprint, phase_fps, &point, cache, policy,
                        verify,
                    )
                }));
                set_point_guard(None);
                // Analysis failures surface as Err (memoized, cheap);
                // catch_unwind additionally guards the evaluation
                // arithmetic itself. A guard unwind inside the cached
                // analysis closure is memoized and rethrown as an Err
                // carrying the panic constant: a cancellation is not
                // a point failure, a timeout is.
                let out = match eval {
                    Ok(Ok(e)) => Outcome::Ok(e),
                    Ok(Err(msg)) => {
                        if msg.contains(POINT_CANCELLED_PANIC) {
                            Outcome::Aborted
                        } else {
                            Outcome::Fail(point, msg)
                        }
                    }
                    Err(payload) => {
                        let msg = panic_message(payload.as_ref());
                        if msg.contains(POINT_CANCELLED_PANIC) {
                            Outcome::Aborted
                        } else {
                            Outcome::Fail(point, msg)
                        }
                    }
                };
                // The queue sender is gone before workers start, so the
                // only way `send` fails is the collector having hung up —
                // at which point the result is moot.
                let _ = rtx.send((idx, out));
            });
        }
        drop(rtx);

        // The collector runs INSIDE the scope: results are committed
        // strictly in enumeration order through a reorder buffer, and
        // the cursor *freezes* at the first abort or cancellation.
        // The committed contiguous prefix is the entire observable
        // outcome — journal, partial report, progress — which is what
        // makes a cancelled sweep independent of worker count and
        // arrival order (a cancelled serial run and a cancelled
        // 32-worker run flush byte-identical journals).
        let mut buffer: BTreeMap<usize, Outcome> = BTreeMap::new();
        let mut frozen = false;
        let mut cursor = 0usize;
        while cursor < n
            && (!ctl.shard.owns(cursor) || replayed.contains_key(&cursor))
        {
            cursor += 1;
        }
        while let Ok((idx, out)) = rrx.recv() {
            if frozen {
                continue; // drain in-flight results, discard
            }
            buffer.insert(idx, out);
            while let Some(out) = buffer.remove(&cursor) {
                match out {
                    Outcome::Aborted => {
                        frozen = true;
                        break;
                    }
                    Outcome::Ok(evals) => {
                        if let Some(w) = writer.as_mut() {
                            let rec = JournalRecord::Ok(
                                evals
                                    .iter()
                                    .map(ReplayedCandidate::of)
                                    .collect(),
                            );
                            if let Err(e) = w.append(cursor, &rec) {
                                warn_once(
                                    &mut warnings,
                                    &mut journal_warned,
                                    e,
                                );
                            }
                        }
                        slots[cursor] = evals;
                    }
                    Outcome::Fail(point, msg) => {
                        if let Some(w) = writer.as_mut() {
                            let rec = JournalRecord::Fail(msg.clone());
                            if let Err(e) = w.append(cursor, &rec) {
                                warn_once(
                                    &mut warnings,
                                    &mut journal_warned,
                                    e,
                                );
                            }
                        }
                        failed.push((cursor, point, msg));
                    }
                }
                committed += 1;
                cursor += 1;
                while cursor < n
                    && (!ctl.shard.owns(cursor)
                        || replayed.contains_key(&cursor))
                {
                    cursor += 1;
                }
                if let Some(p) = &ctl.progress {
                    p(replayed.len() + committed, n_owned);
                }
                // Fault hooks count *newly committed* points, so a
                // resumed run under the same hooks makes progress.
                if ctl.faults.kill_after_points == Some(committed) {
                    if let Some(w) = writer.as_mut() {
                        let _ = w.flush();
                    }
                    // The uncontrolled crash the journal must
                    // survive: no unwinding, no destructors.
                    std::process::abort();
                }
                if ctl.faults.deadline_after_points == Some(committed) {
                    ctl.cancel.cancel_with(CancelReason::Deadline);
                }
                if ctl.cancel.is_cancelled() {
                    frozen = true;
                    break;
                }
            }
        }
    });

    // Flush the tail batch (and, on cancellation, the final partial
    // state).
    if let Some(w) = writer.as_mut() {
        if let Err(e) = w.flush() {
            warn_once(&mut warnings, &mut journal_warned, e);
        }
    }

    // Stitch the replayed prefix back in at its original indices —
    // bit-for-bit, the journal stores every reported f64 as its bits.
    for (idx, rec) in &replayed {
        match rec {
            JournalRecord::Ok(cands) => {
                slots[*idx] = cands
                    .iter()
                    .map(|c| c.to_evaluated(&points[*idx]))
                    .collect();
            }
            JournalRecord::Fail(msg) => {
                failed.push((*idx, points[*idx].clone(), msg.clone()));
            }
        }
    }
    failed.sort_by_key(|(idx, _, _)| *idx);
    let failures: Vec<(DesignPoint, String)> =
        failed.into_iter().map(|(_, p, m)| (p, m)).collect();
    let evaluated: Vec<EvaluatedPoint> =
        slots.into_iter().flatten().collect();

    let (groups, frontier, knee) = compute_frontiers(&evaluated);

    let completed = replayed.len() + committed;
    // A deadline that fires after the last commit lost nothing: the
    // run is complete, not cancelled.
    let cancelled =
        if completed < n_owned { ctl.cancel.cancelled() } else { None };

    Ok(ExploreResult {
        workload: wl.name.clone(),
        points: evaluated,
        groups,
        frontier,
        knee,
        failures,
        cache: cache.stats(),
        wall: t0.elapsed(),
        sim_verify: std::collections::BTreeMap::new(),
        completed,
        total: n_owned,
        replayed: replayed.len(),
        cancelled,
        warnings,
        strategy: space.strategy.clone(),
        shard: if ctl.shard.is_solo() { None } else { Some(ctl.shard) },
    })
}

/// Group evaluated points by scenario (bounds, backend) preserving
/// first-seen order, then compute one Pareto frontier + knee per
/// group, the sorted frontier union, and the single-scenario knee.
/// Shared between [`explore_controlled`] and [`merge_shards`] so a
/// merged report is structurally identical to an unsharded one.
pub(crate) fn compute_frontiers(
    evaluated: &[EvaluatedPoint],
) -> (Vec<FrontierGroup>, Vec<usize>, Option<usize>) {
    let mut groups: Vec<FrontierGroup> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    for (i, p) in evaluated.iter().enumerate() {
        let pos = groups.iter().position(|g| {
            g.bounds == p.point.bounds && g.backend == p.point.backend
        });
        match pos {
            Some(gi) => members[gi].push(i),
            None => {
                groups.push(FrontierGroup {
                    bounds: p.point.bounds.clone(),
                    backend: p.point.backend.clone(),
                    frontier: Vec::new(),
                    knee: None,
                });
                members.push(vec![i]);
            }
        }
    }
    for (g, m) in groups.iter_mut().zip(&members) {
        let objs: Vec<_> = m
            .iter()
            .map(|&i| evaluated[i].objectives().to_array())
            .collect();
        let local = pareto_frontier(&objs);
        g.frontier = local.iter().map(|&k| m[k]).collect();
        let local_objs: Vec<_> = local.iter().map(|&k| objs[k]).collect();
        g.knee = knee_point(&local_objs).map(|k| g.frontier[k]);
    }
    let mut frontier: Vec<usize> =
        groups.iter().flat_map(|g| g.frontier.iter().copied()).collect();
    frontier.sort_unstable();
    let knee = match groups.as_slice() {
        [only] => only.knee,
        _ => None,
    };
    (groups, frontier, knee)
}

/// Fold the checkpoint journals of a sharded sweep (`dse --shard i/n
/// --checkpoint FILE` per process) into one complete [`ExploreResult`],
/// **byte-identical** in every report to the unsharded run: the space
/// is re-enumerated from the same flags, each journal is validated
/// against the workload/space fingerprints (stale inputs fail loudly
/// with the field and file named), and every global index must be
/// covered exactly once by the shard that owns it.
///
/// Merging requires [`Strategy::Exhaustive`]: shard journals are
/// defined over the canonical enumeration, while a beam enumeration
/// depends on cache state the merging process does not replay.
pub fn merge_shards(
    wl: &Workload,
    space: &DesignSpace,
    paths: &[PathBuf],
) -> Result<ExploreResult, String> {
    let t0 = Instant::now();
    if !space.strategy.is_exhaustive() {
        return Err(format!(
            "dse merge requires --strategy exhaustive (got --strategy \
             {}): shard journals index the canonical enumeration",
            space.strategy.label()
        ));
    }
    if paths.is_empty() {
        return Err("dse merge needs at least one --shards journal path"
            .to_string());
    }
    let points = match space.phase_policy {
        PhasePolicy::Uniform => space.points(),
        PhasePolicy::PerPhase => space.phase_points(wl.phases.len()),
    };
    let n = points.len();
    let expected = JournalHeader::new(wl, space, n);

    let mut warnings: Vec<String> = Vec::new();
    // path of each shard index seen so far, for duplicate diagnostics.
    let mut seen: BTreeMap<usize, &PathBuf> = BTreeMap::new();
    let mut records: BTreeMap<usize, JournalRecord> = BTreeMap::new();
    let mut count: Option<usize> = None;
    for path in paths {
        let (shard, recs, w) = journal::load_shard(path, &expected)?;
        warnings.extend(w);
        match count {
            None => count = Some(shard.count),
            Some(c) if c == shard.count => {}
            Some(c) => {
                return Err(format!(
                    "shard journal {} is from a {}-way sweep but {} \
                     declared {c} shards; all inputs must share one \
                     --shard denominator",
                    path.display(),
                    shard.count,
                    seen.values()
                        .next()
                        .map(|p| p.display().to_string())
                        .unwrap_or_default(),
                ));
            }
        }
        if let Some(first) = seen.get(&shard.index) {
            return Err(format!(
                "duplicate shard {}: both {} and {} claim it",
                shard.label(),
                first.display(),
                path.display()
            ));
        }
        seen.insert(shard.index, path);
        for (idx, rec) in recs {
            if !shard.owns(idx) {
                return Err(format!(
                    "shard journal {} contains point {idx}, which shard \
                     {} does not own — the journal was tampered with or \
                     mixed up",
                    path.display(),
                    shard.label()
                ));
            }
            records.insert(idx, rec);
        }
    }
    let count = count.expect("paths is non-empty");
    if seen.len() != count {
        let missing: Vec<String> = (1..=count)
            .filter(|i| !seen.contains_key(i))
            .map(|i| format!("{i}/{count}"))
            .collect();
        return Err(format!(
            "incomplete merge: {} of {count} shard journals given; \
             missing shard(s) {}",
            seen.len(),
            missing.join(", ")
        ));
    }
    for idx in 0..n {
        if !records.contains_key(&idx) {
            let owner = Shard::owner_of(idx, count);
            return Err(format!(
                "incomplete merge: point {idx} has no journal record; \
                 its owner shard {} ({}) did not finish — re-run that \
                 shard with --resume, then merge again",
                owner.label(),
                seen[&owner.index].display()
            ));
        }
    }

    // Reconstruct exactly like an all-replayed resume: bit-for-bit
    // metrics, failures in enumeration order, frontiers recomputed by
    // the shared grouping code.
    let mut slots: Vec<Vec<EvaluatedPoint>> = vec![Vec::new(); n];
    let mut failures: Vec<(DesignPoint, String)> = Vec::new();
    for (idx, rec) in &records {
        match rec {
            JournalRecord::Ok(cands) => {
                slots[*idx] = cands
                    .iter()
                    .map(|c| c.to_evaluated(&points[*idx]))
                    .collect();
            }
            JournalRecord::Fail(msg) => {
                failures.push((points[*idx].clone(), msg.clone()));
            }
        }
    }
    let evaluated: Vec<EvaluatedPoint> =
        slots.into_iter().flatten().collect();
    let (groups, frontier, knee) = compute_frontiers(&evaluated);
    Ok(ExploreResult {
        workload: wl.name.clone(),
        points: evaluated,
        groups,
        frontier,
        knee,
        failures,
        cache: CacheStats::default(),
        wall: t0.elapsed(),
        sim_verify: std::collections::BTreeMap::new(),
        completed: n,
        total: n,
        replayed: n,
        cancelled: None,
        warnings,
        strategy: Strategy::Exhaustive,
        shard: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn small_space() -> DesignSpace {
        DesignSpace::new().with_arrays_2d(4).with_bounds(vec![8, 8])
    }

    #[test]
    fn parallel_equals_serial_bit_for_bit() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let serial = explore(&wl, &space, &ExploreConfig::serial());
        let parallel =
            explore(&wl, &space, &ExploreConfig { workers: 4 });
        assert_eq!(serial.points.len(), parallel.points.len());
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.latency_cycles, b.latency_cycles);
        }
        assert_eq!(serial.frontier, parallel.frontier);
        assert_eq!(serial.knee, parallel.knee);
    }

    #[test]
    fn frontier_beats_edp_only_view() {
        let wl = workloads::by_name("gesummv").unwrap();
        let res = explore(&wl, &small_space(), &ExploreConfig::default());
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        assert!(!res.frontier.is_empty());
        // The 1×1 array uses the fewest PEs: nothing can dominate it, so
        // a multi-objective frontier must retain it even though the EDP
        // sort buries it.
        let serial_idx = res
            .points
            .iter()
            .position(|p| p.point.array == vec![1, 1])
            .unwrap();
        assert!(res.frontier.contains(&serial_idx));
        // Knee lies on the frontier.
        let knee = res.knee.unwrap();
        assert!(res.frontier.contains(&knee));
    }

    #[test]
    fn bounds_sweep_reuses_analyses() {
        let wl = workloads::by_name("gesummv").unwrap();
        let cache = AnalysisCache::new();
        let warm = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds(vec![8, 8]);
        explore_with_cache(&wl, &warm, &ExploreConfig::default(), &cache);
        let shapes = cache.stats().entries;
        let sweep = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds_sweep(&[16, 32, 64], 2);
        let res =
            explore_with_cache(&wl, &sweep, &ExploreConfig::default(), &cache);
        // No new analyses ran: every shape was already cached.
        assert_eq!(res.cache.entries, shapes);
        assert!(res.points.iter().all(|p| p.cache_hit));
    }

    #[test]
    fn scenario_axes_get_separate_frontiers() {
        // Pooled dominance would let the N=8 points (cheaper in every
        // objective at equal shape) erase every N=16 point; per-scenario
        // grouping must keep a frontier for each bounds vector.
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds_sweep(&[8, 16], 2);
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.groups.len(), 2);
        for g in &res.groups {
            assert!(!g.frontier.is_empty(), "{:?} has an empty frontier", g.bounds);
            let k = g.knee.unwrap();
            assert!(g.frontier.contains(&k));
            // Every frontier member belongs to this scenario.
            for &i in &g.frontier {
                assert_eq!(res.points[i].point.bounds, g.bounds);
            }
        }
        assert!(res
            .frontier
            .iter()
            .any(|&i| res.points[i].point.bounds == vec![16, 16]));
        // Multi-scenario result has no single knee.
        assert_eq!(res.knee, None);
    }

    #[test]
    fn backend_axis_orders_architectures() {
        // Same volumes, pricier interpretations: tcpa ≤ systolic ≤ cgra
        // ≤ gpu-sm at every design point (pointwise per-access ordering
        // of the built-in routing tables).
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![16, 16])
            .with_backends(Backend::builtins());
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.points.len(), 4);
        // One scenario per backend: the architectures are compared, not
        // dominated away by the cheapest interpretation.
        assert_eq!(res.groups.len(), 4);
        assert_eq!(res.frontier.len(), 4);
        let by_backend = |name: &str| {
            res.points
                .iter()
                .find(|p| p.point.backend.name() == name)
                .unwrap()
                .energy_pj
        };
        let (tcpa, systolic, cgra, gpu) = (
            by_backend("tcpa"),
            by_backend("systolic"),
            by_backend("cgra"),
            by_backend("gpu-sm"),
        );
        assert!(tcpa < systolic, "{tcpa} vs {systolic}");
        assert!(systolic < cgra, "{systolic} vs {cgra}");
        assert!(cgra < gpu, "{cgra} vs {gpu}");
    }

    #[test]
    fn legacy_policy_axis_still_explores() {
        // The deprecated closed-enum axis rides on the backend machinery.
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![16, 16])
            .with_policies(crate::energy::Policy::ALL.to_vec());
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.points.len(), 3);
        assert_eq!(res.groups.len(), 3);
        let by_name = |name: &str| {
            res.points
                .iter()
                .find(|p| p.point.backend.name() == name)
                .unwrap()
                .energy_pj
        };
        assert!(by_name("tcpa") < by_name("no-fd"));
        assert!(by_name("no-fd") <= by_name("no-reuse"));
    }

    #[test]
    fn failures_carry_point_and_message() {
        // No causal lexicographic order exists: every point must land in
        // `failures` with the scheduler's message, not vanish.
        let wl = workloads::twist_unschedulable();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8]);
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert!(res.points.is_empty());
        assert_eq!(res.failures.len(), 1);
        let (p, msg) = &res.failures[0];
        assert_eq!(p.array, vec![2, 2]);
        assert!(
            msg.contains("schedule"),
            "message should name the scheduling failure: {msg}"
        );
        assert!(res.frontier.is_empty() && res.knee.is_none());
    }

    #[test]
    fn schedule_axis_surfaces_faster_non_default_schedule() {
        // GESUMMV on a 1×4 array at N = (16,16): the natural dimension
        // order routes the expensive inter-tile offset along the mapped
        // dimension (λ^K_1 = 1 + p0·p1 − p0), while the swapped order
        // needs only λ^K_1 = p1 — genuinely faster at identical energy.
        // The single-schedule explorer never sees it.
        let wl = workloads::by_name("gesummv").unwrap();
        let base = DesignSpace::new()
            .with_arrays(vec![vec![1, 4]])
            .with_bounds(vec![16, 16]);
        let first = explore(&wl, &base, &ExploreConfig::default());
        let all = explore(
            &wl,
            &base.with_schedules(SchedulePolicy::All),
            &ExploreConfig::default(),
        );
        assert_eq!(first.points.len(), 1);
        assert_eq!(all.points.len(), 2, "two causal permutations");
        // Energy/PEs/DRAM are schedule-invariant.
        for p in &all.points {
            assert_eq!(
                p.energy_pj.to_bits(),
                first.points[0].energy_pj.to_bits()
            );
            assert_eq!(p.dram_pj.to_bits(), first.points[0].dram_pj.to_bits());
            assert_eq!(p.pes, first.points[0].pes);
        }
        // Candidate 0 is the default pick, identical to --schedules first.
        assert!(all.points[0].point.schedule.is_default());
        assert_eq!(
            all.points[0].latency_cycles,
            first.points[0].latency_cycles
        );
        assert_eq!(all.points[0].schedule_label, "j0j1");
        assert_eq!(all.points[1].schedule_label, "j1j0");
        // The swapped schedule wins; the default is dominated away.
        assert!(
            all.points[1].latency_cycles < all.points[0].latency_cycles,
            "swapped order must be faster: {:?}",
            all.points.iter().map(|p| p.latency_cycles).collect::<Vec<_>>()
        );
        assert_eq!(all.frontier, vec![1]);
    }

    #[test]
    fn schedule_axis_cross_product_over_phases() {
        // Multi-phase workloads expand into the per-phase cross product,
        // in lexicographic index order with deterministic labels.
        let wl = workloads::by_name("atax").unwrap();
        let cache = AnalysisCache::new();
        let (ana, _) = cache.get_or_analyze(&wl, &[2, 2]);
        let per_phase: Vec<usize> = ana
            .phases
            .iter()
            .map(|ph| ph.enumerate_schedules(None).len())
            .collect();
        let expected: usize = per_phase.iter().product();
        assert!(expected >= 1);
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8])
            .with_schedules(SchedulePolicy::All);
        let res = explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        assert_eq!(res.points.len(), expected);
        // Choices are distinct and lexicographically ordered.
        let choices: Vec<Vec<usize>> = res
            .points
            .iter()
            .map(|p| match &p.point.schedule {
                ScheduleChoice::Indices(ix) => ix.clone(),
                other => panic!("expected explicit indices, got {other:?}"),
            })
            .collect();
        let mut sorted = choices.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(choices, sorted, "combo order must be lexicographic");
        assert_eq!(choices[0], vec![0; per_phase.len()]);
        // Limit(1) collapses back to a single (default) candidate with
        // the same latency the First policy reports.
        let limited = explore_with_cache(
            &wl,
            &DesignSpace::new()
                .with_arrays(vec![vec![2, 2]])
                .with_bounds(vec![8, 8])
                .with_schedules(SchedulePolicy::Limit(1)),
            &ExploreConfig::default(),
            &cache,
        );
        assert_eq!(limited.points.len(), 1);
        assert!(limited.points[0].point.schedule.is_default());
        assert_eq!(
            limited.points[0].latency_cycles,
            res.points[0].latency_cycles
        );
    }

    #[test]
    fn per_phase_axis_includes_uniform_diagonal_bit_for_bit() {
        // The per-phase sweep covers every shape combination, including
        // the all-equal diagonal — and a diagonal combination, assembled
        // from single-phase cached analyses, must price exactly like the
        // uniform point of the same shape (same mappings, same table,
        // same π, same merge order).
        let wl = workloads::by_name("atax").unwrap();
        let base = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1]])
            .with_bounds(vec![8, 8]);
        let uniform = explore(&wl, &base, &ExploreConfig::default());
        let per_phase = explore(
            &wl,
            &base.clone().with_phase_shapes(PhasePolicy::PerPhase),
            &ExploreConfig::default(),
        );
        assert!(uniform.failures.is_empty() && per_phase.failures.is_empty());
        assert_eq!(uniform.points.len(), 2);
        assert_eq!(per_phase.points.len(), 4, "2 shapes × 2 phases");
        for u in &uniform.points {
            let shape = &u.point.array;
            let diag = per_phase
                .points
                .iter()
                .find(|p| {
                    p.point.phase_shapes
                        == PhaseShapes::PerPhase(vec![
                            shape.clone(),
                            shape.clone(),
                        ])
                })
                .expect("diagonal combination present");
            assert_eq!(diag.energy_pj.to_bits(), u.energy_pj.to_bits());
            assert_eq!(diag.dram_pj.to_bits(), u.dram_pj.to_bits());
            assert_eq!(diag.latency_cycles, u.latency_cycles);
            assert_eq!(diag.pes, u.pes);
            assert_eq!(diag.schedule_label, u.schedule_label);
        }
    }

    #[test]
    fn per_phase_analysis_count_scales_with_pairs_not_combinations() {
        // 3 shapes × 2 phases → 9 combinations per scenario, but only
        // 6 distinct (phase, shape) pairs may ever be analyzed — the
        // acceptance condition that keeps the combinatorial axis cheap.
        let wl = workloads::by_name("atax").unwrap();
        let cache = AnalysisCache::new();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1], vec![2, 2]])
            .with_bounds_sweep(&[8, 16], 2)
            .with_phase_shapes(PhasePolicy::PerPhase);
        let res = explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        assert_eq!(res.points.len(), 9 * 2, "9 combos × 2 bounds");
        let s = cache.stats();
        assert_eq!(s.entries, 6, "2 phases × 3 shapes analyzed");
        assert_eq!(s.misses, 6);
        // Every other lookup (2 per point) was served from the memo.
        assert_eq!(s.hits, 18 * 2 - 6);
    }

    #[test]
    fn tile_scale_stays_in_context_and_changes_schedule() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![16, 16])
            .with_tile_scales(vec![1, 2]);
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.points.len(), 2);
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        for p in &res.points {
            assert!(p.energy_pj > 0.0);
            assert!(p.latency_cycles > 0);
        }
    }

    fn journal_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tcpa-explore-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn uncontrolled_runs_report_complete_uncancelled_state() {
        let wl = workloads::by_name("gesummv").unwrap();
        let res = explore(&wl, &small_space(), &ExploreConfig::default());
        assert_eq!(res.completed, res.total);
        assert_eq!(res.total, res.points.len() + res.failures.len());
        assert_eq!(res.replayed, 0);
        assert_eq!(res.cancelled, None);
        assert!(res.warnings.is_empty());
    }

    #[test]
    fn cancelled_serial_and_parallel_runs_flush_identical_journals() {
        // The commit-cursor freeze: whatever contiguous prefix was
        // committed when the (injected, deterministic) deadline fired
        // is the whole outcome — independent of worker count.
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let dir = journal_dir("cancel-det");
        let run = |workers: usize, tag: &str| {
            let path = dir.join(format!("{tag}.journal"));
            let ctl = ExploreControl {
                checkpoint: Some(path.clone()),
                faults: FaultPlan {
                    deadline_after_points: Some(3),
                    journal_batch: Some(1),
                    ..FaultPlan::default()
                },
                ..ExploreControl::default()
            };
            let res = explore_controlled(
                &wl,
                &space,
                &ExploreConfig { workers },
                &AnalysisCache::new(),
                &ctl,
            )
            .unwrap();
            (res, std::fs::read(&path).unwrap())
        };
        let (serial, js) = run(1, "serial");
        let (parallel, jp) = run(4, "parallel");
        assert_eq!(serial.completed, 3);
        assert_eq!(parallel.completed, 3);
        assert_eq!(
            serial.cancelled,
            Some(crate::cancel::CancelReason::Deadline)
        );
        assert_eq!(parallel.cancelled, serial.cancelled);
        assert_eq!(js, jp, "journal bytes depend on worker count");
        // The partial result is exactly the committed prefix.
        assert_eq!(serial.points.len(), 3);
        assert_eq!(parallel.points.len(), 3);
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.latency_cycles, b.latency_cycles);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interrupted_then_resumed_matches_uninterrupted_bit_for_bit() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let baseline = explore(&wl, &space, &ExploreConfig::serial());
        assert!(baseline.failures.is_empty());
        let n = baseline.points.len();
        let dir = journal_dir("resume");
        let path = dir.join("sweep.journal");
        let interrupted_ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            faults: FaultPlan {
                deadline_after_points: Some(3),
                journal_batch: Some(1),
                ..FaultPlan::default()
            },
            ..ExploreControl::default()
        };
        let interrupted = explore_controlled(
            &wl,
            &space,
            &ExploreConfig { workers: 4 },
            &AnalysisCache::new(),
            &interrupted_ctl,
        )
        .unwrap();
        assert_eq!(interrupted.completed, 3);
        assert!(interrupted.cancelled.is_some());
        // Resume with a fresh cache and a progress probe.
        let seen =
            std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let probe = seen.clone();
        let resume_ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            resume: true,
            progress: Some(Box::new(move |done, total| {
                probe.lock().unwrap().push((done, total));
            })),
            ..ExploreControl::default()
        };
        let resumed = explore_controlled(
            &wl,
            &space,
            &ExploreConfig::serial(),
            &AnalysisCache::new(),
            &resume_ctl,
        )
        .unwrap();
        assert_eq!(resumed.cancelled, None);
        assert_eq!(resumed.replayed, 3);
        assert_eq!(resumed.completed, resumed.total);
        assert_eq!(resumed.points.len(), n);
        for (a, b) in resumed.points.iter().zip(&baseline.points) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.schedule_label, b.schedule_label);
            assert_eq!(a.pes, b.pes);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.dram_pj.to_bits(), b.dram_pj.to_bits());
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.edp.to_bits(), b.edp.to_bits());
        }
        assert_eq!(resumed.frontier, baseline.frontier);
        assert_eq!(resumed.knee, baseline.knee);
        let seen = seen.lock().unwrap();
        assert_eq!(seen.first(), Some(&(3, n)), "{seen:?}");
        assert_eq!(seen.last(), Some(&(n, n)), "{seen:?}");
        assert!(seen.windows(2).all(|w| w[0].0 < w[1].0), "{seen:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pre_cancelled_token_commits_nothing() {
        let wl = workloads::by_name("gesummv").unwrap();
        let ctl = ExploreControl::default();
        ctl.cancel.cancel();
        let res = explore_controlled(
            &wl,
            &small_space(),
            &ExploreConfig { workers: 2 },
            &AnalysisCache::new(),
            &ctl,
        )
        .unwrap();
        assert_eq!(res.completed, 0);
        assert!(res.points.is_empty() && res.failures.is_empty());
        assert_eq!(
            res.cancelled,
            Some(crate::cancel::CancelReason::Explicit)
        );
        assert!(res.frontier.is_empty() && res.knee.is_none());
    }

    #[test]
    fn journalled_failures_replay_without_reanalysis() {
        let wl = workloads::twist_unschedulable();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8]);
        let dir = journal_dir("fail-replay");
        let path = dir.join("sweep.journal");
        let ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            ..ExploreControl::default()
        };
        let first = explore_controlled(
            &wl,
            &space,
            &ExploreConfig::serial(),
            &AnalysisCache::new(),
            &ctl,
        )
        .unwrap();
        assert_eq!(first.failures.len(), 1);
        assert_eq!(first.cancelled, None, "a failure is not cancellation");
        let resume_ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            resume: true,
            ..ExploreControl::default()
        };
        let cache = AnalysisCache::new();
        let second = explore_controlled(
            &wl,
            &space,
            &ExploreConfig::serial(),
            &cache,
            &resume_ctl,
        )
        .unwrap();
        assert_eq!(second.replayed, 1);
        assert_eq!(second.failures.len(), 1);
        assert_eq!(second.failures[0].1, first.failures[0].1);
        assert_eq!(cache.stats().misses, 0, "nothing re-analyzed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_journal_writes_warn_once_and_do_not_stop_the_sweep() {
        let wl = workloads::by_name("gesummv").unwrap();
        let dir = journal_dir("wfail");
        let path = dir.join("sweep.journal");
        let ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            faults: FaultPlan {
                fail_journal_flush: true,
                journal_batch: Some(1),
                ..FaultPlan::default()
            },
            ..ExploreControl::default()
        };
        let res = explore_controlled(
            &wl,
            &small_space(),
            &ExploreConfig::serial(),
            &AnalysisCache::new(),
            &ctl,
        )
        .unwrap();
        assert_eq!(res.cancelled, None);
        assert_eq!(res.completed, res.total);
        assert_eq!(res.warnings.len(), 1, "warn once: {:?}", res.warnings);
        assert!(res.warnings[0].contains("journal write failed"));
        assert!(!path.exists(), "no torn file may be left behind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_with_stale_journal_is_refused() {
        let wl = workloads::by_name("gesummv").unwrap();
        let dir = journal_dir("stale-resume");
        let path = dir.join("sweep.journal");
        let narrow = DesignSpace::new()
            .with_arrays(vec![vec![1, 2]])
            .with_bounds(vec![8, 8]);
        let ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            ..ExploreControl::default()
        };
        explore_controlled(
            &wl,
            &narrow,
            &ExploreConfig::serial(),
            &AnalysisCache::new(),
            &ctl,
        )
        .unwrap();
        let resume_ctl = ExploreControl {
            checkpoint: Some(path.clone()),
            resume: true,
            ..ExploreControl::default()
        };
        let err = explore_controlled(
            &wl,
            &small_space(),
            &ExploreConfig::serial(),
            &AnalysisCache::new(),
            &resume_ctl,
        )
        .unwrap_err();
        assert!(err.contains("stale"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_plan_reads_env_hooks() {
        let _env = crate::dse::verify::env_guard();
        let keys = [
            FAULT_KILL_AFTER_ENV,
            FAULT_DEADLINE_AFTER_ENV,
            FAULT_JOURNAL_WRITE_ENV,
            JOURNAL_BATCH_ENV,
        ];
        for k in keys {
            std::env::remove_var(k);
        }
        let inert = FaultPlan::from_env();
        assert_eq!(inert.deadline_after_points, None);
        assert_eq!(inert.kill_after_points, None);
        assert!(!inert.fail_journal_flush);
        assert_eq!(inert.journal_batch, None);
        std::env::set_var(FAULT_KILL_AFTER_ENV, "5");
        std::env::set_var(FAULT_DEADLINE_AFTER_ENV, "junk");
        std::env::set_var(FAULT_JOURNAL_WRITE_ENV, "1");
        std::env::set_var(JOURNAL_BATCH_ENV, "1");
        let armed = FaultPlan::from_env();
        assert_eq!(armed.kill_after_points, Some(5));
        assert_eq!(armed.deadline_after_points, None, "junk is inert");
        assert!(armed.fail_journal_flush);
        assert_eq!(armed.journal_batch, Some(1));
        for k in keys {
            std::env::remove_var(k);
        }
    }

    #[test]
    fn shard_run_owns_exactly_its_round_robin_slice() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let full = explore(&wl, &space, &ExploreConfig::serial());
        assert!(full.failures.is_empty(), "{:?}", full.failures);
        let points = space.points();
        let n = points.len();
        let count = 3usize;
        let mut union: Vec<EvaluatedPoint> = Vec::new();
        for index in 1..=count {
            let shard = Shard { index, count };
            let ctl =
                ExploreControl { shard, ..ExploreControl::default() };
            let res = explore_controlled(
                &wl,
                &space,
                &ExploreConfig::serial(),
                &AnalysisCache::new(),
                &ctl,
            )
            .unwrap();
            assert_eq!(res.shard, Some(shard));
            let owned: Vec<usize> =
                (0..n).filter(|&i| shard.owns(i)).collect();
            assert_eq!(res.total, owned.len());
            assert_eq!(res.completed, owned.len());
            assert!(res.cancelled.is_none());
            // The shard evaluated exactly its owned points, in order,
            // bit-identical to the unsharded run's values.
            let expect: Vec<&EvaluatedPoint> = full
                .points
                .iter()
                .filter(|p| {
                    let gi = points
                        .iter()
                        .position(|q| *q == p.point)
                        .expect("point from the same enumeration");
                    shard.owns(gi)
                })
                .collect();
            assert_eq!(res.points.len(), expect.len());
            for (a, b) in res.points.iter().zip(expect) {
                assert_eq!(a.point, b.point);
                assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
                assert_eq!(a.latency_cycles, b.latency_cycles);
            }
            union.extend(res.points.iter().cloned());
        }
        // Shards partition: together they cover every point once.
        assert_eq!(union.len(), full.points.len());
    }

    #[test]
    fn merge_shards_reproduces_the_unsharded_result() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let dir = journal_dir("merge");
        let full = explore(&wl, &space, &ExploreConfig::serial());
        let count = 3usize;
        let mut paths = Vec::new();
        for index in 1..=count {
            let path = dir.join(format!("shard{index}.journal"));
            let ctl = ExploreControl {
                shard: Shard { index, count },
                checkpoint: Some(path.clone()),
                ..ExploreControl::default()
            };
            explore_controlled(
                &wl,
                &space,
                &ExploreConfig::serial(),
                &AnalysisCache::new(),
                &ctl,
            )
            .unwrap();
            paths.push(path);
        }
        let merged = merge_shards(&wl, &space, &paths).unwrap();
        assert_eq!(merged.points.len(), full.points.len());
        for (a, b) in merged.points.iter().zip(&full.points) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.dram_pj.to_bits(), b.dram_pj.to_bits());
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.edp.to_bits(), b.edp.to_bits());
        }
        assert_eq!(merged.groups, full.groups);
        assert_eq!(merged.frontier, full.frontier);
        assert_eq!(merged.knee, full.knee);
        assert_eq!(merged.completed, full.completed);
        assert_eq!(merged.total, full.total);
        assert!(merged.cancelled.is_none());
        assert_eq!(merged.shard, None);
        assert!(merged.strategy.is_exhaustive());
        // Input-order independence: the denominator comes from the
        // headers, not the argument order.
        paths.reverse();
        let reversed = merge_shards(&wl, &space, &paths).unwrap();
        assert_eq!(reversed.frontier, merged.frontier);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_shards_fails_loudly_naming_the_offender() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let dir = journal_dir("merge-loud");
        let count = 3usize;
        let mut paths = Vec::new();
        for index in 1..=count {
            let path = dir.join(format!("shard{index}.journal"));
            let ctl = ExploreControl {
                shard: Shard { index, count },
                checkpoint: Some(path.clone()),
                ..ExploreControl::default()
            };
            explore_controlled(
                &wl,
                &space,
                &ExploreConfig::serial(),
                &AnalysisCache::new(),
                &ctl,
            )
            .unwrap();
            paths.push(path);
        }
        // Missing shard: 2/3 absent from the input set.
        let missing = vec![paths[0].clone(), paths[2].clone()];
        let err = merge_shards(&wl, &space, &missing).unwrap_err();
        assert!(err.contains("incomplete merge"), "{err}");
        assert!(err.contains("2/3"), "{err}");
        // Duplicate shard: 1/3 given twice.
        let dup =
            vec![paths[0].clone(), paths[0].clone(), paths[2].clone()];
        let err = merge_shards(&wl, &space, &dup).unwrap_err();
        assert!(err.contains("duplicate shard 1/3"), "{err}");
        assert!(err.contains("shard1.journal"), "{err}");
        // Stale fingerprint: journals from a different space, the
        // field and file named.
        let other = space.clone().with_bounds(vec![16, 16]);
        let err = merge_shards(&wl, &other, &paths).unwrap_err();
        assert!(err.contains("space_fp"), "{err}");
        assert!(err.contains(".journal"), "{err}");
        // Incomplete shard: truncate shard 2's journal to one record
        // and the missing point must name its owner.
        let content = std::fs::read_to_string(&paths[1]).unwrap();
        let keep: Vec<&str> = content.lines().take(7).collect();
        std::fs::write(&paths[1], format!("{}\n", keep.join("\n")))
            .unwrap();
        let err = merge_shards(&wl, &space, &paths).unwrap_err();
        assert!(err.contains("incomplete merge"), "{err}");
        assert!(err.contains("2/3"), "{err}");
        assert!(err.contains("shard2.journal"), "{err}");
        // Beam journals refuse to merge.
        let beamed = space.clone().with_strategy(Strategy::beam(4));
        let err = merge_shards(&wl, &beamed, &paths).unwrap_err();
        assert!(err.contains("--strategy exhaustive"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn beam_strategy_explores_the_same_small_space_as_exhaustive() {
        let wl = workloads::by_name("gesummv").unwrap();
        let exhaustive =
            explore(&wl, &small_space(), &ExploreConfig::serial());
        let space =
            small_space().with_strategy(Strategy::beam_with_budget(4, 1024));
        let res = explore(&wl, &space, &ExploreConfig::serial());
        assert_eq!(res.strategy, Strategy::beam_with_budget(4, 1024));
        assert_eq!(res.shard, None);
        assert_eq!(res.points.len(), exhaustive.points.len());
        for (a, b) in res.points.iter().zip(&exhaustive.points) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.latency_cycles, b.latency_cycles);
        }
        assert_eq!(res.frontier, exhaustive.frontier);
        assert_eq!(res.knee, exhaustive.knee);
    }
}
