//! The parallel explorer: evaluate every [`DesignPoint`] of a
//! [`DesignSpace`] on a `std::thread` worker pool.
//!
//! Work distribution is a channel-backed queue (an `mpsc` receiver behind
//! a mutex that workers pop from), results flow back over a second
//! channel tagged with the point's enumeration index, and the final
//! vector is stitched together in that index order — so the output is
//! byte-identical whether the sweep ran on 1 worker or 32.
//!
//! Per point, the expensive symbolic pass is fetched from (or inserted
//! into) the shared [`AnalysisCache`]; evaluating energy / latency /
//! counts at the point's bounds, tile scale and energy backend is then
//! just expression evaluation — microseconds, which is what makes wide
//! multi-axis sweeps tractable at all. Cold analyses within one sweep
//! additionally share the cache's Fourier–Motzkin feasibility pool, so a
//! guard proven (in)feasible for one design point is never re-proven for
//! another point with the same parameter context.
//!
//! The **schedule axis** ([`DesignSpace::with_schedules`]) is expanded
//! here rather than in [`DesignSpace::points`]: how many feasible
//! `(permutation, λ^J, λ^K)` candidates a point has depends on the
//! workload's dependence structure. Symbolic volumes are
//! schedule-invariant, so every candidate shares the shape's one cached
//! analysis — energy is priced once, and each candidate re-evaluates
//! latency alone (`SymbolicAnalysis::latency_at_with`). Candidates of
//! one base point compete inside the same (bounds, backend) scenario:
//! a slower schedule at identical energy/PEs/DRAM is dominated away,
//! which is how `--schedules all` can only improve the frontier.
//!
//! The **per-phase shape axis** ([`DesignSpace::with_phase_shapes`]) is
//! resolved here for the same reason: its extent depends on the
//! workload's phase count. Under [`PhasePolicy::PerPhase`] the explorer
//! enumerates [`DesignSpace::phase_points`] — every shape combination
//! across the phases — and assembles each point's totals from
//! *single-phase* analyses cached per (workload, phase, shape)
//! ([`AnalysisCache::try_get_or_analyze_phase_keyed`]): the
//! `shapes^phases` combinatorial sweep re-prices sums of per-phase
//! expressions, while analysis work stays proportional to the distinct
//! (phase, shape) pairs. Combinations compete inside their (bounds,
//! backend) scenario, so a heterogeneous assignment survives exactly
//! when no uniform (or other) assignment matches it everywhere — which
//! is how `--phase-shapes per-phase` can only improve the frontier.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::analysis::{
    energy_at_backend_phases, latency_at_phases, SymbolicAnalysis,
    WorkloadAnalysis,
};
use crate::energy::{Backend, MemoryClass};
use crate::pra::Workload;
use crate::tiling::pad_bounds;

use super::cache::{
    panic_message, phase_fingerprint, workload_fingerprint, AnalysisCache,
    CacheStats,
};
use super::pareto::{knee_point, pareto_frontier, Objectives};
use super::space::{
    DesignPoint, DesignSpace, PhasePolicy, PhaseShapes, ScheduleChoice,
    SchedulePolicy,
};

/// Explorer knobs.
#[derive(Debug, Clone, Default)]
pub struct ExploreConfig {
    /// Worker threads; `0` = one per available CPU.
    pub workers: usize,
}

impl ExploreConfig {
    /// A serial (single-worker) configuration.
    pub fn serial() -> Self {
        ExploreConfig { workers: 1 }
    }

    fn effective_workers(&self, jobs: usize) -> usize {
        let auto = || {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        };
        let w = if self.workers == 0 { auto() } else { self.workers };
        w.clamp(1, jobs.max(1))
    }
}

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct EvaluatedPoint {
    /// The configuration that was evaluated.
    pub point: DesignPoint,
    /// Human-readable schedule description: the per-phase intra-tile
    /// dimension orders (fastest first), phases joined by `|` — e.g.
    /// `j0j1` or `j0j1j2|j1j0`. Distinct schedule candidates of one
    /// shape always render distinctly.
    pub schedule_label: String,
    /// PEs used.
    pub pes: i64,
    /// Total energy `E_tot` in pJ.
    pub energy_pj: f64,
    /// DRAM share of the energy, in pJ.
    pub dram_pj: f64,
    /// Global latency in cycles.
    pub latency_cycles: i64,
    /// Energy-delay product (derived scalar, pJ·cycles).
    pub edp: f64,
    /// Wall time spent obtaining the symbolic analysis for this point —
    /// near zero on a cache hit.
    pub analysis_ms: f64,
    /// Whether the symbolic analysis came from the cache.
    pub cache_hit: bool,
}

impl EvaluatedPoint {
    /// The minimized objective vector (energy, latency, PEs, DRAM).
    pub fn objectives(&self) -> Objectives {
        Objectives {
            energy_pj: self.energy_pj,
            latency_cycles: self.latency_cycles as f64,
            pes: self.pes as f64,
            dram_pj: self.dram_pj,
        }
    }
}

/// The Pareto frontier of one *scenario* — one (bounds, backend) pair.
/// Dominance is only meaningful between points solving the same problem
/// under the same energy interpretation: pooling scenarios would let the
/// smallest bounds (cheaper in every objective) dominate every larger
/// size, and the TCPA backend dominate every pricier architecture.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierGroup {
    /// Loop bounds of this scenario.
    pub bounds: Vec<i64>,
    /// Energy backend of this scenario.
    pub backend: Backend,
    /// Indices into [`ExploreResult::points`] of the non-dominated
    /// points, in enumeration order.
    pub frontier: Vec<usize>,
    /// Index into [`ExploreResult::points`] of this frontier's knee.
    pub knee: Option<usize>,
}

/// Outcome of one exploration.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    /// Workload name.
    pub workload: String,
    /// Every surviving point, in deterministic space-enumeration order.
    pub points: Vec<EvaluatedPoint>,
    /// One Pareto frontier per (bounds, backend) scenario, in first-seen
    /// order.
    pub groups: Vec<FrontierGroup>,
    /// Union of all per-scenario frontiers (sorted indices into
    /// [`Self::points`]) — for a single-scenario space this *is* the
    /// frontier.
    pub frontier: Vec<usize>,
    /// Knee of the frontier when the space has exactly one scenario;
    /// `None` otherwise (each [`FrontierGroup`] carries its own knee).
    pub knee: Option<usize>,
    /// Points dropped because their analysis or evaluation failed
    /// (infeasible schedule etc.), with the failure message — reported,
    /// never silently absorbed into `points`. In enumeration order.
    pub failures: Vec<(DesignPoint, String)>,
    /// Cache counters at the end of the run.
    pub cache: CacheStats,
    /// Wall-clock time of the whole exploration.
    pub wall: Duration,
    /// Event-engine verification verdicts for frontier points, keyed by
    /// index into [`Self::points`]. Empty unless
    /// [`super::verify::sim_verify_frontier`] ran
    /// (`dse --sim-verify-frontier`).
    pub sim_verify: std::collections::BTreeMap<usize, super::verify::SimVerify>,
}

impl ExploreResult {
    /// The frontier, resolved to points (enumeration order).
    pub fn frontier_points(&self) -> Vec<&EvaluatedPoint> {
        self.frontier.iter().map(|&i| &self.points[i]).collect()
    }

    /// The knee point, resolved.
    pub fn knee_point(&self) -> Option<&EvaluatedPoint> {
        self.knee.map(|i| &self.points[i])
    }

    /// Points sorted by EDP (NaN-safe total order), best first — the old
    /// single-scalar ranking, kept as a convenience view.
    pub fn by_edp(&self) -> Vec<&EvaluatedPoint> {
        let mut v: Vec<&EvaluatedPoint> = self.points.iter().collect();
        v.sort_by(|a, b| a.edp.total_cmp(&b.edp));
        v
    }
}

/// Per-phase parameter vectors `(N…, p…)` for `point` against the
/// resolved phase analyses (uniform or heterogeneous). Shared with the
/// frontier verification pass (`super::verify`), which must reconstruct
/// exactly the parameters the sweep evaluated.
pub(crate) fn phase_params(
    phases: &[&SymbolicAnalysis],
    point: &DesignPoint,
) -> Vec<Vec<i64>> {
    phases
        .iter()
        .map(|ph| {
            let b = pad_bounds(&point.bounds, ph.tiled.pra.ndims);
            if point.tile_scale == 1 {
                ph.params_for(&b)
            } else {
                // Oversized tiles: p_ℓ = min(N_ℓ, k·⌈N_ℓ/t_ℓ⌉) stays
                // inside the analysis context 1 ≤ p_ℓ ≤ N_ℓ while
                // covering the iteration space. `tile_sizes` is the
                // exact-cover authority `params_for` also uses.
                let exact = ph.tiled.mapping.tile_sizes(&b);
                let mut v = b.clone();
                for (l, &n) in b.iter().enumerate() {
                    v.push(
                        (point.tile_scale * exact[l]).min(n).max(exact[l]),
                    );
                }
                v
            }
        })
        .collect()
}

/// Evaluate one design point against the (cached) symbolic analyses,
/// expanded into one [`EvaluatedPoint`] per schedule candidate according
/// to `policy`. `Err` carries the analysis failure message (memoized by
/// the cache, so a bad shape fails once and cheaply thereafter).
///
/// A uniform point resolves to the one whole-workload cached analysis of
/// its `array`; a per-phase point resolves each phase's shape to its own
/// cached single-phase analysis (`phase_fps` are the precomputed
/// [`phase_fingerprint`]s, indexed like `wl.phases`) — every shape
/// combination reuses the per-(phase, shape) entries. Either way the
/// evaluation below runs over the same resolved `&[&SymbolicAnalysis]`
/// slice through the same arithmetic
/// (`analysis::energy_at_backend_phases` & friends, which the uniform
/// `WorkloadAnalysis` methods delegate to), so uniform points stay
/// bit-for-bit identical to the pre-axis explorer.
///
/// Energy, DRAM traffic and PEs are schedule-invariant and computed once
/// per base point; only latency (and therefore EDP) is re-evaluated per
/// candidate — the structural cheapness that makes the schedule a free
/// axis on top of the cached analyses.
fn evaluate(
    wl: &Workload,
    fingerprint: u64,
    phase_fps: &[u64],
    point: &DesignPoint,
    cache: &AnalysisCache,
    policy: SchedulePolicy,
) -> Result<Vec<EvaluatedPoint>, String> {
    let t0 = Instant::now();
    // Keep-alives for the Arc'd analyses the `phases` slice borrows.
    let uniform_ana: Option<std::sync::Arc<WorkloadAnalysis>>;
    let mut phase_anas: Vec<std::sync::Arc<SymbolicAnalysis>> = Vec::new();
    let cache_hit = match &point.phase_shapes {
        PhaseShapes::Uniform => {
            let (ana, hit) =
                cache.try_get_or_analyze_keyed(wl, fingerprint, &point.array);
            uniform_ana = Some(ana?);
            hit
        }
        PhaseShapes::PerPhase(shapes) => {
            assert_eq!(
                shapes.len(),
                wl.phases.len(),
                "one shape per phase of {}",
                wl.name
            );
            uniform_ana = None;
            let mut all_hit = true;
            for (i, shape) in shapes.iter().enumerate() {
                let (ana, hit) = cache.try_get_or_analyze_phase_keyed(
                    wl,
                    phase_fps[i],
                    i,
                    shape,
                );
                all_hit &= hit;
                phase_anas.push(ana?);
            }
            all_hit
        }
    };
    let phases: Vec<&SymbolicAnalysis> = match &uniform_ana {
        Some(ana) => ana.phases.iter().collect(),
        None => phase_anas.iter().map(|a| &**a).collect(),
    };
    let analysis_ms = t0.elapsed().as_secs_f64() * 1e3;
    let params = phase_params(&phases, point);
    // One symbolic analysis per phase, any architecture: routing +
    // pricing through the point's backend. For the TCPA backend this is
    // bit-identical to the pre-backend `energy_at` fast path (see
    // `analysis::evaluate`).
    let energy =
        energy_at_backend_phases(phases.iter().copied(), &params, &point.backend);
    let dram_pj = energy
        .mem_pj
        .get(&MemoryClass::Dram)
        .copied()
        .unwrap_or(0.0);
    let with_latency = |latency_cycles: i64,
                        schedule: ScheduleChoice,
                        schedule_label: String| {
        EvaluatedPoint {
            point: DesignPoint { schedule, ..point.clone() },
            schedule_label,
            pes: point.pes(),
            energy_pj: energy.total,
            dram_pj,
            latency_cycles,
            edp: energy.total * latency_cycles as f64,
            analysis_ms,
            cache_hit,
        }
    };
    if policy == SchedulePolicy::First {
        // The pre-axis path: each phase's embedded default schedule, no
        // enumeration — `--schedules first` stays bit-identical to the
        // single-schedule explorer.
        let latency_cycles = latency_at_phases(phases.iter().copied(), &params);
        let label = phases
            .iter()
            .map(|ph| ph.schedule.perm_label())
            .collect::<Vec<_>>()
            .join("|");
        return Ok(vec![with_latency(
            latency_cycles,
            ScheduleChoice::First,
            label,
        )]);
    }
    // Enumerate per phase (candidate 0 always exists: the analysis
    // succeeded, so find_schedule's pick did), then walk the per-phase
    // cross product in lexicographic index order — deterministic, last
    // phase fastest.
    let cands: Vec<Vec<crate::schedule::Schedule>> = phases
        .iter()
        .map(|ph| ph.enumerate_schedules(policy.per_phase_cap()))
        .collect();
    let counts: Vec<usize> = cands.iter().map(Vec::len).collect();
    debug_assert!(counts.iter().all(|&c| c >= 1));
    // Each (phase, candidate) latency once — the combos below only sum
    // table entries (Σ cᵢ evaluations instead of Π cᵢ · phases).
    let lat: Vec<Vec<i64>> = phases
        .iter()
        .zip(&params)
        .zip(&cands)
        .map(|((ph, p), phase_cands)| {
            phase_cands
                .iter()
                .map(|s| ph.latency_at_with(s, p))
                .collect()
        })
        .collect();
    let total: usize = counts.iter().product();
    let mut out = Vec::with_capacity(total);
    for flat in 0..total {
        let mut rem = flat;
        let mut idx = vec![0usize; counts.len()];
        for d in (0..counts.len()).rev() {
            idx[d] = rem % counts[d];
            rem /= counts[d];
        }
        let latency_cycles: i64 = idx
            .iter()
            .enumerate()
            .map(|(phase, &ci)| lat[phase][ci])
            .sum();
        let label = idx
            .iter()
            .enumerate()
            .map(|(phase, &ci)| cands[phase][ci].perm_label())
            .collect::<Vec<_>>()
            .join("|");
        out.push(with_latency(
            latency_cycles,
            ScheduleChoice::Indices(idx),
            label,
        ));
    }
    Ok(out)
}

/// Explore `space` for `wl` with a private, single-use cache.
pub fn explore(
    wl: &Workload,
    space: &DesignSpace,
    cfg: &ExploreConfig,
) -> ExploreResult {
    explore_with_cache(wl, space, cfg, &AnalysisCache::new())
}

/// Explore `space` for `wl`, sharing `cache` with (and warming it for)
/// other sweeps — the bounds-sweep fast path.
pub fn explore_with_cache(
    wl: &Workload,
    space: &DesignSpace,
    cfg: &ExploreConfig,
    cache: &AnalysisCache,
) -> ExploreResult {
    let t0 = Instant::now();
    // The per-phase axis needs the workload's phase count, which the
    // space cannot know — resolve the base-point enumeration here.
    let points = match space.phase_policy {
        PhasePolicy::Uniform => space.points(),
        PhasePolicy::PerPhase => space.phase_points(wl.phases.len()),
    };
    let n = points.len();
    let workers = cfg.effective_workers(n);
    let policy = space.schedules;
    // One IR walk for the whole sweep, not one per design point.
    let fingerprint = workload_fingerprint(wl);
    let phase_fps: Vec<u64> =
        wl.phases.iter().map(phase_fingerprint).collect();

    // Job queue: a channel pre-filled with every (index, point), its
    // receiver shared behind a mutex so idle workers steal the next job.
    let (jtx, jrx) = mpsc::channel::<(usize, DesignPoint)>();
    for job in points.into_iter().enumerate() {
        jtx.send(job).expect("queue send");
    }
    drop(jtx);
    let jrx = Mutex::new(jrx);

    // One base point expands into one evaluated point per schedule
    // candidate (exactly one under `SchedulePolicy::First`).
    type PointResult = Result<Vec<EvaluatedPoint>, (DesignPoint, String)>;
    let (rtx, rrx) = mpsc::channel::<(usize, PointResult)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let rtx = rtx.clone();
            let jrx = &jrx;
            let phase_fps = &phase_fps;
            s.spawn(move || loop {
                // Pop under the lock, evaluate outside it.
                let job = { jrx.lock().unwrap().recv() };
                let Ok((idx, point)) = job else { break };
                // Analysis failures surface as Err (memoized, cheap);
                // catch_unwind additionally guards the evaluation
                // arithmetic itself.
                let eval = match catch_unwind(AssertUnwindSafe(|| {
                    evaluate(wl, fingerprint, phase_fps, &point, cache, policy)
                })) {
                    Ok(Ok(e)) => Ok(e),
                    Ok(Err(msg)) => Err((point, msg)),
                    Err(payload) => {
                        Err((point, panic_message(payload.as_ref())))
                    }
                };
                // The queue sender is gone before workers start, so the
                // only way `send` fails is the collector having hung up —
                // at which point the result is moot.
                let _ = rtx.send((idx, eval));
            });
        }
        drop(rtx);
    });

    // Deterministic ordering: stitch results back by base-point
    // enumeration index, then candidate order within each base point —
    // byte-identical output regardless of worker count.
    let mut slots: Vec<Vec<EvaluatedPoint>> = vec![Vec::new(); n];
    let mut failed: Vec<(usize, DesignPoint, String)> = Vec::new();
    while let Ok((idx, eval)) = rrx.recv() {
        match eval {
            Ok(e) => slots[idx] = e,
            Err((point, msg)) => failed.push((idx, point, msg)),
        }
    }
    failed.sort_by_key(|(idx, _, _)| *idx);
    let failures: Vec<(DesignPoint, String)> =
        failed.into_iter().map(|(_, p, m)| (p, m)).collect();
    let evaluated: Vec<EvaluatedPoint> =
        slots.into_iter().flatten().collect();

    // Group by scenario, preserving first-seen order, then compute one
    // frontier + knee per group.
    let mut groups: Vec<FrontierGroup> = Vec::new();
    let mut members: Vec<Vec<usize>> = Vec::new();
    for (i, p) in evaluated.iter().enumerate() {
        let pos = groups.iter().position(|g| {
            g.bounds == p.point.bounds && g.backend == p.point.backend
        });
        match pos {
            Some(gi) => members[gi].push(i),
            None => {
                groups.push(FrontierGroup {
                    bounds: p.point.bounds.clone(),
                    backend: p.point.backend.clone(),
                    frontier: Vec::new(),
                    knee: None,
                });
                members.push(vec![i]);
            }
        }
    }
    for (g, m) in groups.iter_mut().zip(&members) {
        let objs: Vec<_> = m
            .iter()
            .map(|&i| evaluated[i].objectives().to_array())
            .collect();
        let local = pareto_frontier(&objs);
        g.frontier = local.iter().map(|&k| m[k]).collect();
        let local_objs: Vec<_> = local.iter().map(|&k| objs[k]).collect();
        g.knee = knee_point(&local_objs).map(|k| g.frontier[k]);
    }
    let mut frontier: Vec<usize> =
        groups.iter().flat_map(|g| g.frontier.iter().copied()).collect();
    frontier.sort_unstable();
    let knee = match groups.as_slice() {
        [only] => only.knee,
        _ => None,
    };

    ExploreResult {
        workload: wl.name.clone(),
        points: evaluated,
        groups,
        frontier,
        knee,
        failures,
        cache: cache.stats(),
        wall: t0.elapsed(),
        sim_verify: std::collections::BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn small_space() -> DesignSpace {
        DesignSpace::new().with_arrays_2d(4).with_bounds(vec![8, 8])
    }

    #[test]
    fn parallel_equals_serial_bit_for_bit() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = small_space();
        let serial = explore(&wl, &space, &ExploreConfig::serial());
        let parallel =
            explore(&wl, &space, &ExploreConfig { workers: 4 });
        assert_eq!(serial.points.len(), parallel.points.len());
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(a.point, b.point);
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.latency_cycles, b.latency_cycles);
        }
        assert_eq!(serial.frontier, parallel.frontier);
        assert_eq!(serial.knee, parallel.knee);
    }

    #[test]
    fn frontier_beats_edp_only_view() {
        let wl = workloads::by_name("gesummv").unwrap();
        let res = explore(&wl, &small_space(), &ExploreConfig::default());
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        assert!(!res.frontier.is_empty());
        // The 1×1 array uses the fewest PEs: nothing can dominate it, so
        // a multi-objective frontier must retain it even though the EDP
        // sort buries it.
        let serial_idx = res
            .points
            .iter()
            .position(|p| p.point.array == vec![1, 1])
            .unwrap();
        assert!(res.frontier.contains(&serial_idx));
        // Knee lies on the frontier.
        let knee = res.knee.unwrap();
        assert!(res.frontier.contains(&knee));
    }

    #[test]
    fn bounds_sweep_reuses_analyses() {
        let wl = workloads::by_name("gesummv").unwrap();
        let cache = AnalysisCache::new();
        let warm = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds(vec![8, 8]);
        explore_with_cache(&wl, &warm, &ExploreConfig::default(), &cache);
        let shapes = cache.stats().entries;
        let sweep = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds_sweep(&[16, 32, 64], 2);
        let res =
            explore_with_cache(&wl, &sweep, &ExploreConfig::default(), &cache);
        // No new analyses ran: every shape was already cached.
        assert_eq!(res.cache.entries, shapes);
        assert!(res.points.iter().all(|p| p.cache_hit));
    }

    #[test]
    fn scenario_axes_get_separate_frontiers() {
        // Pooled dominance would let the N=8 points (cheaper in every
        // objective at equal shape) erase every N=16 point; per-scenario
        // grouping must keep a frontier for each bounds vector.
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds_sweep(&[8, 16], 2);
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.groups.len(), 2);
        for g in &res.groups {
            assert!(!g.frontier.is_empty(), "{:?} has an empty frontier", g.bounds);
            let k = g.knee.unwrap();
            assert!(g.frontier.contains(&k));
            // Every frontier member belongs to this scenario.
            for &i in &g.frontier {
                assert_eq!(res.points[i].point.bounds, g.bounds);
            }
        }
        assert!(res
            .frontier
            .iter()
            .any(|&i| res.points[i].point.bounds == vec![16, 16]));
        // Multi-scenario result has no single knee.
        assert_eq!(res.knee, None);
    }

    #[test]
    fn backend_axis_orders_architectures() {
        // Same volumes, pricier interpretations: tcpa ≤ systolic ≤ cgra
        // ≤ gpu-sm at every design point (pointwise per-access ordering
        // of the built-in routing tables).
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![16, 16])
            .with_backends(Backend::builtins());
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.points.len(), 4);
        // One scenario per backend: the architectures are compared, not
        // dominated away by the cheapest interpretation.
        assert_eq!(res.groups.len(), 4);
        assert_eq!(res.frontier.len(), 4);
        let by_backend = |name: &str| {
            res.points
                .iter()
                .find(|p| p.point.backend.name() == name)
                .unwrap()
                .energy_pj
        };
        let (tcpa, systolic, cgra, gpu) = (
            by_backend("tcpa"),
            by_backend("systolic"),
            by_backend("cgra"),
            by_backend("gpu-sm"),
        );
        assert!(tcpa < systolic, "{tcpa} vs {systolic}");
        assert!(systolic < cgra, "{systolic} vs {cgra}");
        assert!(cgra < gpu, "{cgra} vs {gpu}");
    }

    #[test]
    fn legacy_policy_axis_still_explores() {
        // The deprecated closed-enum axis rides on the backend machinery.
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![16, 16])
            .with_policies(crate::energy::Policy::ALL.to_vec());
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.points.len(), 3);
        assert_eq!(res.groups.len(), 3);
        let by_name = |name: &str| {
            res.points
                .iter()
                .find(|p| p.point.backend.name() == name)
                .unwrap()
                .energy_pj
        };
        assert!(by_name("tcpa") < by_name("no-fd"));
        assert!(by_name("no-fd") <= by_name("no-reuse"));
    }

    #[test]
    fn failures_carry_point_and_message() {
        // No causal lexicographic order exists: every point must land in
        // `failures` with the scheduler's message, not vanish.
        let wl = workloads::twist_unschedulable();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8]);
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert!(res.points.is_empty());
        assert_eq!(res.failures.len(), 1);
        let (p, msg) = &res.failures[0];
        assert_eq!(p.array, vec![2, 2]);
        assert!(
            msg.contains("schedule"),
            "message should name the scheduling failure: {msg}"
        );
        assert!(res.frontier.is_empty() && res.knee.is_none());
    }

    #[test]
    fn schedule_axis_surfaces_faster_non_default_schedule() {
        // GESUMMV on a 1×4 array at N = (16,16): the natural dimension
        // order routes the expensive inter-tile offset along the mapped
        // dimension (λ^K_1 = 1 + p0·p1 − p0), while the swapped order
        // needs only λ^K_1 = p1 — genuinely faster at identical energy.
        // The single-schedule explorer never sees it.
        let wl = workloads::by_name("gesummv").unwrap();
        let base = DesignSpace::new()
            .with_arrays(vec![vec![1, 4]])
            .with_bounds(vec![16, 16]);
        let first = explore(&wl, &base, &ExploreConfig::default());
        let all = explore(
            &wl,
            &base.with_schedules(SchedulePolicy::All),
            &ExploreConfig::default(),
        );
        assert_eq!(first.points.len(), 1);
        assert_eq!(all.points.len(), 2, "two causal permutations");
        // Energy/PEs/DRAM are schedule-invariant.
        for p in &all.points {
            assert_eq!(
                p.energy_pj.to_bits(),
                first.points[0].energy_pj.to_bits()
            );
            assert_eq!(p.dram_pj.to_bits(), first.points[0].dram_pj.to_bits());
            assert_eq!(p.pes, first.points[0].pes);
        }
        // Candidate 0 is the default pick, identical to --schedules first.
        assert!(all.points[0].point.schedule.is_default());
        assert_eq!(
            all.points[0].latency_cycles,
            first.points[0].latency_cycles
        );
        assert_eq!(all.points[0].schedule_label, "j0j1");
        assert_eq!(all.points[1].schedule_label, "j1j0");
        // The swapped schedule wins; the default is dominated away.
        assert!(
            all.points[1].latency_cycles < all.points[0].latency_cycles,
            "swapped order must be faster: {:?}",
            all.points.iter().map(|p| p.latency_cycles).collect::<Vec<_>>()
        );
        assert_eq!(all.frontier, vec![1]);
    }

    #[test]
    fn schedule_axis_cross_product_over_phases() {
        // Multi-phase workloads expand into the per-phase cross product,
        // in lexicographic index order with deterministic labels.
        let wl = workloads::by_name("atax").unwrap();
        let cache = AnalysisCache::new();
        let (ana, _) = cache.get_or_analyze(&wl, &[2, 2]);
        let per_phase: Vec<usize> = ana
            .phases
            .iter()
            .map(|ph| ph.enumerate_schedules(None).len())
            .collect();
        let expected: usize = per_phase.iter().product();
        assert!(expected >= 1);
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8])
            .with_schedules(SchedulePolicy::All);
        let res = explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        assert_eq!(res.points.len(), expected);
        // Choices are distinct and lexicographically ordered.
        let choices: Vec<Vec<usize>> = res
            .points
            .iter()
            .map(|p| match &p.point.schedule {
                ScheduleChoice::Indices(ix) => ix.clone(),
                other => panic!("expected explicit indices, got {other:?}"),
            })
            .collect();
        let mut sorted = choices.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(choices, sorted, "combo order must be lexicographic");
        assert_eq!(choices[0], vec![0; per_phase.len()]);
        // Limit(1) collapses back to a single (default) candidate with
        // the same latency the First policy reports.
        let limited = explore_with_cache(
            &wl,
            &DesignSpace::new()
                .with_arrays(vec![vec![2, 2]])
                .with_bounds(vec![8, 8])
                .with_schedules(SchedulePolicy::Limit(1)),
            &ExploreConfig::default(),
            &cache,
        );
        assert_eq!(limited.points.len(), 1);
        assert!(limited.points[0].point.schedule.is_default());
        assert_eq!(
            limited.points[0].latency_cycles,
            res.points[0].latency_cycles
        );
    }

    #[test]
    fn per_phase_axis_includes_uniform_diagonal_bit_for_bit() {
        // The per-phase sweep covers every shape combination, including
        // the all-equal diagonal — and a diagonal combination, assembled
        // from single-phase cached analyses, must price exactly like the
        // uniform point of the same shape (same mappings, same table,
        // same π, same merge order).
        let wl = workloads::by_name("atax").unwrap();
        let base = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1]])
            .with_bounds(vec![8, 8]);
        let uniform = explore(&wl, &base, &ExploreConfig::default());
        let per_phase = explore(
            &wl,
            &base.clone().with_phase_shapes(PhasePolicy::PerPhase),
            &ExploreConfig::default(),
        );
        assert!(uniform.failures.is_empty() && per_phase.failures.is_empty());
        assert_eq!(uniform.points.len(), 2);
        assert_eq!(per_phase.points.len(), 4, "2 shapes × 2 phases");
        for u in &uniform.points {
            let shape = &u.point.array;
            let diag = per_phase
                .points
                .iter()
                .find(|p| {
                    p.point.phase_shapes
                        == PhaseShapes::PerPhase(vec![
                            shape.clone(),
                            shape.clone(),
                        ])
                })
                .expect("diagonal combination present");
            assert_eq!(diag.energy_pj.to_bits(), u.energy_pj.to_bits());
            assert_eq!(diag.dram_pj.to_bits(), u.dram_pj.to_bits());
            assert_eq!(diag.latency_cycles, u.latency_cycles);
            assert_eq!(diag.pes, u.pes);
            assert_eq!(diag.schedule_label, u.schedule_label);
        }
    }

    #[test]
    fn per_phase_analysis_count_scales_with_pairs_not_combinations() {
        // 3 shapes × 2 phases → 9 combinations per scenario, but only
        // 6 distinct (phase, shape) pairs may ever be analyzed — the
        // acceptance condition that keeps the combinatorial axis cheap.
        let wl = workloads::by_name("atax").unwrap();
        let cache = AnalysisCache::new();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1], vec![2, 2]])
            .with_bounds_sweep(&[8, 16], 2)
            .with_phase_shapes(PhasePolicy::PerPhase);
        let res = explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        assert_eq!(res.points.len(), 9 * 2, "9 combos × 2 bounds");
        let s = cache.stats();
        assert_eq!(s.entries, 6, "2 phases × 3 shapes analyzed");
        assert_eq!(s.misses, 6);
        // Every other lookup (2 per point) was served from the memo.
        assert_eq!(s.hits, 18 * 2 - 6);
    }

    #[test]
    fn tile_scale_stays_in_context_and_changes_schedule() {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![16, 16])
            .with_tile_scales(vec![1, 2]);
        let res = explore(&wl, &space, &ExploreConfig::default());
        assert_eq!(res.points.len(), 2);
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        for p in &res.points {
            assert!(p.energy_pj > 0.0);
            assert!(p.latency_cycles > 0);
        }
    }
}
