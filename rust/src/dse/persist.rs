//! Persistent spill of the analysis cache: symbolic volumes on disk.
//!
//! The expensive part of a `WorkloadAnalysis` is the symbolic
//! lattice-point counting; tiling, scheduling and access classification
//! are microseconds. [`DiskCache`] therefore persists, per
//! `(workload, array, energy-table)` key, every statement's
//! [`GuardedSum`] volume in a small line-oriented text format. A warm CLI
//! invocation reloads the volumes and re-derives the cheap parts —
//! producing an analysis **bit-for-bit identical** to a cold run (volumes
//! are exact integer polynomials; Guard/Poly reconstruction re-interns the
//! identical canonical constraints).
//!
//! Keys embed the workload's structural fingerprint and the energy
//! table's bit-exact fingerprint, so a stale file can never serve a
//! changed workload definition or table. Files are advisory: any read,
//! parse or validation failure falls back to recomputation, and writes go
//! through a temp-file rename so concurrent processes never observe a
//! torn file.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::analysis::{PresetVolumes, SymbolicAnalysis, WorkloadAnalysis};
use crate::energy::EnergyTable;
use crate::polyhedral::{AffineExpr, Constraint, Guard, GuardedSum, Poly};
use crate::pra::Workload;

const MAGIC: &str = "tcpa-analysis-cache v1";

/// The phase-scoped cache name of `(workload, phase)` — the key under
/// which the per-phase heterogeneous axis spills a *single phase's*
/// volumes. The scoped name is distinct from every plain workload name
/// in the header line (which records it raw, `#` included), and the
/// phase fingerprint differs from the workload's, so phase entries can
/// never serve — or be mistaken for — whole-workload ones. Callers that
/// prune a shared directory list these as live names alongside the
/// plain workload name (see `dse::AnalysisCache::prune_disk`).
pub fn phase_cache_name(wl_name: &str, phase: usize) -> String {
    format!("{wl_name}#p{phase}")
}

/// On-disk cache of symbolic analysis volumes, one file per
/// `(workload, array, table)` key under a caller-chosen directory.
#[derive(Debug, Clone)]
pub struct DiskCache {
    dir: PathBuf,
}

impl DiskCache {
    /// A cache rooted at `dir` (created lazily on first store).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DiskCache { dir: dir.into() }
    }

    /// The cache directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_for(
        &self,
        wl_name: &str,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
    ) -> PathBuf {
        let safe = sanitize(wl_name);
        let shape = array
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let table_fp = table.fingerprint();
        self.dir
            .join(format!("{safe}-{fp:016x}-{shape}-{table_fp:016x}.volumes"))
    }

    /// Remove stale entries: a `.volumes` file is stale when its
    /// workload name matches some `live` entry's (sanitized) name but
    /// its fingerprint matches **no** live `(name, fingerprint)` pair —
    /// i.e. the workload definition changed, so the file can never be
    /// loaded again (the fingerprint check in [`DiskCache::load`] will
    /// reject it forever). Because the filename-`sanitize` step is
    /// lossy (distinct raw names can share a prefix), deletion also
    /// requires the file's *header* — which records the raw name — to
    /// name a live workload; a collision or unreadable header keeps the
    /// file. Orphaned temp files from interrupted writes of live
    /// workloads (`<key stem>.tmp<pid>`, exactly the writer's naming)
    /// are removed too, as is checkpoint-journal debris parked in the
    /// cache directory (`*.tmp<digits>` / `*.corrupt` whose content
    /// begins with the journal magic — see [`is_journal_debris`]).
    /// Everything else — other workloads, other tools' files,
    /// unrecognized names — is **kept**: a shared directory is not ours
    /// to reap. Returns the number of files removed; a missing
    /// directory counts as already empty.
    pub fn prune(&self, live: &[(String, u64)]) -> std::io::Result<usize> {
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(0)
            }
            Err(e) => return Err(e),
        };
        let sanitized: Vec<(String, u64)> = live
            .iter()
            .map(|(n, fp)| (sanitize(n), *fp))
            .collect();
        let mut removed = 0;
        for entry in entries {
            let entry = entry?;
            let file_name = entry.file_name();
            let name = file_name.to_string_lossy();
            let stale = match name.strip_suffix(".volumes") {
                Some(stem) => match parse_key_stem(stem) {
                    Some((wl, fp)) => {
                        sanitized.iter().any(|(n, _)| *n == wl)
                            && !sanitized
                                .iter()
                                .any(|(n, f)| *n == wl && *f == fp)
                            && header_names_live_workload(
                                &entry.path(),
                                live,
                            )
                    }
                    // Unrecognized name under our extension: keep —
                    // pruning must never guess.
                    None => false,
                },
                // Temp files are rename sources that never made it; the
                // writer treats a failed rename as an advisory miss.
                // Reap only *our* naming — `<key stem>.tmp<digits>` for
                // a live workload name — so a shared directory's
                // `notes.tmpl` or another tool's `.tmp` files are never
                // touched. (A concurrent writer of the same key can
                // still lose its in-flight temp; it degrades to one
                // recomputed analysis, by the advisory-store contract.)
                None => {
                    is_orphan_temp(name.as_ref(), &sanitized)
                        || is_journal_debris(
                            name.as_ref(),
                            &entry.path(),
                        )
                }
            };
            if stale {
                std::fs::remove_file(entry.path())?;
                removed += 1;
            }
        }
        Ok(removed)
    }

    /// Best-effort startup cleanup: remove interrupted-write temp
    /// files — `<key stem>.tmp<digits>` where the stem parses as one
    /// of our keys — without needing the live-workload list that
    /// [`DiskCache::prune`] requires. A concurrent writer's in-flight
    /// temp can be lost; by the advisory-store contract that degrades
    /// to one recomputed analysis. Foreign names are kept. Returns the
    /// number of files removed; a missing directory counts as empty.
    pub fn reap_temps(&self) -> std::io::Result<usize> {
        let entries = match std::fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(0)
            }
            Err(e) => return Err(e),
        };
        let mut removed = 0;
        for entry in entries {
            let entry = entry?;
            let file_name = entry.file_name();
            let name = file_name.to_string_lossy();
            let Some((stem, ext)) = name.rsplit_once('.') else {
                continue;
            };
            let tmpish = ext.strip_prefix("tmp").is_some_and(|p| {
                !p.is_empty() && p.bytes().all(|b| b.is_ascii_digit())
            });
            if tmpish && parse_key_stem(stem).is_some() {
                std::fs::remove_file(entry.path())?;
                removed += 1;
            }
        }
        Ok(removed)
    }

    /// Load the preset volumes for `(wl, array, table)` if a valid file
    /// exists. `fp` is the caller's precomputed workload fingerprint;
    /// `table` must be the energy table the analysis will run under.
    pub fn load(
        &self,
        wl: &Workload,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
    ) -> Option<Vec<PresetVolumes>> {
        self.load_named(&wl.name, fp, array, table, wl.phases.len())
    }

    /// Load the preset volumes of *one phase* spilled by
    /// [`DiskCache::store_phase`]. `fp` is the phase's structural
    /// fingerprint (`dse::cache::phase_fingerprint`), not the workload's.
    pub fn load_phase(
        &self,
        wl_name: &str,
        fp: u64,
        phase: usize,
        array: &[i64],
        table: &EnergyTable,
    ) -> Option<PresetVolumes> {
        let mut v = self.load_named(
            &phase_cache_name(wl_name, phase),
            fp,
            array,
            table,
            1,
        )?;
        v.pop()
    }

    fn load_named(
        &self,
        name: &str,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
        nphases: usize,
    ) -> Option<Vec<PresetVolumes>> {
        let path = self.file_for(name, fp, array, table);
        let content = std::fs::read_to_string(path).ok()?;
        parse(&content, name, fp, array, table, nphases)
    }

    /// Persist the volumes of `ana` under the `(wl, array, table)` key.
    /// Errors are returned but callers may ignore them — the cache is
    /// advisory.
    pub fn store(
        &self,
        wl: &Workload,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
        ana: &WorkloadAnalysis,
    ) -> std::io::Result<()> {
        let phases: Vec<&SymbolicAnalysis> = ana.phases.iter().collect();
        self.store_named(&wl.name, fp, array, table, &phases)
    }

    /// Persist the volumes of *one phase's* analysis under the
    /// phase-scoped key (see [`phase_cache_name`]) — the per-phase
    /// heterogeneous axis spills each (phase, shape) pair individually,
    /// so editing one phase of a workload leaves its siblings' files
    /// loadable.
    pub fn store_phase(
        &self,
        wl_name: &str,
        fp: u64,
        phase: usize,
        array: &[i64],
        table: &EnergyTable,
        ana: &SymbolicAnalysis,
    ) -> std::io::Result<()> {
        self.store_named(
            &phase_cache_name(wl_name, phase),
            fp,
            array,
            table,
            &[ana],
        )
    }

    fn store_named(
        &self,
        name: &str,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
        phases: &[&SymbolicAnalysis],
    ) -> std::io::Result<()> {
        // Statement names are the lookup keys within a file; a name the
        // line format cannot carry round-trip is skipped wholesale.
        let ok_names = phases.iter().all(|ph| {
            ph.statements.iter().all(|s| {
                !s.name.is_empty()
                    && !s.name.contains(char::is_whitespace)
            })
        });
        if !ok_names {
            return Ok(());
        }
        std::fs::create_dir_all(&self.dir)?;
        let path = self.file_for(name, fp, array, table);
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        std::fs::write(&tmp, render(name, fp, array, table, phases))?;
        std::fs::rename(&tmp, &path)
    }
}

/// Filesystem-safe rendering of a workload name (the filename prefix).
fn sanitize(wl_name: &str) -> String {
    wl_name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// Is `file_name` an interrupted-write temp file of ours —
/// `<key stem>.tmp<digits>` (the exact shape `DiskCache::store`
/// produces) whose key stem parses and names a live (sanitized)
/// workload? Anything else in the directory is not ours to reap.
fn is_orphan_temp(file_name: &str, sanitized: &[(String, u64)]) -> bool {
    let Some((stem, ext)) = file_name.rsplit_once('.') else {
        return false;
    };
    let Some(pid) = ext.strip_prefix("tmp") else {
        return false;
    };
    if pid.is_empty() || !pid.bytes().all(|b| b.is_ascii_digit()) {
        return false;
    }
    match parse_key_stem(stem) {
        Some((wl, _)) => sanitized.iter().any(|(n, _)| *n == wl),
        None => false,
    }
}

/// Is `file_name` checkpoint-journal debris — an interrupted-write
/// temp (`*.tmp<digits>`, the journal writer's naming) or a
/// quarantined corrupt journal (`*.corrupt`)? The name shapes alone
/// are too generic to reap on sight in a shared directory, so the
/// file's first line must additionally prove provenance by carrying
/// the journal magic. Live journals (no debris suffix) are never
/// touched.
fn is_journal_debris(file_name: &str, path: &Path) -> bool {
    let Some((_, ext)) = file_name.rsplit_once('.') else {
        return false;
    };
    let tmpish = ext.strip_prefix("tmp").is_some_and(|pid| {
        !pid.is_empty() && pid.bytes().all(|b| b.is_ascii_digit())
    });
    if !tmpish && ext != "corrupt" {
        return false;
    }
    first_line_is(path, crate::dse::journal::MAGIC)
}

/// Does the file at `path` begin with exactly `magic` followed by a
/// newline? Only `magic.len() + 1` bytes are read.
fn first_line_is(path: &Path, magic: &str) -> bool {
    use std::io::Read as _;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut buf = vec![0u8; magic.len() + 1];
    let mut len = 0;
    while len < buf.len() {
        match f.read(&mut buf[len..]) {
            Ok(0) => break,
            Ok(n) => len += n,
            Err(_) => return false,
        }
    }
    buf[..len] == *format!("{magic}\n").as_bytes()
}

/// Does the `.volumes` file at `path` declare one of the live *raw*
/// workload names in its header? `sanitize` is lossy, so the filename
/// prefix alone could attribute a file to the wrong workload; the
/// header line (`workload <raw name>`) is exact. Only a bounded prefix
/// is read — volume files can be large, and the header sits in the
/// first two lines. An unreadable, malformed, or prefix-truncated
/// header disqualifies — [`DiskCache::prune`] keeps such files.
fn header_names_live_workload(
    path: &Path,
    live: &[(String, u64)],
) -> bool {
    use std::io::Read as _;
    let Ok(mut f) = std::fs::File::open(path) else {
        return false;
    };
    let mut buf = [0u8; 256];
    let mut len = 0;
    let eof = loop {
        match f.read(&mut buf[len..]) {
            Ok(0) => break true,
            Ok(n) => {
                len += n;
                if len == buf.len() {
                    break false;
                }
            }
            Err(_) => return false,
        }
    };
    let head = String::from_utf8_lossy(&buf[..len]);
    let mut lines = head.split('\n');
    let (l1, l2) = (lines.next(), lines.next());
    // The name line must be provably complete: either a third segment
    // follows it within the prefix, or the whole file fit — otherwise a
    // truncated longer name could false-match a live one.
    if lines.next().is_none() && !eof {
        return false;
    }
    if l1 != Some(MAGIC) {
        return false;
    }
    match l2.and_then(|l| l.strip_prefix("workload ")) {
        Some(raw) => live.iter().any(|(n, _)| n.as_str() == raw),
        None => false,
    }
}

/// Recover `(sanitized workload name, fingerprint)` from a `.volumes`
/// file stem `{safe}-{fp:016x}-{shape}-{table_fp:016x}`. The name may
/// itself contain `-`, so fields are split from the right; anything that
/// does not scan as two 16-digit hex fingerprints around a shape returns
/// `None` (the caller keeps such files).
fn parse_key_stem(stem: &str) -> Option<(String, u64)> {
    let is_fp = |s: &str| s.len() == 16 && u64::from_str_radix(s, 16).is_ok();
    let (rest, table_fp) = stem.rsplit_once('-')?;
    let (rest, _shape) = rest.rsplit_once('-')?;
    let (name, fp) = rest.rsplit_once('-')?;
    if !is_fp(table_fp) || !is_fp(fp) || name.is_empty() {
        return None;
    }
    Some((name.to_string(), u64::from_str_radix(fp, 16).unwrap()))
}

fn render(
    name: &str,
    fp: u64,
    array: &[i64],
    table: &EnergyTable,
    phases: &[&SymbolicAnalysis],
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{MAGIC}");
    let _ = writeln!(s, "workload {name}");
    let _ = writeln!(s, "fingerprint {fp:016x}");
    let _ = writeln!(
        s,
        "array {}",
        array.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
    );
    let _ = writeln!(s, "table {:016x}", table.fingerprint());
    let _ = writeln!(s, "phases {}", phases.len());
    for (i, ph) in phases.iter().enumerate() {
        let _ = writeln!(s, "phase {i} statements {}", ph.statements.len());
        for st in &ph.statements {
            let _ = writeln!(
                s,
                "stmt {} nparams {} pieces {}",
                st.name,
                st.volume.nparams(),
                st.volume.pieces.len()
            );
            for (g, p) in &st.volume.pieces {
                let cs = g.resolved();
                let _ = writeln!(s, "guard {}", cs.len());
                for c in cs {
                    let _ = writeln!(s, "c {}", render_affine(&c.0));
                }
                let terms: Vec<_> = p.terms().collect();
                let _ = writeln!(s, "poly {}", terms.len());
                for (e, coeff) in terms {
                    let _ = writeln!(
                        s,
                        "t {};{coeff}",
                        e.iter()
                            .map(|x| x.to_string())
                            .collect::<Vec<_>>()
                            .join(",")
                    );
                }
            }
        }
    }
    s.push_str("end\n");
    s
}

fn render_affine(e: &AffineExpr) -> String {
    format!(
        "{};{}",
        e.coeffs
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(","),
        e.konst
    )
}

fn parse_affine(body: &str, np: usize) -> Option<AffineExpr> {
    let (coeffs, konst) = body.split_once(';')?;
    let coeffs: Vec<i64> = coeffs
        .split(',')
        .map(|x| x.parse().ok())
        .collect::<Option<_>>()?;
    if coeffs.len() != np {
        return None;
    }
    Some(AffineExpr { coeffs, konst: konst.parse().ok()? })
}

fn parse_term(body: &str, np: usize) -> Option<(Vec<u32>, i128)> {
    let (expos, coeff) = body.split_once(';')?;
    let expos: Vec<u32> = expos
        .split(',')
        .map(|x| x.parse().ok())
        .collect::<Option<_>>()?;
    if expos.len() != np {
        return None;
    }
    // Packed-lane capacity is enforced by `Poly::try_from_terms` — the
    // single authority on the encoding.
    Some((expos, coeff.parse().ok()?))
}

fn parse(
    content: &str,
    name: &str,
    fp: u64,
    array: &[i64],
    table: &EnergyTable,
    expect_phases: usize,
) -> Option<Vec<PresetVolumes>> {
    let mut lines = content.lines();
    if lines.next()? != MAGIC {
        return None;
    }
    if lines.next()? != format!("workload {name}") {
        return None;
    }
    if lines.next()? != format!("fingerprint {fp:016x}") {
        return None;
    }
    let shape = array
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    if lines.next()? != format!("array {shape}") {
        return None;
    }
    if lines.next()? != format!("table {:016x}", table.fingerprint()) {
        return None;
    }
    let nphases: usize =
        lines.next()?.strip_prefix("phases ")?.parse().ok()?;
    if nphases != expect_phases {
        return None;
    }
    let mut out = Vec::with_capacity(nphases);
    for ph in 0..nphases {
        let nstmts: usize = lines
            .next()?
            .strip_prefix(&format!("phase {ph} statements "))?
            .parse()
            .ok()?;
        let mut map = PresetVolumes::new();
        for _ in 0..nstmts {
            let parts: Vec<&str> = lines.next()?.split(' ').collect();
            if parts.len() != 6
                || parts[0] != "stmt"
                || parts[2] != "nparams"
                || parts[4] != "pieces"
            {
                return None;
            }
            let name = parts[1].to_string();
            let np: usize = parts[3].parse().ok()?;
            let npieces: usize = parts[5].parse().ok()?;
            let mut gs = GuardedSum::zero(np);
            for _ in 0..npieces {
                let nc: usize =
                    lines.next()?.strip_prefix("guard ")?.parse().ok()?;
                let mut cs = Vec::with_capacity(nc);
                for _ in 0..nc {
                    let body = lines.next()?.strip_prefix("c ")?;
                    cs.push(Constraint(parse_affine(body, np)?));
                }
                let nt: usize =
                    lines.next()?.strip_prefix("poly ")?.parse().ok()?;
                let mut terms = Vec::with_capacity(nt);
                for _ in 0..nt {
                    terms.push(parse_term(
                        lines.next()?.strip_prefix("t ")?,
                        np,
                    )?);
                }
                // try_from_terms owns the capacity rules: a corrupt file
                // degrades to recomputation, never a pack-assert panic.
                gs.push(Guard::new(cs), Poly::try_from_terms(np, terms)?);
            }
            map.insert(name, gs);
        }
        out.push(map);
    }
    (lines.next()? == "end").then_some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::cache::workload_fingerprint;
    use crate::workloads;

    fn table() -> EnergyTable {
        EnergyTable::default()
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tcpa-persist-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn volumes_round_trip_bit_for_bit() {
        let dir = tmp_dir("roundtrip");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let fp = workload_fingerprint(&wl);
        let ana = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        cache.store(&wl, fp, &[2, 2], &table(), &ana).unwrap();
        let loaded = cache
            .load(&wl, fp, &[2, 2], &table())
            .expect("file just written");
        assert_eq!(loaded.len(), ana.phases.len());
        for (ph, m) in ana.phases.iter().zip(&loaded) {
            assert_eq!(m.len(), ph.statements.len());
            for st in &ph.statements {
                assert_eq!(
                    m.get(&st.name),
                    Some(&st.volume),
                    "volume of {} must survive the round trip exactly",
                    st.name
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn phase_volumes_round_trip_and_never_cross_keys() {
        use crate::analysis::SymbolicAnalysis;
        use crate::dse::cache::phase_fingerprint;
        use crate::tiling::ArrayMapping;

        let dir = tmp_dir("phase-roundtrip");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("atax").unwrap();
        let fp1 = phase_fingerprint(&wl.phases[1]);
        let ana = SymbolicAnalysis::analyze(
            &wl.phases[1],
            &ArrayMapping::new(vec![4, 1]),
        );
        cache
            .store_phase(&wl.name, fp1, 1, &[4, 1], &table(), &ana)
            .unwrap();
        let loaded = cache
            .load_phase(&wl.name, fp1, 1, &[4, 1], &table())
            .expect("file just written");
        assert_eq!(loaded.len(), ana.statements.len());
        for st in &ana.statements {
            assert_eq!(loaded.get(&st.name), Some(&st.volume), "{}", st.name);
        }
        // A phase entry is invisible to the whole-workload key, another
        // phase index, another fingerprint, and another shape.
        assert!(cache.load(&wl, fp1, &[4, 1], &table()).is_none());
        assert!(cache
            .load_phase(&wl.name, fp1, 0, &[4, 1], &table())
            .is_none());
        assert!(cache
            .load_phase(&wl.name, fp1.wrapping_add(1), 1, &[4, 1], &table())
            .is_none());
        assert!(cache
            .load_phase(&wl.name, fp1, 1, &[1, 4], &table())
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_reaps_stale_phase_entries_under_their_scoped_names() {
        use crate::analysis::SymbolicAnalysis;
        use crate::dse::cache::phase_fingerprint;
        use crate::tiling::ArrayMapping;

        let dir = tmp_dir("phase-prune");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("atax").unwrap();
        let fp0 = phase_fingerprint(&wl.phases[0]);
        let ana = SymbolicAnalysis::analyze(
            &wl.phases[0],
            &ArrayMapping::new(vec![2, 2]),
        );
        cache
            .store_phase(&wl.name, fp0, 0, &[2, 2], &table(), &ana)
            .unwrap();
        cache
            .store_phase(&wl.name, fp0.wrapping_add(3), 0, &[2, 3], &table(), &ana)
            .unwrap();
        let scoped = phase_cache_name(&wl.name, 0);
        // Pruning with only the plain workload name live keeps the
        // phase-scoped files — they are a different (conservatively
        // unrecognized) name.
        assert_eq!(
            cache.prune(&[(wl.name.clone(), fp0)]).unwrap(),
            0,
            "phase entries are not reaped under the plain name"
        );
        // Naming the scoped entry live reaps exactly the stale
        // fingerprint.
        assert_eq!(cache.prune(&[(scoped.clone(), fp0)]).unwrap(), 1);
        assert!(cache
            .load_phase(&wl.name, fp0, 0, &[2, 2], &table())
            .is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_or_corrupt_files_are_ignored() {
        let dir = tmp_dir("corrupt");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let fp = workload_fingerprint(&wl);
        // Nothing stored yet.
        assert!(cache.load(&wl, fp, &[2, 2], &table()).is_none());
        // Corrupt payload under the right file name.
        std::fs::create_dir_all(&dir).unwrap();
        let path = cache.file_for(&wl.name, fp, &[2, 2], &table());
        std::fs::write(&path, "tcpa-analysis-cache v1\ngarbage\n").unwrap();
        assert!(cache.load(&wl, fp, &[2, 2], &table()).is_none());
        // A different fingerprint (changed workload) must miss too.
        let ana = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        cache.store(&wl, fp, &[2, 2], &table(), &ana).unwrap();
        assert!(cache
            .load(&wl, fp.wrapping_add(1), &[2, 2], &table())
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_removes_only_stale_fingerprints_and_temp_files() {
        let dir = tmp_dir("prune");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let other = workloads::by_name("gemm").unwrap();
        let fp = workload_fingerprint(&wl);
        let other_fp = workload_fingerprint(&other);
        let ana = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        let other_ana = WorkloadAnalysis::analyze_uniform(&other, &[2, 2]);
        // Live entry, stale entry (old fingerprint of the same
        // workload), foreign workload entry, and an orphaned temp file.
        cache.store(&wl, fp, &[2, 2], &table(), &ana).unwrap();
        cache
            .store(&wl, fp.wrapping_add(7), &[2, 3], &table(), &ana)
            .unwrap();
        cache
            .store(&other, other_fp, &[2, 2], &table(), &other_ana)
            .unwrap();
        // Orphaned temp in the writer's exact naming: key stem + .tmp<pid>.
        let orphan = dir.join(format!(
            "gesummv-{:016x}-2x2-{:016x}.tmp99999",
            1u64, 2u64
        ));
        std::fs::write(&orphan, "interrupted").unwrap();
        // Files we don't recognize must survive any prune: a stray
        // `.volumes`, another tool's template, and a foreign `.tmp`.
        let foreign = dir.join("README.volumes");
        std::fs::write(&foreign, "not ours to reap").unwrap();
        let template = dir.join("notes.tmpl");
        std::fs::write(&template, "a template, not a temp file").unwrap();
        let other_tmp = dir.join("data.tmp12");
        std::fs::write(&other_tmp, "another tool's temp").unwrap();

        let removed =
            cache.prune(&[(wl.name.clone(), fp)]).expect("prune");
        assert_eq!(removed, 2, "stale gesummv entry + orphaned temp file");
        // Live entry still loads; stale one is gone.
        assert!(cache.load(&wl, fp, &[2, 2], &table()).is_some());
        assert!(cache
            .load(&wl, fp.wrapping_add(7), &[2, 3], &table())
            .is_none());
        // gemm was not named in `live`: kept, still loadable.
        assert!(cache
            .load(&other, other_fp, &[2, 2], &table())
            .is_some());
        assert!(!orphan.exists());
        assert!(foreign.exists(), "unrecognized names are kept");
        assert!(template.exists(), ".tmpl is not a temp file");
        assert!(other_tmp.exists(), "foreign temp naming is kept");
        // Pruning a missing directory is a clean no-op.
        let empty = DiskCache::new(dir.join("never-created"));
        assert_eq!(empty.prune(&[]).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_spares_sanitized_name_collisions() {
        // "a.b" and "a:b" both sanitize to "a_b" in the filename. A
        // prune for live "a.b" must not reap "a:b"'s entry even though
        // the filename prefix and stale-looking fingerprint match — the
        // file header records the raw name and disambiguates.
        let dir = tmp_dir("collide");
        std::fs::create_dir_all(&dir).unwrap();
        let victim = dir.join(format!(
            "a_b-{:016x}-2x2-{:016x}.volumes",
            7u64, 9u64
        ));
        std::fs::write(
            &victim,
            "tcpa-analysis-cache v1\nworkload a:b\nrest irrelevant\n",
        )
        .unwrap();
        let cache = DiskCache::new(&dir);
        assert_eq!(cache.prune(&[("a.b".to_string(), 1)]).unwrap(), 0);
        assert!(victim.exists(), "collision victim must be kept");
        // The same file under its own live raw name *is* reaped once
        // its fingerprint goes stale.
        assert_eq!(cache.prune(&[("a:b".to_string(), 1)]).unwrap(), 1);
        assert!(!victim.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reap_temps_cleans_interrupted_writes_without_a_live_list() {
        let dir = tmp_dir("reap-temps");
        std::fs::create_dir_all(&dir).unwrap();
        let ours = dir.join(format!(
            "gesummv-{:016x}-2x2-{:016x}.tmp4321",
            3u64, 4u64
        ));
        std::fs::write(&ours, "interrupted").unwrap();
        let alien = dir.join("data.tmp12");
        std::fs::write(&alien, "another tool's temp").unwrap();
        let cache = DiskCache::new(&dir);
        assert_eq!(cache.reap_temps().unwrap(), 1);
        assert!(!ours.exists());
        assert!(alien.exists(), "foreign temp naming is kept");
        let missing = DiskCache::new(dir.join("never-created"));
        assert_eq!(missing.reap_temps().unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_reaps_journal_debris_by_content_sniff() {
        let dir = tmp_dir("journal-debris");
        std::fs::create_dir_all(&dir).unwrap();
        let magic = crate::dse::journal::MAGIC;
        let jtmp = dir.join("sweep.journal.tmp4242");
        std::fs::write(&jtmp, format!("{magic}\nworkload x\n")).unwrap();
        let jcorrupt = dir.join("sweep.journal.corrupt");
        std::fs::write(&jcorrupt, format!("{magic}\nworkload x\n"))
            .unwrap();
        // The same name shapes without journal content are not ours.
        let alien_tmp = dir.join("other.tmp7");
        std::fs::write(&alien_tmp, "not a journal").unwrap();
        let alien_corrupt = dir.join("report.corrupt");
        std::fs::write(&alien_corrupt, "someone else's quarantine")
            .unwrap();
        // A live journal (no debris suffix) is never touched.
        let live = dir.join("sweep.journal");
        std::fs::write(&live, format!("{magic}\nworkload x\n")).unwrap();
        let cache = DiskCache::new(&dir);
        assert_eq!(cache.prune(&[]).unwrap(), 2);
        assert!(!jtmp.exists() && !jcorrupt.exists());
        assert!(alien_tmp.exists(), "content sniff protects foreign tmp");
        assert!(alien_corrupt.exists(), "foreign .corrupt is kept");
        assert!(live.exists(), "live journals are kept");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_stem_parser_handles_dashed_names() {
        let stem = "my-odd_name-00000000000000ab-2x4-00000000000000cd";
        assert_eq!(
            parse_key_stem(stem),
            Some(("my-odd_name".to_string(), 0xab))
        );
        assert_eq!(parse_key_stem("nonsense"), None);
        assert_eq!(parse_key_stem("a-b-c-d"), None, "non-hex fields");
    }

    #[test]
    fn distinct_arrays_use_distinct_files() {
        let dir = tmp_dir("arrays");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let fp = workload_fingerprint(&wl);
        let a = cache.file_for(&wl.name, fp, &[2, 2], &table());
        let b = cache.file_for(&wl.name, fp, &[2, 3], &table());
        assert_ne!(a, b);
        // A different energy table is a different key, too.
        let scaled = table().scaled(0.3, 0.12);
        let c = cache.file_for(&wl.name, fp, &[2, 2], &scaled);
        assert_ne!(a, c);
    }
}
