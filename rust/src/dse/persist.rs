//! Persistent spill of the analysis cache: symbolic volumes on disk.
//!
//! The expensive part of a `WorkloadAnalysis` is the symbolic
//! lattice-point counting; tiling, scheduling and access classification
//! are microseconds. [`DiskCache`] therefore persists, per
//! `(workload, array, energy-table)` key, every statement's
//! [`GuardedSum`] volume in a small line-oriented text format. A warm CLI
//! invocation reloads the volumes and re-derives the cheap parts —
//! producing an analysis **bit-for-bit identical** to a cold run (volumes
//! are exact integer polynomials; Guard/Poly reconstruction re-interns the
//! identical canonical constraints).
//!
//! Keys embed the workload's structural fingerprint and the energy
//! table's bit-exact fingerprint, so a stale file can never serve a
//! changed workload definition or table. Files are advisory: any read,
//! parse or validation failure falls back to recomputation, and writes go
//! through a temp-file rename so concurrent processes never observe a
//! torn file.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::analysis::{PresetVolumes, WorkloadAnalysis};
use crate::energy::EnergyTable;
use crate::polyhedral::{AffineExpr, Constraint, Guard, GuardedSum, Poly};
use crate::pra::Workload;

const MAGIC: &str = "tcpa-analysis-cache v1";

/// On-disk cache of symbolic analysis volumes, one file per
/// `(workload, array, table)` key under a caller-chosen directory.
#[derive(Debug, Clone)]
pub struct DiskCache {
    dir: PathBuf,
}

impl DiskCache {
    /// A cache rooted at `dir` (created lazily on first store).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DiskCache { dir: dir.into() }
    }

    /// The cache directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_for(
        &self,
        wl_name: &str,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
    ) -> PathBuf {
        let safe: String = wl_name
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        let shape = array
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join("x");
        let table_fp = table.fingerprint();
        self.dir
            .join(format!("{safe}-{fp:016x}-{shape}-{table_fp:016x}.volumes"))
    }

    /// Load the preset volumes for `(wl, array, table)` if a valid file
    /// exists. `fp` is the caller's precomputed workload fingerprint;
    /// `table` must be the energy table the analysis will run under.
    pub fn load(
        &self,
        wl: &Workload,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
    ) -> Option<Vec<PresetVolumes>> {
        let path = self.file_for(&wl.name, fp, array, table);
        let content = std::fs::read_to_string(path).ok()?;
        parse(&content, wl, fp, array, table)
    }

    /// Persist the volumes of `ana` under the `(wl, array, table)` key.
    /// Errors are returned but callers may ignore them — the cache is
    /// advisory.
    pub fn store(
        &self,
        wl: &Workload,
        fp: u64,
        array: &[i64],
        table: &EnergyTable,
        ana: &WorkloadAnalysis,
    ) -> std::io::Result<()> {
        // Statement names are the lookup keys within a file; a name the
        // line format cannot carry round-trip is skipped wholesale.
        let ok_names = ana.phases.iter().all(|ph| {
            ph.statements.iter().all(|s| {
                !s.name.is_empty()
                    && !s.name.contains(char::is_whitespace)
            })
        });
        if !ok_names {
            return Ok(());
        }
        std::fs::create_dir_all(&self.dir)?;
        let path = self.file_for(&wl.name, fp, array, table);
        let tmp = path.with_extension(format!("tmp{}", std::process::id()));
        std::fs::write(&tmp, render(wl, fp, array, table, ana))?;
        std::fs::rename(&tmp, &path)
    }
}

fn render(
    wl: &Workload,
    fp: u64,
    array: &[i64],
    table: &EnergyTable,
    ana: &WorkloadAnalysis,
) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{MAGIC}");
    let _ = writeln!(s, "workload {}", wl.name);
    let _ = writeln!(s, "fingerprint {fp:016x}");
    let _ = writeln!(
        s,
        "array {}",
        array.iter().map(|t| t.to_string()).collect::<Vec<_>>().join(",")
    );
    let _ = writeln!(s, "table {:016x}", table.fingerprint());
    let _ = writeln!(s, "phases {}", ana.phases.len());
    for (i, ph) in ana.phases.iter().enumerate() {
        let _ = writeln!(s, "phase {i} statements {}", ph.statements.len());
        for st in &ph.statements {
            let _ = writeln!(
                s,
                "stmt {} nparams {} pieces {}",
                st.name,
                st.volume.nparams(),
                st.volume.pieces.len()
            );
            for (g, p) in &st.volume.pieces {
                let cs = g.resolved();
                let _ = writeln!(s, "guard {}", cs.len());
                for c in cs {
                    let _ = writeln!(s, "c {}", render_affine(&c.0));
                }
                let terms: Vec<_> = p.terms().collect();
                let _ = writeln!(s, "poly {}", terms.len());
                for (e, coeff) in terms {
                    let _ = writeln!(
                        s,
                        "t {};{coeff}",
                        e.iter()
                            .map(|x| x.to_string())
                            .collect::<Vec<_>>()
                            .join(",")
                    );
                }
            }
        }
    }
    s.push_str("end\n");
    s
}

fn render_affine(e: &AffineExpr) -> String {
    format!(
        "{};{}",
        e.coeffs
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(","),
        e.konst
    )
}

fn parse_affine(body: &str, np: usize) -> Option<AffineExpr> {
    let (coeffs, konst) = body.split_once(';')?;
    let coeffs: Vec<i64> = coeffs
        .split(',')
        .map(|x| x.parse().ok())
        .collect::<Option<_>>()?;
    if coeffs.len() != np {
        return None;
    }
    Some(AffineExpr { coeffs, konst: konst.parse().ok()? })
}

fn parse_term(body: &str, np: usize) -> Option<(Vec<u32>, i128)> {
    let (expos, coeff) = body.split_once(';')?;
    let expos: Vec<u32> = expos
        .split(',')
        .map(|x| x.parse().ok())
        .collect::<Option<_>>()?;
    if expos.len() != np {
        return None;
    }
    // Packed-lane capacity is enforced by `Poly::try_from_terms` — the
    // single authority on the encoding.
    Some((expos, coeff.parse().ok()?))
}

fn parse(
    content: &str,
    wl: &Workload,
    fp: u64,
    array: &[i64],
    table: &EnergyTable,
) -> Option<Vec<PresetVolumes>> {
    let mut lines = content.lines();
    if lines.next()? != MAGIC {
        return None;
    }
    if lines.next()? != format!("workload {}", wl.name) {
        return None;
    }
    if lines.next()? != format!("fingerprint {fp:016x}") {
        return None;
    }
    let shape = array
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    if lines.next()? != format!("array {shape}") {
        return None;
    }
    if lines.next()? != format!("table {:016x}", table.fingerprint()) {
        return None;
    }
    let nphases: usize =
        lines.next()?.strip_prefix("phases ")?.parse().ok()?;
    if nphases != wl.phases.len() {
        return None;
    }
    let mut out = Vec::with_capacity(nphases);
    for ph in 0..nphases {
        let nstmts: usize = lines
            .next()?
            .strip_prefix(&format!("phase {ph} statements "))?
            .parse()
            .ok()?;
        let mut map = PresetVolumes::new();
        for _ in 0..nstmts {
            let parts: Vec<&str> = lines.next()?.split(' ').collect();
            if parts.len() != 6
                || parts[0] != "stmt"
                || parts[2] != "nparams"
                || parts[4] != "pieces"
            {
                return None;
            }
            let name = parts[1].to_string();
            let np: usize = parts[3].parse().ok()?;
            let npieces: usize = parts[5].parse().ok()?;
            let mut gs = GuardedSum::zero(np);
            for _ in 0..npieces {
                let nc: usize =
                    lines.next()?.strip_prefix("guard ")?.parse().ok()?;
                let mut cs = Vec::with_capacity(nc);
                for _ in 0..nc {
                    let body = lines.next()?.strip_prefix("c ")?;
                    cs.push(Constraint(parse_affine(body, np)?));
                }
                let nt: usize =
                    lines.next()?.strip_prefix("poly ")?.parse().ok()?;
                let mut terms = Vec::with_capacity(nt);
                for _ in 0..nt {
                    terms.push(parse_term(
                        lines.next()?.strip_prefix("t ")?,
                        np,
                    )?);
                }
                // try_from_terms owns the capacity rules: a corrupt file
                // degrades to recomputation, never a pack-assert panic.
                gs.push(Guard::new(cs), Poly::try_from_terms(np, terms)?);
            }
            map.insert(name, gs);
        }
        out.push(map);
    }
    (lines.next()? == "end").then_some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::cache::workload_fingerprint;
    use crate::workloads;

    fn table() -> EnergyTable {
        EnergyTable::default()
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tcpa-persist-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn volumes_round_trip_bit_for_bit() {
        let dir = tmp_dir("roundtrip");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let fp = workload_fingerprint(&wl);
        let ana = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        cache.store(&wl, fp, &[2, 2], &table(), &ana).unwrap();
        let loaded = cache
            .load(&wl, fp, &[2, 2], &table())
            .expect("file just written");
        assert_eq!(loaded.len(), ana.phases.len());
        for (ph, m) in ana.phases.iter().zip(&loaded) {
            assert_eq!(m.len(), ph.statements.len());
            for st in &ph.statements {
                assert_eq!(
                    m.get(&st.name),
                    Some(&st.volume),
                    "volume of {} must survive the round trip exactly",
                    st.name
                );
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_or_corrupt_files_are_ignored() {
        let dir = tmp_dir("corrupt");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let fp = workload_fingerprint(&wl);
        // Nothing stored yet.
        assert!(cache.load(&wl, fp, &[2, 2], &table()).is_none());
        // Corrupt payload under the right file name.
        std::fs::create_dir_all(&dir).unwrap();
        let path = cache.file_for(&wl.name, fp, &[2, 2], &table());
        std::fs::write(&path, "tcpa-analysis-cache v1\ngarbage\n").unwrap();
        assert!(cache.load(&wl, fp, &[2, 2], &table()).is_none());
        // A different fingerprint (changed workload) must miss too.
        let ana = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        cache.store(&wl, fp, &[2, 2], &table(), &ana).unwrap();
        assert!(cache
            .load(&wl, fp.wrapping_add(1), &[2, 2], &table())
            .is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn distinct_arrays_use_distinct_files() {
        let dir = tmp_dir("arrays");
        let cache = DiskCache::new(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let fp = workload_fingerprint(&wl);
        let a = cache.file_for(&wl.name, fp, &[2, 2], &table());
        let b = cache.file_for(&wl.name, fp, &[2, 3], &table());
        assert_ne!(a, b);
        // A different energy table is a different key, too.
        let scaled = table().scaled(0.3, 0.12);
        let c = cache.file_for(&wl.name, fp, &[2, 2], &scaled);
        assert_ne!(a, c);
    }
}
