//! Post-sweep frontier verification: re-run the Pareto-frontier points
//! through the discrete-event simulator (`dse --sim-verify-frontier`).
//!
//! The sweep itself never simulates — that is the point of the symbolic
//! analysis. But frontier points are the ones a user acts on, so this
//! pass buys cheap end-to-end confidence exactly where it matters: each
//! frontier point is reconstructed (per-phase mapping, schedule
//! candidate, parameter vectors — the same resolution the explorer used)
//! and executed on the event engine ([`crate::sim::EngineKind::Event`])
//! at its *full design bounds*, which the tick engine could not afford.
//! The report gains a `sim_cycles` column; any disagreement — counter
//! mismatch against the symbolic volumes, cycle count differing from the
//! Eq. 8 latency, or a schedule-causality violation — is a divergence
//! that the CLI escalates to a non-zero exit.

use std::collections::BTreeMap;

use crate::analysis::SymbolicAnalysis;
use crate::pra::Workload;
use crate::sim::{simulate, ArchConfig, EngineKind};
use crate::workloads::workload_inputs;

use super::cache::AnalysisCache;
use super::explore::{phase_params, ExploreResult};
use super::space::{PhaseShapes, ScheduleChoice};

/// Verification outcome of one frontier point.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimVerify {
    /// Simulated total cycles (all phases, chained). `-1` when the point
    /// could not be simulated at all (see `divergences`).
    pub cycles: i64,
    /// Human-readable disagreements between simulation and the symbolic
    /// prediction. Empty = sim-confirmed.
    pub divergences: Vec<String>,
}

impl SimVerify {
    /// True when simulation confirmed the symbolic prediction.
    pub fn confirmed(&self) -> bool {
        self.divergences.is_empty()
    }
}

/// Test seam: when this environment variable is set (non-empty), every
/// verified point additionally reports a synthetic divergence — letting
/// CLI tests exercise the loud-failure path without constructing a real
/// symbolic/simulation disagreement (the differential suites exist to
/// prove there isn't one).
pub const FORCE_DIVERGE_ENV: &str = "TCPA_SIM_VERIFY_FORCE_DIVERGE";

fn forced_divergence() -> Option<String> {
    match std::env::var_os(FORCE_DIVERGE_ENV) {
        Some(v) if !v.is_empty() => Some(format!(
            "injected divergence ({FORCE_DIVERGE_ENV} is set)"
        )),
        _ => None,
    }
}

/// Serialize tests that read or set [`FORCE_DIVERGE_ENV`]: the
/// environment is process-global, so the injection test must not race
/// tests asserting clean verdicts. Poison-tolerant — a panicked holder
/// must not cascade.
#[cfg(test)]
pub(crate) fn env_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Simulate every frontier point of `res` on the event engine and attach
/// the outcomes to [`ExploreResult::sim_verify`] (keyed by point index).
/// `cache` is the sweep's analysis cache — every lookup here is a hit,
/// so the pass costs simulation time only.
pub fn sim_verify_frontier(
    wl: &Workload,
    res: &mut ExploreResult,
    cache: &AnalysisCache,
) {
    let mut out: BTreeMap<usize, SimVerify> = BTreeMap::new();
    for &pi in &res.frontier {
        out.insert(pi, verify_point(wl, res, pi, cache));
    }
    res.sim_verify = out;
}

fn verify_point(
    wl: &Workload,
    res: &ExploreResult,
    pi: usize,
    cache: &AnalysisCache,
) -> SimVerify {
    let ep = &res.points[pi];
    let point = &ep.point;
    let mut divergences: Vec<String> = Vec::new();

    // Resolve the per-phase analyses exactly as the explorer did.
    let uniform_ana;
    let mut phase_anas = Vec::new();
    match &point.phase_shapes {
        PhaseShapes::Uniform => {
            let (ana, _) = cache.try_get_or_analyze(wl, &point.array);
            match ana {
                Ok(a) => uniform_ana = Some(a),
                Err(msg) => {
                    return SimVerify {
                        cycles: -1,
                        divergences: vec![format!(
                            "analysis unavailable: {msg}"
                        )],
                    }
                }
            }
        }
        PhaseShapes::PerPhase(shapes) => {
            uniform_ana = None;
            for (i, shape) in shapes.iter().enumerate() {
                let (ana, _) = cache.try_get_or_analyze_phase(wl, i, shape);
                match ana {
                    Ok(a) => phase_anas.push(a),
                    Err(msg) => {
                        return SimVerify {
                            cycles: -1,
                            divergences: vec![format!(
                                "phase {i} analysis unavailable: {msg}"
                            )],
                        }
                    }
                }
            }
        }
    }
    let phases: Vec<&SymbolicAnalysis> = match &uniform_ana {
        Some(ana) => ana.phases.iter().collect(),
        None => phase_anas.iter().map(|a| &**a).collect(),
    };
    let params = phase_params(&phases, point);

    // Chain phases through the tensor environment, as on real hardware.
    let mut env = workload_inputs(wl, &params);
    let mut total_cycles = 0i64;
    for (phase_idx, (ph, p)) in phases.iter().zip(&params).enumerate() {
        let schedule = match &point.schedule {
            ScheduleChoice::First => ph.schedule.clone(),
            ScheduleChoice::Indices(ix) => {
                let cands = ph.enumerate_schedules(None);
                match cands.into_iter().nth(ix[phase_idx]) {
                    Some(s) => s,
                    None => {
                        return SimVerify {
                            cycles: -1,
                            divergences: vec![format!(
                                "phase {phase_idx}: schedule candidate \
                                 {} out of range",
                                ix[phase_idx]
                            )],
                        }
                    }
                }
            }
        };
        let mut arch = ArchConfig::with_array(ph.tiled.mapping.t.clone());
        // The verify pass checks schedule causality and counts, not
        // register provisioning — FD sizing is a separate design axis.
        arch.regs.fd = 1 << 20;
        arch.engine = EngineKind::Event;
        let sim = simulate(&ph.tiled.pra, &arch, &schedule, p, &env);
        total_cycles += sim.cycles;
        for v in &sim.violations {
            divergences.push(format!("phase {phase_idx}: {v}"));
        }
        let sym = ph.counts_at(p);
        for d in sim.counters.diff_symbolic(&sym) {
            divergences.push(format!("phase {phase_idx}: {d}"));
        }
        for (name, tensor) in sim.outputs {
            env.insert(name, tensor);
        }
    }
    if total_cycles != ep.latency_cycles {
        divergences.push(format!(
            "simulated {total_cycles} cycles != symbolic latency {}",
            ep.latency_cycles
        ));
    }
    if let Some(msg) = forced_divergence() {
        divergences.push(msg);
    }
    SimVerify { cycles: total_cycles, divergences }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{
        explore_with_cache, DesignSpace, ExploreConfig, PhasePolicy,
        SchedulePolicy,
    };
    use crate::workloads;

    fn verified(
        wl_name: &str,
        space: DesignSpace,
    ) -> (ExploreResult, usize) {
        let wl = workloads::by_name(wl_name).unwrap();
        let cache = AnalysisCache::new();
        let mut res = explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        assert!(res.failures.is_empty(), "failures: {:?}", res.failures);
        sim_verify_frontier(&wl, &mut res, &cache);
        let n = res.frontier.len();
        (res, n)
    }

    #[test]
    fn frontier_points_are_sim_confirmed() {
        let _env = env_guard();
        let (res, n) = verified(
            "gesummv",
            DesignSpace::new().with_arrays_2d(4).with_bounds(vec![8, 8]),
        );
        assert!(n > 0);
        assert_eq!(res.sim_verify.len(), n, "one verdict per frontier point");
        for (&i, v) in &res.sim_verify {
            assert!(res.frontier.contains(&i));
            assert!(v.confirmed(), "point {i} diverged: {:?}", v.divergences);
            assert_eq!(
                v.cycles, res.points[i].latency_cycles,
                "sim-confirmed cycles echo the symbolic latency"
            );
        }
        // Non-frontier points are never simulated.
        for i in 0..res.points.len() {
            assert_eq!(
                res.sim_verify.contains_key(&i),
                res.frontier.contains(&i)
            );
        }
    }

    #[test]
    fn composes_with_schedule_and_phase_axes() {
        // Multi-phase workload, heterogeneous shapes, full schedule
        // enumeration: the verify pass must reconstruct each frontier
        // point's exact (shape, schedule) assignment per phase.
        let _env = env_guard();
        let (res, n) = verified(
            "atax",
            DesignSpace::new()
                .with_arrays(vec![vec![1, 2], vec![2, 1]])
                .with_bounds(vec![8, 8])
                .with_schedules(SchedulePolicy::All)
                .with_phase_shapes(PhasePolicy::PerPhase),
        );
        assert!(n > 0);
        for (&i, v) in &res.sim_verify {
            assert!(v.confirmed(), "point {i} diverged: {:?}", v.divergences);
        }
        // The axes actually expanded something worth verifying.
        assert!(res
            .sim_verify
            .keys()
            .any(|&i| !res.points[i].point.schedule.is_default())
            || res.points.iter().any(|p| matches!(
                p.point.phase_shapes,
                crate::dse::PhaseShapes::PerPhase(_)
            )));
    }

    #[test]
    fn oversized_tiles_verify_too() {
        let _env = env_guard();
        let (res, n) = verified(
            "gesummv",
            DesignSpace::new()
                .with_arrays(vec![vec![2, 2]])
                .with_bounds(vec![8, 8])
                .with_tile_scales(vec![1, 2]),
        );
        assert!(n > 0);
        for v in res.sim_verify.values() {
            assert!(v.confirmed(), "diverged: {:?}", v.divergences);
        }
    }
}
