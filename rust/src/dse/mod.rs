//! Design-space exploration engine (§V-B / §VI).
//!
//! The paper's headline use of the symbolic analysis is that comparing
//! architectural configurations is *cheap*: the expensive tiling /
//! scheduling / counting pass runs once per (workload, array shape), and
//! every further query — different loop bounds, tile sizes, or energy
//! backends — is a handful of expression evaluations. This module turns
//! that observation into a real exploration subsystem:
//!
//! * [`space`] — the **design-space model**: multi-axis spaces over 1-D /
//!   2-D array shapes, tile-size scales, cross-architecture
//!   [`crate::energy::Backend`] descriptors (TCPA / CGRA / GPU-SM /
//!   systolic, or custom), **schedule-vector candidates**
//!   (`DesignSpace::with_schedules`: every feasible `(permutation, λ^J,
//!   λ^K)` per mapping instead of `find_schedule`'s single pick — a
//!   latency/FD-pressure trade-off at fixed shape and identical energy),
//!   **per-phase shape assignments**
//!   (`DesignSpace::with_phase_shapes(PhasePolicy::PerPhase)`: each
//!   phase of a multi-phase workload takes its own shape under the
//!   shared PE budget — phases run sequentially, so a combination costs
//!   `max`, not `Σ`, of its phases' PEs) and loop-bound grids, with
//!   PE-budget, fits-the-problem and opt-in transposition-symmetry
//!   pruning (shape combinations deduplicate up to *global*
//!   transposition only — mirroring one phase alone changes real
//!   objectives). Each backend is its own comparison scenario with its
//!   own Pareto frontier.
//! * [`cache`] — the **analysis cache**: memoizes
//!   [`crate::analysis::WorkloadAnalysis::analyze_uniform`] per
//!   (workload, array) key — and single-phase analyses per
//!   (workload, phase, shape) key for the per-phase axis, so the
//!   `shapes^phases` combinatorial sweep never re-analyzes a pair two
//!   combinations share — and bounds/tile/policy sweeps over an
//!   already-analyzed shape never re-run the symbolic pass: the O(1)
//!   per-query scalability of Fig. 4, made explicit. Analyses run against
//!   one shared Fourier–Motzkin feasibility pool
//!   ([`crate::polyhedral::FeasPool`]), so design points with the same
//!   parameter context decide each distinct guard once per sweep.
//! * [`persist`] — the **persistent spill**: symbolic volumes on disk,
//!   keyed by (workload fingerprint, array, energy-table fingerprint), so
//!   repeated CLI invocations reuse the one-time analyses across
//!   processes (`AnalysisCache::with_disk`, `dse --analysis-cache DIR`).
//! * [`explore`] — the **parallel explorer**: fans design points out over
//!   a `std::thread` worker pool fed by a channel work queue, with
//!   results stitched back in deterministic enumeration order. The
//!   controlled entry point ([`explore_controlled`] /
//!   [`ExploreControl`]) adds cooperative cancellation
//!   ([`crate::cancel::CancelToken`]: SIGINT, `--deadline`, per-point
//!   timeouts), progress callbacks, deterministic fault injection and
//!   partial results — the explorer-as-a-library shape that `dse
//!   serve` and sharded sweeps will sit on.
//! * [`journal`] — the **checkpoint journal**: an append-only,
//!   checksummed, line-oriented record of completed points
//!   (`dse --checkpoint FILE`), fingerprint-locked to its (workload,
//!   space, shard), tolerant of truncated tails, quarantining corrupt
//!   headers — `--resume` replays completed points bit-for-bit and
//!   evaluates only the remainder.
//! * [`strategy`] — the **search strategies**: [`Strategy::Exhaustive`]
//!   (the default and the differential oracle) vs. a deterministic
//!   Pareto-guided beam over the shape / phase-shape axis
//!   (`dse --strategy beam[:W]`), seeded from per-phase energy argmins
//!   off the shared analysis cache. Combined with design-space
//!   **sharding** ([`Shard`], `dse --shard i/n`): a stable round-robin
//!   partition of the canonical enumeration whose per-shard journals
//!   [`merge_shards`] (`dse merge`) folds into a report byte-identical
//!   to the unsharded run.
//! * [`pareto`] — **multi-objective selection**: (energy, latency,
//!   PE count, DRAM traffic) non-dominated frontiers and knee-point
//!   picking, replacing the old single-scalar EDP sort. All float
//!   orderings use `f64::total_cmp` — a NaN cannot panic the sweep.
//! * [`verify`] — the **frontier confidence pass**
//!   (`dse --sim-verify-frontier`): re-simulate only the Pareto-frontier
//!   points on the discrete-event engine at their full design bounds and
//!   annotate the report with sim-confirmed cycles, escalating any
//!   divergence from the symbolic prediction.
//!
//! ```no_run
//! use tcpa_energy::dse::{explore, DesignSpace, ExploreConfig};
//! let wl = tcpa_energy::workloads::by_name("gemm").unwrap();
//! let space = DesignSpace::new()
//!     .with_arrays_2d(64)
//!     .with_bounds(vec![64, 64, 64]);
//! let res = explore(&wl, &space, &ExploreConfig::default());
//! for p in res.frontier_points() {
//!     println!("{:?} {:.1} pJ {} cyc", p.point.array, p.energy_pj,
//!              p.latency_cycles);
//! }
//! ```

pub mod cache;
pub mod explore;
pub mod journal;
pub mod pareto;
pub mod persist;
pub mod space;
pub mod strategy;
pub mod verify;

pub use cache::{
    phase_fingerprint, workload_fingerprint, AnalysisCache, CacheStats,
};
pub use explore::{
    explore, explore_controlled, explore_with_cache, merge_shards,
    EvaluatedPoint, ExploreConfig, ExploreControl, ExploreResult,
    FaultPlan, FrontierGroup, FAULT_DEADLINE_AFTER_ENV,
    FAULT_JOURNAL_WRITE_ENV, FAULT_KILL_AFTER_ENV, JOURNAL_BATCH_ENV,
};
pub use journal::{
    space_fingerprint, JournalHeader, JournalLoad, JournalRecord,
    JournalWriter, ReplayedCandidate,
};
pub use pareto::{dominates, knee_point, pareto_frontier, Objectives};
pub use persist::{phase_cache_name, DiskCache};
pub use space::{
    DesignPoint, DesignSpace, PhasePolicy, PhaseShapes, ScheduleChoice,
    SchedulePolicy, Shard,
};
pub use strategy::{Strategy, DEFAULT_BEAM_BUDGET, DEFAULT_BEAM_WIDTH};
pub use verify::{sim_verify_frontier, SimVerify};
