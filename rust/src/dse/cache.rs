//! Memoization of the expensive one-time symbolic pass.
//!
//! [`crate::analysis::WorkloadAnalysis::analyze_uniform`] runs tiling,
//! scheduling and symbolic counting — milliseconds per (workload, array)
//! pair. Every *evaluation* against the resulting expressions is
//! microseconds. The cache makes the asymmetry structural: one analysis
//! per (workload, array) key — and, for the per-phase heterogeneous
//! mapping axis, one single-phase analysis per (workload, phase, array)
//! key — for the lifetime of the cache, shared lock-free across reader
//! threads via `Arc`. The per-phase table is what keeps the
//! combinatorial `shapes^phases` sweep honest: a phase's analysis on a
//! shape is computed once and reused by *every* combination containing
//! it, so analysis work scales with distinct (phase, shape) pairs, never
//! with the number of combinations.

use std::cell::Cell;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once};

use crate::analysis::{SymbolicAnalysis, WorkloadAnalysis};
use crate::energy::EnergyTable;
use crate::polyhedral::FeasPool;
use crate::pra::{Pra, Workload};
use crate::tiling::{pad_array, ArrayMapping};

use super::persist::DiskCache;

/// The whole-workload memo key. Deliberately **schedule-free**: the
/// symbolic volumes — and therefore every count and energy — depend only
/// on the tiling of `(workload, array)`, never on which feasible
/// `(λ^J, λ^K)` candidate executes them, so all schedule-axis candidates
/// of a shape (`DesignSpace::with_schedules`) share one cached analysis
/// and re-evaluate latency alone. A schedule dimension would belong in
/// this key only if schedules ever started changing counts.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    workload: String,
    /// Structural fingerprint of the workload definition, so two
    /// distinct `Workload` values sharing a display name can never
    /// serve each other's memoized analysis.
    fingerprint: u64,
    array: Vec<i64>,
}

/// The single-phase memo key of the per-phase heterogeneous mapping axis
/// (`DesignSpace::with_phase_shapes`): one entry per (workload, phase,
/// shape), shared by every shape combination that assigns `array` to
/// phase `phase`. Schedule-free for the same reason as [`CacheKey`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PhaseKey {
    workload: String,
    phase: usize,
    /// Structural fingerprint of *this phase's* PRA
    /// ([`phase_fingerprint`]), so editing one phase of a workload never
    /// invalidates (or worse, mis-serves) its siblings' entries.
    fingerprint: u64,
    array: Vec<i64>,
}

/// Structural fingerprint of a workload definition. The IR has no Hash
/// derives; its Debug rendering is a faithful structural description.
/// Computing it walks the whole IR, so hot paths (one lookup per design
/// point) should compute it once per workload and use
/// [`AnalysisCache::try_get_or_analyze_keyed`].
pub fn workload_fingerprint(wl: &Workload) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    format!("{:?}", wl.phases).hash(&mut h);
    h.finish()
}

/// Structural fingerprint of one phase's PRA — the per-phase analogue of
/// [`workload_fingerprint`], keying the single-phase memo and disk
/// entries. Hot paths should compute it once per (workload, phase) and
/// use [`AnalysisCache::try_get_or_analyze_phase_keyed`].
pub fn phase_fingerprint(pra: &Pra) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    format!("{pra:?}").hash(&mut h);
    h.finish()
}

/// One memoized slot: `Pending` marks a value some thread is currently
/// computing; other threads block on the memo's condvar instead of
/// duplicating the work.
#[derive(Debug)]
enum Slot<V> {
    Pending,
    Done(V),
}

/// A blocking memo table: the first requester of a key computes the
/// value *outside* the lock while concurrent requesters of the same key
/// wait on the condvar. Analyses that fail are memoized too (the value
/// is a `Result`), so a sweep never re-runs a known-bad pass.
///
/// Invariant: the compute closure must not unwind — callers wrap the
/// fallible symbolic pass in `catch_unwind` and memoize the failure as a
/// value. An escaping panic would leave the `Pending` slot unresolved
/// and deadlock later requesters of the key.
#[derive(Debug)]
struct Memo<K, V> {
    map: Mutex<HashMap<K, Slot<V>>>,
    /// Signalled whenever a `Pending` slot resolves.
    resolved: Condvar,
}

impl<K, V> Default for Memo<K, V> {
    fn default() -> Self {
        Memo { map: Mutex::new(HashMap::new()), resolved: Condvar::new() }
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Memo<K, V> {
    /// The memoized value for `key`, computing it on first request.
    /// Returns the value and whether it was served from the table (a
    /// thread that waited out another's `Pending` computation counts as
    /// served — the work ran once).
    fn get_or_compute(&self, key: K, compute: impl FnOnce() -> V) -> (V, bool) {
        {
            let mut map = self.map.lock().unwrap();
            loop {
                match map.get(&key) {
                    Some(Slot::Done(v)) => return (v.clone(), true),
                    Some(Slot::Pending) => {
                        map = self.resolved.wait(map).unwrap();
                    }
                    None => break,
                }
            }
            map.insert(key.clone(), Slot::Pending);
        }
        // This thread owns the computation for `key`; compute outside
        // the lock so a slow pass never stalls other keys.
        let v = compute();
        self.map.lock().unwrap().insert(key, Slot::Done(v.clone()));
        self.resolved.notify_all();
        (v, false)
    }

    fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

/// Hit/miss counters of an [`AnalysisCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that ran a fresh symbolic analysis.
    pub misses: u64,
    /// In-memory misses whose symbolic volumes were restored from the
    /// persistent disk cache instead of recomputed.
    pub disk_hits: u64,
    /// Distinct analysis keys currently stored: (workload, array) for
    /// uniform mappings plus (workload, phase, array) for the per-phase
    /// axis.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 when empty).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe memo of the one-time symbolic pass:
/// `(workload, array) → Arc<WorkloadAnalysis>` for uniform mappings,
/// plus `(workload, phase, array) → Arc<SymbolicAnalysis>` for the
/// per-phase heterogeneous axis.
#[derive(Debug, Default)]
pub struct AnalysisCache {
    /// Whole-workload analyses under one uniform shape.
    uniform: Memo<CacheKey, Result<Arc<WorkloadAnalysis>, String>>,
    /// Single-phase analyses of the per-phase shape axis.
    phase: Memo<PhaseKey, Result<Arc<SymbolicAnalysis>, String>>,
    /// Shared Fourier–Motzkin feasibility memo: every analysis this cache
    /// runs reuses one `SymbolicCtx` per distinct parameter context, so
    /// guards repeating across statements, phases and design points are
    /// decided once per sweep.
    feas: FeasPool,
    /// Optional persistent spill of symbolic volumes to disk.
    disk: Option<DiskCache>,
    hits: AtomicU64,
    misses: AtomicU64,
    disk_hits: AtomicU64,
}

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "symbolic analysis panicked".to_string()
    }
}

thread_local! {
    /// True while this thread runs an analysis whose panic is memoized —
    /// the default "thread panicked at ..." stderr trace would be noise.
    static SUPPRESS_PANIC_TRACE: Cell<bool> = const { Cell::new(false) };
}

/// Install (once, process-wide) a panic hook that stays silent for
/// panics this module catches and memoizes, and delegates to the
/// previously installed hook for every other panic.
fn install_quiet_hook() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPPRESS_PANIC_TRACE.with(Cell::get) {
                prev(info);
            }
        }));
    });
}

impl AnalysisCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty cache spilling symbolic volumes to `dir`, so repeated CLI
    /// invocations share the one-time analyses across processes (keyed by
    /// workload fingerprint, array shape and energy-table fingerprint).
    pub fn with_disk(dir: impl Into<PathBuf>) -> Self {
        let disk = DiskCache::new(dir);
        // Startup hygiene: interrupted-write temps from a crashed
        // prior process never accumulate. Best-effort, like the spill
        // itself.
        let _ = disk.reap_temps();
        AnalysisCache { disk: Some(disk), ..Self::default() }
    }

    /// The shared feasibility pool (for diagnostics and benches).
    pub fn feas_pool(&self) -> &FeasPool {
        &self.feas
    }

    /// The analysis of `wl` on `array`, memoized — including failures,
    /// returned as `Err(message)`. Returns the outcome and whether it
    /// was a cache hit. The symbolic pass runs *outside* the lock, so a
    /// slow analysis never stalls workers evaluating other shapes; a
    /// cold key is claimed with a `Pending` slot first, so concurrent
    /// requests for the same shape wait on the condvar instead of
    /// duplicating the milliseconds-scale pass (same-shape points are
    /// adjacent in the explorer's queue, making that race the common
    /// case).
    pub fn try_get_or_analyze(
        &self,
        wl: &Workload,
        array: &[i64],
    ) -> (Result<Arc<WorkloadAnalysis>, String>, bool) {
        self.try_get_or_analyze_keyed(wl, workload_fingerprint(wl), array)
    }

    /// As [`Self::try_get_or_analyze`] with the workload fingerprint
    /// precomputed by the caller ([`workload_fingerprint`]) — the hot
    /// path for sweeps, which would otherwise re-serialize the IR on
    /// every design point.
    pub fn try_get_or_analyze_keyed(
        &self,
        wl: &Workload,
        fingerprint: u64,
        array: &[i64],
    ) -> (Result<Arc<WorkloadAnalysis>, String>, bool) {
        let key = CacheKey {
            workload: wl.name.clone(),
            fingerprint,
            array: array.to_vec(),
        };
        let (out, hit) = self.uniform.get_or_compute(key, || {
            // `analyze_uniform_in` always prices with the default table,
            // so the disk key uses it too.
            let table = EnergyTable::default();
            let preset = self
                .disk
                .as_ref()
                .and_then(|d| d.load(wl, fingerprint, array, &table));
            // The catch_unwind upholds the Memo no-unwind invariant:
            // failed analyses resolve the slot as an Err value.
            install_quiet_hook();
            SUPPRESS_PANIC_TRACE.with(|s| s.set(true));
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                WorkloadAnalysis::analyze_uniform_in(
                    wl,
                    array,
                    &self.feas,
                    preset.as_deref(),
                )
            }));
            SUPPRESS_PANIC_TRACE.with(|s| s.set(false));
            match outcome {
                Ok(ana) => {
                    // A disk hit only counts if the loaded volumes
                    // actually covered every statement — a
                    // parseable-but-stale file (e.g. older format under
                    // an unchanged fingerprint) falls through analyze's
                    // per-entry validation and must be rewritten, not
                    // celebrated.
                    let fully_preset = preset.as_ref().is_some_and(|pre| {
                        ana.phases.len() == pre.len()
                            && ana.phases.iter().zip(pre).all(|(ph, m)| {
                                ph.statements.iter().all(|s| {
                                    m.get(&s.name) == Some(&s.volume)
                                })
                            })
                    });
                    if fully_preset {
                        self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    } else if let Some(d) = &self.disk {
                        // Advisory spill: an IO failure must not fail
                        // the analysis that just succeeded.
                        let _ =
                            d.store(wl, fingerprint, array, &table, &ana);
                    }
                    Ok(Arc::new(ana))
                }
                Err(payload) => Err(panic_message(payload.as_ref())),
            }
        });
        self.count(hit);
        (out, hit)
    }

    /// The analysis of *one phase* of `wl` on `array`, memoized per
    /// (workload, phase, shape) — the entry point of the per-phase
    /// heterogeneous mapping axis (`DesignSpace::with_phase_shapes`).
    /// Failures are memoized like [`Self::try_get_or_analyze`]'s. The
    /// analysis is bit-for-bit the phase a uniform
    /// `WorkloadAnalysis::analyze_uniform` of the same shape would
    /// produce: same padded mapping, default energy table, π = 1, and
    /// the cache's shared feasibility pool.
    pub fn try_get_or_analyze_phase(
        &self,
        wl: &Workload,
        phase: usize,
        array: &[i64],
    ) -> (Result<Arc<SymbolicAnalysis>, String>, bool) {
        self.try_get_or_analyze_phase_keyed(
            wl,
            phase_fingerprint(&wl.phases[phase]),
            phase,
            array,
        )
    }

    /// As [`Self::try_get_or_analyze_phase`] with the phase fingerprint
    /// precomputed by the caller ([`phase_fingerprint`]) — the hot path
    /// for per-phase sweeps, which would otherwise re-serialize the
    /// phase IR on every design point.
    pub fn try_get_or_analyze_phase_keyed(
        &self,
        wl: &Workload,
        fingerprint: u64,
        phase: usize,
        array: &[i64],
    ) -> (Result<Arc<SymbolicAnalysis>, String>, bool) {
        assert!(
            phase < wl.phases.len(),
            "phase {phase} out of range for {} ({} phases)",
            wl.name,
            wl.phases.len()
        );
        let pra = &wl.phases[phase];
        let key = PhaseKey {
            workload: wl.name.clone(),
            phase,
            fingerprint,
            array: array.to_vec(),
        };
        let (out, hit) = self.phase.get_or_compute(key, || {
            let table = EnergyTable::default();
            let preset = self.disk.as_ref().and_then(|d| {
                d.load_phase(&wl.name, fingerprint, phase, array, &table)
            });
            install_quiet_hook();
            SUPPRESS_PANIC_TRACE.with(|s| s.set(true));
            // The mapping construction must sit inside the catch_unwind
            // too: a degenerate shape (e.g. a zero extent) panics in
            // `ArrayMapping::new`, and an unwind escaping this closure
            // would leave the Pending slot unresolved forever (the Memo
            // no-unwind invariant) — the uniform path builds its
            // mappings inside `analyze_uniform_in` for the same reason.
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                let mapping =
                    ArrayMapping::new(pad_array(array, pra.ndims));
                SymbolicAnalysis::analyze_in(
                    pra,
                    &mapping,
                    &table,
                    1,
                    &self.feas,
                    preset.as_ref(),
                )
            }));
            SUPPRESS_PANIC_TRACE.with(|s| s.set(false));
            match outcome {
                Ok(ana) => {
                    let fully_preset = preset.as_ref().is_some_and(|m| {
                        ana.statements.iter().all(|s| {
                            m.get(&s.name) == Some(&s.volume)
                        })
                    });
                    if fully_preset {
                        self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    } else if let Some(d) = &self.disk {
                        let _ = d.store_phase(
                            &wl.name,
                            fingerprint,
                            phase,
                            array,
                            &table,
                            &ana,
                        );
                    }
                    Ok(Arc::new(ana))
                }
                Err(payload) => Err(panic_message(payload.as_ref())),
            }
        });
        self.count(hit);
        (out, hit)
    }

    fn count(&self, hit: bool) {
        if hit {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// As [`Self::try_get_or_analyze`], panicking on analysis failure
    /// (the pre-caching `analyze_uniform` behavior, for callers that
    /// treat an infeasible shape as a bug).
    pub fn get_or_analyze(
        &self,
        wl: &Workload,
        array: &[i64],
    ) -> (Arc<WorkloadAnalysis>, bool) {
        match self.try_get_or_analyze(wl, array) {
            (Ok(a), hit) => (a, hit),
            (Err(msg), _) => panic!(
                "symbolic analysis of {} on {array:?} failed: {msg}",
                wl.name
            ),
        }
    }

    /// Current counters. `entries` counts whole-workload and
    /// single-phase memo entries together — for a per-phase sweep it is
    /// exactly the number of distinct (phase, shape) pairs analyzed.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            entries: self.uniform.len() + self.phase.len(),
        }
    }

    /// Drop all cached analyses (counters keep accumulating).
    pub fn clear(&self) {
        self.uniform.clear();
        self.phase.clear();
    }

    /// Prune the persistent spill directory (no-op without one): remove
    /// files whose workload name matches a `live` entry but whose
    /// fingerprint matches none — the workload definition changed and
    /// those volumes can never be loaded again — plus orphaned temp
    /// files. See [`DiskCache::prune`]. Returns the number of files
    /// removed.
    pub fn prune_disk(
        &self,
        live: &[(String, u64)],
    ) -> std::io::Result<usize> {
        match &self.disk {
            Some(d) => d.prune(live),
            None => Ok(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn second_lookup_hits() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("gesummv").unwrap();
        let (_, hit0) = cache.get_or_analyze(&wl, &[2, 2]);
        let (_, hit1) = cache.get_or_analyze(&wl, &[2, 2]);
        assert!(!hit0);
        assert!(hit1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn failed_analyses_are_cached_not_rerun() {
        // The "twist" PRA has no feasible schedule: its analysis panics
        // in `find_schedule` and must be memoized as a failure.
        let cache = AnalysisCache::new();
        let wl = workloads::twist_unschedulable();
        let (r0, h0) = cache.try_get_or_analyze(&wl, &[2, 2]);
        let (r1, h1) = cache.try_get_or_analyze(&wl, &[2, 2]);
        assert!(r0.is_err() && r1.is_err());
        assert!(!h0);
        assert!(h1, "the failed analysis must be served from the cache");
        let s = cache.stats();
        assert_eq!(
            s.misses, 1,
            "the failing pass must run once, not per lookup"
        );
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn same_name_different_workload_is_not_conflated() {
        // A Workload that merely *claims* another's name must not be
        // served its memoized analysis.
        let cache = AnalysisCache::new();
        let real = workloads::by_name("gesummv").unwrap();
        let mut imposter = workloads::by_name("atax").unwrap();
        imposter.name = "gesummv".into();
        let (_, h0) = cache.try_get_or_analyze(&real, &[2, 2]);
        let (_, h1) = cache.try_get_or_analyze(&imposter, &[2, 2]);
        assert!(!h0);
        assert!(!h1, "structurally different workload must miss");
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn distinct_arrays_are_distinct_entries() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("gesummv").unwrap();
        cache.get_or_analyze(&wl, &[2, 2]);
        cache.get_or_analyze(&wl, &[2, 3]);
        assert_eq!(cache.stats().entries, 2);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn disk_spill_reloads_across_cache_instances_bit_for_bit() {
        let dir = std::env::temp_dir().join(format!(
            "tcpa-cache-spill-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let params = vec![vec![8i64, 8, 4, 4]];

        // Cold process: computes and spills.
        let cold = AnalysisCache::with_disk(&dir);
        let (a, _) = cold.get_or_analyze(&wl, &[2, 2]);
        assert_eq!(cold.stats().disk_hits, 0);

        // "Second process": fresh in-memory cache, same directory.
        let warm = AnalysisCache::with_disk(&dir);
        let (b, hit) = warm.get_or_analyze(&wl, &[2, 2]);
        assert!(!hit, "in-memory cache is cold");
        assert_eq!(
            warm.stats().disk_hits,
            1,
            "volumes must come from the spilled file"
        );
        // Bit-for-bit: identical volumes, counts, energies, latencies.
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            for (sa, sb) in pa.statements.iter().zip(&pb.statements) {
                assert_eq!(sa.volume, sb.volume, "{}", sa.name);
            }
        }
        assert_eq!(a.counts_at(&params), b.counts_at(&params));
        let (ea, eb) = (a.energy_at(&params), b.energy_at(&params));
        assert_eq!(ea.total.to_bits(), eb.total.to_bits());
        assert_eq!(a.latency_at(&params), b.latency_at(&params));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_disk_reaps_stale_spills_and_noops_without_disk() {
        // No spill directory: prune is a structural no-op.
        assert_eq!(
            AnalysisCache::new().prune_disk(&[("x".into(), 1)]).unwrap(),
            0
        );
        let dir = std::env::temp_dir().join(format!(
            "tcpa-cache-prune-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let cache = AnalysisCache::with_disk(&dir);
        cache.get_or_analyze(&wl, &[2, 2]);
        let fp = workload_fingerprint(&wl);
        // Current fingerprint live: nothing to reap.
        assert_eq!(
            cache.prune_disk(&[(wl.name.clone(), fp)]).unwrap(),
            0
        );
        // Pretend the workload definition changed: the old spill is
        // unreachable and must go.
        assert_eq!(
            cache
                .prune_disk(&[(wl.name.clone(), fp.wrapping_add(1))])
                .unwrap(),
            1
        );
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn phase_lookups_memoize_per_phase_shape_pair() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("atax").unwrap();
        let (a0, h0) = cache.try_get_or_analyze_phase(&wl, 0, &[1, 4]);
        let (_, h1) = cache.try_get_or_analyze_phase(&wl, 0, &[1, 4]);
        assert!(!h0 && h1, "second lookup of the pair must hit");
        // A different phase — or a different shape — is its own entry.
        let (a1, h2) = cache.try_get_or_analyze_phase(&wl, 1, &[1, 4]);
        let (_, h3) = cache.try_get_or_analyze_phase(&wl, 0, &[4, 1]);
        assert!(!h2 && !h3);
        assert_eq!(cache.stats().entries, 3);
        // The memoized phase analysis is bit-for-bit the phase of a
        // uniform whole-workload analysis on the same shape.
        let uni = WorkloadAnalysis::analyze_uniform(&wl, &[1, 4]);
        let (p0, p1) = (a0.unwrap(), a1.unwrap());
        let params0 = p0.params_for(&[8, 8]);
        let params1 = p1.params_for(&[8, 8]);
        assert_eq!(
            p0.energy_at(&params0).total.to_bits(),
            uni.phases[0].energy_at(&params0).total.to_bits()
        );
        assert_eq!(
            p1.latency_at(&params1),
            uni.phases[1].latency_at(&params1)
        );
        assert_eq!(p0.counts_at(&params0), uni.phases[0].counts_at(&params0));
    }

    #[test]
    fn degenerate_phase_shape_fails_once_without_deadlock() {
        // A zero extent panics in ArrayMapping::new — inside the
        // catch_unwind, so the failure resolves the Pending slot as a
        // memoized Err instead of deadlocking later requesters.
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("atax").unwrap();
        let (r0, h0) = cache.try_get_or_analyze_phase(&wl, 0, &[0, 4]);
        let (r1, h1) = cache.try_get_or_analyze_phase(&wl, 0, &[0, 4]);
        assert!(r0.is_err() && r1.is_err());
        assert!(!h0);
        assert!(h1, "the failure must be served from the memo");
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn phase_fingerprints_distinguish_phases_and_survive_renames() {
        let wl = workloads::by_name("atax").unwrap();
        assert_ne!(
            phase_fingerprint(&wl.phases[0]),
            phase_fingerprint(&wl.phases[1])
        );
        // Same structure → same fingerprint, independent of the
        // enclosing workload value.
        let again = workloads::by_name("atax").unwrap();
        assert_eq!(
            phase_fingerprint(&wl.phases[0]),
            phase_fingerprint(&again.phases[0])
        );
    }

    #[test]
    fn phase_disk_spill_reloads_across_cache_instances() {
        let dir = std::env::temp_dir().join(format!(
            "tcpa-phase-spill-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = workloads::by_name("atax").unwrap();

        let cold = AnalysisCache::with_disk(&dir);
        let (a, _) = cold.try_get_or_analyze_phase(&wl, 1, &[2, 2]);
        let a = a.unwrap();
        assert_eq!(cold.stats().disk_hits, 0);

        let warm = AnalysisCache::with_disk(&dir);
        let (b, hit) = warm.try_get_or_analyze_phase(&wl, 1, &[2, 2]);
        let b = b.unwrap();
        assert!(!hit, "in-memory cache is cold");
        assert_eq!(warm.stats().disk_hits, 1, "volumes must come from disk");
        for (sa, sb) in a.statements.iter().zip(&b.statements) {
            assert_eq!(sa.volume, sb.volume, "{}", sa.name);
        }
        let params = a.params_for(&[8, 8]);
        assert_eq!(
            a.energy_at(&params).total.to_bits(),
            b.energy_at(&params).total.to_bits()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_and_fresh_agree_bit_for_bit() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("gesummv").unwrap();
        let (cached, _) = cache.get_or_analyze(&wl, &[2, 2]);
        let fresh = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        let params = vec![vec![8i64, 8, 4, 4]];
        assert_eq!(cached.energy_at(&params), fresh.energy_at(&params));
        assert_eq!(cached.counts_at(&params), fresh.counts_at(&params));
        assert_eq!(cached.latency_at(&params), fresh.latency_at(&params));
    }
}
