//! Memoization of the expensive one-time symbolic pass.
//!
//! [`crate::analysis::WorkloadAnalysis::analyze_uniform`] runs tiling,
//! scheduling and symbolic counting — milliseconds per (workload, array)
//! pair. Every *evaluation* against the resulting expressions is
//! microseconds. The cache makes the asymmetry structural: one analysis
//! per (workload, array) key for the lifetime of the cache, shared
//! lock-free across reader threads via `Arc`.

use std::cell::Cell;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Once};

use crate::analysis::WorkloadAnalysis;
use crate::energy::EnergyTable;
use crate::polyhedral::FeasPool;
use crate::pra::Workload;

use super::persist::DiskCache;

/// The memo key. Deliberately **schedule-free**: the symbolic volumes —
/// and therefore every count and energy — depend only on the tiling of
/// `(workload, array)`, never on which feasible `(λ^J, λ^K)` candidate
/// executes them, so all schedule-axis candidates of a shape
/// (`DesignSpace::with_schedules`) share one cached analysis and
/// re-evaluate latency alone. A schedule dimension would belong in this
/// key only if schedules ever started changing counts.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    workload: String,
    /// Structural fingerprint of the workload definition, so two
    /// distinct `Workload` values sharing a display name can never
    /// serve each other's memoized analysis.
    fingerprint: u64,
    array: Vec<i64>,
}

/// Structural fingerprint of a workload definition. The IR has no Hash
/// derives; its Debug rendering is a faithful structural description.
/// Computing it walks the whole IR, so hot paths (one lookup per design
/// point) should compute it once per workload and use
/// [`AnalysisCache::try_get_or_analyze_keyed`].
pub fn workload_fingerprint(wl: &Workload) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    format!("{:?}", wl.phases).hash(&mut h);
    h.finish()
}

/// One memoized outcome: analyses that *fail* (e.g. no feasible LSGP
/// schedule for the shape) are cached too, so a sweep never re-runs a
/// known-bad tiling/scheduling pass per bounds/tile/policy point.
/// `Pending` marks an analysis some thread is currently running; other
/// threads block on the condvar instead of duplicating the work.
#[derive(Debug)]
enum Slot {
    Pending,
    Ready(Arc<WorkloadAnalysis>),
    Failed(String),
}

/// Hit/miss counters of an [`AnalysisCache`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that ran a fresh symbolic analysis.
    pub misses: u64,
    /// In-memory misses whose symbolic volumes were restored from the
    /// persistent disk cache instead of recomputed.
    pub disk_hits: u64,
    /// Distinct (workload, array) keys currently stored.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 when empty).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe memo table `(workload, array) → Arc<WorkloadAnalysis>`.
#[derive(Debug, Default)]
pub struct AnalysisCache {
    map: Mutex<HashMap<CacheKey, Slot>>,
    /// Signalled whenever a `Pending` slot resolves.
    resolved: Condvar,
    /// Shared Fourier–Motzkin feasibility memo: every analysis this cache
    /// runs reuses one `SymbolicCtx` per distinct parameter context, so
    /// guards repeating across statements, phases and design points are
    /// decided once per sweep.
    feas: FeasPool,
    /// Optional persistent spill of symbolic volumes to disk.
    disk: Option<DiskCache>,
    hits: AtomicU64,
    misses: AtomicU64,
    disk_hits: AtomicU64,
}

pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "symbolic analysis panicked".to_string()
    }
}

thread_local! {
    /// True while this thread runs an analysis whose panic is memoized —
    /// the default "thread panicked at ..." stderr trace would be noise.
    static SUPPRESS_PANIC_TRACE: Cell<bool> = const { Cell::new(false) };
}

/// Install (once, process-wide) a panic hook that stays silent for
/// panics this module catches and memoizes, and delegates to the
/// previously installed hook for every other panic.
fn install_quiet_hook() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !SUPPRESS_PANIC_TRACE.with(Cell::get) {
                prev(info);
            }
        }));
    });
}

impl AnalysisCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty cache spilling symbolic volumes to `dir`, so repeated CLI
    /// invocations share the one-time analyses across processes (keyed by
    /// workload fingerprint, array shape and energy-table fingerprint).
    pub fn with_disk(dir: impl Into<PathBuf>) -> Self {
        AnalysisCache { disk: Some(DiskCache::new(dir)), ..Self::default() }
    }

    /// The shared feasibility pool (for diagnostics and benches).
    pub fn feas_pool(&self) -> &FeasPool {
        &self.feas
    }

    /// The analysis of `wl` on `array`, memoized — including failures,
    /// returned as `Err(message)`. Returns the outcome and whether it
    /// was a cache hit. The symbolic pass runs *outside* the lock, so a
    /// slow analysis never stalls workers evaluating other shapes; a
    /// cold key is claimed with a `Pending` slot first, so concurrent
    /// requests for the same shape wait on the condvar instead of
    /// duplicating the milliseconds-scale pass (same-shape points are
    /// adjacent in the explorer's queue, making that race the common
    /// case).
    pub fn try_get_or_analyze(
        &self,
        wl: &Workload,
        array: &[i64],
    ) -> (Result<Arc<WorkloadAnalysis>, String>, bool) {
        self.try_get_or_analyze_keyed(wl, workload_fingerprint(wl), array)
    }

    /// As [`Self::try_get_or_analyze`] with the workload fingerprint
    /// precomputed by the caller ([`workload_fingerprint`]) — the hot
    /// path for sweeps, which would otherwise re-serialize the IR on
    /// every design point.
    pub fn try_get_or_analyze_keyed(
        &self,
        wl: &Workload,
        fingerprint: u64,
        array: &[i64],
    ) -> (Result<Arc<WorkloadAnalysis>, String>, bool) {
        let key = CacheKey {
            workload: wl.name.clone(),
            fingerprint,
            array: array.to_vec(),
        };
        {
            let mut map = self.map.lock().unwrap();
            loop {
                match map.get(&key) {
                    Some(Slot::Ready(a)) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return (Ok(Arc::clone(a)), true);
                    }
                    Some(Slot::Failed(msg)) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return (Err(msg.clone()), true);
                    }
                    Some(Slot::Pending) => {
                        map = self.resolved.wait(map).unwrap();
                    }
                    None => break,
                }
            }
            map.insert(key.clone(), Slot::Pending);
        }
        // This thread owns the analysis for `key`; the catch_unwind
        // guarantees the Pending slot is always resolved.
        // `analyze_uniform_in` always prices with the default table, so
        // the disk key uses it too.
        let table = EnergyTable::default();
        let preset = self
            .disk
            .as_ref()
            .and_then(|d| d.load(wl, fingerprint, array, &table));
        install_quiet_hook();
        SUPPRESS_PANIC_TRACE.with(|s| s.set(true));
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            WorkloadAnalysis::analyze_uniform_in(
                wl,
                array,
                &self.feas,
                preset.as_deref(),
            )
        }));
        SUPPRESS_PANIC_TRACE.with(|s| s.set(false));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let (slot, out) = match outcome {
            Ok(ana) => {
                // A disk hit only counts if the loaded volumes actually
                // covered every statement — a parseable-but-stale file
                // (e.g. older format under an unchanged fingerprint)
                // falls through analyze's per-entry validation and must
                // be rewritten, not celebrated.
                let fully_preset = preset.as_ref().is_some_and(|pre| {
                    ana.phases.len() == pre.len()
                        && ana.phases.iter().zip(pre).all(|(ph, m)| {
                            ph.statements.iter().all(|s| {
                                m.get(&s.name) == Some(&s.volume)
                            })
                        })
                });
                if fully_preset {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                } else if let Some(d) = &self.disk {
                    // Advisory spill: an IO failure must not fail the
                    // analysis that just succeeded.
                    let _ = d.store(wl, fingerprint, array, &table, &ana);
                }
                let arc = Arc::new(ana);
                (Slot::Ready(Arc::clone(&arc)), Ok(arc))
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                (Slot::Failed(msg.clone()), Err(msg))
            }
        };
        self.map.lock().unwrap().insert(key, slot);
        self.resolved.notify_all();
        (out, false)
    }

    /// As [`Self::try_get_or_analyze`], panicking on analysis failure
    /// (the pre-caching `analyze_uniform` behavior, for callers that
    /// treat an infeasible shape as a bug).
    pub fn get_or_analyze(
        &self,
        wl: &Workload,
        array: &[i64],
    ) -> (Arc<WorkloadAnalysis>, bool) {
        match self.try_get_or_analyze(wl, array) {
            (Ok(a), hit) => (a, hit),
            (Err(msg), _) => panic!(
                "symbolic analysis of {} on {array:?} failed: {msg}",
                wl.name
            ),
        }
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            entries: self.map.lock().unwrap().len(),
        }
    }

    /// Drop all cached analyses (counters keep accumulating).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }

    /// Prune the persistent spill directory (no-op without one): remove
    /// files whose workload name matches a `live` entry but whose
    /// fingerprint matches none — the workload definition changed and
    /// those volumes can never be loaded again — plus orphaned temp
    /// files. See [`DiskCache::prune`]. Returns the number of files
    /// removed.
    pub fn prune_disk(
        &self,
        live: &[(String, u64)],
    ) -> std::io::Result<usize> {
        match &self.disk {
            Some(d) => d.prune(live),
            None => Ok(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn second_lookup_hits() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("gesummv").unwrap();
        let (_, hit0) = cache.get_or_analyze(&wl, &[2, 2]);
        let (_, hit1) = cache.get_or_analyze(&wl, &[2, 2]);
        assert!(!hit0);
        assert!(hit1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn failed_analyses_are_cached_not_rerun() {
        // The "twist" PRA has no feasible schedule: its analysis panics
        // in `find_schedule` and must be memoized as a failure.
        let cache = AnalysisCache::new();
        let wl = workloads::twist_unschedulable();
        let (r0, h0) = cache.try_get_or_analyze(&wl, &[2, 2]);
        let (r1, h1) = cache.try_get_or_analyze(&wl, &[2, 2]);
        assert!(r0.is_err() && r1.is_err());
        assert!(!h0);
        assert!(h1, "the failed analysis must be served from the cache");
        let s = cache.stats();
        assert_eq!(
            s.misses, 1,
            "the failing pass must run once, not per lookup"
        );
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn same_name_different_workload_is_not_conflated() {
        // A Workload that merely *claims* another's name must not be
        // served its memoized analysis.
        let cache = AnalysisCache::new();
        let real = workloads::by_name("gesummv").unwrap();
        let mut imposter = workloads::by_name("atax").unwrap();
        imposter.name = "gesummv".into();
        let (_, h0) = cache.try_get_or_analyze(&real, &[2, 2]);
        let (_, h1) = cache.try_get_or_analyze(&imposter, &[2, 2]);
        assert!(!h0);
        assert!(!h1, "structurally different workload must miss");
        assert_eq!(cache.stats().entries, 2);
    }

    #[test]
    fn distinct_arrays_are_distinct_entries() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("gesummv").unwrap();
        cache.get_or_analyze(&wl, &[2, 2]);
        cache.get_or_analyze(&wl, &[2, 3]);
        assert_eq!(cache.stats().entries, 2);
        cache.clear();
        assert_eq!(cache.stats().entries, 0);
    }

    #[test]
    fn disk_spill_reloads_across_cache_instances_bit_for_bit() {
        let dir = std::env::temp_dir().join(format!(
            "tcpa-cache-spill-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let params = vec![vec![8i64, 8, 4, 4]];

        // Cold process: computes and spills.
        let cold = AnalysisCache::with_disk(&dir);
        let (a, _) = cold.get_or_analyze(&wl, &[2, 2]);
        assert_eq!(cold.stats().disk_hits, 0);

        // "Second process": fresh in-memory cache, same directory.
        let warm = AnalysisCache::with_disk(&dir);
        let (b, hit) = warm.get_or_analyze(&wl, &[2, 2]);
        assert!(!hit, "in-memory cache is cold");
        assert_eq!(
            warm.stats().disk_hits,
            1,
            "volumes must come from the spilled file"
        );
        // Bit-for-bit: identical volumes, counts, energies, latencies.
        for (pa, pb) in a.phases.iter().zip(&b.phases) {
            for (sa, sb) in pa.statements.iter().zip(&pb.statements) {
                assert_eq!(sa.volume, sb.volume, "{}", sa.name);
            }
        }
        assert_eq!(a.counts_at(&params), b.counts_at(&params));
        let (ea, eb) = (a.energy_at(&params), b.energy_at(&params));
        assert_eq!(ea.total.to_bits(), eb.total.to_bits());
        assert_eq!(a.latency_at(&params), b.latency_at(&params));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_disk_reaps_stale_spills_and_noops_without_disk() {
        // No spill directory: prune is a structural no-op.
        assert_eq!(
            AnalysisCache::new().prune_disk(&[("x".into(), 1)]).unwrap(),
            0
        );
        let dir = std::env::temp_dir().join(format!(
            "tcpa-cache-prune-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let wl = workloads::by_name("gesummv").unwrap();
        let cache = AnalysisCache::with_disk(&dir);
        cache.get_or_analyze(&wl, &[2, 2]);
        let fp = workload_fingerprint(&wl);
        // Current fingerprint live: nothing to reap.
        assert_eq!(
            cache.prune_disk(&[(wl.name.clone(), fp)]).unwrap(),
            0
        );
        // Pretend the workload definition changed: the old spill is
        // unreachable and must go.
        assert_eq!(
            cache
                .prune_disk(&[(wl.name.clone(), fp.wrapping_add(1))])
                .unwrap(),
            1
        );
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_and_fresh_agree_bit_for_bit() {
        let cache = AnalysisCache::new();
        let wl = workloads::by_name("gesummv").unwrap();
        let (cached, _) = cache.get_or_analyze(&wl, &[2, 2]);
        let fresh = WorkloadAnalysis::analyze_uniform(&wl, &[2, 2]);
        let params = vec![vec![8i64, 8, 4, 4]];
        assert_eq!(cached.energy_at(&params), fresh.energy_at(&params));
        assert_eq!(cached.counts_at(&params), fresh.counts_at(&params));
        assert_eq!(cached.latency_at(&params), fresh.latency_at(&params));
    }
}
