//! Search strategies over the design space.
//!
//! PR 8 made long sweeps *survivable* (checkpoint/resume, deadlines);
//! this module makes them *avoidable*. [`Strategy`] is a first-class
//! axis of [`DesignSpace`]: `Exhaustive` keeps the canonical
//! enumeration ([`DesignSpace::points`] / [`DesignSpace::phase_points`])
//! and stays the oracle, while `Beam` replaces it with a deterministic
//! Pareto-guided local search over the shape / phase-shape axis that
//! visits only a budgeted subset of the combination space. The paper's
//! bargain — one symbolic analysis per (phase, shape) covers every
//! combination that reuses the shape — is exactly what makes the beam
//! cheap: pricing a candidate combination is a cache hit on analyses
//! the seeds already paid for.
//!
//! # Beam state and neighborhood model
//!
//! A *state* is a vector of shape indices into the surviving shape
//! list, one per phase (length 1 under [`PhasePolicy::Uniform`]). The
//! search runs once per *scenario* — each (bounds, tile-scale,
//! backend) triple — because shape fitness and energy both depend on
//! the bounds/backend, and the frontier is grouped per (bounds,
//! backend) downstream.
//!
//! - **Seeds.** The extreme uniform diagonals (smallest and largest
//!   fitting shape in every phase) plus, per phase, the shape with the
//!   minimal single-phase energy. Phase energies are *separable* — a
//!   combination's energy is the sum of its phases' — so the vector of
//!   per-phase energy argmins IS the global energy argmin: the beam
//!   starts at the optimum of the energy objective and explores
//!   outward. Each argmin shape also seeds its uniform diagonal.
//! - **Neighbors.** Per phase: the transposed shape (when enumerated)
//!   and the previous/next *fitting* shape in enumeration order
//!   (resize steps). Neighbors falling on a symmetry-pruned duplicate
//!   are canonicalized to their mirror representative, and an expanded
//!   state also contributes its mirror's raw neighbors — the search
//!   graph is then exactly the symmetry quotient of a product of
//!   paths, which is connected.
//! - **Generations.** Every visited state is priced with the same
//!   [`evaluate`](super::explore) the exhaustive explorer uses (so
//!   objective values are bit-identical), and the open list is ranked:
//!   states Pareto-nondominated against everything seen so far first
//!   (canonical order within a class), then dominated states, then
//!   failed ones. The top `W` are expanded. Nothing is ever discarded
//!   — dominated and failed states keep their place in the open list,
//!   so with `budget >=` the reachable set the beam degenerates to a
//!   full traversal and emits *exactly* the exhaustive enumeration
//!   (the oracle-equality pin in `tests/strategy_oracle.rs`).
//! - **Termination.** The scenario search stops when the open list is
//!   empty or `budget` states have been visited.
//!
//! The visited sets of all scenarios are then re-emitted in the
//! canonical enumeration order (combination-major, then bounds, tile
//! scales, backends — the same nesting as `points()`/`phase_points()`),
//! so journal indices, shard ownership and report ordering are
//! meaningful under both strategies.

use std::collections::{BTreeMap, BTreeSet};

use crate::energy::Backend;
use crate::pra::Workload;

use super::cache::AnalysisCache;
use super::explore::{evaluate, phase_params};
use super::pareto::{dominates, NUM_OBJECTIVES};
use super::space::{
    DesignPoint, DesignSpace, PhasePolicy, PhaseShapes, ScheduleChoice,
};

/// Beam width when `--strategy beam` is given without `:W`.
pub const DEFAULT_BEAM_WIDTH: usize = 8;

/// Visited-state budget per scenario. Chosen so small spaces (a few
/// hundred combinations) are covered in full — beam == exhaustive —
/// while the >20k cliffs the CLI refuses under exhaustion stay
/// bounded.
pub const DEFAULT_BEAM_BUDGET: usize = 4096;

/// How the explorer walks the design space.
///
/// Part of [`DesignSpace`] (not the control block) because the
/// strategy changes *which* points exist: it participates in the
/// space fingerprint that checkpoint journals bind to, so a beam
/// journal can never silently resume an exhaustive sweep or vice
/// versa.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Strategy {
    /// Enumerate every point (the default, and the oracle the beam is
    /// differentially tested against).
    Exhaustive,
    /// Deterministic Pareto-guided beam search (see module docs).
    Beam {
        /// States expanded per generation.
        width: usize,
        /// Visited-state cap per (bounds, tile-scale, backend)
        /// scenario.
        budget: usize,
    },
}

impl Default for Strategy {
    fn default() -> Self {
        Strategy::Exhaustive
    }
}

impl Strategy {
    /// Beam search with the default visited budget.
    pub fn beam(width: usize) -> Strategy {
        Strategy::Beam { width: width.max(1), budget: DEFAULT_BEAM_BUDGET }
    }

    /// Beam search with an explicit visited budget (tests use a huge
    /// budget to force full coverage, benches a small one to measure
    /// regret).
    pub fn beam_with_budget(width: usize, budget: usize) -> Strategy {
        Strategy::Beam { width: width.max(1), budget: budget.max(1) }
    }

    /// Parse a `--strategy` argument: `exhaustive`, `beam`, or
    /// `beam:W`.
    pub fn parse(s: &str) -> Result<Strategy, String> {
        match s {
            "exhaustive" => Ok(Strategy::Exhaustive),
            "beam" => Ok(Strategy::beam(DEFAULT_BEAM_WIDTH)),
            _ => match s.strip_prefix("beam:") {
                Some(w) => match w.parse::<usize>() {
                    Ok(width) if width >= 1 => Ok(Strategy::beam(width)),
                    _ => Err(format!(
                        "bad beam width {w:?} in --strategy {s:?} \
                         (expected beam:W with W >= 1, e.g. beam:8)"
                    )),
                },
                None => Err(format!(
                    "unknown strategy {s:?} (expected exhaustive, beam \
                     or beam:W)"
                )),
            },
        }
    }

    /// Round-trippable CLI label: `exhaustive` or `beam:W`.
    pub fn label(&self) -> String {
        match self {
            Strategy::Exhaustive => "exhaustive".to_string(),
            Strategy::Beam { width, .. } => format!("beam:{width}"),
        }
    }

    /// True for the exhaustive oracle.
    pub fn is_exhaustive(&self) -> bool {
        matches!(self, Strategy::Exhaustive)
    }
}

/// Enumerate the design points the beam strategy visits, in canonical
/// enumeration order (a subsequence of what `Exhaustive` would emit).
///
/// `fingerprint`/`phase_fps` are the workload fingerprints the caller
/// already computed for cache keying; pricing goes through the shared
/// `cache`, so the analyses paid for here are hits when the explorer
/// evaluates the emitted points.
pub(crate) fn beam_points(
    wl: &Workload,
    fingerprint: u64,
    phase_fps: &[u64],
    space: &DesignSpace,
    cache: &AnalysisCache,
) -> Vec<DesignPoint> {
    let Strategy::Beam { width, budget } = space.strategy.clone() else {
        return match space.phase_policy {
            PhasePolicy::Uniform => space.points(),
            PhasePolicy::PerPhase => space.phase_points(wl.phases.len()),
        };
    };
    let shapes = space.surviving_shapes();
    if shapes.is_empty() {
        return Vec::new();
    }
    let nphases = match space.phase_policy {
        PhasePolicy::Uniform => 1,
        PhasePolicy::PerPhase => wl.phases.len(),
    };
    if nphases == 0 {
        return Vec::new();
    }
    let index_of: BTreeMap<&[i64], usize> = shapes
        .iter()
        .enumerate()
        .map(|(i, s)| (s.as_slice(), i))
        .collect();

    // Visited combination sets, per scenario and pooled. BTreeSet keys
    // are index vectors, so iteration order IS the canonical
    // combination order (the odometer in `phase_points` ticks phase 0
    // most significantly; under Uniform the single index matches the
    // surviving-shape order).
    let mut per_scenario: BTreeMap<(usize, usize, usize), BTreeSet<Vec<usize>>> =
        BTreeMap::new();
    let mut all: BTreeSet<Vec<usize>> = BTreeSet::new();
    for (bi, bounds) in space.bounds_grid.iter().enumerate() {
        for (ti, &tile_scale) in space.tile_scales.iter().enumerate() {
            for (ki, backend) in space.backends.iter().enumerate() {
                let visited = beam_scenario(
                    wl, fingerprint, phase_fps, space, cache, &shapes,
                    &index_of, nphases, bounds, tile_scale, backend, width,
                    budget,
                );
                all.extend(visited.iter().cloned());
                per_scenario.insert((bi, ti, ki), visited);
            }
        }
    }

    let mut out = Vec::new();
    for combo in &all {
        for (bi, bounds) in space.bounds_grid.iter().enumerate() {
            for (ti, &tile_scale) in space.tile_scales.iter().enumerate() {
                for (ki, backend) in space.backends.iter().enumerate() {
                    if per_scenario[&(bi, ti, ki)].contains(combo) {
                        out.push(combo_point(
                            space, &shapes, combo, bounds, tile_scale,
                            backend,
                        ));
                    }
                }
            }
        }
    }
    out
}

/// Materialize a combination as a [`DesignPoint`], mirroring the
/// construction in `points()`/`phase_points()` field for field so the
/// emitted points are indistinguishable from exhaustively enumerated
/// ones.
fn combo_point(
    space: &DesignSpace,
    shapes: &[&Vec<i64>],
    combo: &[usize],
    bounds: &[i64],
    tile_scale: i64,
    backend: &Backend,
) -> DesignPoint {
    match space.phase_policy {
        PhasePolicy::Uniform => DesignPoint {
            array: shapes[combo[0]].clone(),
            bounds: bounds.to_vec(),
            tile_scale,
            backend: backend.clone(),
            schedule: ScheduleChoice::First,
            phase_shapes: PhaseShapes::Uniform,
        },
        PhasePolicy::PerPhase => {
            let per: Vec<Vec<i64>> =
                combo.iter().map(|&i| shapes[i].clone()).collect();
            // Provision the array for the largest phase shape — the
            // same last-wins tie-break as `phase_points`.
            let array = per
                .iter()
                .rev()
                .max_by_key(|s| s.iter().product::<i64>())
                .expect("combo has >= 1 phase")
                .clone();
            DesignPoint {
                array,
                bounds: bounds.to_vec(),
                tile_scale,
                backend: backend.clone(),
                schedule: ScheduleChoice::First,
                phase_shapes: PhaseShapes::PerPhase(per),
            }
        }
    }
}

/// Minimal single-phase energy of `shape` for phase `q` under this
/// scenario, priced off the shared per-(phase, shape) analysis cache —
/// the same analyses and parameter choice `evaluate` uses, so the
/// argmin is exact w.r.t. the explorer's own numbers. `None` when the
/// analysis fails (the full combination would fail too).
#[allow(clippy::too_many_arguments)]
fn phase_energy(
    wl: &Workload,
    phase_fps: &[u64],
    q: usize,
    shape: &[i64],
    bounds: &[i64],
    tile_scale: i64,
    backend: &Backend,
    cache: &AnalysisCache,
) -> Option<f64> {
    let (ana, _) =
        cache.try_get_or_analyze_phase_keyed(wl, phase_fps[q], q, shape);
    let ana = ana.ok()?;
    let probe = DesignPoint {
        array: shape.to_vec(),
        bounds: bounds.to_vec(),
        tile_scale,
        backend: backend.clone(),
        schedule: ScheduleChoice::First,
        phase_shapes: PhaseShapes::Uniform,
    };
    let params = phase_params(&[&*ana], &probe);
    let energy = crate::analysis::energy_at_backend_phases(
        std::iter::once(&*ana),
        &params,
        backend,
    );
    Some(energy.total)
}

/// One scenario's beam search; returns the visited (canonical,
/// enumerable) combinations.
#[allow(clippy::too_many_arguments)]
fn beam_scenario(
    wl: &Workload,
    fingerprint: u64,
    phase_fps: &[u64],
    space: &DesignSpace,
    cache: &AnalysisCache,
    shapes: &[&Vec<i64>],
    index_of: &BTreeMap<&[i64], usize>,
    nphases: usize,
    bounds: &[i64],
    tile_scale: i64,
    backend: &Backend,
    width: usize,
    budget: usize,
) -> BTreeSet<Vec<usize>> {
    // Shapes that fit these bounds — the axis resize moves walk along.
    let fitting: Vec<usize> = (0..shapes.len())
        .filter(|&i| DesignSpace::fits(shapes[i], bounds))
        .collect();
    if fitting.is_empty() {
        return BTreeSet::new();
    }

    // A combination is enumerable iff the exhaustive enumeration would
    // emit it for these bounds: every shape fits and it is not a
    // symmetry-pruned duplicate.
    let valid = |combo: &[usize]| -> bool {
        match space.phase_policy {
            PhasePolicy::Uniform => {
                let s = shapes[combo[0]];
                DesignSpace::fits(s, bounds)
                    && !space.symmetric_duplicate(s, bounds)
            }
            PhasePolicy::PerPhase => {
                let per: Vec<Vec<i64>> =
                    combo.iter().map(|&i| shapes[i].clone()).collect();
                per.iter().all(|s| DesignSpace::fits(s, bounds))
                    && !space.symmetric_combo_duplicate(&per, bounds)
            }
        }
    };

    // Canonicalize a raw move target: drop it if some shape does not
    // fit; if it lands on a symmetry-pruned duplicate, jump to the
    // mirror representative the exhaustive enumeration kept.
    let canon = |combo: Vec<usize>| -> Option<Vec<usize>> {
        if !combo.iter().all(|&i| fitting.binary_search(&i).is_ok()) {
            return None;
        }
        if valid(&combo) {
            return Some(combo);
        }
        let mirror: Option<Vec<usize>> = match space.phase_policy {
            PhasePolicy::Uniform => {
                // `symmetric_duplicate` canonicalizes to the sorted
                // orientation.
                let mut sorted = shapes[combo[0]].clone();
                sorted.sort_unstable();
                index_of.get(sorted.as_slice()).map(|&i| vec![i])
            }
            PhasePolicy::PerPhase => combo
                .iter()
                .map(|&i| {
                    let rev: Vec<i64> =
                        shapes[i].iter().rev().copied().collect();
                    index_of.get(rev.as_slice()).copied()
                })
                .collect(),
        };
        mirror.filter(|m| valid(m))
    };

    // Raw neighborhood of one state: per phase, the transposed shape
    // and the adjacent fitting shapes in enumeration order. When
    // symmetry pruning is on, a state stands for its whole mirror
    // orbit, so its mirror's raw neighbors count too — that makes the
    // canonicalized search graph the exact quotient of the (connected)
    // product-of-paths graph, hence connected: sufficient budget
    // reaches everything.
    let raw_neighbors = |state: &[usize]| -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        let mut bases: Vec<Vec<usize>> = vec![state.to_vec()];
        if space.prune_symmetric {
            let mirror: Option<Vec<usize>> = state
                .iter()
                .map(|&i| {
                    let rev: Vec<i64> =
                        shapes[i].iter().rev().copied().collect();
                    index_of.get(rev.as_slice()).copied()
                })
                .collect();
            if let Some(m) = mirror {
                if m.as_slice() != state
                    && m.iter()
                        .all(|&i| fitting.binary_search(&i).is_ok())
                {
                    bases.push(m);
                }
            }
        }
        for base in &bases {
            for q in 0..base.len() {
                let i = base[q];
                let rev: Vec<i64> =
                    shapes[i].iter().rev().copied().collect();
                if let Some(&j) = index_of.get(rev.as_slice()) {
                    if j != i {
                        let mut nb = base.clone();
                        nb[q] = j;
                        out.push(nb);
                    }
                }
                if let Ok(pos) = fitting.binary_search(&i) {
                    if pos > 0 {
                        let mut nb = base.clone();
                        nb[q] = fitting[pos - 1];
                        out.push(nb);
                    }
                    if pos + 1 < fitting.len() {
                        let mut nb = base.clone();
                        nb[q] = fitting[pos + 1];
                        out.push(nb);
                    }
                }
            }
        }
        out
    };

    // Price a state exactly as the explorer will: same `evaluate`,
    // same cache — the analyses are hits when the emitted points are
    // re-evaluated. A state with several schedule candidates carries
    // all their objective vectors.
    let price = |combo: &[usize]| -> Option<Vec<[f64; NUM_OBJECTIVES]>> {
        let point =
            combo_point(space, shapes, combo, bounds, tile_scale, backend);
        evaluate(
            wl,
            fingerprint,
            phase_fps,
            &point,
            cache,
            space.schedules,
            space.verify_schedules,
        )
        .ok()
        .map(|evals| {
            evals.iter().map(|e| e.objectives().to_array()).collect()
        })
    };

    // Seeds: extreme uniform diagonals + per-phase energy argmins (see
    // module docs for why the argmin vector is the exact global energy
    // optimum).
    let mut seeds: BTreeSet<Vec<usize>> = BTreeSet::new();
    let first = *fitting.first().expect("fitting is non-empty");
    let last = *fitting.last().expect("fitting is non-empty");
    for i in [first, last] {
        if let Some(c) = canon(vec![i; nphases]) {
            seeds.insert(c);
        }
    }
    if space.phase_policy == PhasePolicy::PerPhase {
        let mut argmin: Vec<usize> = Vec::with_capacity(nphases);
        for q in 0..nphases {
            let mut best: Option<(f64, usize)> = None;
            for &i in &fitting {
                if let Some(e) = phase_energy(
                    wl, phase_fps, q, shapes[i], bounds, tile_scale,
                    backend, cache,
                ) {
                    let better = match best {
                        Some((be, _)) => e < be,
                        None => true,
                    };
                    if better {
                        best = Some((e, i));
                    }
                }
            }
            match best {
                Some((_, i)) => argmin.push(i),
                None => {
                    argmin.clear();
                    break;
                }
            }
        }
        if argmin.len() == nphases {
            for &i in &argmin {
                if let Some(c) = canon(vec![i; nphases]) {
                    seeds.insert(c);
                }
            }
            if let Some(c) = canon(argmin) {
                seeds.insert(c);
            }
        }
    }

    let mut visited: BTreeSet<Vec<usize>> = BTreeSet::new();
    let mut open: BTreeSet<Vec<usize>> = BTreeSet::new();
    let mut scored: BTreeMap<Vec<usize>, Option<Vec<[f64; NUM_OBJECTIVES]>>> =
        BTreeMap::new();
    for s in seeds {
        if visited.len() >= budget {
            break;
        }
        if visited.insert(s.clone()) {
            scored.insert(s.clone(), price(&s));
            open.insert(s);
        }
    }

    while !open.is_empty() && visited.len() < budget {
        // Rank the whole open list against every objective vector seen
        // so far: nondominated first (a state survives if ANY of its
        // schedule candidates is nondominated), then dominated, then
        // failed — canonical combination order inside each class.
        // Nothing is discarded; a state skipped this generation stays
        // open for the next.
        let pool: Vec<[f64; NUM_OBJECTIVES]> = scored
            .values()
            .flatten()
            .flatten()
            .copied()
            .collect();
        let mut ranked: Vec<(u8, Vec<usize>)> = open
            .iter()
            .map(|c| {
                let class = match &scored[c] {
                    None => 2u8,
                    Some(objs) => {
                        let nondominated = objs.iter().any(|o| {
                            !pool.iter().any(|p| dominates(p, o))
                        });
                        if nondominated {
                            0
                        } else {
                            1
                        }
                    }
                };
                (class, c.clone())
            })
            .collect();
        ranked.sort();
        for (_, state) in ranked.into_iter().take(width) {
            open.remove(&state);
            for nb in raw_neighbors(&state) {
                if visited.len() >= budget {
                    break;
                }
                if let Some(c) = canon(nb) {
                    if visited.insert(c.clone()) {
                        scored.insert(c.clone(), price(&c));
                        open.insert(c);
                    }
                }
            }
        }
    }
    visited
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;
    use super::super::cache::{phase_fingerprint, workload_fingerprint};

    #[test]
    fn parse_and_label_round_trip() {
        assert_eq!(Strategy::parse("exhaustive"), Ok(Strategy::Exhaustive));
        assert_eq!(
            Strategy::parse("beam"),
            Ok(Strategy::beam(DEFAULT_BEAM_WIDTH))
        );
        assert_eq!(Strategy::parse("beam:3"), Ok(Strategy::beam(3)));
        for s in ["exhaustive", "beam:8", "beam:3"] {
            let parsed = Strategy::parse(s).unwrap();
            assert_eq!(Strategy::parse(&parsed.label()), Ok(parsed));
        }
        assert_eq!(Strategy::Exhaustive.label(), "exhaustive");
        assert_eq!(Strategy::beam(4).label(), "beam:4");
        assert!(Strategy::Exhaustive.is_exhaustive());
        assert!(!Strategy::beam(4).is_exhaustive());
        assert_eq!(Strategy::default(), Strategy::Exhaustive);
    }

    #[test]
    fn parse_rejects_malformed_strategies() {
        for s in ["", "beam:", "beam:0", "beam:x", "beams", "BEAM", "beam:-1"]
        {
            let err = Strategy::parse(s).unwrap_err();
            assert!(
                err.contains(&format!("{s:?}")),
                "error {err:?} should name the input {s:?}"
            );
        }
    }

    #[test]
    fn width_and_budget_are_clamped_to_one() {
        assert_eq!(
            Strategy::beam(0),
            Strategy::Beam { width: 1, budget: DEFAULT_BEAM_BUDGET }
        );
        assert_eq!(
            Strategy::beam_with_budget(0, 0),
            Strategy::Beam { width: 1, budget: 1 }
        );
    }

    /// With a budget covering the whole space the beam is a full
    /// traversal: the emitted list must equal the exhaustive
    /// enumeration exactly — order included — for both phase policies
    /// and with symmetry pruning on.
    #[test]
    fn full_budget_beam_equals_exhaustive_enumeration() {
        let wl = workloads::by_name("gemver").unwrap();
        let fingerprint = workload_fingerprint(&wl);
        let phase_fps: Vec<u64> =
            wl.phases.iter().map(phase_fingerprint).collect();
        for per_phase in [false, true] {
            for prune in [false, true] {
                let mut space = DesignSpace::new()
                    .with_arrays_2d(4)
                    .with_bounds_sweep(&[8, 16], 2)
                    .with_strategy(Strategy::beam_with_budget(2, 1_000_000));
                if per_phase {
                    space = space.with_phase_shapes(PhasePolicy::PerPhase);
                }
                space.prune_symmetric = prune;
                let exhaustive = match space.phase_policy {
                    PhasePolicy::Uniform => space.points(),
                    PhasePolicy::PerPhase => {
                        space.phase_points(wl.phases.len())
                    }
                };
                let cache = AnalysisCache::new();
                let beam = beam_points(
                    &wl, fingerprint, &phase_fps, &space, &cache,
                );
                assert_eq!(
                    beam, exhaustive,
                    "per_phase={per_phase} prune={prune}: full-budget \
                     beam must reproduce the exhaustive enumeration"
                );
            }
        }
    }

    /// A tight budget yields a strict, deterministic subset in
    /// canonical order.
    #[test]
    fn tight_budget_beam_is_a_deterministic_ordered_subset() {
        let wl = workloads::by_name("gemver").unwrap();
        let fingerprint = workload_fingerprint(&wl);
        let phase_fps: Vec<u64> =
            wl.phases.iter().map(phase_fingerprint).collect();
        let space = DesignSpace::new()
            .with_arrays_2d(6)
            .with_bounds(vec![12, 12])
            .with_phase_shapes(PhasePolicy::PerPhase)
            .with_strategy(Strategy::beam_with_budget(2, 12));
        let exhaustive = space.phase_points(wl.phases.len());
        let a = beam_points(
            &wl,
            fingerprint,
            &phase_fps,
            &space,
            &AnalysisCache::new(),
        );
        let b = beam_points(
            &wl,
            fingerprint,
            &phase_fps,
            &space,
            &AnalysisCache::new(),
        );
        assert_eq!(a, b, "beam enumeration must be deterministic");
        assert!(
            a.len() < exhaustive.len(),
            "budget 12 must prune a {}-point space",
            exhaustive.len()
        );
        // Subset in canonical order: walking the exhaustive list must
        // encounter every beam point in sequence.
        let mut it = exhaustive.iter();
        for p in &a {
            assert!(
                it.any(|e| e == p),
                "beam point missing from the exhaustive enumeration \
                 or out of canonical order: {p:?}"
            );
        }
    }
}
