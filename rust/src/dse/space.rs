//! The design-space model: which (array shape, loop bounds, tile scale,
//! energy backend, schedule vector, per-phase shape assignment)
//! combinations a sweep covers, and which of them pruning removes before
//! any analysis runs.

use std::collections::HashSet;

use super::strategy::Strategy;
use crate::energy::{Backend, Policy};

/// One slice of a deterministically partitioned sweep: shard `index` of
/// `count` (1-based, rendered `i/n`) owns every enumeration index `idx`
/// with `idx % count == index - 1`.
///
/// Round-robin over the canonical enumeration order — not contiguous
/// blocks — so every shard sees every (bounds, backend) scenario group:
/// the axes vary fastest innermost, and striding by `count` cycles
/// through them. The partition depends only on `(index, count)` and the
/// enumeration order, never on timing or worker count, which is the
/// invariant that makes shard journals mergeable (`dse merge`): shard
/// identity is bound into the journal header, and the merged union of
/// owned indices reconstructs the unsharded sweep exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    /// 1-based shard index, `1 ≤ index ≤ count`.
    pub index: usize,
    /// Total number of shards, `≥ 1`.
    pub count: usize,
}

impl Default for Shard {
    fn default() -> Self {
        Shard::solo()
    }
}

impl Shard {
    /// The trivial partition: one shard owning every point.
    pub fn solo() -> Self {
        Shard { index: 1, count: 1 }
    }

    /// True for the trivial `1/1` partition.
    pub fn is_solo(&self) -> bool {
        self.count == 1
    }

    /// Parse the CLI form `i/n` (e.g. `2/3`), validating `1 ≤ i ≤ n`.
    pub fn parse(s: &str) -> Result<Shard, String> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| format!("expected i/n (e.g. 2/3), got {s:?}"))?;
        let index: usize = i
            .trim()
            .parse()
            .map_err(|_| format!("bad shard index {i:?} in {s:?}"))?;
        let count: usize = n
            .trim()
            .parse()
            .map_err(|_| format!("bad shard count {n:?} in {s:?}"))?;
        if count == 0 {
            return Err(format!("shard count must be >= 1, got {s:?}"));
        }
        if index == 0 || index > count {
            return Err(format!(
                "shard index must be in 1..={count}, got {s:?}"
            ));
        }
        Ok(Shard { index, count })
    }

    /// Render back to the `i/n` CLI/journal form.
    pub fn label(&self) -> String {
        format!("{}/{}", self.index, self.count)
    }

    /// Does this shard own enumeration index `idx`?
    pub fn owns(&self, idx: usize) -> bool {
        idx % self.count == self.index - 1
    }

    /// The shard that owns enumeration index `idx` in an `n`-way
    /// partition — how `dse merge` names the shard responsible for a
    /// missing record.
    pub fn owner_of(idx: usize, count: usize) -> Shard {
        assert!(count >= 1, "shard count must be >= 1");
        Shard { index: idx % count + 1, count }
    }
}

/// Whether a multi-phase workload's phases share one array shape or each
/// take their own — the per-phase heterogeneous mapping axis.
///
/// Multi-phase workloads (ATAX, 2MM, GEMVER) run their phases
/// sequentially on the same physical array, so nothing forces one shape
/// on all of them: a phase accumulating along `i1` prefers the transposed
/// orientation of a phase accumulating along `i0`. `PerPhase` turns the
/// assignment into a swept axis ([`DesignSpace::phase_points`]); the PE
/// budget is shared — a combination needs `max` (not `Σ`) of its phases'
/// PEs, since only one phase occupies the array at a time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PhasePolicy {
    /// Every phase uses the point's single `array` shape (padded per
    /// phase) — the pre-axis behavior, bit-for-bit.
    #[default]
    Uniform,
    /// Each phase draws its own shape from the `arrays` axis; the sweep
    /// covers every combination (including the uniform diagonal).
    PerPhase,
}

/// The per-phase shape assignment of one [`DesignPoint`].
#[derive(Debug, Clone, PartialEq)]
pub enum PhaseShapes {
    /// All phases take [`DesignPoint::array`] (padded to each phase's
    /// depth, exactly as `WorkloadAnalysis::analyze_uniform` does).
    Uniform,
    /// Explicit shape per phase, indexed like `Workload::phases` —
    /// emitted by [`DesignSpace::phase_points`] under
    /// [`PhasePolicy::PerPhase`].
    PerPhase(Vec<Vec<i64>>),
}

impl PhaseShapes {
    /// Compact display form: `uniform`, or the per-phase shape labels
    /// joined by `|` (e.g. `1x4|4x1|2x2`), mirroring the schedule
    /// label convention.
    pub fn label(&self) -> String {
        match self {
            PhaseShapes::Uniform => "uniform".to_string(),
            PhaseShapes::PerPhase(shapes) => shapes
                .iter()
                .map(|s| {
                    s.iter()
                        .map(|t| t.to_string())
                        .collect::<Vec<_>>()
                        .join("x")
                })
                .collect::<Vec<_>>()
                .join("|"),
        }
    }

    /// True when every phase uses one shared shape — either symbolically
    /// (`Uniform`) or as an explicit all-equal assignment.
    pub fn is_uniform(&self) -> bool {
        match self {
            PhaseShapes::Uniform => true,
            PhaseShapes::PerPhase(shapes) => {
                shapes.windows(2).all(|w| w[0] == w[1])
            }
        }
    }

    /// True when at least two phases genuinely differ in shape — the
    /// assignments only the per-phase axis can reach.
    pub fn is_heterogeneous(&self) -> bool {
        !self.is_uniform()
    }
}

/// How many schedule-vector candidates the explorer evaluates per design
/// point. The schedule axis is special: its extent depends on the
/// workload's dependence structure (number of causal dimension
/// permutations), which the space cannot know — so [`DesignSpace::points`]
/// emits base points with [`ScheduleChoice::First`] and the explorer
/// expands each into per-candidate points according to this policy
/// (`crate::schedule::enumerate_schedules`). Because the symbolic
/// volumes are schedule-invariant, every candidate of a shape shares the
/// one cached analysis — the axis costs expression evaluations only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Only the scheduler's default pick (enumeration index 0) — the
    /// pre-sweep behavior, bit-identical to it.
    First,
    /// Every feasible candidate (bounded by `ndims!` per phase).
    All,
    /// At most this many candidates per phase, in enumeration order.
    Limit(usize),
}

impl SchedulePolicy {
    /// The per-phase enumeration cap this policy induces (`None` = all).
    /// `Limit(0)` clamps to 1: "no candidates" would silently erase
    /// every design point from a sweep, and the fields of
    /// [`DesignSpace`] are public, so the [`DesignSpace::with_schedules`]
    /// assert alone cannot guarantee the cap is positive.
    pub fn per_phase_cap(self) -> Option<usize> {
        match self {
            SchedulePolicy::First => Some(1),
            SchedulePolicy::All => None,
            SchedulePolicy::Limit(n) => Some(n.max(1)),
        }
    }
}

/// Which schedule candidate a design point uses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleChoice {
    /// The scheduler's default pick for every phase (candidate 0 of the
    /// enumeration) — what [`DesignSpace::points`] emits.
    First,
    /// Explicit per-phase indices into the enumerated candidate lists
    /// (`crate::schedule::enumerate_schedules` order), assigned by the
    /// explorer when a [`SchedulePolicy`] beyond `First` is active.
    Indices(Vec<usize>),
}

impl ScheduleChoice {
    /// Compact display form: `first` for the default pick, else the
    /// per-phase indices joined by `.` (e.g. `s1` or `s1.0`).
    pub fn label(&self) -> String {
        match self {
            ScheduleChoice::First => "first".to_string(),
            ScheduleChoice::Indices(ix) => format!(
                "s{}",
                ix.iter()
                    .map(|i| i.to_string())
                    .collect::<Vec<_>>()
                    .join(".")
            ),
        }
    }

    /// True when this choice selects the scheduler's default pick —
    /// either symbolically (`First`) or as explicit all-zero indices.
    pub fn is_default(&self) -> bool {
        match self {
            ScheduleChoice::First => true,
            ScheduleChoice::Indices(ix) => ix.iter().all(|&i| i == 0),
        }
    }
}

/// One candidate configuration, prior to evaluation.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    /// Array shape `t` (1-D or 2-D here; deeper phases are padded with
    /// `t = 1` by the analysis, exactly as `analyze_uniform` does).
    pub array: Vec<i64>,
    /// Loop bounds `N` (padded per phase with its last entry, the CLI
    /// convention).
    pub bounds: Vec<i64>,
    /// Tile-size scale `k ≥ 1`: per dimension `p_ℓ = min(N_ℓ,
    /// k·⌈N_ℓ/t_ℓ⌉)`. `k = 1` is the paper's exact-cover sizing rule;
    /// larger `k` oversizes tiles (fewer active tiles, less inter-tile
    /// traffic, longer per-PE chains) while staying inside the analysis
    /// context `1 ≤ p_ℓ ≤ N_ℓ`.
    pub tile_scale: i64,
    /// Cross-architecture energy backend (routing + energy table).
    pub backend: Backend,
    /// Schedule-vector candidate (see [`ScheduleChoice`]).
    pub schedule: ScheduleChoice,
    /// Per-phase shape assignment (see [`PhaseShapes`]). For `PerPhase`
    /// points, `array` holds the *provisioned* shape — the phase shape
    /// with the most PEs (earliest phase among ties) — since phases run
    /// sequentially and the array is sized for the widest of them.
    pub phase_shapes: PhaseShapes,
}

impl DesignPoint {
    /// Total PEs this point uses: the product of `array`, or — for a
    /// heterogeneous per-phase assignment — the maximum over the phase
    /// shapes (phases run back to back on the same array, so the budget
    /// is `max`, not `Σ`).
    pub fn pes(&self) -> i64 {
        match &self.phase_shapes {
            PhaseShapes::Uniform => self.array.iter().product(),
            PhaseShapes::PerPhase(shapes) => shapes
                .iter()
                .map(|s| s.iter().product::<i64>())
                .max()
                .unwrap_or_else(|| self.array.iter().product()),
        }
    }

    /// Compact display label, e.g. `8x4` or `16`.
    pub fn array_label(&self) -> String {
        self.array
            .iter()
            .map(|t| t.to_string())
            .collect::<Vec<_>>()
            .join("x")
    }
}

/// A multi-axis design space. Build with the `with_*` methods, then
/// enumerate concrete points with [`DesignSpace::points`].
#[derive(Debug, Clone)]
pub struct DesignSpace {
    /// Candidate array shapes. Duplicates (e.g. from repeated `with_*`
    /// calls) are skipped once by [`DesignSpace::points`].
    pub arrays: Vec<Vec<i64>>,
    /// Loop-bound vectors to sweep (the cheap axis: cached analyses are
    /// reused across every entry).
    pub bounds_grid: Vec<Vec<i64>>,
    /// Tile-size scales (see [`DesignPoint::tile_scale`]).
    pub tile_scales: Vec<i64>,
    /// Energy backends to compare (per-backend Pareto frontiers).
    pub backends: Vec<Backend>,
    /// Schedule-vector axis policy (see [`SchedulePolicy`]; the explorer
    /// expands it per point, since its extent is workload-dependent).
    pub schedules: SchedulePolicy,
    /// Per-phase shape axis policy (see [`PhasePolicy`]). Like the
    /// schedule axis, its extent depends on the workload (its phase
    /// count), so the explorer selects between [`DesignSpace::points`]
    /// and [`DesignSpace::phase_points`].
    pub phase_policy: PhasePolicy,
    /// PE budget: shapes with more PEs are pruned.
    pub max_pes: Option<i64>,
    /// Prune transposed duplicates `(b,a)` when `(a,b)` is enumerated.
    /// Exact for workloads whose dependence structure is symmetric under
    /// the dimension swap (see `dse_properties` tests); for asymmetric
    /// workloads it is a deliberate approximation — DRAM-dominated energy
    /// is mapping-invariant, only FD/ID terms shift.
    pub prune_symmetric: bool,
    /// Prove every evaluated schedule causally correct for all
    /// parameter values (`Schedule::verify_symbolic`) before pricing a
    /// point; unprovable candidates fail the point loudly. Off by
    /// default — builtins carry their own test coverage — and switched
    /// on for untrusted input (`dse --workload-file`).
    pub verify_schedules: bool,
    /// How the explorer walks this space (see [`Strategy`]): exhaustive
    /// enumeration (the default and the oracle), or a beam search over
    /// the shape/phase-shape axis that visits only a budgeted,
    /// deterministically chosen subset. Part of the space — not the
    /// control block — because the strategy changes *which* points
    /// exist, so it belongs in the space fingerprint that checkpoint
    /// journals bind to.
    pub strategy: Strategy,
}

impl Default for DesignSpace {
    fn default() -> Self {
        Self::new()
    }
}

impl DesignSpace {
    /// An empty space: no arrays, no bounds, exact-cover tiles, the
    /// paper's TCPA backend.
    pub fn new() -> Self {
        DesignSpace {
            arrays: Vec::new(),
            bounds_grid: Vec::new(),
            tile_scales: vec![1],
            backends: vec![Backend::tcpa()],
            schedules: SchedulePolicy::First,
            phase_policy: PhasePolicy::Uniform,
            max_pes: None,
            prune_symmetric: false,
            verify_schedules: false,
            strategy: Strategy::Exhaustive,
        }
    }

    /// All 2-D shapes `(t0, t1)` with `t0·t1 ≤ max_pes`. The inner loop
    /// is bounded by `max_pes / t0`, so enumeration is O(budget·log)
    /// harmonic-sum work instead of the full `max_pes²` grid.
    pub fn with_arrays_2d(mut self, max_pes: i64) -> Self {
        for t0 in 1..=max_pes {
            for t1 in 1..=(max_pes / t0) {
                self.arrays.push(vec![t0, t1]);
            }
        }
        self.max_pes = Some(max_pes);
        self
    }

    /// All 1-D shapes `(t0)` with `t0 ≤ max_pes` (linear arrays; deeper
    /// loop dimensions stay on-PE).
    pub fn with_arrays_1d(mut self, max_pes: i64) -> Self {
        for t0 in 1..=max_pes {
            self.arrays.push(vec![t0]);
        }
        self.max_pes = Some(max_pes);
        self
    }

    /// Explicit candidate shapes.
    pub fn with_arrays(mut self, arrays: Vec<Vec<i64>>) -> Self {
        self.arrays.extend(arrays);
        self
    }

    /// A single loop-bound vector.
    pub fn with_bounds(mut self, bounds: Vec<i64>) -> Self {
        self.bounds_grid.push(bounds);
        self
    }

    /// Several loop-bound vectors (the cache-backed sweep axis).
    pub fn with_bounds_grid(mut self, grid: Vec<Vec<i64>>) -> Self {
        self.bounds_grid.extend(grid);
        self
    }

    /// Square bound vectors `[n; dims]` for every `n` in `sizes`.
    pub fn with_bounds_sweep(mut self, sizes: &[i64], dims: usize) -> Self {
        for &n in sizes {
            self.bounds_grid.push(vec![n; dims]);
        }
        self
    }

    /// Tile-size scales to sweep (default `[1]`, the exact-cover rule).
    pub fn with_tile_scales(mut self, scales: Vec<i64>) -> Self {
        assert!(scales.iter().all(|&k| k >= 1), "tile scales must be >= 1");
        self.tile_scales = scales;
        self
    }

    /// Energy backends to compare (default `[Backend::tcpa()]`); each
    /// backend becomes its own comparison scenario with its own Pareto
    /// frontier.
    pub fn with_backends(mut self, backends: Vec<Backend>) -> Self {
        self.backends = backends;
        self
    }

    /// Legacy [`Policy`] axis, priced against Table I — converts the
    /// policies into the equivalent [`Backend`] descriptors.
    pub fn with_policies(self, policies: Vec<Policy>) -> Self {
        let table = crate::energy::EnergyTable::table1_45nm();
        self.with_backends(
            policies.iter().map(|p| p.backend(&table)).collect(),
        )
    }

    /// Schedule-vector candidates per design point (default
    /// [`SchedulePolicy::First`], the pre-sweep single-schedule
    /// behavior). With `All` or `Limit(n)` the explorer evaluates every
    /// (capped) feasible `(permutation, λ^J, λ^K)` candidate against the
    /// shape's one cached analysis — latency becomes a genuinely
    /// explored objective at identical energy. `Limit(0)` would make
    /// every point silently vanish from the sweep, so it is rejected
    /// here (like `with_tile_scales` rejects scale 0).
    pub fn with_schedules(mut self, policy: SchedulePolicy) -> Self {
        assert!(
            !matches!(policy, SchedulePolicy::Limit(0)),
            "schedule candidate cap must be >= 1"
        );
        self.schedules = policy;
        self
    }

    /// Per-phase shape assignment policy (default [`PhasePolicy::Uniform`],
    /// the single-shape behavior). With [`PhasePolicy::PerPhase`] the
    /// explorer enumerates [`DesignSpace::phase_points`] instead of
    /// [`DesignSpace::points`]: every combination of `arrays` shapes
    /// across the workload's phases, pruned by the shared PE budget.
    /// Each distinct (phase, shape) pair is analyzed once and reused
    /// across all combinations containing it (`dse::AnalysisCache`), so
    /// the combinatorial sweep multiplies expression evaluations only.
    pub fn with_phase_shapes(mut self, policy: PhasePolicy) -> Self {
        self.phase_policy = policy;
        self
    }

    /// PE budget (also set by `with_arrays_2d`/`with_arrays_1d`).
    pub fn with_max_pes(mut self, max_pes: i64) -> Self {
        self.max_pes = Some(max_pes);
        self
    }

    /// Enable transposition-symmetry pruning (see field docs).
    pub fn with_symmetry_pruning(mut self) -> Self {
        self.prune_symmetric = true;
        self
    }

    /// Require a symbolic causality proof for every evaluated schedule
    /// (default and enumerated candidates alike) before a point is
    /// priced; see [`DesignSpace::verify_schedules`]. The proofs are
    /// memoized on the cached analysis, so the cost is once per
    /// (workload, shape), not per point.
    pub fn with_schedule_verification(mut self) -> Self {
        self.verify_schedules = true;
        self
    }

    /// Exploration strategy (default [`Strategy::Exhaustive`]). With a
    /// [`Strategy::Beam`] the explorer enumerates only the combos the
    /// beam search visits (`dse::strategy::beam_points`) instead of the
    /// full [`Self::points`] / [`Self::phase_points`] cross-product —
    /// which is what lifts the CLI's per-phase point cap.
    pub fn with_strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Does `array` survive the shape-level pruning rules?
    fn keep_array(&self, array: &[i64]) -> bool {
        if let Some(budget) = self.max_pes {
            if array.iter().product::<i64>() > budget {
                return false;
            }
        }
        true
    }

    /// Is `array` a transposed duplicate at these `bounds`? True only
    /// when its canonical mirror (the sorted, non-decreasing shape) is
    /// enumerated *and* itself fits `bounds` — otherwise pruning would
    /// silently lose a feasible orientation (e.g. `(4,2)` under bounds
    /// `(16,2)`, whose mirror `(2,4)` does not fit).
    pub(crate) fn symmetric_duplicate(
        &self,
        array: &[i64],
        bounds: &[i64],
    ) -> bool {
        if !self.prune_symmetric {
            return false;
        }
        let mut sorted = array.to_vec();
        sorted.sort_unstable();
        sorted != array
            && self.arrays.contains(&sorted)
            && Self::fits(&sorted, bounds)
    }

    /// Does `array` fit the problem `bounds`? (A PE row/column beyond the
    /// iteration extent would idle entirely — prune, like the original
    /// serial sweep did.) `bounds` is padded with its last entry.
    pub(crate) fn fits(array: &[i64], bounds: &[i64]) -> bool {
        let last = *bounds.last().expect("non-empty bounds");
        array
            .iter()
            .enumerate()
            .all(|(l, &t)| t <= bounds.get(l).copied().unwrap_or(last))
    }

    /// Enumerate the concrete design points, pruning applied, in a
    /// deterministic order (arrays outermost, so consecutive points share
    /// cached analyses; then bounds, tile scales, backends). Duplicate
    /// shapes — e.g. pushed by repeated `with_arrays*` calls — are
    /// enumerated once (first occurrence wins), so the explorer never
    /// analyzes the same configuration twice. An empty axis (no arrays,
    /// e.g. a zero PE budget, or no bounds) yields an empty sweep,
    /// matching the old serial `dse_sweep` behavior.
    pub fn points(&self) -> Vec<DesignPoint> {
        let mut out = Vec::new();
        for array in self.surviving_shapes() {
            for bounds in &self.bounds_grid {
                if !Self::fits(array, bounds)
                    || self.symmetric_duplicate(array, bounds)
                {
                    continue;
                }
                for &tile_scale in &self.tile_scales {
                    for backend in &self.backends {
                        // Schedule axis: emitted as `First` here and
                        // expanded per point by the explorer — the
                        // candidate count depends on the workload's
                        // dependence structure, unknown to the space.
                        out.push(DesignPoint {
                            array: array.clone(),
                            bounds: bounds.clone(),
                            tile_scale,
                            backend: backend.clone(),
                            schedule: ScheduleChoice::First,
                            phase_shapes: PhaseShapes::Uniform,
                        });
                    }
                }
            }
        }
        out
    }

    /// The deduplicated, budget-pruned shape list [`Self::points`] and
    /// [`Self::phase_points`] both draw from (first occurrence wins).
    pub(crate) fn surviving_shapes(&self) -> Vec<&Vec<i64>> {
        let mut seen: HashSet<&[i64]> = HashSet::new();
        self.arrays
            .iter()
            .filter(|a| seen.insert(a.as_slice()) && self.keep_array(a))
            .collect()
    }

    /// Enumerate the per-phase design points of a workload with
    /// `nphases` phases — every combination of surviving shapes across
    /// the phases (shapes^nphases before pruning, including the uniform
    /// diagonal, so the resulting frontier can only improve on the
    /// uniform one), in a deterministic order: combinations
    /// lexicographic by phase (phase 0 outermost), then bounds, tile
    /// scales, backends as in [`Self::points`].
    ///
    /// Pruning: every phase's shape must fit the bounds vector, and
    /// with symmetry pruning enabled combinations are deduplicated up
    /// to **global** transposition — mirroring *every* phase's shape at
    /// once, the only orientation symmetry of the objectives
    /// ([`Self::symmetric_combo_duplicate`]; transposing a single
    /// phase's shape genuinely changes per-phase energies, so a
    /// combination is never dropped just because one phase uses a
    /// non-canonical orientation). The shared PE budget needs no extra
    /// rule: phases run sequentially, so a combination uses `max` of
    /// its phases' PEs, and every surviving shape already respects the
    /// budget individually.
    ///
    /// The combination count grows as `shapes^nphases`; callers should
    /// check [`Self::phase_point_estimate`] first — this method panics
    /// (loudly, never truncating silently) if the count overflows.
    pub fn phase_points(&self, nphases: usize) -> Vec<DesignPoint> {
        assert!(nphases >= 1, "a workload has at least one phase");
        let shapes = self.surviving_shapes();
        let mut out = Vec::new();
        if shapes.is_empty() {
            return out;
        }
        let total = shapes
            .len()
            .checked_pow(nphases as u32)
            .expect("per-phase combination count overflows; shrink the shape axis");
        for flat in 0..total {
            // Odometer: phase 0 is the most significant digit.
            let mut rem = flat;
            let mut idx = vec![0usize; nphases];
            for d in (0..nphases).rev() {
                idx[d] = rem % shapes.len();
                rem /= shapes.len();
            }
            let combo: Vec<Vec<i64>> =
                idx.iter().map(|&i| shapes[i].clone()).collect();
            // Provisioned shape: the widest phase shape (phases execute
            // sequentially on one array). `rev().max_by_key` resolves
            // PE-count ties to the earliest phase.
            let array = combo
                .iter()
                .rev()
                .max_by_key(|s| s.iter().product::<i64>())
                .expect("nphases >= 1")
                .clone();
            for bounds in &self.bounds_grid {
                if !combo.iter().all(|s| Self::fits(s, bounds))
                    || self.symmetric_combo_duplicate(&combo, bounds)
                {
                    continue;
                }
                for &tile_scale in &self.tile_scales {
                    for backend in &self.backends {
                        out.push(DesignPoint {
                            array: array.clone(),
                            bounds: bounds.clone(),
                            tile_scale,
                            backend: backend.clone(),
                            schedule: ScheduleChoice::First,
                            phase_shapes: PhaseShapes::PerPhase(
                                combo.clone(),
                            ),
                        });
                    }
                }
            }
        }
        out
    }

    /// Is `combo` a transposed duplicate at these `bounds`? True only
    /// when mirroring **every** phase's shape at once — the global
    /// transposition, the only orientation change that maps a
    /// combination's objectives onto another's (transposing a single
    /// phase's shape changes that phase's energy/latency for real, per
    /// the per-phase axis's whole premise) — yields a lexicographically
    /// smaller combination whose shapes are all enumerated *and* fit
    /// the bounds. Like [`Self::symmetric_duplicate`], exact for
    /// dimension-swap-symmetric workloads and a documented
    /// approximation otherwise.
    pub(crate) fn symmetric_combo_duplicate(
        &self,
        combo: &[Vec<i64>],
        bounds: &[i64],
    ) -> bool {
        if !self.prune_symmetric {
            return false;
        }
        let mirror: Vec<Vec<i64>> = combo
            .iter()
            .map(|s| s.iter().rev().copied().collect())
            .collect();
        mirror.as_slice() < combo
            && mirror
                .iter()
                .all(|s| self.arrays.contains(s) && Self::fits(s, bounds))
    }

    /// Upper bound on the number of points [`Self::phase_points`] would
    /// emit for `nphases` phases (bounds-fit and symmetry pruning not
    /// applied) — lets callers refuse a combinatorial explosion with a
    /// clear message instead of launching an hours-long sweep or
    /// silently capping coverage.
    pub fn phase_point_estimate(&self, nphases: usize) -> u128 {
        let shapes = self.surviving_shapes().len() as u128;
        shapes
            .checked_pow(nphases as u32)
            .unwrap_or(u128::MAX)
            .saturating_mul(self.bounds_grid.len() as u128)
            .saturating_mul(self.tile_scales.len() as u128)
            .saturating_mul(self.backends.len() as u128)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_d_enumeration_respects_budget() {
        let s = DesignSpace::new()
            .with_arrays_2d(8)
            .with_bounds(vec![16, 16]);
        let pts = s.points();
        assert!(!pts.is_empty());
        assert!(pts.iter().all(|p| p.pes() <= 8));
        // (1,1) through (8,1) and (1,8) present; (3,3) pruned by budget.
        assert!(pts.iter().any(|p| p.array == vec![1, 1]));
        assert!(pts.iter().any(|p| p.array == vec![8, 1]));
        assert!(!pts.iter().any(|p| p.array == vec![3, 3]));
    }

    #[test]
    fn two_d_enumeration_never_visits_over_budget_shapes() {
        // The harmonic-sum enumeration must produce exactly the shapes
        // with t0·t1 ≤ budget — Σ_t0 ⌊budget/t0⌋ of them — without ever
        // materializing the quadratic grid.
        for budget in [1i64, 2, 7, 16] {
            let s = DesignSpace::new().with_arrays_2d(budget);
            let expect: i64 = (1..=budget).map(|t0| budget / t0).sum();
            assert_eq!(s.arrays.len() as i64, expect, "budget {budget}");
            assert!(s
                .arrays
                .iter()
                .all(|a| a[0] * a[1] <= budget));
        }
        assert!(DesignSpace::new().with_arrays_2d(0).arrays.is_empty());
    }

    #[test]
    fn duplicate_shapes_enumerate_once() {
        // Repeated with_arrays* calls must not make the explorer analyze
        // the same configuration twice.
        let s = DesignSpace::new()
            .with_arrays(vec![vec![2, 2], vec![4, 1]])
            .with_arrays(vec![vec![2, 2]])
            .with_arrays_2d(4)
            .with_bounds(vec![8, 8]);
        let pts = s.points();
        let mut labels: Vec<String> =
            pts.iter().map(|p| p.array_label()).collect();
        let before = labels.len();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), before, "duplicate design points emitted");
        // First occurrence wins: the explicit (2,2) leads the order.
        assert_eq!(pts[0].array, vec![2, 2]);
    }

    #[test]
    fn symmetry_pruning_keeps_canonical_only() {
        let s = DesignSpace::new()
            .with_arrays_2d(8)
            .with_bounds(vec![16, 16])
            .with_symmetry_pruning();
        let pts = s.points();
        assert!(pts.iter().any(|p| p.array == vec![2, 4]));
        assert!(!pts.iter().any(|p| p.array == vec![4, 2]));
        // Squares survive.
        assert!(pts.iter().any(|p| p.array == vec![2, 2]));
    }

    #[test]
    fn symmetry_pruning_keeps_orientation_whose_mirror_does_not_fit() {
        // Under rectangular bounds (16, 2) the canonical mirror (2,4)
        // does not fit (4 > 2), so (4,2) must survive the pruning.
        let s = DesignSpace::new()
            .with_arrays_2d(8)
            .with_bounds(vec![16, 2])
            .with_symmetry_pruning();
        let pts = s.points();
        assert!(pts.iter().any(|p| p.array == vec![4, 2]));
        assert!(!pts.iter().any(|p| p.array == vec![2, 4]));
    }

    #[test]
    fn shapes_larger_than_problem_pruned_per_bounds() {
        let s = DesignSpace::new()
            .with_arrays_2d(16)
            .with_bounds_grid(vec![vec![4, 4], vec![16, 16]]);
        let pts = s.points();
        // (8,1) fits N=16 but not N=4.
        assert!(pts
            .iter()
            .any(|p| p.array == vec![8, 1] && p.bounds == vec![16, 16]));
        assert!(!pts
            .iter()
            .any(|p| p.array == vec![8, 1] && p.bounds == vec![4, 4]));
    }

    #[test]
    fn axes_multiply() {
        let s = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds_sweep(&[8, 16], 2)
            .with_tile_scales(vec![1, 2])
            .with_backends(Backend::builtins());
        assert_eq!(s.points().len(), 2 * 2 * 4);
    }

    #[test]
    fn legacy_policy_axis_maps_to_backends() {
        let s = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8])
            .with_policies(Policy::ALL.to_vec());
        let pts = s.points();
        assert_eq!(pts.len(), 3);
        let names: Vec<&str> =
            pts.iter().map(|p| p.backend.name()).collect();
        assert_eq!(names, vec!["tcpa", "no-fd", "no-reuse"]);
    }

    #[test]
    fn empty_axes_yield_empty_sweep() {
        let s = DesignSpace::new().with_arrays_2d(0).with_bounds(vec![8]);
        assert!(s.points().is_empty());
        let s = DesignSpace::new().with_arrays(vec![vec![2]]);
        assert!(s.points().is_empty(), "no bounds → no points");
    }

    #[test]
    fn array_label_formats() {
        let p = DesignPoint {
            array: vec![8, 4],
            bounds: vec![64, 64],
            tile_scale: 1,
            backend: Backend::tcpa(),
            schedule: ScheduleChoice::First,
            phase_shapes: PhaseShapes::Uniform,
        };
        assert_eq!(p.array_label(), "8x4");
        assert_eq!(p.pes(), 32);
    }

    #[test]
    fn phase_shapes_labels_and_pe_budget() {
        assert_eq!(PhaseShapes::Uniform.label(), "uniform");
        let hetero =
            PhaseShapes::PerPhase(vec![vec![1, 4], vec![4, 1], vec![2, 2]]);
        assert_eq!(hetero.label(), "1x4|4x1|2x2");
        assert!(hetero.is_heterogeneous());
        // An all-equal explicit assignment is effectively uniform.
        let diag = PhaseShapes::PerPhase(vec![vec![2, 2], vec![2, 2]]);
        assert!(diag.is_uniform() && !diag.is_heterogeneous());
        assert!(PhaseShapes::Uniform.is_uniform());
        // Shared budget: sequential phases need max, not Σ, of their PEs.
        let p = DesignPoint {
            array: vec![4, 1],
            bounds: vec![8, 8],
            tile_scale: 1,
            backend: Backend::tcpa(),
            schedule: ScheduleChoice::First,
            phase_shapes: hetero,
        };
        assert_eq!(p.pes(), 4);
    }

    #[test]
    fn phase_points_cover_all_combinations_in_lexicographic_order() {
        let s = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1], vec![2, 2]])
            .with_bounds(vec![8, 8]);
        let pts = s.phase_points(2);
        assert_eq!(pts.len(), 9, "3 shapes, 2 phases → 3² combinations");
        let combos: Vec<Vec<Vec<i64>>> = pts
            .iter()
            .map(|p| match &p.phase_shapes {
                PhaseShapes::PerPhase(c) => c.clone(),
                other => panic!("expected per-phase shapes, got {other:?}"),
            })
            .collect();
        // Lexicographic by phase, phase 0 outermost; uniform diagonal
        // included.
        assert_eq!(combos[0], vec![vec![1, 2], vec![1, 2]]);
        assert_eq!(combos[1], vec![vec![1, 2], vec![2, 1]]);
        assert_eq!(combos[3], vec![vec![2, 1], vec![1, 2]]);
        let mut sorted = combos.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 9, "no duplicate combinations");
        // The provisioned shape is the widest phase's (max PEs, earliest
        // phase among ties).
        let hetero = pts
            .iter()
            .find(|p| {
                p.phase_shapes
                    == PhaseShapes::PerPhase(vec![vec![1, 2], vec![2, 2]])
            })
            .unwrap();
        assert_eq!(hetero.array, vec![2, 2]);
        assert_eq!(hetero.pes(), 4);
        let tied = &pts[1]; // (1,2) then (2,1): both 2 PEs.
        assert_eq!(tied.array, vec![1, 2], "PE ties resolve to phase 0");
        // Single-phase per-phase enumeration degenerates to one shape
        // per point.
        assert_eq!(s.phase_points(1).len(), 3);
    }

    #[test]
    fn phase_points_prune_budget_fits_and_symmetry_phase_wise() {
        // Budget: (2,2) pruned at max_pes 2 before combining.
        let s = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1], vec![2, 2]])
            .with_max_pes(2)
            .with_bounds(vec![8, 8]);
        assert_eq!(s.phase_points(2).len(), 4);
        assert_eq!(s.phase_point_estimate(2), 4);
        // Fits: a phase shape exceeding the bounds removes the whole
        // combination for those bounds only.
        let s = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![4, 1]])
            .with_bounds_grid(vec![vec![2, 2], vec![8, 8]]);
        let pts = s.phase_points(2);
        assert!(pts
            .iter()
            .filter(|p| p.bounds == vec![2, 2])
            .all(|p| p.phase_shapes
                == PhaseShapes::PerPhase(vec![vec![1, 2], vec![1, 2]])));
        assert_eq!(
            pts.iter().filter(|p| p.bounds == vec![8, 8]).count(),
            4
        );
        // Symmetry: combinations deduplicate up to *global*
        // transposition only — one representative per mirror orbit.
        // Heterogeneous assignments like (1,2)|(2,1) survive (their
        // objectives are NOT equal to any uniform combo's; only the
        // all-phases mirror (2,1)|(1,2) is the duplicate).
        let s = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1]])
            .with_bounds(vec![8, 8])
            .with_symmetry_pruning();
        let combos: Vec<PhaseShapes> = s
            .phase_points(2)
            .into_iter()
            .map(|p| p.phase_shapes)
            .collect();
        assert_eq!(
            combos,
            vec![
                PhaseShapes::PerPhase(vec![vec![1, 2], vec![1, 2]]),
                PhaseShapes::PerPhase(vec![vec![1, 2], vec![2, 1]]),
            ],
            "one canonical representative per global-transposition orbit"
        );
        // A mirror whose shape does not fit keeps the original: under
        // bounds (8, 1) the combo (2,1)|(2,1) survives because its
        // mirror (1,2)|(1,2) does not fit (2 > 1 in dim 1).
        let s = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1]])
            .with_bounds(vec![8, 1])
            .with_symmetry_pruning();
        let pts = s.phase_points(2);
        assert_eq!(pts.len(), 1);
        assert_eq!(
            pts[0].phase_shapes,
            PhaseShapes::PerPhase(vec![vec![2, 1], vec![2, 1]])
        );
    }

    #[test]
    fn phase_point_estimate_bounds_the_enumeration() {
        let s = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds_sweep(&[8, 16], 2)
            .with_tile_scales(vec![1, 2])
            .with_backends(Backend::builtins());
        let est = s.phase_point_estimate(3);
        assert_eq!(est, 8u128.pow(3) * 2 * 2 * 4);
        assert!(est >= s.phase_points(3).len() as u128);
        // Empty shape axis → zero estimate and zero points.
        let empty = DesignSpace::new().with_bounds(vec![8, 8]);
        assert_eq!(empty.phase_point_estimate(2), 0);
        assert!(empty.phase_points(2).is_empty());
    }

    #[test]
    fn schedule_choice_labels_and_defaults() {
        assert_eq!(ScheduleChoice::First.label(), "first");
        assert_eq!(ScheduleChoice::Indices(vec![1]).label(), "s1");
        assert_eq!(ScheduleChoice::Indices(vec![1, 0]).label(), "s1.0");
        assert!(ScheduleChoice::First.is_default());
        assert!(ScheduleChoice::Indices(vec![0, 0]).is_default());
        assert!(!ScheduleChoice::Indices(vec![0, 2]).is_default());
        // Policy → per-phase cap mapping the explorer relies on.
        assert_eq!(SchedulePolicy::First.per_phase_cap(), Some(1));
        assert_eq!(SchedulePolicy::All.per_phase_cap(), None);
        assert_eq!(SchedulePolicy::Limit(3).per_phase_cap(), Some(3));
        // Limit(0) — reachable through the public `schedules` field
        // despite with_schedules' assert — clamps instead of silently
        // erasing every point from the sweep.
        assert_eq!(SchedulePolicy::Limit(0).per_phase_cap(), Some(1));
    }

    #[test]
    fn shard_parse_label_and_validation() {
        assert_eq!(Shard::parse("2/3"), Ok(Shard { index: 2, count: 3 }));
        assert_eq!(Shard::parse("2/3").unwrap().label(), "2/3");
        assert_eq!(Shard::solo(), Shard { index: 1, count: 1 });
        assert!(Shard::solo().is_solo());
        assert!(!Shard::parse("1/2").unwrap().is_solo());
        assert_eq!(Shard::default(), Shard::solo());
        for bad in ["", "2", "0/3", "4/3", "a/3", "2/b", "2/0", "/"] {
            assert!(Shard::parse(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn shards_partition_every_enumeration_exactly() {
        // The stability invariant `dse merge` relies on: for any n, the
        // owned index sets of shards 1..=n partition 0..len with no
        // overlap, and ownership is pure round-robin.
        let s = DesignSpace::new()
            .with_arrays_2d(8)
            .with_bounds_sweep(&[8, 16], 2)
            .with_backends(Backend::builtins());
        let len = s.points().len();
        assert!(len > 8);
        for n in [1usize, 2, 3, 4, 7] {
            let mut owners = vec![0usize; len];
            for i in 1..=n {
                let shard = Shard { index: i, count: n };
                for (idx, o) in owners.iter_mut().enumerate() {
                    if shard.owns(idx) {
                        *o += 1;
                        assert_eq!(Shard::owner_of(idx, n), shard);
                    }
                }
            }
            assert!(
                owners.iter().all(|&o| o == 1),
                "every index owned exactly once for n = {n}"
            );
        }
        // Round-robin, not block: consecutive indices go to consecutive
        // shards, so every shard sees every backend/bounds group.
        let two = Shard { index: 2, count: 3 };
        assert!(!two.owns(0) && two.owns(1) && !two.owns(2) && two.owns(4));
    }

    #[test]
    fn strategy_defaults_to_exhaustive_and_is_a_space_axis() {
        let s = DesignSpace::new();
        assert_eq!(s.strategy, Strategy::Exhaustive);
        let s = s.with_strategy(Strategy::beam(4));
        assert!(matches!(s.strategy, Strategy::Beam { width: 4, .. }));
        // The strategy is part of the Debug form and therefore of the
        // journal's space fingerprint: beam and exhaustive journals can
        // never be confused for one another.
        assert!(format!("{s:?}").contains("Beam"));
    }

    #[test]
    fn points_emit_default_schedule_choice() {
        // The space never expands the schedule axis itself: every base
        // point carries the default choice regardless of policy.
        let s = DesignSpace::new()
            .with_arrays(vec![vec![2, 2]])
            .with_bounds(vec![8, 8])
            .with_schedules(SchedulePolicy::All);
        let pts = s.points();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].schedule, ScheduleChoice::First);
    }
}
