//! Report emitters: CSV tables, markdown tables, and ASCII line charts for
//! regenerating the paper's tables and figures (the offline vendor tree
//! has no plotting or serde crates; these hand-rolled emitters are all the
//! benches and the `figures` subcommand need).

pub mod chart;
pub mod csv;
pub mod frontier;

pub use chart::ascii_chart;
pub use csv::{markdown_table, write_csv, CsvTable};
pub use frontier::{
    dse_frontier_markdown, dse_frontier_table, dse_points_table,
    write_dse_report,
};
