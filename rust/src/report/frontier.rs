//! CSV / markdown emitters for design-space-exploration results: the
//! full point cloud and the multi-objective Pareto frontier.

use std::path::Path;

use crate::dse::{EvaluatedPoint, ExploreResult, SimVerify};

use super::csv::{write_csv, CsvTable};

fn fmt_bounds(b: &[i64]) -> String {
    b.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
}

/// The `sim_cycles` cell: empty when the point was not sim-verified,
/// the event-engine cycle count when confirmed, and a loud marker when
/// simulation disagreed with the symbolic prediction.
fn fmt_sim_verify(v: Option<&SimVerify>) -> String {
    match v {
        None => String::new(),
        Some(v) if v.confirmed() => v.cycles.to_string(),
        Some(v) => format!("{} DIVERGED({})", v.cycles, v.divergences.len()),
    }
}

fn point_row(
    p: &EvaluatedPoint,
    on_frontier: bool,
    knee: bool,
    sim: Option<&SimVerify>,
) -> Vec<String> {
    vec![
        p.point.array_label(),
        // Per-phase shape assignment: `uniform`, or one shape per phase
        // joined by `|` (e.g. `1x4|4x1`) under the per-phase axis —
        // there, `array` shows the provisioned (widest-phase) shape and
        // this column tells the assignments apart.
        p.point.phase_shapes.label(),
        p.pes.to_string(),
        fmt_bounds(&p.point.bounds),
        p.point.tile_scale.to_string(),
        p.point.backend.name().to_string(),
        // Schedule candidate: choice id + the intra-tile dimension order
        // it denotes, e.g. `first (j0j1)` or `s1 (j1j0)`. With the
        // schedule axis active, rows of one shape differ here and in
        // latency/EDP alone.
        format!("{} ({})", p.point.schedule.label(), p.schedule_label),
        format!("{:.3}", p.energy_pj),
        format!("{:.3}", p.dram_pj),
        p.latency_cycles.to_string(),
        format!("{:.6e}", p.edp),
        if on_frontier { "yes" } else { "no" }.to_string(),
        if knee { "knee" } else { "" }.to_string(),
        fmt_sim_verify(sim),
    ]
}

const HEADER: [&str; 14] = [
    "array",
    "phases",
    "pes",
    "bounds",
    "tile_scale",
    "backend",
    "schedule",
    "energy_pj",
    "dram_pj",
    "latency_cycles",
    "edp",
    "pareto",
    "knee",
    // Event-engine confirmation (`dse --sim-verify-frontier`); empty
    // when the verify pass did not run or the point is off-frontier.
    "sim_cycles",
];

fn is_knee(res: &ExploreResult, i: usize) -> bool {
    res.groups.iter().any(|g| g.knee == Some(i))
}

/// Every evaluated point, frontier membership annotated.
pub fn dse_points_table(res: &ExploreResult) -> CsvTable {
    let mut t = CsvTable::new(HEADER.to_vec());
    for (i, p) in res.points.iter().enumerate() {
        t.push(point_row(
            p,
            res.frontier.contains(&i),
            is_knee(res, i),
            res.sim_verify.get(&i),
        ));
    }
    t
}

/// Only the non-dominated points, grouped by scenario, in enumeration
/// order within each group.
pub fn dse_frontier_table(res: &ExploreResult) -> CsvTable {
    let mut t = CsvTable::new(HEADER.to_vec());
    for g in &res.groups {
        for &i in &g.frontier {
            t.push(point_row(
                &res.points[i],
                true,
                is_knee(res, i),
                res.sim_verify.get(&i),
            ));
        }
    }
    t
}

/// Markdown rendering: a run summary plus one frontier table per
/// (bounds, backend) scenario.
pub fn dse_frontier_markdown(res: &ExploreResult) -> String {
    use std::fmt::Write as _;
    // A cancelled sweep's frontier only covers the committed prefix —
    // say so in the header, loudly, before anyone trusts the tables.
    let partial = match res.cancelled {
        Some(reason) => format!(
            " — partial ({}/{} points): {}",
            res.completed,
            res.total,
            reason.label()
        ),
        None => String::new(),
    };
    let mut out = format!(
        "## {} — Pareto frontiers ({} of {} points, {} failed)\
         {partial}\n\n\
         objectives minimized: energy [pJ], latency [cycles], PEs, \
         DRAM [pJ]\n",
        res.workload,
        res.frontier.len(),
        res.points.len(),
        res.failures.len(),
    );
    // Provenance lines appear only off the defaults, so exhaustive
    // unsharded reports — including merged shard reports — stay
    // byte-identical to what earlier versions emitted.
    if !res.strategy.is_exhaustive() {
        let _ = writeln!(
            out,
            "strategy: {} (heuristic subset; rerun with --strategy \
             exhaustive for the oracle)",
            res.strategy.label()
        );
    }
    if let Some(shard) = res.shard {
        let _ = writeln!(
            out,
            "shard: {} (this slice only; `dse merge` folds all shards \
             into the full frontier)",
            shard.label()
        );
    }
    for g in &res.groups {
        let mut t = CsvTable::new(HEADER.to_vec());
        for &i in &g.frontier {
            t.push(point_row(
                &res.points[i],
                true,
                is_knee(res, i),
                res.sim_verify.get(&i),
            ));
        }
        let _ = write!(
            out,
            "\n### bounds {} · backend {}\n\n{}",
            fmt_bounds(&g.bounds),
            g.backend.name(),
            t.to_markdown()
        );
    }
    out
}

/// Write `<stem>_points.csv`, `<stem>_frontier.csv` and
/// `<stem>_frontier.md` into `dir`.
pub fn write_dse_report(
    res: &ExploreResult,
    dir: &Path,
    stem: &str,
) -> std::io::Result<()> {
    write_csv(&dse_points_table(res), dir, &format!("{stem}_points"))?;
    write_csv(&dse_frontier_table(res), dir, &format!("{stem}_frontier"))?;
    std::fs::write(
        dir.join(format!("{stem}_frontier.md")),
        dse_frontier_markdown(res),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{explore, DesignSpace, ExploreConfig};
    use crate::workloads;

    fn small_result() -> ExploreResult {
        let wl = workloads::by_name("gesummv").unwrap();
        let space = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds(vec![8, 8]);
        explore(&wl, &space, &ExploreConfig::default())
    }

    #[test]
    fn tables_cover_all_points_and_frontier() {
        let res = small_result();
        let all = dse_points_table(&res);
        assert_eq!(all.rows.len(), res.points.len());
        let front = dse_frontier_table(&res);
        assert_eq!(front.rows.len(), res.frontier.len());
        assert!(front.rows.iter().all(|r| r[11] == "yes"));
        // Exactly one knee across the full table.
        let knees =
            all.rows.iter().filter(|r| r[12] == "knee").count();
        assert_eq!(knees, 1);
        // Default policies: every row shows the scheduler's pick and the
        // uniform shape assignment.
        assert!(all.rows.iter().all(|r| r[6].starts_with("first (")));
        assert!(all.rows.iter().all(|r| r[1] == "uniform"));
    }

    #[test]
    fn sim_verify_column_annotates_frontier_rows() {
        use crate::dse::{sim_verify_frontier, AnalysisCache, SimVerify};
        let _env = crate::dse::verify::env_guard();
        let wl = workloads::by_name("gesummv").unwrap();
        let cache = AnalysisCache::new();
        let space = DesignSpace::new()
            .with_arrays_2d(4)
            .with_bounds(vec![8, 8]);
        let mut res = crate::dse::explore_with_cache(
            &wl,
            &space,
            &ExploreConfig::default(),
            &cache,
        );
        // Before the pass: the column exists but is empty everywhere.
        let before = dse_points_table(&res);
        assert_eq!(before.header[13], "sim_cycles");
        assert!(before.rows.iter().all(|r| r[13].is_empty()));
        sim_verify_frontier(&wl, &mut res, &cache);
        let all = dse_points_table(&res);
        for (i, r) in all.rows.iter().enumerate() {
            if res.frontier.contains(&i) {
                assert_eq!(r[13], res.points[i].latency_cycles.to_string());
            } else {
                assert!(r[13].is_empty());
            }
        }
        // A divergence renders loudly.
        let fi = res.frontier[0];
        res.sim_verify.insert(
            fi,
            SimVerify {
                cycles: 999,
                divergences: vec!["synthetic".into()],
            },
        );
        let loud = dse_frontier_table(&res);
        assert!(loud
            .rows
            .iter()
            .any(|r| r[13] == "999 DIVERGED(1)"));
    }

    #[test]
    fn markdown_mentions_objectives_and_workload() {
        let md = dse_frontier_markdown(&small_result());
        assert!(md.contains("gesummv"));
        assert!(md.contains("objectives minimized"));
        assert!(md.contains("| array |"));
        assert!(md.contains("| schedule |"));
        assert!(
            !md.contains("partial ("),
            "a complete sweep must not be marked partial"
        );
    }

    #[test]
    fn markdown_carries_strategy_and_shard_provenance() {
        use crate::dse::{Shard, Strategy};
        // Defaults: no provenance lines at all (byte-compat with
        // pre-strategy reports, and with merged shard reports).
        let mut res = small_result();
        let md = dse_frontier_markdown(&res);
        assert!(!md.contains("strategy:"), "{md}");
        assert!(!md.contains("shard:"), "{md}");
        res.strategy = Strategy::beam(4);
        res.shard = Some(Shard::parse("2/3").unwrap());
        let md = dse_frontier_markdown(&res);
        assert!(md.contains("strategy: beam:4"), "{md}");
        assert!(md.contains("shard: 2/3"), "{md}");
    }

    #[test]
    fn markdown_marks_cancelled_sweeps_partial_in_the_header() {
        let mut res = small_result();
        res.cancelled = Some(crate::cancel::CancelReason::Deadline);
        res.completed = 3;
        res.total = 8;
        let md = dse_frontier_markdown(&res);
        assert!(
            md.contains("partial (3/8 points): deadline exceeded"),
            "{md}"
        );
    }

    #[test]
    fn schedule_axis_rows_distinguish_candidates() {
        use crate::dse::SchedulePolicy;
        let wl = workloads::by_name("gesummv").unwrap();
        // 1×4 array: two causal permutations with different latency
        // (see explore.rs tests), so the sweep emits two rows per
        // (bounds, backend) differing in the schedule column.
        let space = DesignSpace::new()
            .with_arrays(vec![vec![1, 4]])
            .with_bounds(vec![16, 16])
            .with_schedules(SchedulePolicy::All);
        let res = explore(&wl, &space, &ExploreConfig::default());
        let all = dse_points_table(&res);
        assert_eq!(all.rows.len(), 2);
        assert_eq!(all.rows[0][6], "s0 (j0j1)");
        assert_eq!(all.rows[1][6], "s1 (j1j0)");
        // Same shape and energy, distinguished by schedule + latency.
        assert_eq!(all.rows[0][7], all.rows[1][7]);
        assert_ne!(all.rows[0][9], all.rows[1][9]);
    }

    #[test]
    fn phase_axis_rows_show_the_assignment() {
        use crate::dse::{PhasePolicy, PhaseShapes};
        let wl = workloads::by_name("atax").unwrap();
        let space = DesignSpace::new()
            .with_arrays(vec![vec![1, 2], vec![2, 1]])
            .with_bounds(vec![8, 8])
            .with_phase_shapes(PhasePolicy::PerPhase);
        let res = explore(&wl, &space, &ExploreConfig::default());
        let all = dse_points_table(&res);
        assert_eq!(all.rows.len(), 4, "2 shapes × 2 phases");
        let phases_col: Vec<&str> =
            all.rows.iter().map(|r| r[1].as_str()).collect();
        assert_eq!(
            phases_col,
            vec!["1x2|1x2", "1x2|2x1", "2x1|1x2", "2x1|2x1"]
        );
        // Heterogeneous rows label the provisioned shape in the array
        // column (PE ties resolve to the earliest phase).
        let hetero = res
            .points
            .iter()
            .zip(&all.rows)
            .find(|(p, _)| p.point.phase_shapes.is_heterogeneous())
            .unwrap();
        assert!(matches!(
            hetero.0.point.phase_shapes,
            PhaseShapes::PerPhase(_)
        ));
        assert_eq!(hetero.1[0], hetero.0.point.array_label());
    }
}
