//! Minimal CSV/markdown table emitters.

use std::fmt::Write as _;
use std::path::Path;

/// A simple in-memory table: header plus stringly-typed rows.
#[derive(Debug, Clone, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// Create with a header.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        CsvTable {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn push<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "row width mismatch");
        self.rows.push(row);
    }

    /// Render as CSV (quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |cell: &str| {
            if cell.contains(',') || cell.contains('"') {
                format!("\"{}\"", cell.replace('"', "\"\""))
            } else {
                cell.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        markdown_table(&self.header, &self.rows)
    }
}

/// Render header + rows as a markdown table.
pub fn markdown_table(header: &[String], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "| {} |", header.join(" | "));
    let _ = writeln!(
        out,
        "|{}|",
        header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
    );
    for r in rows {
        let _ = writeln!(out, "| {} |", r.join(" | "));
    }
    out
}

/// Write a table to `<dir>/<name>.csv`, creating the directory.
pub fn write_csv(table: &CsvTable, dir: &Path, name: &str) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.csv")), table.to_csv())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = CsvTable::new(vec!["a", "b"]);
        t.push(vec!["1", "x,y"]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    fn markdown_render() {
        let mut t = CsvTable::new(vec!["n", "v"]);
        t.push(vec!["8", "1.5"]);
        let md = t.to_markdown();
        assert!(md.starts_with("| n | v |"));
        assert!(md.contains("| 8 | 1.5 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(vec!["a"]);
        t.push(vec!["1", "2"]);
    }
}
