//! ASCII line charts for terminal rendering of the paper's figures
//! (log-scale aware, multiple series).

/// Render series as an ASCII chart. `series` = (label, points); points are
/// (x, y). `logy` plots log10(y).
pub fn ascii_chart(
    title: &str,
    series: &[(&str, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
    logy: bool,
) -> String {
    let marks = ['*', 'o', '+', 'x', '#', '@'];
    let tx = |v: f64| v;
    let ty = |v: f64| if logy { v.max(1e-12).log10() } else { v };
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().map(|&(x, y)| (tx(x), ty(y))))
        .collect();
    if all.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let (mut x0, mut x1) = (f64::MAX, f64::MIN);
    let (mut y0, mut y1) = (f64::MAX, f64::MIN);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let m = marks[si % marks.len()];
        for &(x, y) in pts {
            let gx = (((tx(x) - x0) / (x1 - x0)) * (width - 1) as f64).round()
                as usize;
            let gy = (((ty(y) - y0) / (y1 - y0)) * (height - 1) as f64)
                .round() as usize;
            let gy = height - 1 - gy.min(height - 1);
            grid[gy][gx.min(width - 1)] = m;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let ylab = |v: f64| if logy { format!("1e{v:.1}") } else { format!("{v:.3e}") };
    for (r, row) in grid.iter().enumerate() {
        let label = if r == 0 {
            ylab(y1)
        } else if r == height - 1 {
            ylab(y0)
        } else {
            String::new()
        };
        out.push_str(&format!("{label:>10} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>10} +{}\n{:>12}{:<.0}{:>width$.0}\n",
        "",
        "-".repeat(width),
        "",
        x0,
        x1,
        width = width - 2
    ));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {label}\n", marks[si % marks.len()]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_and_legend() {
        let s = ascii_chart(
            "t",
            &[("lin", vec![(1.0, 1.0), (2.0, 2.0)]), ("quad", vec![(1.0, 1.0), (2.0, 4.0)])],
            40,
            10,
            false,
        );
        assert!(s.contains('*'));
        assert!(s.contains('o'));
        assert!(s.contains("lin"));
        assert!(s.contains("quad"));
    }

    #[test]
    fn empty_series_safe() {
        let s = ascii_chart("t", &[("e", vec![])], 10, 5, true);
        assert!(s.contains("no data"));
    }

    #[test]
    fn single_point_no_panic() {
        let s = ascii_chart("t", &[("p", vec![(1.0, 5.0)])], 10, 5, false);
        assert!(s.contains('*'));
    }
}
