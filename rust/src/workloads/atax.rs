//! ATAX (PolyBench): `y = Aᵀ(A·x)` as a two-phase workload.
//!
//! Phase 1 computes `TMP = A·x` (accumulation along `i1`), phase 2 computes
//! `Y = Aᵀ·TMP` (accumulation along `i0`). The `TMP` tensor produced by
//! phase 1 streams back to DRAM and re-enters as an input of phase 2 —
//! exactly the host-mediated inter-kernel data flow of a TCPA deployment.

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Phase 1: `TMP[i0] = Σ_{i1} A[i0,i1]·X[i1]`.
pub fn atax_phase1() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("atax_p1", nd);
    b.tensor("A", &[0, 1]).tensor("X", &[1]).tensor("TMP", &[0]);
    b.propagate("xx", "X", IndexMap::select(&[1], nd), 0);
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("xx", nd),
        ],
        vec![],
    );
    b.acc_chain("s", "m", 1);
    let top = b.eq_top(1);
    b.stmt(
        Lhs::Tensor { name: "TMP".into(), map: IndexMap::select(&[0], nd) },
        Op::Copy,
        vec![Operand::var0("s", nd)],
        top,
    );
    b.build()
}

/// Phase 2: `Y[i1] = Σ_{i0} A[i0,i1]·TMP[i0]`.
pub fn atax_phase2() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("atax_p2", nd);
    b.tensor("A", &[0, 1]).tensor("TMP", &[0]).tensor("Y", &[1]);
    b.propagate("tt", "TMP", IndexMap::select(&[0], nd), 1);
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("tt", nd),
        ],
        vec![],
    );
    b.acc_chain("s", "m", 0);
    let top = b.eq_top(0);
    b.stmt(
        Lhs::Tensor { name: "Y".into(), map: IndexMap::select(&[1], nd) },
        Op::Copy,
        vec![Operand::var0("s", nd)],
        top,
    );
    b.build()
}

/// The two-phase ATAX workload.
pub fn atax() -> Workload {
    Workload { name: "atax".into(), phases: vec![atax_phase1(), atax_phase2()] }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret_workload;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn phases_validate() {
        for p in atax().phases {
            assert!(validate(&p).is_empty(), "{}: {:?}", p.name, validate(&p));
        }
    }

    #[test]
    fn atax_functional() {
        let wl = atax();
        let (n0, n1) = (4i64, 3i64);
        let params = vec![vec![n0, n1, 1, 1], vec![n0, n1, 1, 1]];
        let inputs = synth_inputs(&[
            ("A".into(), vec![n0, n1]),
            ("X".into(), vec![n1]),
        ]);
        let out = interpret_workload(&wl, &params, &inputs);
        let y = &out["Y"];
        // reference y = A^T (A x)
        let mut tmp = vec![0.0f32; n0 as usize];
        for i in 0..n0 {
            for j in 0..n1 {
                tmp[i as usize] +=
                    inputs["A"].get(&[i, j]) * inputs["X"].get(&[j]);
            }
        }
        for j in 0..n1 {
            let mut acc = 0.0f32;
            for i in 0..n0 {
                acc += inputs["A"].get(&[i, j]) * tmp[i as usize];
            }
            assert!(
                (y.get(&[j]) - acc).abs() < 1e-3,
                "Y[{j}] = {} vs {acc}",
                y.get(&[j])
            );
        }
        // TMP is also produced (phase-1 output).
        assert_eq!(out["TMP"].shape, vec![n0]);
    }
}
