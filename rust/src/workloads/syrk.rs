//! SYRK (PolyBench): symmetric rank-k update `C = A·Aᵀ + C_in` over the
//! full rectangular index set (the triangular-only update of PolyBench is
//! relaxed to rectangular — see DESIGN.md §6; the access-count structure
//! per iteration is identical). 3-deep nest `(i0, i1, i2)` with `i0, i1`
//! indexing `C` (both bounded by the matrix height) and `i2` the reduction.
//! Evaluated with `N0 = N1`.

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Build the SYRK PRA (3-deep nest).
pub fn syrk_pra() -> Pra {
    let nd = 3;
    let mut b = PraBuilder::new("syrk", nd);
    // The transposed propagation reads A[i1, i2]: in bounds only for
    // N1 = N0 (C is square).
    b.require_equal_bounds(0, 1);
    b.tensor("A", &[0, 2]) // A[N0, N2]
        .tensor("Cin", &[0, 1])
        .tensor("C", &[0, 1]);
    // a[i] propagates A[i0, i2] along i1; at[i] propagates A[i1, i2] along i0.
    b.propagate("a", "A", IndexMap::select(&[0, 2], nd), 1);
    b.propagate("at", "A", IndexMap::select(&[1, 2], nd), 0);
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![Operand::var0("a", nd), Operand::var0("at", nd)],
        vec![],
    );
    b.acc_chain("s", "m", 2);
    // C[i0,i1] = s + Cin[i0,i1] at i2 = N2 − 1 (computational output).
    let top = b.eq_top(2);
    b.stmt(
        Lhs::Tensor { name: "C".into(), map: IndexMap::select(&[0, 1], nd) },
        Op::Add,
        vec![
            Operand::var0("s", nd),
            Operand::tensor("Cin", IndexMap::select(&[0, 1], nd)),
        ],
        top,
    );
    b.build()
}

/// Single-phase workload wrapper.
pub fn syrk() -> Workload {
    Workload::single(syrk_pra())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn validates() {
        let p = syrk_pra();
        assert!(validate(&p).is_empty(), "{:?}", validate(&p));
    }

    #[test]
    fn syrk_functional() {
        let pra = syrk_pra();
        let (n, nk) = (4i64, 3i64);
        let params = [n, n, nk, 1, 1, 1];
        let inputs = synth_inputs(&[
            ("A".into(), vec![n, nk]),
            ("Cin".into(), vec![n, n]),
        ]);
        let out = interpret(&pra, &params, &inputs);
        for i in 0..n {
            for j in 0..n {
                let mut acc = inputs["Cin"].get(&[i, j]);
                for k in 0..nk {
                    acc += inputs["A"].get(&[i, k]) * inputs["A"].get(&[j, k]);
                }
                assert!(
                    (out["C"].get(&[i, j]) - acc).abs() < 1e-4,
                    "C[{i},{j}] {} vs {acc}",
                    out["C"].get(&[i, j])
                );
            }
        }
    }
}
