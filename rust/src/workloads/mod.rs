//! The paper's evaluation workloads (§V): eight PolyBench kernels expressed
//! as PRAs, plus tensors, synthetic inputs, and a lexicographic functional
//! interpreter used as the in-crate golden model.

pub mod atax;
pub mod bicg;
pub mod builder;
pub mod doitgen;
pub mod gemm;
pub mod gemver;
pub mod gesummv;
pub mod interp;
pub mod jacobi1d;
pub mod k2mm;
pub mod mvt;
pub mod syrk;
pub mod tensor;
pub mod text;

pub use builder::PraBuilder;
pub use interp::{interpret, interpret_workload};
pub use tensor::{synth_inputs, synth_value, Tensor, TensorEnv};

use crate::pra::Workload;

use crate::pra::classify::{classify, VarClass};

/// Declarations (name, concrete shape) of the *external* input tensors a
/// workload needs, given per-phase parameter vectors. Tensors produced by
/// an earlier phase (e.g. ATAX's `TMP`) are not inputs.
pub fn workload_input_decls(
    wl: &Workload,
    params: &[Vec<i64>],
) -> Vec<(String, Vec<i64>)> {
    let mut produced = std::collections::BTreeSet::new();
    let mut decls: Vec<(String, Vec<i64>)> = Vec::new();
    for (phase, p) in wl.phases.iter().zip(params) {
        let cls = classify(phase);
        for (name, c) in &cls {
            if *c == VarClass::Input
                && !produced.contains(name)
                && !decls.iter().any(|(n, _)| n == name)
            {
                let decl = phase
                    .tensor(name)
                    .unwrap_or_else(|| panic!("{name} not declared"));
                decls.push((name.clone(), decl.concrete_shape(p)));
            }
            if *c == VarClass::Output {
                produced.insert(name.clone());
            }
        }
    }
    decls
}

/// Synthesize deterministic inputs for a workload.
pub fn workload_inputs(wl: &Workload, params: &[Vec<i64>]) -> TensorEnv {
    synth_inputs(&workload_input_decls(wl, params))
}

/// A deliberately *unschedulable* two-statement PRA: its dependence
/// vectors `(1,−1)` and `(−1,1)` admit no causal lexicographic order,
/// so `find_schedule` must reject it. A counterexample fixture shared
/// by the scheduler, DSE-cache and failure-injection tests.
pub fn twist_unschedulable() -> Workload {
    use crate::polyhedral::ParamSpace;
    use crate::pra::ir::{Lhs, Op, Operand, Pra, Statement};
    Workload::single(Pra {
        name: "twist".into(),
        ndims: 2,
        space: ParamSpace::loop_nest(2),
        statements: vec![
            Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Copy,
                args: vec![Operand::var("b", vec![1, -1])],
                cond: vec![],
            },
            Statement {
                name: "S2".into(),
                lhs: Lhs::Var("b".into()),
                op: Op::Copy,
                args: vec![Operand::var("a", vec![-1, 1])],
                cond: vec![],
            },
        ],
        tensors: vec![],
        requires: vec![],
    })
}


/// All benchmark workloads: the paper's eight plus the doitgen (4-deep)
/// and gemver (3-phase) extensions.
pub fn all() -> Vec<Workload> {
    vec![
        Workload::single(gesummv::gesummv()),
        Workload::single(gemm::gemm()),
        atax::atax(),
        bicg::bicg(),
        mvt::mvt(),
        syrk::syrk(),
        k2mm::k2mm(),
        jacobi1d::jacobi1d(),
        doitgen::doitgen(),
        gemver::gemver(),
    ]
}

/// Look up a workload by name.
pub fn by_name(name: &str) -> Option<Workload> {
    all().into_iter().find(|w| w.name == name)
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_has_eight() {
        let names: Vec<String> =
            super::all().iter().map(|w| w.name.clone()).collect();
        assert_eq!(names.len(), 10);
        assert!(super::by_name("gesummv").is_some());
        assert!(super::by_name("gemm").is_some());
        assert!(super::by_name("nope").is_none());
    }
}
