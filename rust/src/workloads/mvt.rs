//! MVT (PolyBench): `X1 = X1_in + A·Y1` and `X2 = X2_in + Aᵀ·Y2`, fused
//! into one 2-deep PRA. Both accumulation chains run along `i1`; the second
//! product reads `A` transposed (`A[i1, i0]`). The `+ X_in` update happens
//! in the output statements, which therefore are *computational* output
//! statements (unlike GESUMMV's copy-out) — exercising the
//! DRAM+IOb+OD-with-compute case of the energy model.

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Build the fused MVT PRA.
pub fn mvt_pra() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("mvt", nd);
    // The transposed read A[i1, i0] is in bounds only on square problems.
    b.require_equal_bounds(0, 1);
    b.tensor("A", &[0, 1])
        .tensor("Y1", &[1])
        .tensor("Y2", &[1])
        .tensor("X1in", &[0])
        .tensor("X2in", &[0])
        .tensor("X1", &[0])
        .tensor("X2", &[0]);
    // y1/y2 propagate along i0.
    b.propagate("v1", "Y1", IndexMap::select(&[1], nd), 0);
    b.propagate("v2", "Y2", IndexMap::select(&[1], nd), 0);
    // products: m1 = A[i0,i1]·v1, m2 = A[i1,i0]·v2 (transposed read).
    b.stmt(
        Lhs::Var("m1".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("v1", nd),
        ],
        vec![],
    );
    b.stmt(
        Lhs::Var("m2".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::select(&[1, 0], nd)),
            Operand::var0("v2", nd),
        ],
        vec![],
    );
    b.acc_chain("s1", "m1", 1);
    b.acc_chain("s2", "m2", 1);
    // Outputs at i1 = N1 − 1 add the DRAM-resident inputs X1in/X2in.
    let top = b.eq_top(1);
    b.stmt(
        Lhs::Tensor { name: "X1".into(), map: IndexMap::select(&[0], nd) },
        Op::Add,
        vec![
            Operand::var0("s1", nd),
            Operand::tensor("X1in", IndexMap::select(&[0], nd)),
        ],
        top.clone(),
    );
    b.stmt(
        Lhs::Tensor { name: "X2".into(), map: IndexMap::select(&[0], nd) },
        Op::Add,
        vec![
            Operand::var0("s2", nd),
            Operand::tensor("X2in", IndexMap::select(&[0], nd)),
        ],
        top,
    );
    b.build()
}

/// Single-phase workload wrapper.
pub fn mvt() -> Workload {
    Workload::single(mvt_pra())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn validates() {
        let p = mvt_pra();
        assert!(validate(&p).is_empty(), "{:?}", validate(&p));
    }

    #[test]
    fn mvt_functional_square() {
        // MVT is square in PolyBench (A: N×N); the transposed read A[i1,i0]
        // requires N0 = N1, so the workload is always evaluated square.
        let pra = mvt_pra();
        let n = 4i64;
        let params = [n, n, 1, 1];
        let inputs = synth_inputs(&[
            ("A".into(), vec![n, n]),
            ("Y1".into(), vec![n]),
            ("Y2".into(), vec![n]),
            ("X1in".into(), vec![n]),
            ("X2in".into(), vec![n]),
        ]);
        let out = interpret(&pra, &params, &inputs);
        for i in 0..n {
            let mut a1 = inputs["X1in"].get(&[i]);
            let mut a2 = inputs["X2in"].get(&[i]);
            for j in 0..n {
                a1 += inputs["A"].get(&[i, j]) * inputs["Y1"].get(&[j]);
                a2 += inputs["A"].get(&[j, i]) * inputs["Y2"].get(&[j]);
            }
            assert!(
                (out["X1"].get(&[i]) - a1).abs() < 1e-4,
                "X1[{i}] {} vs {a1}",
                out["X1"].get(&[i])
            );
            assert!(
                (out["X2"].get(&[i]) - a2).abs() < 1e-4,
                "X2[{i}] {} vs {a2}",
                out["X2"].get(&[i])
            );
        }
    }
}
