//! GEMM (PolyBench): `C = A·B` over a 3-deep nest `(i0, i1, i2) =
//! (row, col, reduction)`, `N0×N1×N2` iterations.
//!
//! Systolic PRA shape: `A` values propagate along the column dimension
//! `i1`, `B` values along the row dimension `i0`, products accumulate
//! along `i2`. (PolyBench's `alpha/beta` scaling is omitted — scalar
//! constants do not affect the access-count analysis; see DESIGN.md §6.)

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra};

use super::builder::PraBuilder;

/// Build the GEMM PRA (3-deep nest, params `N0, N1, N2, p0, p1, p2`).
pub fn gemm() -> Pra {
    let nd = 3;
    let mut b = PraBuilder::new("gemm", nd);
    b.tensor("A", &[0, 2]) // A[N0, N2]
        .tensor("B", &[2, 1]) // B[N2, N1]
        .tensor("C", &[0, 1]); // C[N0, N1] (output)
    // S1, S2: a[i] propagates A[i0, i2] along i1.
    b.propagate("a", "A", IndexMap::select(&[0, 2], nd), 1);
    // S3, S4: bb[i] propagates B[i2, i1] along i0.
    b.propagate("bb", "B", IndexMap::select(&[2, 1], nd), 0);
    // S5: m = a · bb.
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![Operand::var0("a", nd), Operand::var0("bb", nd)],
        vec![],
    );
    // S6–S8: accumulate along i2.
    b.acc_chain("s", "m", 2);
    // S9: C[i0, i1] = s at i2 = N2 − 1.
    let top = b.eq_top(2);
    b.stmt(
        Lhs::Tensor { name: "C".into(), map: IndexMap::select(&[0, 1], nd) },
        Op::Copy,
        vec![Operand::var0("s", nd)],
        top,
    );
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn gemm_structure() {
        let pra = gemm();
        assert_eq!(pra.statements.len(), 9);
        assert!(validate(&pra).is_empty(), "{:?}", validate(&pra));
    }

    #[test]
    fn gemm_functional() {
        let pra = gemm();
        let (n0, n1, n2) = (3i64, 4i64, 5i64);
        let params = [n0, n1, n2, 1, 1, 1];
        let inputs = synth_inputs(&[
            ("A".into(), vec![n0, n2]),
            ("B".into(), vec![n2, n1]),
        ]);
        let out = interpret(&pra, &params, &inputs);
        let c = &out["C"];
        for i in 0..n0 {
            for j in 0..n1 {
                let mut acc = 0.0f32;
                for k in 0..n2 {
                    acc += inputs["A"].get(&[i, k]) * inputs["B"].get(&[k, j]);
                }
                assert!(
                    (c.get(&[i, j]) - acc).abs() < 1e-4,
                    "C[{i},{j}] = {} vs {acc}",
                    c.get(&[i, j])
                );
            }
        }
    }
}
