//! Builder for the recurring PRA idioms of systolic loop mappings:
//! broadcast-by-propagation chains and accumulation chains.
//!
//! TCPA compilation (and the paper's running example) localizes every data
//! flow: a tensor value used by many iterations is *propagated* through
//! neighbour iterations (statements S1/S2 of GESUMMV); a reduction becomes
//! an *accumulation chain* (S5–S7). These helpers generate the statement
//! triples with consistent naming so the eight benchmark PRAs stay terse
//! and uniform.

use crate::polyhedral::{AffineExpr, Constraint, ParamSpace};
use crate::pra::ir::{
    CondConstraint, IndexMap, Lhs, Op, Operand, Pra, Statement, TensorDecl,
    TensorDim,
};

/// Incremental PRA builder.
pub struct PraBuilder {
    name: String,
    ndims: usize,
    space: ParamSpace,
    statements: Vec<Statement>,
    tensors: Vec<TensorDecl>,
    requires: Vec<Constraint>,
    next_stmt: usize,
}

impl PraBuilder {
    /// Start a PRA of loop depth `ndims` with the conventional
    /// `N0.., p0..` parameter space.
    pub fn new(name: &str, ndims: usize) -> Self {
        PraBuilder {
            name: name.into(),
            ndims,
            space: ParamSpace::loop_nest(ndims),
            statements: Vec::new(),
            tensors: Vec::new(),
            requires: Vec::new(),
            next_stmt: 1,
        }
    }

    /// Number of parameters.
    pub fn nparams(&self) -> usize {
        self.space.len()
    }

    /// Declare an external tensor whose dimensions are loop-bound
    /// parameters (`dims[r]` = loop dimension index).
    pub fn tensor(&mut self, name: &str, dims: &[usize]) -> &mut Self {
        self.tensors.push(TensorDecl {
            name: name.into(),
            shape: dims.iter().map(|&d| TensorDim::Param(d)).collect(),
        });
        self
    }

    /// Declare an external tensor with explicit dimension descriptors —
    /// the general form behind [`Self::tensor`], used by the text
    /// frontend where a dimension may be a fixed integer.
    pub fn tensor_decl(&mut self, name: &str, shape: Vec<TensorDim>) -> &mut Self {
        self.tensors.push(TensorDecl { name: name.into(), shape });
        self
    }

    /// Record a raw precondition over the bound parameters (the general
    /// form behind [`Self::require_equal_bounds`] /
    /// [`Self::require_min_bound`], used by the text frontend's
    /// `requires` lines).
    pub fn require(&mut self, c: Constraint) -> &mut Self {
        self.requires.push(c);
        self
    }

    fn fresh_name(&mut self) -> String {
        let n = format!("S{}", self.next_stmt);
        self.next_stmt += 1;
        n
    }

    /// Append a raw statement with an auto-assigned name.
    pub fn stmt(
        &mut self,
        lhs: Lhs,
        op: Op,
        args: Vec<Operand>,
        cond: Vec<CondConstraint>,
    ) -> &mut Self {
        let name = self.fresh_name();
        self.statements.push(Statement { name, lhs, op, args, cond });
        self
    }

    /// Append a raw statement with an explicit name. The auto-naming
    /// counter of [`Self::stmt`] is *not* advanced: an explicit `S3`
    /// followed by enough auto-named statements collides, which the
    /// text frontend reports as a duplicate-name diagnostic.
    pub fn named_stmt(
        &mut self,
        name: &str,
        lhs: Lhs,
        op: Op,
        args: Vec<Operand>,
        cond: Vec<CondConstraint>,
    ) -> &mut Self {
        self.statements.push(Statement {
            name: name.into(),
            lhs,
            op,
            args,
            cond,
        });
        self
    }

    /// `i_dim = c` as a condition pair.
    pub fn eq_const(&self, dim: usize, c: i64) -> Vec<CondConstraint> {
        vec![
            CondConstraint::ge_const(dim, c, self.ndims, self.nparams()),
            CondConstraint::le_const(dim, c, self.ndims, self.nparams()),
        ]
    }

    /// `i_dim > c`.
    pub fn gt_const(&self, dim: usize, c: i64) -> CondConstraint {
        CondConstraint::ge_const(dim, c + 1, self.ndims, self.nparams())
    }

    /// `i_dim = N_dim − 1`.
    pub fn eq_top(&self, dim: usize) -> Vec<CondConstraint> {
        vec![CondConstraint::ge_n_plus(
            dim,
            self.space.n_index(dim),
            0,
            self.ndims,
            self.nparams(),
        )]
    }

    /// `i_dim ≤ N_dim − 2`.
    pub fn below_top(&self, dim: usize) -> CondConstraint {
        CondConstraint::le_n_minus_2(
            dim,
            self.space.n_index(dim),
            self.ndims,
            self.nparams(),
        )
    }

    /// Unit dependence vector along `dim`.
    pub fn unit_dep(&self, dim: usize) -> Vec<i64> {
        let mut d = vec![0; self.ndims];
        d[dim] = 1;
        d
    }

    /// Declare the precondition `N_d0 = N_d1` (e.g. for transposed
    /// accesses like MVT's `A[i1, i0]`, which stay in bounds only on
    /// square problems). Recorded in [`Pra::requires`]; the lint
    /// engine's bounds-safety proofs run under these constraints.
    pub fn require_equal_bounds(&mut self, d0: usize, d1: usize) -> &mut Self {
        let np = self.nparams();
        let a = AffineExpr::param(np, self.space.n_index(d0));
        let b = AffineExpr::param(np, self.space.n_index(d1));
        self.requires.push(Constraint::ge(&a, &b));
        self.requires.push(Constraint::le(&a, &b));
        self
    }

    /// Declare the precondition `N_dim ≥ min` (e.g. a stencil needing at
    /// least three spatial points).
    pub fn require_min_bound(&mut self, dim: usize, min: i64) -> &mut Self {
        let np = self.nparams();
        let n = AffineExpr::param(np, self.space.n_index(dim));
        self.requires
            .push(Constraint::ge(&n, &AffineExpr::constant(np, min)));
        self
    }

    /// Broadcast-by-propagation: two statements defining `var` everywhere:
    ///
    /// ```text
    /// S_a : var[i] = T[map(i)]          if i_dim = 0
    /// S_b : var[i] = var[i − e_dim]     if i_dim > 0
    /// ```
    pub fn propagate(
        &mut self,
        var: &str,
        tensor: &str,
        map: IndexMap,
        along: usize,
    ) -> &mut Self {
        let at0 = self.eq_const(along, 0);
        self.stmt(
            Lhs::Var(var.into()),
            Op::Copy,
            vec![Operand::tensor(tensor, map)],
            at0,
        );
        let gt0 = vec![self.gt_const(along, 0)];
        let dep = self.unit_dep(along);
        self.stmt(
            Lhs::Var(var.into()),
            Op::Copy,
            vec![Operand::var(var, dep)],
            gt0,
        );
        self
    }

    /// Accumulation chain for `sum = Σ_along term` (GESUMMV S5–S7 shape):
    ///
    /// ```text
    /// S_a : sum[i]  = term[i]                 if i_dim = 0
    /// S_b : sum[i]  = sum*[i] + term[i]       if i_dim > 0
    /// S_c : sum*[i] = sum[i − e_dim]          if i_dim > 0
    /// ```
    pub fn acc_chain(&mut self, sum: &str, term: &str, along: usize) -> &mut Self {
        let star = format!("{sum}*");
        let at0 = self.eq_const(along, 0);
        self.stmt(
            Lhs::Var(sum.into()),
            Op::Copy,
            vec![Operand::var0(term, self.ndims)],
            at0,
        );
        let gt0 = vec![self.gt_const(along, 0)];
        self.stmt(
            Lhs::Var(sum.into()),
            Op::Add,
            vec![Operand::var0(&star, self.ndims), Operand::var0(term, self.ndims)],
            gt0.clone(),
        );
        let dep = self.unit_dep(along);
        self.stmt(Lhs::Var(star), Op::Copy, vec![Operand::var(sum, dep)], gt0);
        self
    }

    /// Finish, asserting structural validity: every builtin-workload
    /// constructor funnels through this single check (the shared helper
    /// behind [`crate::pra::assert_valid`]), so no builder-made PRA
    /// reaches tiling, analysis, or simulation malformed. Tests that
    /// need a deliberately broken PRA use [`Self::build_unchecked`].
    pub fn build(self) -> Pra {
        let pra = self.build_unchecked();
        crate::pra::assert_valid(&pra);
        pra
    }

    /// Finish without the structural validation of [`Self::build`].
    pub fn build_unchecked(self) -> Pra {
        Pra {
            name: self.name,
            ndims: self.ndims,
            space: self.space,
            statements: self.statements,
            tensors: self.tensors,
            requires: self.requires,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;

    #[test]
    fn builder_generates_valid_chain() {
        let mut b = PraBuilder::new("mv", 2);
        b.tensor("A", &[0, 1]).tensor("X", &[1]).tensor("Y", &[0]);
        b.propagate("xx", "X", IndexMap::select(&[1], 2), 0);
        b.stmt(
            Lhs::Var("m".into()),
            Op::Mul,
            vec![
                Operand::tensor("A", IndexMap::identity(2, 2)),
                Operand::var0("xx", 2),
            ],
            vec![],
        );
        b.acc_chain("s", "m", 1);
        let top = b.eq_top(1);
        b.stmt(
            Lhs::Tensor { name: "Y".into(), map: IndexMap::select(&[0], 2) },
            Op::Copy,
            vec![Operand::var0("s", 2)],
            top,
        );
        let pra = b.build();
        assert_eq!(pra.statements.len(), 7);
        assert!(validate(&pra).is_empty(), "{:?}", validate(&pra));
    }

    #[test]
    fn fresh_names_sequential() {
        let mut b = PraBuilder::new("t", 1);
        b.tensor("T", &[0]);
        b.propagate("v", "T", IndexMap::select(&[0], 1), 0);
        let pra = b.build();
        assert_eq!(pra.statements[0].name, "S1");
        assert_eq!(pra.statements[1].name, "S2");
    }
}
