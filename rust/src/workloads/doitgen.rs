//! DOITGEN (PolyBench): multiresolution-analysis kernel
//! `SUM[r,q,p] = Σ_s A[r,q,s]·C4[s,p]` — a 4-deep nest `(i0,i1,i2,i3) =
//! (r, q, p, s)`, the deepest workload in the suite. Mapped with the
//! leading two dimensions across the array (`t = (t0, t1, 1, 1)`), it
//! exercises the counters and schedules on loop depth 4.

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Build the DOITGEN PRA (4-deep nest).
pub fn doitgen_pra() -> Pra {
    let nd = 4;
    let mut b = PraBuilder::new("doitgen", nd);
    b.tensor("A", &[0, 1, 3]) // A[r, q, s]
        .tensor("C4", &[3, 2]) // C4[s, p]
        .tensor("SUM", &[0, 1, 2]); // SUM[r, q, p]
    // a[i] propagates A[r,q,s] along the p dimension (i2).
    b.propagate("a", "A", IndexMap::select(&[0, 1, 3], nd), 2);
    // c0[i]: C4[s,p] streams in along the r boundary (i0 = 0) and
    // propagates down the r dimension — one DRAM trip per (q,p,s) slice,
    // the row-stationary reuse choice of the mapping.
    b.propagate("c0", "C4", IndexMap::select(&[3, 2], nd), 0);
    // m = a · c0.
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![Operand::var0("a", nd), Operand::var0("c0", nd)],
        vec![],
    );
    // accumulate along s (i3).
    b.acc_chain("s", "m", 3);
    let top = b.eq_top(3);
    b.stmt(
        Lhs::Tensor {
            name: "SUM".into(),
            map: IndexMap::select(&[0, 1, 2], nd),
        },
        Op::Copy,
        vec![Operand::var0("s", nd)],
        top,
    );
    b.build()
}

/// Single-phase workload wrapper.
pub fn doitgen() -> Workload {
    Workload::single(doitgen_pra())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn validates() {
        let p = doitgen_pra();
        assert!(validate(&p).is_empty(), "{:?}", validate(&p));
        assert_eq!(p.ndims, 4);
    }

    #[test]
    fn doitgen_functional() {
        let pra = doitgen_pra();
        let (nr, nq, np_, ns) = (2i64, 3i64, 4i64, 3i64);
        let params = [nr, nq, np_, ns, 1, 1, 1, 1];
        let inputs = synth_inputs(&[
            ("A".into(), vec![nr, nq, ns]),
            ("C4".into(), vec![ns, np_]),
        ]);
        let out = interpret(&pra, &params, &inputs);
        for r in 0..nr {
            for q in 0..nq {
                for p in 0..np_ {
                    let mut acc = 0.0f32;
                    for s in 0..ns {
                        acc += inputs["A"].get(&[r, q, s])
                            * inputs["C4"].get(&[s, p]);
                    }
                    let got = out["SUM"].get(&[r, q, p]);
                    assert!(
                        (got - acc).abs() < 1e-4,
                        "SUM[{r},{q},{p}] {got} vs {acc}"
                    );
                }
            }
        }
    }
}
