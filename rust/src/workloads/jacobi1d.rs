//! Jacobi-1D (PolyBench stencils): `N0` unscaled relaxation sweeps over a
//! length-`N1` array, `v[t,i] = v[t−1,i−1] + v[t−1,i] + v[t−1,i+1]`
//! (boundaries propagate unchanged). The `(1,−1)` dependence vector — the
//! right-neighbour read — exercises negative intra-tile displacement and
//! the γ = +1 inter-tile solutions of the tiling transform, which none of
//! the linear-algebra kernels produce.
//!
//! (PolyBench scales by 1/3; a constant scalar factor does not change any
//! access counts, see DESIGN.md §6. Requires `N1 ≥ 3`.)

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Build the Jacobi-1D PRA (2-deep nest: `i0` = time, `i1` = space).
pub fn jacobi1d_pra() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("jacobi1d", nd);
    // The three-point stencil needs at least three spatial points.
    b.require_min_bound(1, 3);
    b.tensor("Ain", &[1]).tensor("Aout", &[1]);
    // S1: v = Ain[i1] at t = 0.
    let at_t0 = b.eq_const(0, 0);
    b.stmt(
        Lhs::Var("v".into()),
        Op::Copy,
        vec![Operand::tensor("Ain", IndexMap::select(&[1], nd))],
        at_t0,
    );
    // Neighbour transports from the previous sweep (t > 0):
    // S2: l = v[t−1, i−1]   (d = (1, 1)), needs i1 > 0
    let mut c_l = vec![b.gt_const(0, 0)];
    c_l.push(b.gt_const(1, 0));
    b.stmt(
        Lhs::Var("l".into()),
        Op::Copy,
        vec![Operand::var("v", vec![1, 1])],
        c_l,
    );
    // S3: c = v[t−1, i]     (d = (1, 0))
    b.stmt(
        Lhs::Var("c".into()),
        Op::Copy,
        vec![Operand::var("v", vec![1, 0])],
        vec![b.gt_const(0, 0)],
    );
    // S4: r = v[t−1, i+1]   (d = (1, −1)), needs i1 < N1 − 1
    let c_r = vec![b.gt_const(0, 0), b.below_top(1)];
    b.stmt(
        Lhs::Var("r".into()),
        Op::Copy,
        vec![Operand::var("v", vec![1, -1])],
        c_r,
    );
    // S5: v = l + c + r for interior points of sweeps t > 0.
    let interior = vec![b.gt_const(0, 0), b.gt_const(1, 0), b.below_top(1)];
    b.stmt(
        Lhs::Var("v".into()),
        Op::Add3,
        vec![
            Operand::var0("l", nd),
            Operand::var0("c", nd),
            Operand::var0("r", nd),
        ],
        interior,
    );
    // S6/S7: boundary points propagate unchanged.
    let left = {
        let mut c = vec![b.gt_const(0, 0)];
        c.extend(b.eq_const(1, 0));
        c
    };
    b.stmt(Lhs::Var("v".into()), Op::Copy, vec![Operand::var0("c", nd)], left);
    let right = {
        let mut c = vec![b.gt_const(0, 0)];
        c.extend(b.eq_top(1));
        c
    };
    b.stmt(Lhs::Var("v".into()), Op::Copy, vec![Operand::var0("c", nd)], right);
    // S8: Aout[i1] = v at the final sweep.
    let last = b.eq_top(0);
    b.stmt(
        Lhs::Tensor { name: "Aout".into(), map: IndexMap::select(&[1], nd) },
        Op::Copy,
        vec![Operand::var0("v", nd)],
        last,
    );
    b.build()
}

/// Single-phase workload wrapper.
pub fn jacobi1d() -> Workload {
    Workload::single(jacobi1d_pra())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn validates() {
        let p = jacobi1d_pra();
        assert!(validate(&p).is_empty(), "{:?}", validate(&p));
        assert_eq!(p.statements.len(), 8);
    }

    #[test]
    fn jacobi_functional() {
        let pra = jacobi1d_pra();
        let (steps, n) = (3i64, 6i64);
        let params = [steps, n, 1, 1];
        let inputs = synth_inputs(&[("Ain".into(), vec![n])]);
        let out = interpret(&pra, &params, &inputs);
        // reference sweeps
        let mut cur: Vec<f32> =
            (0..n).map(|i| inputs["Ain"].get(&[i])).collect();
        for _t in 1..steps {
            let mut nxt = cur.clone();
            for i in 1..(n - 1) as usize {
                nxt[i] = cur[i - 1] + cur[i] + cur[i + 1];
            }
            cur = nxt;
        }
        for i in 0..n {
            assert!(
                (out["Aout"].get(&[i]) - cur[i as usize]).abs() < 1e-3,
                "Aout[{i}] {} vs {}",
                out["Aout"].get(&[i]),
                cur[i as usize]
            );
        }
    }

    #[test]
    fn has_negative_displacement_dep() {
        // The defining feature vs. the linear-algebra kernels.
        let pra = jacobi1d_pra();
        let has = pra.statements.iter().any(|s| {
            s.args.iter().any(|a| match a {
                crate::pra::Operand::Var { dep, .. } => {
                    dep.iter().any(|&d| d < 0)
                }
                _ => false,
            })
        });
        assert!(has);
    }
}
