//! GESUMMV (PolyBench): `Y = (A + B)·X` as the paper's running example
//! (Example 1) — scalar sum of two matrix–vector products,
//! `Y[i0] = Σ_{i1} (A[i0,i1]·X[i1] + B[i0,i1]·X[i1])`.
//!
//! The generated statements reproduce the paper's S1–S11 exactly:
//! X-propagation along `i0` (S1, S2), elementwise products (S3, S4), two
//! accumulation chains along `i1` (S5–S7, S8–S10), and the output sum at
//! `i1 = N1 − 1` (S11).

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra};

use super::builder::PraBuilder;

/// Build the GESUMMV PRA (2-deep nest, params `N0, N1, p0, p1`).
pub fn gesummv() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("gesummv", nd);
    b.tensor("A", &[0, 1])
        .tensor("B", &[0, 1])
        .tensor("X", &[1])
        .tensor("Y", &[0]);
    // S1, S2: x-propagation along i0.
    b.propagate("x", "X", IndexMap::select(&[1], nd), 0);
    // S3: a = A ⊙ x, S4: b = B ⊙ x.
    b.stmt(
        Lhs::Var("a".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("x", nd),
        ],
        vec![],
    );
    b.stmt(
        Lhs::Var("b".into()),
        Op::Mul,
        vec![
            Operand::tensor("B", IndexMap::identity(2, nd)),
            Operand::var0("x", nd),
        ],
        vec![],
    );
    // S5–S7 and S8–S10: accumulation chains along i1.
    b.acc_chain("sA", "a", 1);
    b.acc_chain("sB", "b", 1);
    // S11: Y[i0] = sA + sB at i1 = N1 − 1.
    let top = b.eq_top(1);
    b.stmt(
        Lhs::Tensor { name: "Y".into(), map: IndexMap::select(&[0], nd) },
        Op::Add,
        vec![Operand::var0("sA", nd), Operand::var0("sB", nd)],
        top,
    );
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::{validate, Op};

    #[test]
    fn statement_names_and_ops_match_paper() {
        let pra = gesummv();
        assert_eq!(pra.statements.len(), 11);
        let ops: Vec<(&str, Op)> = pra
            .statements
            .iter()
            .map(|s| (s.name.as_str(), s.op))
            .collect();
        assert_eq!(
            ops,
            vec![
                ("S1", Op::Copy),
                ("S2", Op::Copy),
                ("S3", Op::Mul),
                ("S4", Op::Mul),
                ("S5", Op::Copy),
                ("S6", Op::Add),
                ("S7", Op::Copy),
                ("S8", Op::Copy),
                ("S9", Op::Add),
                ("S10", Op::Copy),
                ("S11", Op::Add),
            ]
        );
        assert!(validate(&pra).is_empty());
    }

    #[test]
    fn computational_and_memory_sets_match_example4() {
        // Example 4: C = {S3,S4,S6,S9,S11}, M = {S1,S2,S5,S7,S8,S10}.
        let pra = gesummv();
        let c: Vec<&str> = pra
            .statements
            .iter()
            .filter(|s| !s.is_memory())
            .map(|s| s.name.as_str())
            .collect();
        assert_eq!(c, vec!["S3", "S4", "S6", "S9", "S11"]);
        let m: Vec<&str> = pra
            .statements
            .iter()
            .filter(|s| s.is_memory())
            .map(|s| s.name.as_str())
            .collect();
        assert_eq!(m, vec!["S1", "S2", "S5", "S7", "S8", "S10"]);
    }

    #[test]
    fn s7_dependence_vector() {
        let pra = gesummv();
        let s7 = pra.statement("S7").unwrap();
        match &s7.args[0] {
            crate::pra::Operand::Var { name, dep } => {
                assert_eq!(name, "sA");
                assert_eq!(dep, &vec![0, 1]);
            }
            _ => panic!("S7 must read sA"),
        }
    }
}
