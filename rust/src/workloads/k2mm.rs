//! 2MM (PolyBench): `D = (A·B)·C` as two GEMM-shaped phases. Phase 1
//! produces `TMP = A·B`, phase 2 produces `D = TMP·C`. As with ATAX, the
//! intermediate tensor round-trips through DRAM between kernels.
//!
//! Dimension naming (all phases use their own `N0,N1,N2` parameters):
//! phase 1 runs over `(N0, N1, N2)` with `A[N0,N2]`, `B[N2,N1]`; phase 2
//! over `(N0, N3, N1)` — rebound to its local `(N0, N1, N2)` — with
//! `TMP[N0,N1]`, `C[N1,N3]`.

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// GEMM-shaped phase computing `Out = L·R` with tensor names.
fn gemm_phase(name: &str, l: &str, r: &str, out: &str) -> Pra {
    let nd = 3;
    let mut b = PraBuilder::new(name, nd);
    b.tensor(l, &[0, 2]).tensor(r, &[2, 1]).tensor(out, &[0, 1]);
    b.propagate("a", l, IndexMap::select(&[0, 2], nd), 1);
    b.propagate("bb", r, IndexMap::select(&[2, 1], nd), 0);
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![Operand::var0("a", nd), Operand::var0("bb", nd)],
        vec![],
    );
    b.acc_chain("s", "m", 2);
    let top = b.eq_top(2);
    b.stmt(
        Lhs::Tensor { name: out.into(), map: IndexMap::select(&[0, 1], nd) },
        Op::Copy,
        vec![Operand::var0("s", nd)],
        top,
    );
    b.build()
}

/// The two-phase 2MM workload.
pub fn k2mm() -> Workload {
    Workload {
        name: "k2mm".into(),
        phases: vec![
            gemm_phase("k2mm_p1", "A", "B", "TMP"),
            gemm_phase("k2mm_p2", "TMP", "C", "D"),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret_workload;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn phases_validate() {
        for p in k2mm().phases {
            assert!(validate(&p).is_empty(), "{}: {:?}", p.name, validate(&p));
        }
    }

    #[test]
    fn k2mm_functional() {
        let wl = k2mm();
        // D[N0,N3] = A[N0,N1]·B[N1,N3… naming: phase1 (n0,n1,n2)=(2,3,4):
        // TMP[2,3] = A[2,4]·B[4,3]; phase2 (n0,n1,n2)=(2,5,3):
        // D[2,5] = TMP[2,3]·C[3,5].
        let params = vec![vec![2, 3, 4, 1, 1, 1], vec![2, 5, 3, 1, 1, 1]];
        let inputs = synth_inputs(&[
            ("A".into(), vec![2, 4]),
            ("B".into(), vec![4, 3]),
            ("C".into(), vec![3, 5]),
        ]);
        let out = interpret_workload(&wl, &params, &inputs);
        let d = &out["D"];
        assert_eq!(d.shape, vec![2, 5]);
        for i in 0..2i64 {
            for j in 0..5i64 {
                let mut acc = 0.0f32;
                for t in 0..3i64 {
                    let mut tmp = 0.0f32;
                    for k in 0..4i64 {
                        tmp += inputs["A"].get(&[i, k]) * inputs["B"].get(&[k, t]);
                    }
                    acc += tmp * inputs["C"].get(&[t, j]);
                }
                assert!(
                    (d.get(&[i, j]) - acc).abs() < 1e-3,
                    "D[{i},{j}] {} vs {acc}",
                    d.get(&[i, j])
                );
            }
        }
    }
}
