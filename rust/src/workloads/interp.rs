//! Lexicographic functional PRA interpreter — the in-crate golden model.
//!
//! Executes a PRA over its concrete iteration space in lexicographic order
//! (valid because [`crate::pra::validate`] enforces lexicographically
//! non-negative dependence vectors), producing output tensors. Used to
//! validate the cycle-accurate simulator's functional results, and itself
//! validated against the AOT-compiled JAX model through the PJRT runtime.

use std::collections::BTreeMap;

use crate::pra::{Lhs, Operand, Pra, Rdg, Workload};

use super::tensor::{Tensor, TensorEnv};

/// Dense storage for one internal variable over the iteration space.
struct VarStore {
    bounds: Vec<i64>,
    data: Vec<f32>,
    written: Vec<bool>,
}

impl VarStore {
    fn new(bounds: &[i64]) -> Self {
        let n: i64 = bounds.iter().product();
        VarStore {
            bounds: bounds.to_vec(),
            data: vec![0.0; n as usize],
            written: vec![false; n as usize],
        }
    }

    fn flat(&self, i: &[i64]) -> Option<usize> {
        let mut off = 0i64;
        for (&x, &b) in i.iter().zip(&self.bounds) {
            if x < 0 || x >= b {
                return None;
            }
            off = off * b + x;
        }
        Some(off as usize)
    }

    fn get(&self, i: &[i64], var: &str) -> f32 {
        let off = self.flat(i).filter(|&o| self.written[o]);
        match off {
            Some(o) => self.data[o],
            None => panic!(
                "read of {var}[{i:?}] before definition (malformed PRA or schedule)"
            ),
        }
    }

    fn set(&mut self, i: &[i64], v: f32) {
        let off = self.flat(i).expect("write outside iteration space");
        self.data[off] = v;
        self.written[off] = true;
    }
}

/// Interpret one PRA phase: read `inputs`, return produced output tensors.
pub fn interpret(pra: &Pra, params: &[i64], inputs: &TensorEnv) -> TensorEnv {
    let bounds: Vec<i64> =
        (0..pra.ndims).map(|l| params[pra.space.n_index(l)]).collect();
    let rdg = Rdg::build(pra);
    let order = rdg
        .intra_iteration_order(pra.statements.len())
        .expect("PRA has an intra-iteration dependence cycle");

    let mut vars: BTreeMap<&str, VarStore> = BTreeMap::new();
    let mut outputs: TensorEnv = BTreeMap::new();
    for s in &pra.statements {
        match &s.lhs {
            Lhs::Var(n) => {
                vars.entry(n).or_insert_with(|| VarStore::new(&bounds));
            }
            Lhs::Tensor { name, .. } => {
                if !outputs.contains_key(name) {
                    let decl = pra
                        .tensor(name)
                        .unwrap_or_else(|| panic!("undeclared tensor {name}"));
                    outputs.insert(
                        name.clone(),
                        Tensor::zeros(decl.concrete_shape(params)),
                    );
                }
            }
        }
    }

    // Lexicographic walk with an odometer (avoids materializing the list).
    let total: i64 = bounds.iter().product();
    let mut i = vec![0i64; pra.ndims];
    let mut argbuf: Vec<f32> = Vec::with_capacity(3);
    for _ in 0..total {
        for &q in &order {
            let s = &pra.statements[q];
            if !s.active_at(&i, params) {
                continue;
            }
            argbuf.clear();
            for a in &s.args {
                let v = match a {
                    Operand::Var { name, dep } => {
                        let src: Vec<i64> =
                            i.iter().zip(dep).map(|(x, d)| x - d).collect();
                        vars[name.as_str()].get(&src, name)
                    }
                    Operand::Tensor { name, map } => {
                        let idx = map.apply(&i);
                        inputs
                            .get(name)
                            .unwrap_or_else(|| panic!("missing input {name}"))
                            .get(&idx)
                    }
                };
                argbuf.push(v);
            }
            let v = s.op.apply(&argbuf);
            match &s.lhs {
                Lhs::Var(n) => vars.get_mut(n.as_str()).unwrap().set(&i, v),
                Lhs::Tensor { name, map } => {
                    let idx = map.apply(&i);
                    outputs.get_mut(name).unwrap().set(&idx, v);
                }
            }
        }
        // odometer, last dim fastest = lexicographic order
        for d in (0..pra.ndims).rev() {
            i[d] += 1;
            if i[d] < bounds[d] {
                break;
            }
            i[d] = 0;
        }
    }
    outputs
}

/// Interpret a multi-phase workload: each phase's outputs are added to the
/// environment available to later phases. `params` gives one parameter
/// vector per phase. Returns the final environment of produced tensors.
pub fn interpret_workload(
    wl: &Workload,
    params: &[Vec<i64>],
    inputs: &TensorEnv,
) -> TensorEnv {
    assert_eq!(params.len(), wl.phases.len());
    let mut env = inputs.clone();
    let mut produced: TensorEnv = BTreeMap::new();
    for (phase, p) in wl.phases.iter().zip(params) {
        let out = interpret(phase, p, &env);
        for (k, v) in out {
            env.insert(k.clone(), v.clone());
            produced.insert(k, v);
        }
    }
    produced
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gesummv::gesummv;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn gesummv_interprets_to_reference() {
        // Y[i] = Σ_j (A[i,j] + B[i,j]) · X[j]
        let pra = gesummv();
        let (n0, n1) = (4i64, 5i64);
        let params = [n0, n1, 2, 3]; // p unused by interpretation
        let inputs = synth_inputs(&[
            ("A".into(), vec![n0, n1]),
            ("B".into(), vec![n0, n1]),
            ("X".into(), vec![n1]),
        ]);
        let out = interpret(&pra, &params, &inputs);
        let y = &out["Y"];
        assert_eq!(y.shape, vec![n0]);
        for i in 0..n0 {
            let mut acc_a = 0.0f32;
            let mut acc_b = 0.0f32;
            for j in 0..n1 {
                acc_a += inputs["A"].get(&[i, j]) * inputs["X"].get(&[j]);
                acc_b += inputs["B"].get(&[i, j]) * inputs["X"].get(&[j]);
            }
            let expect = acc_a + acc_b;
            assert!(
                (y.get(&[i]) - expect).abs() < 1e-4,
                "row {i}: {} vs {expect}",
                y.get(&[i])
            );
        }
    }

    #[test]
    #[should_panic(expected = "before definition")]
    fn uninitialized_read_panics() {
        use crate::polyhedral::ParamSpace;
        use crate::pra::ir::*;
        // Reads a[i0-1] at i0=0 without an init statement.
        let nd = 1;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Copy,
                args: vec![Operand::var("a", vec![1])],
                cond: vec![],
            }],
            tensors: vec![],
            requires: vec![],
        };
        interpret(&pra, &[3, 1], &Default::default());
    }
}
