//! GEMVER (PolyBench): the four-phase vector-multiplication / matrix-
//! addition kernel — the richest multi-phase workload in the suite:
//!
//! 1. `B = A + u1·v1ᵀ + u2·v2ᵀ`   (rank-2 update, 2-D)
//! 2. `X = Bᵀ·Y + Z`              (transposed MV + vector add, 2-D)
//! 3. `W = B·X`                   (MV, 2-D)
//!
//! (PolyBench's α/β scalings are omitted as in GEMM — constant factors do
//! not change access counts, DESIGN.md §6. The two rank-1 updates fuse
//! into one pass over A; PolyBench's separate `x = x + z` loop fuses into
//! phase 2's output statement.) Square: evaluated with `N0 = N1`.

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Phase 1: `B[i,j] = A[i,j] + u1[i]·v1[j] + u2[i]·v2[j]`.
pub fn gemver_phase1() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("gemver_p1", nd);
    b.tensor("A", &[0, 1])
        .tensor("U1", &[0])
        .tensor("V1", &[1])
        .tensor("U2", &[0])
        .tensor("V2", &[1])
        .tensor("B", &[0, 1]);
    // Row-constant u vectors propagate along j (i1); column-constant v
    // vectors propagate along i (i0).
    b.propagate("u1", "U1", IndexMap::select(&[0], nd), 1);
    b.propagate("v1", "V1", IndexMap::select(&[1], nd), 0);
    b.propagate("u2", "U2", IndexMap::select(&[0], nd), 1);
    b.propagate("v2", "V2", IndexMap::select(&[1], nd), 0);
    b.stmt(
        Lhs::Var("r1".into()),
        Op::Mul,
        vec![Operand::var0("u1", nd), Operand::var0("v1", nd)],
        vec![],
    );
    b.stmt(
        Lhs::Var("r2".into()),
        Op::Mul,
        vec![Operand::var0("u2", nd), Operand::var0("v2", nd)],
        vec![],
    );
    b.stmt(
        Lhs::Var("t".into()),
        Op::Add,
        vec![Operand::var0("r1", nd), Operand::var0("r2", nd)],
        vec![],
    );
    b.stmt(
        Lhs::Tensor { name: "B".into(), map: IndexMap::identity(2, nd) },
        Op::Add,
        vec![
            Operand::var0("t", nd),
            Operand::tensor("A", IndexMap::identity(2, nd)),
        ],
        vec![],
    );
    b.build()
}

/// Phase 2: `X[j] = Σ_i B[i,j]·Y[i] + Z[j]` (transposed MV, accumulate
/// along i0, add `Z` at the output).
pub fn gemver_phase2() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("gemver_p2", nd);
    b.tensor("B", &[0, 1]).tensor("Y", &[0]).tensor("Z", &[1]).tensor("X", &[1]);
    b.propagate("y", "Y", IndexMap::select(&[0], nd), 1);
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![
            Operand::tensor("B", IndexMap::identity(2, nd)),
            Operand::var0("y", nd),
        ],
        vec![],
    );
    b.acc_chain("s", "m", 0);
    let top = b.eq_top(0);
    b.stmt(
        Lhs::Tensor { name: "X".into(), map: IndexMap::select(&[1], nd) },
        Op::Add,
        vec![
            Operand::var0("s", nd),
            Operand::tensor("Z", IndexMap::select(&[1], nd)),
        ],
        top,
    );
    b.build()
}

/// Phase 3: `W[i] = Σ_j B[i,j]·X[j]`.
pub fn gemver_phase3() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("gemver_p3", nd);
    b.tensor("B", &[0, 1]).tensor("X", &[1]).tensor("W", &[0]);
    b.propagate("x", "X", IndexMap::select(&[1], nd), 0);
    b.stmt(
        Lhs::Var("m".into()),
        Op::Mul,
        vec![
            Operand::tensor("B", IndexMap::identity(2, nd)),
            Operand::var0("x", nd),
        ],
        vec![],
    );
    b.acc_chain("s", "m", 1);
    let top = b.eq_top(1);
    b.stmt(
        Lhs::Tensor { name: "W".into(), map: IndexMap::select(&[0], nd) },
        Op::Copy,
        vec![Operand::var0("s", nd)],
        top,
    );
    b.build()
}

/// The three-phase GEMVER workload.
pub fn gemver() -> Workload {
    Workload {
        name: "gemver".into(),
        phases: vec![gemver_phase1(), gemver_phase2(), gemver_phase3()],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret_workload;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn phases_validate() {
        for p in gemver().phases {
            assert!(validate(&p).is_empty(), "{}: {:?}", p.name, validate(&p));
        }
    }

    #[test]
    fn gemver_functional() {
        let wl = gemver();
        let n = 4i64;
        let params = vec![vec![n, n, 1, 1]; 3];
        let inputs = synth_inputs(&[
            ("A".into(), vec![n, n]),
            ("U1".into(), vec![n]),
            ("V1".into(), vec![n]),
            ("U2".into(), vec![n]),
            ("V2".into(), vec![n]),
            ("Y".into(), vec![n]),
            ("Z".into(), vec![n]),
        ]);
        let out = interpret_workload(&wl, &params, &inputs);
        // reference
        let g = |t: &str, i: &[i64]| inputs[t].get(i);
        let mut bmat = vec![vec![0.0f32; n as usize]; n as usize];
        for i in 0..n {
            for j in 0..n {
                bmat[i as usize][j as usize] = g("A", &[i, j])
                    + g("U1", &[i]) * g("V1", &[j])
                    + g("U2", &[i]) * g("V2", &[j]);
            }
        }
        let mut x = vec![0.0f32; n as usize];
        for j in 0..n as usize {
            for i in 0..n as usize {
                x[j] += bmat[i][j] * g("Y", &[i as i64]);
            }
            x[j] += g("Z", &[j as i64]);
        }
        let mut w = vec![0.0f32; n as usize];
        for i in 0..n as usize {
            for j in 0..n as usize {
                w[i] += bmat[i][j] * x[j];
            }
        }
        for i in 0..n {
            assert!(
                (out["B"].get(&[i, 0]) - bmat[i as usize][0]).abs() < 1e-4
            );
            assert!((out["X"].get(&[i]) - x[i as usize]).abs() < 1e-3);
            assert!(
                (out["W"].get(&[i]) - w[i as usize]).abs() < 1e-2,
                "W[{i}] {} vs {}",
                out["W"].get(&[i]),
                w[i as usize]
            );
        }
    }
}
