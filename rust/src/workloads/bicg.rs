//! BiCG (PolyBench): the two matrix–vector products of the BiCGSTAB
//! stabilizer, `Q = A·P` and `S = Aᵀ·R`, fused into a *single* 2-deep PRA
//! that reads `A[i0,i1]` once per iteration and drives two orthogonal
//! accumulation chains (along `i1` for `Q`, along `i0` for `S`).

use crate::pra::ir::{IndexMap, Lhs, Op, Operand, Pra, Workload};

use super::builder::PraBuilder;

/// Build the fused BiCG PRA.
pub fn bicg_pra() -> Pra {
    let nd = 2;
    let mut b = PraBuilder::new("bicg", nd);
    b.tensor("A", &[0, 1])
        .tensor("P", &[1])
        .tensor("R", &[0])
        .tensor("Q", &[0])
        .tensor("S", &[1]);
    // pp propagates P[i1] along i0; rr propagates R[i0] along i1.
    b.propagate("pp", "P", IndexMap::select(&[1], nd), 0);
    b.propagate("rr", "R", IndexMap::select(&[0], nd), 1);
    // products
    b.stmt(
        Lhs::Var("mq".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("pp", nd),
        ],
        vec![],
    );
    b.stmt(
        Lhs::Var("ms".into()),
        Op::Mul,
        vec![
            Operand::tensor("A", IndexMap::identity(2, nd)),
            Operand::var0("rr", nd),
        ],
        vec![],
    );
    // Q chain along i1, S chain along i0.
    b.acc_chain("sq", "mq", 1);
    b.acc_chain("ss", "ms", 0);
    let top1 = b.eq_top(1);
    b.stmt(
        Lhs::Tensor { name: "Q".into(), map: IndexMap::select(&[0], nd) },
        Op::Copy,
        vec![Operand::var0("sq", nd)],
        top1,
    );
    let top0 = b.eq_top(0);
    b.stmt(
        Lhs::Tensor { name: "S".into(), map: IndexMap::select(&[1], nd) },
        Op::Copy,
        vec![Operand::var0("ss", nd)],
        top0,
    );
    b.build()
}

/// Single-phase workload wrapper.
pub fn bicg() -> Workload {
    Workload::single(bicg_pra())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pra::validate;
    use crate::workloads::interp::interpret;
    use crate::workloads::tensor::synth_inputs;

    #[test]
    fn validates() {
        let p = bicg_pra();
        assert!(validate(&p).is_empty(), "{:?}", validate(&p));
        assert_eq!(p.statements.len(), 14);
    }

    #[test]
    fn bicg_functional() {
        let pra = bicg_pra();
        let (n0, n1) = (4i64, 5i64);
        let params = [n0, n1, 1, 1];
        let inputs = synth_inputs(&[
            ("A".into(), vec![n0, n1]),
            ("P".into(), vec![n1]),
            ("R".into(), vec![n0]),
        ]);
        let out = interpret(&pra, &params, &inputs);
        for i in 0..n0 {
            let mut acc = 0.0f32;
            for j in 0..n1 {
                acc += inputs["A"].get(&[i, j]) * inputs["P"].get(&[j]);
            }
            assert!((out["Q"].get(&[i]) - acc).abs() < 1e-4);
        }
        for j in 0..n1 {
            let mut acc = 0.0f32;
            for i in 0..n0 {
                acc += inputs["A"].get(&[i, j]) * inputs["R"].get(&[i]);
            }
            assert!((out["S"].get(&[j]) - acc).abs() < 1e-4);
        }
    }
}
