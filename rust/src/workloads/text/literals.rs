//! Lexical layer of the textual workload format: raw source text →
//! positioned tokens.
//!
//! Hand-rolled (the crate is dependency-free) and deliberately small:
//! identifiers, integer literals, punctuation, comparison operators, the
//! range marker `..`, `#` line comments, and explicit newline tokens —
//! the format is line-oriented, so the parser treats `Newline` as a
//! directive terminator. Every token carries a 1-based [`Pos`]; every
//! diagnostic of the frontend (this layer, [`super::grammar`],
//! [`super::semantics`]) is a [`ParseError`] anchored to one.
//!
//! One wrinkle: accumulation chains name their carry variable with a
//! trailing star (`sA*`, see
//! [`crate::workloads::PraBuilder::acc_chain`]), and rendered builtins
//! must round-trip. A `*` is glued onto an identifier only when it is
//! followed immediately by `[` (an access like `sA*[i0, i1]`); in every
//! other position — `a * b`, `2*N0` — it lexes as the multiplication
//! token.

use std::fmt;

/// A 1-based source position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pos {
    pub line: usize,
    pub col: usize,
}

/// A positioned diagnostic from any layer of the text frontend.
///
/// `Display` renders `LINE:COL: MESSAGE`; callers that know the file
/// name prepend it (`file.wl:3:7: unknown parameter \`M\``).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub col: usize,
    pub message: String,
}

impl ParseError {
    /// A diagnostic anchored at `pos`.
    pub fn at(pos: Pos, message: impl Into<String>) -> Self {
        ParseError { line: pos.line, col: pos.col, message: message.into() }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {}", self.line, self.col, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Token kinds.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// `[A-Za-z_][A-Za-z0-9_]*`, optionally with a glued trailing `*`
    /// (see the module docs). Keywords (`workload`, `loop`, `stmt`, …)
    /// are contextual: they lex as identifiers and the grammar decides.
    Ident(String),
    /// Non-negative integer literal (signs are grammar-level).
    Int(i64),
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    LParen,
    RParen,
    Comma,
    Colon,
    Plus,
    Minus,
    Star,
    /// `=` (assignment in statements).
    Assign,
    /// `==`
    EqEq,
    /// `<=`
    Le,
    /// `>=`
    Ge,
    /// `<`
    Lt,
    /// `>`
    Gt,
    /// `..`
    DotDot,
    /// End of a source line (comments collapse into it). The lexer also
    /// emits one synthetic trailing `Newline` so every directive —
    /// including the last line of an unterminated file — has a
    /// terminator.
    Newline,
}

impl Tok {
    /// Short description for diagnostics, e.g. ``identifier `loop` ``.
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("identifier `{s}`"),
            Tok::Int(v) => format!("integer `{v}`"),
            Tok::LBrace => "`{`".into(),
            Tok::RBrace => "`}`".into(),
            Tok::LBracket => "`[`".into(),
            Tok::RBracket => "`]`".into(),
            Tok::LParen => "`(`".into(),
            Tok::RParen => "`)`".into(),
            Tok::Comma => "`,`".into(),
            Tok::Colon => "`:`".into(),
            Tok::Plus => "`+`".into(),
            Tok::Minus => "`-`".into(),
            Tok::Star => "`*`".into(),
            Tok::Assign => "`=`".into(),
            Tok::EqEq => "`==`".into(),
            Tok::Le => "`<=`".into(),
            Tok::Ge => "`>=`".into(),
            Tok::Lt => "`<`".into(),
            Tok::Gt => "`>`".into(),
            Tok::DotDot => "`..`".into(),
            Tok::Newline => "end of line".into(),
        }
    }
}

/// A token with its source position.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Token {
    pub tok: Tok,
    pub pos: Pos,
}

/// Tokenize `src`. The only lexical errors are unexpected characters,
/// stray `.` (only `..` exists), and out-of-range integer literals.
pub fn lex(src: &str) -> Result<Vec<Token>, ParseError> {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut col = 1usize;
    while i < chars.len() {
        let pos = Pos { line, col };
        let c = chars[i];
        match c {
            ' ' | '\t' | '\r' => {
                i += 1;
                col += 1;
            }
            '#' => {
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                    col += 1;
                }
            }
            '\n' => {
                out.push(Token { tok: Tok::Newline, pos });
                i += 1;
                line += 1;
                col = 1;
            }
            'A'..='Z' | 'a'..='z' | '_' => {
                let start = i;
                while i < chars.len()
                    && (chars[i].is_ascii_alphanumeric() || chars[i] == '_')
                {
                    i += 1;
                    col += 1;
                }
                // Glue a trailing `*` only before `[` (star-named
                // accumulation carries like `sA*[…]`; see module docs).
                if i + 1 < chars.len()
                    && chars[i] == '*'
                    && chars[i + 1] == '['
                {
                    i += 1;
                    col += 1;
                }
                let name: String = chars[start..i].iter().collect();
                out.push(Token { tok: Tok::Ident(name), pos });
            }
            '0'..='9' => {
                let start = i;
                while i < chars.len() && chars[i].is_ascii_digit() {
                    i += 1;
                    col += 1;
                }
                let text: String = chars[start..i].iter().collect();
                let v: i64 = text.parse().map_err(|_| {
                    ParseError::at(
                        pos,
                        format!("integer literal `{text}` out of range"),
                    )
                })?;
                out.push(Token { tok: Tok::Int(v), pos });
            }
            '=' => {
                if chars.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::EqEq, pos });
                    i += 2;
                    col += 2;
                } else {
                    out.push(Token { tok: Tok::Assign, pos });
                    i += 1;
                    col += 1;
                }
            }
            '<' => {
                if chars.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::Le, pos });
                    i += 2;
                    col += 2;
                } else {
                    out.push(Token { tok: Tok::Lt, pos });
                    i += 1;
                    col += 1;
                }
            }
            '>' => {
                if chars.get(i + 1) == Some(&'=') {
                    out.push(Token { tok: Tok::Ge, pos });
                    i += 2;
                    col += 2;
                } else {
                    out.push(Token { tok: Tok::Gt, pos });
                    i += 1;
                    col += 1;
                }
            }
            '.' => {
                if chars.get(i + 1) == Some(&'.') {
                    out.push(Token { tok: Tok::DotDot, pos });
                    i += 2;
                    col += 2;
                } else {
                    return Err(ParseError::at(
                        pos,
                        "unexpected character `.` (ranges are written \
                         `0..N`)",
                    ));
                }
            }
            _ => {
                let tok = match c {
                    '{' => Tok::LBrace,
                    '}' => Tok::RBrace,
                    '[' => Tok::LBracket,
                    ']' => Tok::RBracket,
                    '(' => Tok::LParen,
                    ')' => Tok::RParen,
                    ',' => Tok::Comma,
                    ':' => Tok::Colon,
                    '+' => Tok::Plus,
                    '-' => Tok::Minus,
                    '*' => Tok::Star,
                    other => {
                        return Err(ParseError::at(
                            pos,
                            format!("unexpected character `{other}`"),
                        ))
                    }
                };
                out.push(Token { tok, pos });
                i += 1;
                col += 1;
            }
        }
    }
    // Synthetic terminator so the last directive always ends cleanly.
    out.push(Token { tok: Tok::Newline, pos: Pos { line, col } });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn positions_are_one_based_and_comments_collapse() {
        let toks = lex("loop i0 in 0..N0  # bound\nstmt:").unwrap();
        assert_eq!(toks[0].tok, Tok::Ident("loop".into()));
        assert_eq!(toks[0].pos, Pos { line: 1, col: 1 });
        assert_eq!(toks[3].tok, Tok::Int(0));
        assert_eq!(toks[4].tok, Tok::DotDot);
        assert_eq!(toks[5].tok, Tok::Ident("N0".into()));
        assert_eq!(toks[6].tok, Tok::Newline);
        let stmt = &toks[7];
        assert_eq!(stmt.tok, Tok::Ident("stmt".into()));
        assert_eq!(stmt.pos, Pos { line: 2, col: 1 });
        // Synthetic trailing newline even without one in the source.
        assert_eq!(toks.last().unwrap().tok, Tok::Newline);
    }

    #[test]
    fn star_glues_onto_identifiers_only_before_brackets() {
        let toks = lex("sA*[i0] = a * b").unwrap();
        assert_eq!(toks[0].tok, Tok::Ident("sA*".into()));
        assert_eq!(toks[1].tok, Tok::LBracket);
        let stars: Vec<_> =
            toks.iter().filter(|t| t.tok == Tok::Star).collect();
        assert_eq!(stars.len(), 1, "spaced `*` stays multiplication");
    }

    #[test]
    fn lexical_errors_carry_line_and_column() {
        let e = lex("loop i0 in 0..N0\n  x = $y\n").unwrap_err();
        assert_eq!((e.line, e.col), (2, 7));
        assert!(e.message.contains("unexpected character"), "{e}");
    }
}
