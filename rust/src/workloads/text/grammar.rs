//! Grammar layer of the textual workload format: positioned tokens →
//! AST. Purely syntactic — name resolution, rank checks and the
//! lowering to PRA IR live in [`super::semantics`].
//!
//! The format is line-oriented; one directive per line. Keywords are
//! contextual identifiers. The full grammar (also reproduced in the
//! README's "Bring your own workload" section):
//!
//! ```text
//! file      := 'workload' NAME NL (phase+ | item+)
//! phase     := 'phase' NAME '{' NL item+ '}' NL
//! item      := loop | tensor | requires | stmt | propagate | reduce
//! loop      := 'loop' ITER 'in' '0' '..' BOUND NL
//! tensor    := 'tensor' NAME '[' dim (',' dim)* ']' NL
//! dim       := BOUND | INT
//! requires  := 'requires' aff cmp aff NL
//! stmt      := 'stmt' [NAME] ':' access '=' rhs ['if' cond (',' cond)*] NL
//! rhs       := access | access '+' access ['+' access]
//!            | access '-' access | access '*' access
//!            | 'max' '(' access ',' access ')'
//! access    := NAME '[' aff (',' aff)* ']'
//! cond      := aff cmp aff
//! cmp       := '==' | '>=' | '<=' | '>' | '<'
//! aff       := ['-'] term (('+'|'-') term)*
//! term      := INT ['*' IDENT] | IDENT
//! propagate := 'propagate' VAR '=' access 'along' ITER NL
//! reduce    := 'reduce' VAR '=' VAR 'along' ITER NL
//! ```
//!
//! `#` starts a comment; blank lines are free. Products of two
//! identifiers (`N0*N0`) are rejected here with a `non-affine
//! expression` diagnostic — every index, bound and condition must stay
//! affine for the polyhedral machinery to apply.

use super::literals::{lex, ParseError, Pos, Tok, Token};

/// A parsed workload file.
#[derive(Debug, Clone)]
pub struct Ast {
    pub name: String,
    pub name_pos: Pos,
    pub phases: Vec<PhaseAst>,
}

/// One phase block (or the whole file in single-phase shorthand, in
/// which case the phase inherits the workload name).
#[derive(Debug, Clone)]
pub struct PhaseAst {
    pub name: String,
    pub pos: Pos,
    pub items: Vec<Item>,
}

/// One `coeff · ident` term of an affine expression (`ident = None`
/// for the constant part).
#[derive(Debug, Clone)]
pub struct Term {
    pub coeff: i64,
    pub ident: Option<(String, Pos)>,
}

/// A (syntactically) affine expression: a sum of terms.
#[derive(Debug, Clone)]
pub struct AffAst {
    pub pos: Pos,
    pub terms: Vec<Term>,
}

/// Comparison operator of a `requires` line or an `if` condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    Eq,
    Ge,
    Le,
    Gt,
    Lt,
}

/// An indexed access `name[aff, …]` (tensor or internal variable —
/// resolved by the semantic layer).
#[derive(Debug, Clone)]
pub struct AccessAst {
    pub name: String,
    pub pos: Pos,
    pub indices: Vec<AffAst>,
}

/// One `if` condition `aff cmp aff`.
#[derive(Debug, Clone)]
pub struct CondAst {
    pub lhs: AffAst,
    pub cmp: Cmp,
    pub rhs: AffAst,
    pub pos: Pos,
}

/// Statement operator, derived from the shape of the right-hand side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RhsOp {
    Copy,
    Add,
    Sub,
    Mul,
    Add3,
    Max,
}

/// One directive.
#[derive(Debug, Clone)]
pub enum Item {
    Loop {
        iter: String,
        iter_pos: Pos,
        bound: AffAst,
        pos: Pos,
    },
    Tensor {
        name: String,
        pos: Pos,
        dims: Vec<AffAst>,
    },
    Requires {
        lhs: AffAst,
        cmp: Cmp,
        rhs: AffAst,
        pos: Pos,
    },
    Stmt {
        /// Explicit statement name; `None` auto-assigns `S1, S2, …` in
        /// file order (matching [`crate::workloads::PraBuilder`]).
        name: Option<String>,
        name_pos: Pos,
        lhs: AccessAst,
        op: RhsOp,
        args: Vec<AccessAst>,
        cond: Vec<CondAst>,
        pos: Pos,
    },
    Propagate {
        var: String,
        var_pos: Pos,
        tensor: AccessAst,
        along: String,
        along_pos: Pos,
        pos: Pos,
    },
    Reduce {
        var: String,
        var_pos: Pos,
        term: String,
        term_pos: Pos,
        along: String,
        along_pos: Pos,
        pos: Pos,
    },
}

/// Parse source text into an [`Ast`].
pub fn parse(src: &str) -> Result<Ast, ParseError> {
    let tokens = lex(src)?;
    let mut p = Parser { tokens, at: 0 };
    p.file()
}

struct Parser {
    tokens: Vec<Token>,
    at: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        // The token stream always ends with a synthetic Newline; treat
        // anything past it as more newlines so peeks never panic.
        self.tokens
            .get(self.at)
            .map(|t| &t.tok)
            .unwrap_or(&Tok::Newline)
    }

    fn pos(&self) -> Pos {
        self.tokens
            .get(self.at)
            .or_else(|| self.tokens.last())
            .map(|t| t.pos)
            .unwrap_or(Pos { line: 1, col: 1 })
    }

    fn at_eof(&self) -> bool {
        self.at >= self.tokens.len()
    }

    fn bump(&mut self) -> Tok {
        let t = self.peek().clone();
        self.at += 1;
        t
    }

    fn expect(&mut self, want: &Tok, ctx: &str) -> Result<Pos, ParseError> {
        let pos = self.pos();
        if self.peek() == want {
            self.bump();
            Ok(pos)
        } else {
            Err(ParseError::at(
                pos,
                format!(
                    "expected {} {ctx}, found {}",
                    want.describe(),
                    self.peek().describe()
                ),
            ))
        }
    }

    fn expect_ident(&mut self, ctx: &str) -> Result<(String, Pos), ParseError> {
        let pos = self.pos();
        match self.peek().clone() {
            Tok::Ident(s) => {
                self.bump();
                Ok((s, pos))
            }
            other => Err(ParseError::at(
                pos,
                format!("expected a name {ctx}, found {}", other.describe()),
            )),
        }
    }

    /// The contextual keyword `kw` (lexed as an identifier).
    fn expect_keyword(&mut self, kw: &str) -> Result<Pos, ParseError> {
        let pos = self.pos();
        match self.peek() {
            Tok::Ident(s) if s == kw => {
                self.bump();
                Ok(pos)
            }
            other => Err(ParseError::at(
                pos,
                format!("expected `{kw}`, found {}", other.describe()),
            )),
        }
    }

    fn skip_newlines(&mut self) {
        while !self.at_eof() && *self.peek() == Tok::Newline {
            self.bump();
        }
    }

    fn end_of_line(&mut self, ctx: &str) -> Result<(), ParseError> {
        self.expect(&Tok::Newline, ctx)?;
        Ok(())
    }

    fn file(&mut self) -> Result<Ast, ParseError> {
        self.skip_newlines();
        self.expect_keyword("workload").map_err(|e| {
            ParseError {
                message: format!(
                    "{} (a workload file starts with `workload NAME`)",
                    e.message
                ),
                ..e
            }
        })?;
        let (name, name_pos) = self.expect_ident("after `workload`")?;
        self.end_of_line("after the workload header")?;
        self.skip_newlines();
        let mut phases = Vec::new();
        if matches!(self.peek(), Tok::Ident(s) if s == "phase") {
            // Multi-phase form: every item lives in a phase block.
            while !self.at_eof() {
                if *self.peek() == Tok::Newline {
                    self.bump();
                    continue;
                }
                phases.push(self.phase_block()?);
            }
        } else if !self.at_eof() {
            // Single-phase shorthand: top-level items, phase = workload.
            let items = self.items_until(None, name_pos)?;
            phases.push(PhaseAst { name: name.clone(), pos: name_pos, items });
        }
        Ok(Ast { name, name_pos, phases })
    }

    fn phase_block(&mut self) -> Result<PhaseAst, ParseError> {
        self.expect_keyword("phase")?;
        let (name, pos) = self.expect_ident("after `phase`")?;
        let open = self.expect(&Tok::LBrace, "to open the phase block")?;
        self.end_of_line("after `{`")?;
        let items = self.items_until(Some((open, name.clone())), pos)?;
        self.end_of_line("after `}`")?;
        Ok(PhaseAst { name, pos, items })
    }

    /// Items until `}` (inside a block) or end of file (flat form).
    /// `block` carries the opening-brace position for the unterminated
    /// diagnostic.
    fn items_until(
        &mut self,
        block: Option<(Pos, String)>,
        _phase_pos: Pos,
    ) -> Result<Vec<Item>, ParseError> {
        let mut items = Vec::new();
        loop {
            if *self.peek() == Tok::Newline && !self.at_eof() {
                self.bump();
                continue;
            }
            match (&block, self.peek()) {
                (Some(_), Tok::RBrace) => {
                    self.bump();
                    return Ok(items);
                }
                (Some((open, name)), _) if self.at_eof() => {
                    return Err(ParseError::at(
                        *open,
                        format!(
                            "unterminated phase block `{name}` (no closing \
                             `}}` before end of file)"
                        ),
                    ));
                }
                (None, _) if self.at_eof() => return Ok(items),
                _ => items.push(self.item()?),
            }
        }
    }

    fn item(&mut self) -> Result<Item, ParseError> {
        let pos = self.pos();
        let kw = match self.peek() {
            Tok::Ident(s) => s.clone(),
            other => {
                return Err(ParseError::at(
                    pos,
                    format!(
                        "expected a directive (loop, tensor, requires, \
                         stmt, propagate, reduce), found {}",
                        other.describe()
                    ),
                ))
            }
        };
        match kw.as_str() {
            "loop" => self.loop_item(pos),
            "tensor" => self.tensor_item(pos),
            "requires" => self.requires_item(pos),
            "stmt" => self.stmt_item(pos),
            "propagate" => self.propagate_item(pos),
            "reduce" => self.reduce_item(pos),
            "phase" => Err(ParseError::at(
                pos,
                "`phase` blocks cannot be mixed with top-level items \
                 (move every item into a phase block)",
            )),
            other => Err(ParseError::at(
                pos,
                format!(
                    "unknown directive `{other}`; expected loop, tensor, \
                     requires, stmt, propagate, or reduce"
                ),
            )),
        }
    }

    fn loop_item(&mut self, pos: Pos) -> Result<Item, ParseError> {
        self.expect_keyword("loop")?;
        let (iter, iter_pos) = self.expect_ident("for the loop iterator")?;
        self.expect_keyword("in")?;
        let zero = self.pos();
        match self.bump() {
            Tok::Int(0) => {}
            other => {
                return Err(ParseError::at(
                    zero,
                    format!(
                        "loop ranges start at 0 (`loop {iter} in 0..N`), \
                         found {}",
                        other.describe()
                    ),
                ))
            }
        }
        self.expect(&Tok::DotDot, "in the loop range")?;
        let bound = self.aff()?;
        self.end_of_line("after the loop bound")?;
        Ok(Item::Loop { iter, iter_pos, bound, pos })
    }

    fn tensor_item(&mut self, pos: Pos) -> Result<Item, ParseError> {
        self.expect_keyword("tensor")?;
        let (name, _) = self.expect_ident("for the tensor")?;
        self.expect(&Tok::LBracket, "to open the tensor shape")?;
        let mut dims = vec![self.aff()?];
        while *self.peek() == Tok::Comma {
            self.bump();
            dims.push(self.aff()?);
        }
        self.expect(&Tok::RBracket, "to close the tensor shape")?;
        self.end_of_line("after the tensor declaration")?;
        Ok(Item::Tensor { name, pos, dims })
    }

    fn requires_item(&mut self, pos: Pos) -> Result<Item, ParseError> {
        self.expect_keyword("requires")?;
        let lhs = self.aff()?;
        let cmp = self.cmp()?;
        let rhs = self.aff()?;
        self.end_of_line("after the requires constraint")?;
        Ok(Item::Requires { lhs, cmp, rhs, pos })
    }

    fn stmt_item(&mut self, pos: Pos) -> Result<Item, ParseError> {
        self.expect_keyword("stmt")?;
        let name_pos = self.pos();
        let name = match self.peek().clone() {
            Tok::Ident(s) => {
                self.bump();
                Some(s)
            }
            _ => None,
        };
        self.expect(&Tok::Colon, "after `stmt` (statement names are \
                                  optional: `stmt:` auto-names S1, S2, …)")?;
        let lhs = self.access()?;
        self.expect(&Tok::Assign, "between the target and the expression")?;
        let (op, args) = self.rhs()?;
        let mut cond = Vec::new();
        if matches!(self.peek(), Tok::Ident(s) if s == "if") {
            self.bump();
            cond.push(self.cond()?);
            while *self.peek() == Tok::Comma {
                self.bump();
                cond.push(self.cond()?);
            }
        }
        self.end_of_line("after the statement")?;
        Ok(Item::Stmt { name, name_pos, lhs, op, args, cond, pos })
    }

    fn propagate_item(&mut self, pos: Pos) -> Result<Item, ParseError> {
        self.expect_keyword("propagate")?;
        let (var, var_pos) = self.expect_ident("for the propagated value")?;
        self.expect(&Tok::Assign, "in the propagate directive")?;
        let tensor = self.access()?;
        self.expect_keyword("along")?;
        let (along, along_pos) = self.expect_ident("after `along`")?;
        self.end_of_line("after the propagate directive")?;
        Ok(Item::Propagate { var, var_pos, tensor, along, along_pos, pos })
    }

    fn reduce_item(&mut self, pos: Pos) -> Result<Item, ParseError> {
        self.expect_keyword("reduce")?;
        let (var, var_pos) = self.expect_ident("for the reduction result")?;
        self.expect(&Tok::Assign, "in the reduce directive")?;
        let (term, term_pos) = self.expect_ident("for the reduced term")?;
        self.expect_keyword("along")?;
        let (along, along_pos) = self.expect_ident("after `along`")?;
        self.end_of_line("after the reduce directive")?;
        Ok(Item::Reduce { var, var_pos, term, term_pos, along, along_pos, pos })
    }

    fn cmp(&mut self) -> Result<Cmp, ParseError> {
        let pos = self.pos();
        let c = match self.peek() {
            Tok::EqEq => Cmp::Eq,
            Tok::Ge => Cmp::Ge,
            Tok::Le => Cmp::Le,
            Tok::Gt => Cmp::Gt,
            Tok::Lt => Cmp::Lt,
            Tok::Assign => {
                return Err(ParseError::at(
                    pos,
                    "comparisons use `==` (a single `=` is assignment)",
                ))
            }
            other => {
                return Err(ParseError::at(
                    pos,
                    format!(
                        "expected a comparison (==, >=, <=, >, <), \
                         found {}",
                        other.describe()
                    ),
                ))
            }
        };
        self.bump();
        Ok(c)
    }

    fn cond(&mut self) -> Result<CondAst, ParseError> {
        let pos = self.pos();
        let lhs = self.aff()?;
        let cmp = self.cmp()?;
        let rhs = self.aff()?;
        Ok(CondAst { lhs, cmp, rhs, pos })
    }

    /// `name[aff, …]` — every statement operand is indexed; bare names
    /// appear only in the `propagate`/`reduce` sugar.
    fn access(&mut self) -> Result<AccessAst, ParseError> {
        let (name, pos) = self.expect_ident("for an indexed access")?;
        self.expect(
            &Tok::LBracket,
            "to open the index list (every statement operand is indexed, \
             e.g. `x[i0, i1]`)",
        )?;
        let mut indices = vec![self.aff()?];
        while *self.peek() == Tok::Comma {
            self.bump();
            indices.push(self.aff()?);
        }
        self.expect(&Tok::RBracket, "to close the index list")?;
        Ok(AccessAst { name, pos, indices })
    }

    /// Statement right-hand side: 1–3 accesses joined by one operator
    /// kind, or `max(a, b)`.
    fn rhs(&mut self) -> Result<(RhsOp, Vec<AccessAst>), ParseError> {
        if matches!(self.peek(), Tok::Ident(s) if s == "max")
            && self.tokens.get(self.at + 1).map(|t| &t.tok)
                == Some(&Tok::LParen)
        {
            self.bump();
            self.bump();
            let a = self.access()?;
            self.expect(&Tok::Comma, "between the max operands")?;
            let b = self.access()?;
            self.expect(&Tok::RParen, "to close max(…)")?;
            return Ok((RhsOp::Max, vec![a, b]));
        }
        let first = self.access()?;
        match self.peek().clone() {
            Tok::Plus => {
                self.bump();
                let second = self.access()?;
                if *self.peek() == Tok::Plus {
                    self.bump();
                    let third = self.access()?;
                    if *self.peek() == Tok::Plus {
                        return Err(ParseError::at(
                            self.pos(),
                            "at most three addends per statement (PRA \
                             operators are unary/binary/ternary); split \
                             the sum across statements",
                        ));
                    }
                    Ok((RhsOp::Add3, vec![first, second, third]))
                } else {
                    Ok((RhsOp::Add, vec![first, second]))
                }
            }
            Tok::Minus => {
                self.bump();
                let second = self.access()?;
                Ok((RhsOp::Sub, vec![first, second]))
            }
            Tok::Star => {
                self.bump();
                let second = self.access()?;
                Ok((RhsOp::Mul, vec![first, second]))
            }
            _ => Ok((RhsOp::Copy, vec![first])),
        }
    }

    /// An affine expression. Products of two identifiers are rejected
    /// here — the diagnostic every non-affine bound/index/condition
    /// funnels through.
    fn aff(&mut self) -> Result<AffAst, ParseError> {
        let pos = self.pos();
        let mut terms = Vec::new();
        let mut sign = 1i64;
        if *self.peek() == Tok::Minus {
            self.bump();
            sign = -1;
        }
        loop {
            let tpos = self.pos();
            match self.peek().clone() {
                Tok::Int(v) => {
                    self.bump();
                    if *self.peek() == Tok::Star {
                        self.bump();
                        let (name, npos) =
                            self.expect_ident("after `*` in a coefficient \
                                               term")?;
                        terms.push(Term {
                            coeff: sign * v,
                            ident: Some((name, npos)),
                        });
                    } else {
                        terms.push(Term { coeff: sign * v, ident: None });
                    }
                }
                Tok::Ident(name) => {
                    self.bump();
                    if *self.peek() == Tok::Star {
                        return Err(ParseError::at(
                            self.pos(),
                            format!(
                                "non-affine expression: product with \
                                 `{name}` (only integer coefficients may \
                                 multiply a name, e.g. `2*{name}`)"
                            ),
                        ));
                    }
                    terms.push(Term { coeff: sign, ident: Some((name, tpos)) });
                }
                other => {
                    return Err(ParseError::at(
                        tpos,
                        format!(
                            "expected an affine term (integer or name), \
                             found {}",
                            other.describe()
                        ),
                    ))
                }
            }
            match self.peek() {
                Tok::Plus => {
                    self.bump();
                    sign = 1;
                }
                Tok::Minus => {
                    self.bump();
                    sign = -1;
                }
                _ => break,
            }
        }
        Ok(AffAst { pos, terms })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_and_phased_forms_parse() {
        let flat = parse(
            "workload w\nloop i0 in 0..N0\nstmt: y[i0] = x[i0]\n",
        )
        .unwrap();
        assert_eq!(flat.name, "w");
        assert_eq!(flat.phases.len(), 1);
        assert_eq!(flat.phases[0].name, "w");
        assert_eq!(flat.phases[0].items.len(), 2);

        let phased = parse(
            "workload two\nphase a {\n loop i0 in 0..N0\n}\n\
             phase b {\n loop i0 in 0..N0\n}\n",
        )
        .unwrap();
        assert_eq!(phased.phases.len(), 2);
        assert_eq!(phased.phases[1].name, "b");
    }

    #[test]
    fn unterminated_block_points_at_the_open_brace() {
        let e = parse("workload w\nphase p {\n loop i0 in 0..N0\n")
            .unwrap_err();
        assert!(e.message.starts_with("unterminated phase block"), "{e}");
        assert_eq!((e.line, e.col), (2, 9));
    }

    #[test]
    fn non_affine_products_are_rejected_at_the_star() {
        let e =
            parse("workload w\nloop i0 in 0..N0*N0\n").unwrap_err();
        assert!(e.message.starts_with("non-affine expression"), "{e}");
        assert_eq!(e.line, 2);
    }

    #[test]
    fn rhs_shapes_map_to_operators() {
        let src = "workload w\nloop i0 in 0..N0\n\
                   stmt: a[i0] = b[i0]\n\
                   stmt: c[i0] = a[i0] + b[i0] + a[i0]\n\
                   stmt: d[i0] = max(a[i0], c[i0])\n";
        let ast = parse(src).unwrap();
        let ops: Vec<RhsOp> = ast.phases[0]
            .items
            .iter()
            .filter_map(|i| match i {
                Item::Stmt { op, .. } => Some(*op),
                _ => None,
            })
            .collect();
        assert_eq!(ops, vec![RhsOp::Copy, RhsOp::Add3, RhsOp::Max]);
    }
}
