//! Semantic layer of the textual workload format: AST → PRA IR.
//!
//! Name resolution, rank checking, and the lowering rules that make a
//! parsed file *bit-identical* to the equivalent
//! [`crate::workloads::PraBuilder`] construction — the workload
//! fingerprint hashes the IR's `Debug` form, so textual renditions of
//! builtins share cache entries with their Rust constructors only if
//! the lowered constraints match coefficient for coefficient. The
//! invariants that guarantee this:
//!
//! - the parameter space is always the canonical
//!   [`crate::polyhedral::ParamSpace::loop_nest`] space; surface bound
//!   names map positionally (the bound of the ℓ-th `loop` line is
//!   parameter ℓ, whatever it is called in the file);
//! - `if` conditions lower `lhs cmp rhs` to the exact
//!   [`CondConstraint`] forms the builder sugar produces (`==` becomes
//!   the `[≥, ≤]` pair in that order, matching `eq_const`);
//! - `requires` lines lower through [`Constraint::ge`]/[`le`]/… whose
//!   gcd-normalisation is idempotent, and `==` again expands ≥-then-≤
//!   (matching `require_equal_bounds`);
//! - `propagate`/`reduce` reuse the builder sugar itself, so the
//!   generated statement triples and auto-assigned names line up.
//!
//! Deliberately *not* validated here: deep structural and polyhedral
//! properties (bounds-safety, dependence coverage, guard
//! satisfiability). Those are the lint engine's job — the frontend
//! lowers via [`PraBuilder::build_unchecked`] and the CLI routes every
//! parsed workload through the `lint_pra` deny gate, which reports
//! stable L-codes instead of panicking.
//!
//! [`Constraint::ge`]: crate::polyhedral::Constraint::ge
//! [`le`]: crate::polyhedral::Constraint::le

use std::collections::{HashMap, HashSet};

use super::grammar::{AccessAst, AffAst, Ast, Cmp, Item, PhaseAst, RhsOp};
use super::literals::{ParseError, Pos};
use crate::polyhedral::{AffineExpr, Constraint};
use crate::pra::ir::{
    CondConstraint, IndexMap, Lhs, Op, Operand, Pra, TensorDim, Workload,
};
use crate::workloads::PraBuilder;

/// Maximum loop depth accepted from untrusted input (builtins use ≤ 3;
/// the polyhedral machinery is exponential in depth).
const MAX_NDIMS: usize = 8;

/// Lower a parsed [`Ast`] to a [`Workload`].
pub fn lower(ast: &Ast) -> Result<Workload, ParseError> {
    if ast.phases.is_empty() {
        return Err(ParseError::at(
            ast.name_pos,
            format!("workload `{}` has no phases (declare loops and \
                     statements, or phase blocks)", ast.name),
        ));
    }
    let mut seen_phases = HashSet::new();
    let mut phases = Vec::with_capacity(ast.phases.len());
    for ph in &ast.phases {
        if !seen_phases.insert(ph.name.clone()) {
            return Err(ParseError::at(
                ph.pos,
                format!("duplicate phase name `{}`", ph.name),
            ));
        }
        phases.push(lower_phase(ph)?);
    }
    Ok(Workload { name: ast.name.clone(), phases })
}

/// Per-phase name environment: loop iterators, bound parameters, tensor
/// shapes.
struct Env {
    ndims: usize,
    nparams: usize,
    /// iterator name → loop dimension.
    iters: HashMap<String, usize>,
    /// surface bound name → loop dimension (= parameter index).
    bounds: HashMap<String, usize>,
    /// tensor name → declared shape.
    tensors: HashMap<String, Vec<TensorDim>>,
}

fn lower_phase(ph: &PhaseAst) -> Result<Pra, ParseError> {
    // Pass 1: loops (fixing dimensions in file order) and tensor
    // declarations, so later items resolve names regardless of order.
    let mut env = Env {
        ndims: 0,
        nparams: 0,
        iters: HashMap::new(),
        bounds: HashMap::new(),
        tensors: HashMap::new(),
    };
    let mut tensor_items: Vec<(&str, Vec<TensorDim>)> = Vec::new();
    for item in &ph.items {
        match item {
            Item::Loop { iter, iter_pos, bound, pos: _ } => {
                let dim = env.iters.len();
                if dim >= MAX_NDIMS {
                    return Err(ParseError::at(
                        *iter_pos,
                        format!("too many loops (max {MAX_NDIMS})"),
                    ));
                }
                if env.iters.contains_key(iter) {
                    return Err(ParseError::at(
                        *iter_pos,
                        format!("duplicate loop iterator `{iter}`"),
                    ));
                }
                let bname = single_fresh_name(bound, &env)?;
                env.iters.insert(iter.clone(), dim);
                env.bounds.insert(bname, dim);
            }
            Item::Tensor { name, pos, dims } => {
                if env.tensors.contains_key(name) {
                    return Err(ParseError::at(
                        *pos,
                        format!("duplicate tensor `{name}`"),
                    ));
                }
                // Shapes are resolved in pass 1b below, once every
                // loop (and hence every bound name) is known.
                tensor_items.push((name, Vec::new()));
                env.tensors.insert(name.clone(), Vec::new());
                let _ = dims;
            }
            _ => {}
        }
    }
    env.ndims = env.iters.len();
    env.nparams = 2 * env.ndims;
    if env.ndims == 0 {
        return Err(ParseError::at(
            ph.pos,
            format!("phase `{}` declares no loops", ph.name),
        ));
    }

    // Pass 1b: resolve tensor shapes (bounds are all known now).
    let mut t_at = 0usize;
    for item in &ph.items {
        if let Item::Tensor { name, dims, .. } = item {
            let shape: Vec<TensorDim> = dims
                .iter()
                .map(|d| tensor_dim(d, &env))
                .collect::<Result<_, _>>()?;
            env.tensors.insert(name.clone(), shape.clone());
            tensor_items[t_at].1 = shape;
            t_at += 1;
        }
    }

    let mut b = PraBuilder::new(&ph.name, env.ndims);
    for (name, shape) in tensor_items {
        b.tensor_decl(name, shape);
    }

    // Pass 2: requires and statements, in file order. `auto` mirrors
    // the builder's S1, S2, … counter (advanced by anonymous `stmt`,
    // `propagate` ×2, `reduce` ×3 — never by explicit names) so
    // duplicate names are caught here with a position instead of
    // surfacing later as an unanchored lint finding.
    let mut auto = 1usize;
    let mut names: HashSet<String> = HashSet::new();
    let mut defined: HashSet<String> = HashSet::new();
    let mut var_reads: Vec<(String, Pos)> = Vec::new();
    let mut claim = |name: String, pos: Pos, names: &mut HashSet<String>| {
        if names.insert(name.clone()) {
            Ok(())
        } else {
            Err(ParseError::at(
                pos,
                format!("duplicate statement name `{name}`"),
            ))
        }
    };
    for item in &ph.items {
        match item {
            Item::Loop { .. } | Item::Tensor { .. } => {}
            Item::Requires { lhs, cmp, rhs, pos: _ } => {
                let l = aff_over_params(lhs, &env)?;
                let r = aff_over_params(rhs, &env)?;
                match cmp {
                    Cmp::Eq => {
                        b.require(Constraint::ge(&l, &r));
                        b.require(Constraint::le(&l, &r));
                    }
                    Cmp::Ge => {
                        b.require(Constraint::ge(&l, &r));
                    }
                    Cmp::Le => {
                        b.require(Constraint::le(&l, &r));
                    }
                    Cmp::Gt => {
                        b.require(Constraint::gt(&l, &r));
                    }
                    Cmp::Lt => {
                        b.require(Constraint::lt(&l, &r));
                    }
                }
            }
            Item::Stmt { name, name_pos, lhs, op, args, cond, pos: _ } => {
                let lowered_lhs = lower_lhs(lhs, &env)?;
                if let Lhs::Var(v) = &lowered_lhs {
                    defined.insert(v.clone());
                }
                let op = match op {
                    RhsOp::Copy => Op::Copy,
                    RhsOp::Add => Op::Add,
                    RhsOp::Sub => Op::Sub,
                    RhsOp::Mul => Op::Mul,
                    RhsOp::Add3 => Op::Add3,
                    RhsOp::Max => Op::Max,
                };
                let args: Vec<Operand> = args
                    .iter()
                    .map(|a| lower_operand(a, &env, &mut var_reads))
                    .collect::<Result<_, _>>()?;
                let cond: Vec<CondConstraint> = {
                    let mut cs = Vec::new();
                    for c in cond {
                        lower_cond(c, &env, &mut cs)?;
                    }
                    cs
                };
                match name {
                    Some(n) => {
                        claim(n.clone(), *name_pos, &mut names)?;
                        b.named_stmt(n, lowered_lhs, op, args, cond);
                    }
                    None => {
                        claim(format!("S{auto}"), *name_pos, &mut names)?;
                        auto += 1;
                        b.stmt(lowered_lhs, op, args, cond);
                    }
                }
            }
            Item::Propagate { var, var_pos, tensor, along, along_pos, pos: _ } => {
                let dim = iter_dim(along, *along_pos, &env)?;
                if !env.tensors.contains_key(&tensor.name) {
                    return Err(ParseError::at(
                        tensor.pos,
                        format!(
                            "unknown tensor `{}` (propagate broadcasts a \
                             declared input tensor)",
                            tensor.name
                        ),
                    ));
                }
                let map = tensor_map(tensor, &env)?;
                for k in 0..2 {
                    claim(format!("S{}", auto + k), *var_pos, &mut names)?;
                }
                auto += 2;
                defined.insert(var.clone());
                b.propagate(var, &tensor.name, map, dim);
            }
            Item::Reduce { var, var_pos, term, term_pos, along, along_pos, pos: _ } => {
                let dim = iter_dim(along, *along_pos, &env)?;
                for k in 0..3 {
                    claim(format!("S{}", auto + k), *var_pos, &mut names)?;
                }
                auto += 3;
                defined.insert(var.clone());
                defined.insert(format!("{var}*"));
                var_reads.push((term.clone(), *term_pos));
                b.acc_chain(var, term, dim);
            }
        }
    }

    // Post-pass: every internal-variable read must have a defining
    // statement somewhere in the phase (single-assignment semantics are
    // order-free, so this runs after all items).
    for (name, pos) in &var_reads {
        if !defined.contains(name) {
            return Err(ParseError::at(
                *pos,
                format!(
                    "dangling dependence: variable `{name}` is read but \
                     never defined"
                ),
            ));
        }
    }

    // Structural validity beyond this point is the lint gate's job.
    Ok(b.build_unchecked())
}

/// A loop bound: exactly one fresh bare name with coefficient 1.
fn single_fresh_name(aff: &AffAst, env: &Env) -> Result<String, ParseError> {
    if let [t] = aff.terms.as_slice() {
        if let (1, Some((name, pos))) = (t.coeff, &t.ident) {
            if env.iters.contains_key(name) || env.bounds.contains_key(name) {
                return Err(ParseError::at(
                    *pos,
                    format!("loop bound `{name}` is already in use"),
                ));
            }
            return Ok(name.clone());
        }
    }
    Err(ParseError::at(
        aff.pos,
        "loop bound must be a single fresh parameter name (e.g. \
         `loop i0 in 0..N0`)",
    ))
}

fn iter_dim(name: &str, pos: Pos, env: &Env) -> Result<usize, ParseError> {
    env.iters.get(name).copied().ok_or_else(|| {
        ParseError::at(pos, format!("unknown loop iterator `{name}`"))
    })
}

/// One tensor dimension: a fixed integer or a single loop-bound name.
fn tensor_dim(aff: &AffAst, env: &Env) -> Result<TensorDim, ParseError> {
    if let [t] = aff.terms.as_slice() {
        match (&t.ident, t.coeff) {
            (None, c) => return Ok(TensorDim::Fixed(c)),
            (Some((name, pos)), 1) => {
                if let Some(&dim) = env.bounds.get(name) {
                    return Ok(TensorDim::Param(dim));
                }
                if env.iters.contains_key(name) {
                    return Err(ParseError::at(
                        *pos,
                        format!(
                            "tensor dimensions must be a loop bound or a \
                             fixed integer, not the iterator `{name}`"
                        ),
                    ));
                }
                return Err(ParseError::at(
                    *pos,
                    format!("unknown parameter `{name}`"),
                ));
            }
            _ => {}
        }
    }
    Err(ParseError::at(
        aff.pos,
        "tensor dimensions must be a loop bound or a fixed integer",
    ))
}

/// An affine expression over *parameters only* (`requires` lines).
fn aff_over_params(aff: &AffAst, env: &Env) -> Result<AffineExpr, ParseError> {
    let mut e = AffineExpr::zero(env.nparams);
    for t in &aff.terms {
        match &t.ident {
            None => e.konst += t.coeff,
            Some((name, pos)) => {
                if let Some(&dim) = env.bounds.get(name) {
                    e.coeffs[dim] += t.coeff;
                } else if env.iters.contains_key(name) {
                    return Err(ParseError::at(
                        *pos,
                        format!(
                            "loop iterator `{name}` cannot appear in a \
                             `requires` constraint (parameters only)"
                        ),
                    ));
                } else {
                    return Err(ParseError::at(
                        *pos,
                        format!("unknown parameter `{name}`"),
                    ));
                }
            }
        }
    }
    Ok(e)
}

/// Split an affine expression into iterator coefficients and a
/// parametric remainder: `Σ a_ℓ·i_ℓ + (Σ c_k·N_k + konst)`.
fn aff_split(
    aff: &AffAst,
    env: &Env,
) -> Result<(Vec<i64>, AffineExpr), ParseError> {
    let mut a = vec![0i64; env.ndims];
    let mut e = AffineExpr::zero(env.nparams);
    for t in &aff.terms {
        match &t.ident {
            None => e.konst += t.coeff,
            Some((name, pos)) => {
                if let Some(&dim) = env.iters.get(name) {
                    a[dim] += t.coeff;
                } else if let Some(&dim) = env.bounds.get(name) {
                    e.coeffs[dim] += t.coeff;
                } else {
                    return Err(ParseError::at(
                        *pos,
                        format!("unknown parameter `{name}`"),
                    ));
                }
            }
        }
    }
    Ok((a, e))
}

/// Lower `lhs cmp rhs` into [`CondConstraint`]s, appending to `out`.
///
/// With `D = lhs − rhs` split as `a·i + p`, the forms are exactly what
/// the builder sugar emits: `≥` → `{a, p}`; `>` → `{a, p − 1}`;
/// `≤` → `{−a, −p}`; `<` → `{−a, −p − 1}`; `==` → the `≥` pair then
/// the `≤` pair (matching `eq_const`).
fn lower_cond(
    c: &super::grammar::CondAst,
    env: &Env,
    out: &mut Vec<CondConstraint>,
) -> Result<(), ParseError> {
    let (la, le) = aff_split(&c.lhs, env)?;
    let (ra, re) = aff_split(&c.rhs, env)?;
    let a: Vec<i64> = la.iter().zip(&ra).map(|(x, y)| x - y).collect();
    let p = &le - &re;
    let neg_a: Vec<i64> = a.iter().map(|x| -x).collect();
    match c.cmp {
        Cmp::Ge => out.push(CondConstraint { a, konst: p }),
        Cmp::Gt => out.push(CondConstraint { a, konst: p.plus(-1) }),
        Cmp::Le => out.push(CondConstraint { a: neg_a, konst: -&p }),
        Cmp::Lt => {
            out.push(CondConstraint { a: neg_a, konst: (-&p).plus(-1) })
        }
        Cmp::Eq => {
            out.push(CondConstraint { a, konst: p.clone() });
            out.push(CondConstraint { a: neg_a, konst: -&p });
        }
    }
    Ok(())
}

/// Lower a tensor access into an [`IndexMap`], rank-checked against the
/// declaration. Indices may mix iterators and integer offsets but not
/// bound parameters (a tensor extent is parametric; an *index* into it
/// must be an affine function of iterators alone).
fn tensor_map(acc: &AccessAst, env: &Env) -> Result<IndexMap, ParseError> {
    let shape = &env.tensors[&acc.name];
    if acc.indices.len() != shape.len() {
        return Err(ParseError::at(
            acc.pos,
            format!(
                "rank mismatch: tensor `{}` has rank {} but the access \
                 has {} indices",
                acc.name,
                shape.len(),
                acc.indices.len()
            ),
        ));
    }
    let mut rows = Vec::with_capacity(acc.indices.len());
    let mut offset = Vec::with_capacity(acc.indices.len());
    for idx in &acc.indices {
        let (row, p) = aff_split(idx, env)?;
        if p.coeffs.iter().any(|&c| c != 0) {
            return Err(ParseError::at(
                idx.pos,
                format!(
                    "tensor index into `{}` may not involve a bound \
                     parameter",
                    acc.name
                ),
            ));
        }
        rows.push(row);
        offset.push(p.konst);
    }
    Ok(IndexMap { rows, offset })
}

/// Lower an internal-variable read `x[i0 − d0, i1 − d1, …]` into its
/// dependence vector: index ℓ must be iterator ℓ minus a constant.
fn var_dep(acc: &AccessAst, env: &Env) -> Result<Vec<i64>, ParseError> {
    let shape_err = || {
        ParseError::at(
            acc.pos,
            format!(
                "internal-variable reads must be of the form `i - d` per \
                 dimension (`{0}[i0, i1]` or `{0}[i0 - 1, i1]`), with \
                 all {1} iterators in order",
                acc.name, env.ndims
            ),
        )
    };
    if acc.indices.len() != env.ndims {
        return Err(shape_err());
    }
    let mut dep = Vec::with_capacity(env.ndims);
    for (l, idx) in acc.indices.iter().enumerate() {
        let (row, p) = aff_split(idx, env)?;
        let unit =
            row.iter().enumerate().all(|(k, &c)| c == i64::from(k == l));
        if !unit || p.coeffs.iter().any(|&c| c != 0) {
            return Err(shape_err());
        }
        dep.push(-p.konst);
    }
    Ok(dep)
}

fn lower_operand(
    acc: &AccessAst,
    env: &Env,
    var_reads: &mut Vec<(String, Pos)>,
) -> Result<Operand, ParseError> {
    if env.tensors.contains_key(&acc.name) {
        Ok(Operand::Tensor {
            name: acc.name.clone(),
            map: tensor_map(acc, env)?,
        })
    } else {
        var_reads.push((acc.name.clone(), acc.pos));
        Ok(Operand::Var { name: acc.name.clone(), dep: var_dep(acc, env)? })
    }
}

fn lower_lhs(acc: &AccessAst, env: &Env) -> Result<Lhs, ParseError> {
    if env.tensors.contains_key(&acc.name) {
        Ok(Lhs::Tensor { name: acc.name.clone(), map: tensor_map(acc, env)? })
    } else {
        let dep = var_dep(acc, env)?;
        if dep.iter().any(|&d| d != 0) {
            return Err(ParseError::at(
                acc.pos,
                format!(
                    "internal-variable writes must use the identity index \
                     `{}[i0, i1, …]` (PRA single-assignment form)",
                    acc.name
                ),
            ));
        }
        Ok(Lhs::Var(acc.name.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::super::grammar::parse;
    use super::*;

    fn lower_src(src: &str) -> Result<Workload, ParseError> {
        lower(&parse(src).unwrap())
    }

    #[test]
    fn elementwise_lowering_matches_builder() {
        let wl = lower_src(
            "workload axpy\n\
             loop i0 in 0..N0\n\
             tensor A[N0]\n\
             tensor B[N0]\n\
             tensor C[N0]\n\
             stmt: C[i0] = A[i0] + B[i0]\n",
        )
        .unwrap();
        let pra = &wl.phases[0];
        assert_eq!(pra.ndims, 1);
        assert_eq!(pra.statements.len(), 1);
        assert_eq!(pra.statements[0].name, "S1");
        assert_eq!(pra.statements[0].op, Op::Add);
        assert!(matches!(&pra.statements[0].lhs, Lhs::Tensor { name, .. }
                         if name == "C"));
    }

    #[test]
    fn conditions_match_builder_sugar() {
        // `if i0 == 0` / `if i0 > 0` / `if i1 >= N1 - 1` /
        // `if i1 <= N1 - 2` against eq_const / gt_const / eq_top /
        // below_top — the bit-identity the fingerprint depends on.
        let wl = lower_src(
            "workload c\n\
             loop i0 in 0..N0\n\
             loop i1 in 0..N1\n\
             tensor T[N0, N1]\n\
             stmt: x[i0, i1] = T[i0, i1] if i0 == 0\n\
             stmt: x[i0, i1] = x[i0 - 1, i1] if i0 > 0\n\
             stmt: T[i0, i1] = x[i0, i1] if i1 >= N1 - 1\n\
             stmt: y[i0, i1] = x[i0, i1] if i1 <= N1 - 2\n",
        )
        .unwrap();
        let b = PraBuilder::new("c", 2);
        let s = &wl.phases[0].statements;
        assert_eq!(s[0].cond, b.eq_const(0, 0));
        assert_eq!(s[1].cond, vec![b.gt_const(0, 0)]);
        assert_eq!(s[2].cond, b.eq_top(1));
        assert_eq!(s[3].cond, vec![b.below_top(1)]);
        assert_eq!(
            s[1].args,
            vec![Operand::var("x", vec![1, 0])],
            "i0 - 1 is the unit dependence along dim 0"
        );
    }

    #[test]
    fn diagnostics_are_anchored() {
        let cases: &[(&str, &str, usize)] = &[
            (
                "workload w\nloop i0 in 0..N0\nrequires M >= 3\n",
                "unknown parameter `M`",
                3,
            ),
            (
                "workload w\nloop i0 in 0..N0\ntensor A[N0, 4]\n\
                 stmt: x[i0] = A[i0]\n",
                "rank mismatch: tensor `A` has rank 2 but the access \
                 has 1 indices",
                4,
            ),
            (
                "workload w\nloop i0 in 0..N0\n\
                 stmt S1: x[i0] = y[i0]\nstmt S1: z[i0] = x[i0]\n",
                "duplicate statement name `S1`",
                4,
            ),
            (
                "workload w\nloop i0 in 0..N0\nstmt: x[i0] = ghost[i0]\n",
                "dangling dependence: variable `ghost` is read but never \
                 defined",
                3,
            ),
        ];
        for (src, want, line) in cases {
            let e = lower_src(src).unwrap_err();
            assert!(e.message.starts_with(want), "{src:?} → {e}");
            assert_eq!(e.line, *line, "{src:?} → {e}");
        }
    }

    #[test]
    fn anonymous_and_sugar_naming_mirrors_the_builder() {
        // propagate (2 names) + anonymous (1) + reduce (3) + explicit:
        // explicit `S4` collides with the reduce's auto-assigned range.
        let e = lower_src(
            "workload w\n\
             loop i0 in 0..N0\nloop i1 in 0..N1\n\
             tensor X[N1]\n\
             propagate x = X[i1] along i0\n\
             stmt: m[i0, i1] = x[i0, i1]\n\
             reduce s = m along i1\n\
             stmt S4: q[i0, i1] = s[i0, i1]\n",
        )
        .unwrap_err();
        assert!(
            e.message.starts_with("duplicate statement name `S4`"),
            "{e}"
        );
    }
}
