//! Textual workload frontend: parse PolyBench-style loop-nest
//! descriptions into [`Workload`]s at runtime (`--workload-file` on the
//! CLI) instead of adding Rust constructors for every scenario.
//!
//! Three layers, each with its own diagnostics anchored to a
//! line/column [`Pos`]:
//!
//! 1. [`literals`] — lexer: source text → positioned tokens;
//! 2. [`grammar`] — parser: tokens → AST (purely syntactic);
//! 3. [`semantics`] — lowering: AST → PRA IR via
//!    [`crate::workloads::PraBuilder`], bit-identical to the builtin
//!    Rust constructors so parsed workloads share fingerprint-keyed
//!    cache entries (memory and disk) with them for free.
//!
//! The frontend validates *names, shapes, and affine-ness* only. Deep
//! validation of untrusted input — bounds-safety, dependence coverage,
//! guard satisfiability, schedule causality — is deliberately left to
//! the existing [`crate::lint`] deny gate and
//! `Schedule::verify_symbolic`, which the CLI applies to every parsed
//! workload.
//!
//! # Format by example
//!
//! ```text
//! # gesummv: y = A·x + B·x  (flat form: one phase named `gesummv`)
//! workload gesummv
//! loop i0 in 0..N0
//! loop i1 in 0..N1
//! tensor A[N0, N1]
//! tensor X[N1]
//! tensor Y[N0]
//! requires N0 >= 1
//! propagate x = X[i1] along i0
//! stmt: a[i0, i1] = A[i0, i1] * x[i0, i1]
//! reduce sA = a along i1
//! stmt: Y[i0] = sA[i0, i1] if i1 >= N1 - 1
//! ```
//!
//! Multi-phase workloads wrap items in `phase NAME { … }` blocks. The
//! full grammar lives in the [`grammar`] module docs (and the README's
//! "Bring your own workload" section). `propagate` and `reduce` are
//! sugar for the broadcast/accumulation statement chains of
//! [`PraBuilder::propagate`] / [`PraBuilder::acc_chain`]; anonymous
//! `stmt:` lines share the same `S1, S2, …` auto-naming counter.
//!
//! [`PraBuilder::propagate`]: crate::workloads::PraBuilder::propagate
//! [`PraBuilder::acc_chain`]: crate::workloads::PraBuilder::acc_chain
//!
//! # Round-tripping
//!
//! [`render_workload`] prints any [`Workload`] — builtin or parsed — in
//! this format with canonical iterator/bound names (`i0…`, `N0…`) and
//! explicit statement names; [`parse_workload`] re-parses the rendition
//! to an identical fingerprint (property-tested over every builtin).
//!
//! ```
//! use tcpa_energy::dse::workload_fingerprint;
//! use tcpa_energy::workloads::{self, text};
//!
//! let wl = text::parse_workload(
//!     "workload axpy\n\
//!      loop i0 in 0..N0\n\
//!      tensor X[N0]\n\
//!      tensor Y[N0]\n\
//!      stmt: Y[i0] = X[i0] + Y[i0]\n",
//! ).unwrap();
//! assert_eq!(wl.phases[0].statements.len(), 1);
//!
//! // Renditions of builtins re-parse to the same fingerprint.
//! let gesummv = workloads::by_name("gesummv").unwrap();
//! let back = text::parse_workload(&text::render_workload(&gesummv)).unwrap();
//! assert_eq!(
//!     workload_fingerprint(&back),
//!     workload_fingerprint(&gesummv),
//! );
//! ```
//!
//! Errors implement `Display` as `LINE:COL: message`:
//!
//! ```
//! use tcpa_energy::workloads::text::parse_workload;
//!
//! let err = parse_workload(
//!     "workload bad\nloop i0 in 0..N0*N0\n",
//! ).unwrap_err();
//! assert_eq!(err.line, 2);
//! assert!(err.message.starts_with("non-affine expression"));
//! ```

pub mod grammar;
pub mod literals;
pub mod semantics;

pub use literals::{ParseError, Pos};

use crate::polyhedral::{AffineExpr, ParamSpace};
use crate::pra::ir::{
    CondConstraint, Lhs, Op, Operand, Pra, Statement, TensorDim, Workload,
};

/// Parse a textual workload description into a [`Workload`].
///
/// This is frontend validation only (lexical, syntactic, name/rank
/// resolution); callers analysing untrusted input must still route the
/// result through the [`crate::lint`] gate, as every CLI path does.
pub fn parse_workload(src: &str) -> Result<Workload, ParseError> {
    semantics::lower(&grammar::parse(src)?)
}

/// Render a [`Workload`] in the textual format, such that
/// [`parse_workload`] reconstructs it bit-identically (same
/// fingerprint). Iterators and bounds get the canonical `i0…` / `N0…`
/// names; every statement is named explicitly.
pub fn render_workload(wl: &Workload) -> String {
    let flat = wl.phases.len() == 1 && wl.phases[0].name == wl.name;
    let mut out = format!("workload {}\n", wl.name);
    for ph in &wl.phases {
        if flat {
            render_phase(&mut out, ph, "");
        } else {
            out.push_str(&format!("phase {} {{\n", ph.name));
            render_phase(&mut out, ph, "  ");
            out.push_str("}\n");
        }
    }
    out
}

fn render_phase(out: &mut String, pra: &Pra, ind: &str) {
    for l in 0..pra.ndims {
        out.push_str(&format!(
            "{ind}loop i{l} in 0..{}\n",
            pra.space.name(l)
        ));
    }
    let mut r = 0;
    while r < pra.requires.len() {
        // `==` preconditions are stored as a `[≥, ≤]` pair; fold them
        // back for readability (the pair re-expands on parse).
        let e = &pra.requires[r].0;
        let paired = pra
            .requires
            .get(r + 1)
            .map(|n| n.0 == -e)
            .unwrap_or(false);
        let (lhs, rhs) = split_params(e, &pra.space);
        if paired {
            out.push_str(&format!("{ind}requires {lhs} == {rhs}\n"));
            r += 2;
        } else if e.coeffs.iter().any(|&c| c > 0) {
            out.push_str(&format!("{ind}requires {lhs} >= {rhs}\n"));
            r += 1;
        } else {
            let neg = -e;
            out.push_str(&format!(
                "{ind}requires {} <= {}\n",
                params_str(
                    &AffineExpr { coeffs: neg.coeffs.clone(), konst: 0 },
                    &pra.space
                ),
                aff_str(Vec::new(), e.konst),
            ));
            r += 1;
        }
    }
    for t in &pra.tensors {
        let dims: Vec<String> = t
            .shape
            .iter()
            .map(|d| match d {
                TensorDim::Param(l) => pra.space.name(*l).to_string(),
                TensorDim::Fixed(v) => v.to_string(),
            })
            .collect();
        out.push_str(&format!(
            "{ind}tensor {}[{}]\n",
            t.name,
            dims.join(", ")
        ));
    }
    for s in &pra.statements {
        out.push_str(&format!("{ind}{}\n", stmt_str(s, pra)));
    }
}

fn stmt_str(s: &Statement, pra: &Pra) -> String {
    let lhs = match &s.lhs {
        Lhs::Var(v) => var_str(v, &vec![0; pra.ndims]),
        Lhs::Tensor { name, map } => {
            let idx: Vec<String> = map
                .rows
                .iter()
                .zip(&map.offset)
                .map(|(row, &off)| aff_str(iter_terms(row), off))
                .collect();
            format!("{name}[{}]", idx.join(", "))
        }
    };
    let args: Vec<String> = s
        .args
        .iter()
        .map(|a| match a {
            Operand::Var { name, dep } => var_str(name, dep),
            Operand::Tensor { name, map } => {
                let idx: Vec<String> = map
                    .rows
                    .iter()
                    .zip(&map.offset)
                    .map(|(row, &off)| aff_str(iter_terms(row), off))
                    .collect();
                format!("{name}[{}]", idx.join(", "))
            }
        })
        .collect();
    let rhs = match s.op {
        Op::Copy => args[0].clone(),
        Op::Add | Op::Add3 => args.join(" + "),
        Op::Sub => args.join(" - "),
        Op::Mul => args.join(" * "),
        Op::Max => format!("max({}, {})", args[0], args[1]),
    };
    let mut line = format!("stmt {}: {lhs} = {rhs}", s.name);
    if !s.cond.is_empty() {
        line.push_str(&format!(" if {}", conds_str(&s.cond, &pra.space)));
    }
    line
}

fn conds_str(cond: &[CondConstraint], space: &ParamSpace) -> String {
    let mut parts = Vec::new();
    let mut i = 0;
    while i < cond.len() {
        let c = &cond[i];
        // An equality lowered to `[≥, ≤]`: fold back to `==`.
        let paired = cond
            .get(i + 1)
            .map(|n| {
                n.a.iter().zip(&c.a).all(|(x, y)| *x == -y)
                    && n.konst == -&c.konst
            })
            .unwrap_or(false);
        if paired {
            parts.push(format!(
                "{} == {}",
                aff_str(iter_terms(&c.a), 0),
                params_str(&-&c.konst, space),
            ));
            i += 2;
        } else if c.a.iter().any(|&x| x > 0) {
            parts.push(format!(
                "{} >= {}",
                aff_str(iter_terms(&c.a), 0),
                params_str(&-&c.konst, space),
            ));
            i += 1;
        } else {
            let neg: Vec<i64> = c.a.iter().map(|x| -x).collect();
            parts.push(format!(
                "{} <= {}",
                aff_str(iter_terms(&neg), 0),
                params_str(&c.konst, space),
            ));
            i += 1;
        }
    }
    parts.join(", ")
}

/// Internal-variable access: dependence `d` renders as `iℓ - d`.
fn var_str(name: &str, dep: &[i64]) -> String {
    let idx: Vec<String> = dep
        .iter()
        .enumerate()
        .map(|(l, &d)| {
            if d == 0 {
                format!("i{l}")
            } else if d > 0 {
                format!("i{l} - {d}")
            } else {
                format!("i{l} + {}", -d)
            }
        })
        .collect();
    format!("{name}[{}]", idx.join(", "))
}

fn iter_terms(a: &[i64]) -> Vec<(i64, String)> {
    a.iter()
        .enumerate()
        .filter(|(_, &c)| c != 0)
        .map(|(l, &c)| (c, format!("i{l}")))
        .collect()
}

fn params_str(e: &AffineExpr, space: &ParamSpace) -> String {
    let terms: Vec<(i64, String)> = e
        .coeffs
        .iter()
        .enumerate()
        .filter(|(_, &c)| c != 0)
        .map(|(k, &c)| (c, space.name(k).to_string()))
        .collect();
    aff_str(terms, e.konst)
}

/// Split `e ≥ 0` (or `= 0`) into a comparison's two sides: positive
/// coefficients stay left, negated negative coefficients and the
/// negated constant go right — `P − Q + k` prints as `P ⋈ Q − k`.
fn split_params(e: &AffineExpr, space: &ParamSpace) -> (String, String) {
    let pos = AffineExpr {
        coeffs: e.coeffs.iter().map(|&c| c.max(0)).collect(),
        konst: 0,
    };
    let neg = AffineExpr {
        coeffs: e.coeffs.iter().map(|&c| (-c).max(0)).collect(),
        konst: -e.konst,
    };
    (params_str(&pos, space), params_str(&neg, space))
}

/// Render an affine sum of named terms plus a constant; empty → `0`.
fn aff_str(terms: Vec<(i64, String)>, konst: i64) -> String {
    let mut out = String::new();
    for (c, name) in terms {
        if out.is_empty() {
            out = match c {
                1 => name,
                -1 => format!("-{name}"),
                c => format!("{c}*{name}"),
            };
        } else {
            let (sign, m) = if c < 0 { (" - ", -c) } else { (" + ", c) };
            out.push_str(sign);
            if m == 1 {
                out.push_str(&name);
            } else {
                out.push_str(&format!("{m}*{name}"));
            }
        }
    }
    if out.is_empty() {
        konst.to_string()
    } else if konst > 0 {
        format!("{out} + {konst}")
    } else if konst < 0 {
        format!("{out} - {}", -konst)
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::workload_fingerprint;
    use crate::workloads;

    #[test]
    fn gesummv_rendition_round_trips_bit_identically() {
        let builtin = workloads::by_name("gesummv").unwrap();
        let text = render_workload(&builtin);
        let back = parse_workload(&text).unwrap();
        assert_eq!(
            workload_fingerprint(&back),
            workload_fingerprint(&builtin),
            "render → parse must reconstruct the exact IR:\n{text}"
        );
    }

    #[test]
    fn multi_phase_rendition_round_trips() {
        let builtin = workloads::by_name("atax").unwrap();
        let text = render_workload(&builtin);
        assert!(text.contains("phase atax_p1 {"), "{text}");
        let back = parse_workload(&text).unwrap();
        assert_eq!(
            workload_fingerprint(&back),
            workload_fingerprint(&builtin)
        );
    }

    #[test]
    fn requires_pairs_fold_to_equality() {
        let builtin = workloads::by_name("mvt").unwrap();
        let text = render_workload(&builtin);
        assert!(text.contains("requires N0 == N1"), "{text}");
        let back = parse_workload(&text).unwrap();
        assert_eq!(
            workload_fingerprint(&back),
            workload_fingerprint(&builtin)
        );
    }
}
