//! Dense row-major f32 tensors used by the functional interpreter, the
//! cycle-accurate simulator, and the PJRT golden-model comparison.

use std::collections::BTreeMap;

/// A dense row-major tensor of `f32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<i64>,
    pub data: Vec<f32>,
}

impl Tensor {
    /// All-zeros tensor.
    pub fn zeros(shape: Vec<i64>) -> Self {
        let n: i64 = shape.iter().product();
        Tensor { shape, data: vec![0.0; n as usize] }
    }

    /// Build from a function of the index vector.
    pub fn from_fn(shape: Vec<i64>, mut f: impl FnMut(&[i64]) -> f32) -> Self {
        let mut t = Tensor::zeros(shape);
        let mut idx = vec![0i64; t.shape.len()];
        let n = t.data.len();
        for flat in 0..n {
            t.data[flat] = f(&idx);
            // increment row-major odometer (last dim fastest)
            for d in (0..idx.len()).rev() {
                idx[d] += 1;
                if idx[d] < t.shape[d] {
                    break;
                }
                idx[d] = 0;
            }
        }
        t
    }

    /// Row-major flat offset of an index vector.
    pub fn flat(&self, idx: &[i64]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off: i64 = 0;
        for (d, (&i, &s)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(
                i >= 0 && i < s,
                "index {idx:?} out of shape {:?} at dim {d}",
                self.shape
            );
            off = off * s + i;
        }
        off as usize
    }

    /// Read one element.
    pub fn get(&self, idx: &[i64]) -> f32 {
        self.data[self.flat(idx)]
    }

    /// Write one element.
    pub fn set(&mut self, idx: &[i64], v: f32) {
        let off = self.flat(idx);
        self.data[off] = v;
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for an empty tensor.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Max absolute elementwise difference to another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Allclose with combined absolute/relative tolerance.
    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data.iter().zip(&other.data).all(|(a, b)| {
            (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
        })
    }
}

/// Named tensor environment flowing between workload phases.
pub type TensorEnv = BTreeMap<String, Tensor>;

/// Deterministic pseudo-random input value for tensor `name` at `idx`:
/// quantized to multiples of 1/8 in [-1, 1] so that f32 accumulation across
/// differently-ordered reductions stays comparable.
pub fn synth_value(name: &str, idx: &[i64]) -> f32 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    for &i in idx {
        h = (h ^ i as u64).wrapping_mul(0x100000001b3);
    }
    let q = (h >> 40) % 17; // 0..16
    (q as f32 - 8.0) / 8.0
}

/// Build synthetic input tensors for the given (name, shape) pairs.
pub fn synth_inputs(decls: &[(String, Vec<i64>)]) -> TensorEnv {
    decls
        .iter()
        .map(|(name, shape)| {
            let t = Tensor::from_fn(shape.clone(), |idx| synth_value(name, idx));
            (name.clone(), t)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_major_layout() {
        let t = Tensor::from_fn(vec![2, 3], |i| (i[0] * 10 + i[1]) as f32);
        assert_eq!(t.get(&[0, 0]), 0.0);
        assert_eq!(t.get(&[0, 2]), 2.0);
        assert_eq!(t.get(&[1, 0]), 10.0);
        assert_eq!(t.data, vec![0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut t = Tensor::zeros(vec![4, 4]);
        t.set(&[3, 1], 7.5);
        assert_eq!(t.get(&[3, 1]), 7.5);
        assert_eq!(t.len(), 16);
        assert!(!t.is_empty());
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::from_fn(vec![3], |i| i[0] as f32);
        let mut b = a.clone();
        assert!(a.allclose(&b, 0.0, 0.0));
        b.set(&[2], 2.0 + 1e-6);
        assert!(a.allclose(&b, 1e-5, 0.0));
        assert!(!a.allclose(&b, 1e-8, 0.0));
        assert!(a.max_abs_diff(&b) > 0.0);
    }

    #[test]
    fn synth_deterministic_and_quantized() {
        let v1 = synth_value("A", &[3, 4]);
        let v2 = synth_value("A", &[3, 4]);
        assert_eq!(v1, v2);
        // Different names give different sequences (17 quantization buckets
        // mean single-point collisions are expected; compare a run of them).
        let run_a: Vec<f32> =
            (0..32).map(|i| synth_value("A", &[i, 0])).collect();
        let run_b: Vec<f32> =
            (0..32).map(|i| synth_value("B", &[i, 0])).collect();
        assert_ne!(run_a, run_b);
        assert!((-1.0..=1.0).contains(&v1));
        // quantized to eighths
        assert_eq!((v1 * 8.0).fract(), 0.0);
    }

    #[test]
    fn synth_inputs_env() {
        let env = synth_inputs(&[
            ("A".into(), vec![2, 2]),
            ("x".into(), vec![2]),
        ]);
        assert_eq!(env.len(), 2);
        assert_eq!(env["A"].shape, vec![2, 2]);
    }
}
