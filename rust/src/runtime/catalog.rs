//! Catalog binding each AOT artifact to its workload: input tensor order,
//! output tensor order, and the loop bounds the artifact was lowered at.
//! Mirrors `python/compile/model.py::MANIFEST` (checked against
//! `artifacts/manifest.txt` at load time).

/// Binding between a workload and its AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Workload / artifact name.
    pub name: &'static str,
    /// Workload tensor names, in the artifact's positional input order.
    pub inputs: &'static [&'static str],
    /// Workload tensor names, in the artifact's tuple output order.
    pub outputs: &'static [&'static str],
    /// Loop bounds `N` per phase that reproduce the artifact's shapes.
    pub bounds: &'static [&'static [i64]],
}

/// The full artifact catalog.
pub fn catalog() -> Vec<ArtifactSpec> {
    vec![
        ArtifactSpec {
            name: "gesummv",
            inputs: &["A", "B", "X"],
            outputs: &["Y"],
            bounds: &[&[16, 16]],
        },
        ArtifactSpec {
            name: "gemm",
            inputs: &["A", "B"],
            outputs: &["C"],
            bounds: &[&[16, 16, 16]],
        },
        ArtifactSpec {
            name: "atax",
            inputs: &["A", "X"],
            outputs: &["Y", "TMP"],
            bounds: &[&[16, 16], &[16, 16]],
        },
        ArtifactSpec {
            name: "bicg",
            inputs: &["A", "P", "R"],
            outputs: &["Q", "S"],
            bounds: &[&[16, 16]],
        },
        ArtifactSpec {
            name: "mvt",
            inputs: &["A", "Y1", "Y2", "X1in", "X2in"],
            outputs: &["X1", "X2"],
            bounds: &[&[16, 16]],
        },
        ArtifactSpec {
            name: "syrk",
            inputs: &["A", "Cin"],
            outputs: &["C"],
            bounds: &[&[16, 16, 16]],
        },
        ArtifactSpec {
            name: "k2mm",
            inputs: &["A", "B", "C"],
            outputs: &["D", "TMP"],
            bounds: &[&[16, 16, 16], &[16, 16, 16]],
        },
        ArtifactSpec {
            name: "jacobi1d",
            inputs: &["Ain"],
            outputs: &["Aout"],
            bounds: &[&[4, 32]],
        },
        ArtifactSpec {
            name: "doitgen",
            inputs: &["A", "C4"],
            outputs: &["SUM"],
            bounds: &[&[4, 4, 8, 8]],
        },
        ArtifactSpec {
            name: "gemver",
            inputs: &["A", "U1", "V1", "U2", "V2", "Y", "Z"],
            outputs: &["B", "X", "W"],
            bounds: &[&[16, 16], &[16, 16], &[16, 16]],
        },
    ]
}

/// Look up one artifact spec.
pub fn spec(name: &str) -> Option<ArtifactSpec> {
    catalog().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_covers_all_workloads() {
        let names: Vec<&str> = catalog().iter().map(|s| s.name).collect();
        for wl in crate::workloads::all() {
            assert!(names.contains(&wl.name.as_str()), "{}", wl.name);
        }
    }

    #[test]
    fn bounds_match_phase_count() {
        for s in catalog() {
            let wl = crate::workloads::by_name(s.name).unwrap();
            assert_eq!(s.bounds.len(), wl.phases.len(), "{}", s.name);
            for (b, ph) in s.bounds.iter().zip(&wl.phases) {
                assert_eq!(b.len(), ph.ndims, "{} {}", s.name, ph.name);
            }
        }
    }
}
