//! Dependency-free stand-in for the PJRT backend (default build, `pjrt`
//! feature off). Mirrors `pjrt::Runtime`'s API exactly: construction,
//! platform introspection and manifest parsing work; actually compiling
//! or executing an artifact reports that the XLA toolchain is absent.

use std::path::Path;

use crate::workloads::Tensor;

use super::{parse_manifest, Result, RuntimeError};

/// The artifact runtime (stub backend). Holds no state: nothing can be
/// loaded, so `has` is always false and `execute` always errors.
#[derive(Debug, Default)]
pub struct Runtime {}

impl Runtime {
    /// Create the stub runtime (always succeeds).
    pub fn new() -> Result<Self> {
        Ok(Runtime::default())
    }

    /// True when this build uses the stub backend (callers and tests
    /// use this to skip artifact-execution paths).
    pub fn is_stub(&self) -> bool {
        true
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        "cpu (stub; rebuild with --features pjrt for XLA execution)".into()
    }

    /// Compiling an artifact needs the real backend.
    pub fn load(
        &mut self,
        name: &str,
        path: &Path,
        _input_shapes: Vec<Vec<i64>>,
    ) -> Result<()> {
        Err(RuntimeError::new(format!(
            "cannot compile {name} ({}): PJRT backend not built — enable \
             the `pjrt` cargo feature (see Cargo.toml for the required \
             vendored xla dependency)",
            path.display()
        )))
    }

    /// Load every artifact listed in `<dir>/manifest.txt`. With the stub
    /// backend this fails on the first artifact (after a successful
    /// manifest parse) — or earlier, with a `make artifacts` hint, when
    /// the manifest itself is missing.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let entries = parse_manifest(dir)?;
        let mut names = Vec::new();
        for (name, input_shapes) in entries {
            self.load(
                &name,
                &dir.join(format!("{name}.hlo.txt")),
                input_shapes,
            )?;
            names.push(name);
        }
        Ok(names)
    }

    /// True when `name` has been loaded — never, for the stub.
    pub fn has(&self, _name: &str) -> bool {
        false
    }

    /// Executing needs the real backend; unknown models report the same
    /// error as the PJRT path.
    pub fn execute(
        &self,
        name: &str,
        _inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        Err(RuntimeError::new(format!("model {name} not loaded")))
    }
}
