//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust — the L2/L1 golden
//! numeric model on the L3 hot path, with Python nowhere at runtime.
//!
//! The real backend (`pjrt.rs`, wiring follows /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`) needs the vendored `xla` bindings and is gated behind the
//! `pjrt` cargo feature. The default build ships `stub.rs`: the same API,
//! constructible and introspectable, erroring descriptively on `load`/
//! `execute` so callers and tests degrade gracefully in environments
//! without the XLA toolchain. Artifacts are lowered with
//! `return_tuple=True`, so results are always tuples.

pub mod catalog;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
mod stub;

pub use catalog::{catalog, ArtifactSpec};

#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

use std::path::Path;

/// Runtime failure: a message plus an optional source error.
#[derive(Debug)]
pub struct RuntimeError {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl RuntimeError {
    /// A message-only error.
    pub fn new(msg: impl Into<String>) -> Self {
        RuntimeError { msg: msg.into(), source: None }
    }

    /// Wrap a source error with context (the `anyhow::Context` idiom).
    pub fn with_source(
        msg: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Self {
        RuntimeError { msg: msg.into(), source: Some(Box::new(source)) }
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)?;
        // `{:#}` renders the cause chain, as anyhow does.
        if f.alternate() {
            if let Some(s) = &self.source {
                write!(f, ": {s}")?;
            }
        }
        Ok(())
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|s| s as &(dyn std::error::Error))
    }
}

/// Runtime result.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Parse `<dir>/manifest.txt` (written by `python -m compile.aot`) into
/// `(artifact name, input shapes)` entries. Shared by both backends.
pub(crate) fn parse_manifest(
    dir: &Path,
) -> Result<Vec<(String, Vec<Vec<i64>>)>> {
    let manifest =
        std::fs::read_to_string(dir.join("manifest.txt")).map_err(|e| {
            RuntimeError::with_source(
                format!(
                    "{}/manifest.txt missing — run `make artifacts`",
                    dir.display()
                ),
                e,
            )
        })?;
    let mut out = Vec::new();
    for line in manifest.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (name, shapes) = line
            .split_once(' ')
            .ok_or_else(|| RuntimeError::new("malformed manifest line"))?;
        let input_shapes: Vec<Vec<i64>> = shapes
            .split(';')
            .map(|s| {
                s.split(',')
                    .filter(|x| !x.is_empty() && *x != "scalar")
                    .map(|x| {
                        x.parse::<i64>().map_err(|e| {
                            RuntimeError::with_source(
                                format!("bad dimension {x:?} in manifest"),
                                e,
                            )
                        })
                    })
                    .collect::<Result<Vec<i64>>>()
            })
            .collect::<Result<_>>()?;
        out.push((name.to_string(), input_shapes));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_construction() {
        let rt = Runtime::new().expect("runtime");
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert!(!rt.has("nothing"));
    }

    #[test]
    fn missing_model_errors() {
        let rt = Runtime::new().unwrap();
        let err = rt.execute("ghost", &[]).unwrap_err();
        assert!(err.to_string().contains("not loaded"));
    }

    #[test]
    fn missing_manifest_points_at_make_artifacts() {
        let mut rt = Runtime::new().unwrap();
        let err = rt
            .load_dir(Path::new("/nonexistent-artifacts-dir"))
            .unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err:#}");
    }
}
