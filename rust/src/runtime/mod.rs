//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from Rust — the L2/L1 golden
//! numeric model on the L3 hot path, with Python nowhere at runtime.
//!
//! Wiring follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`; artifacts are
//! lowered with `return_tuple=True`, so results are always tuples.

pub mod catalog;

pub use catalog::{catalog, ArtifactSpec};

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::workloads::Tensor;

/// A loaded PJRT executable with its input/output shape manifest.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Input shapes as lowered (from `artifacts/manifest.txt`).
    pub input_shapes: Vec<Vec<i64>>,
}

/// The artifact runtime: a CPU PJRT client plus compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    models: BTreeMap<String, LoadedModel>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn new() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, models: BTreeMap::new() })
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(
        &mut self,
        name: &str,
        path: &Path,
        input_shapes: Vec<Vec<i64>>,
    ) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        self.models
            .insert(name.to_string(), LoadedModel { exe, input_shapes });
        Ok(())
    }

    /// Load every artifact listed in `<dir>/manifest.txt` (written by
    /// `python -m compile.aot`). Returns the loaded names.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| {
                format!(
                    "{}/manifest.txt missing — run `make artifacts`",
                    dir.display()
                )
            })?;
        let mut names = Vec::new();
        for line in manifest.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (name, shapes) =
                line.split_once(' ').context("malformed manifest line")?;
            let input_shapes: Vec<Vec<i64>> = shapes
                .split(';')
                .map(|s| {
                    s.split(',')
                        .filter(|x| !x.is_empty() && *x != "scalar")
                        .map(|x| x.parse::<i64>().map_err(Into::into))
                        .collect::<Result<Vec<i64>>>()
                })
                .collect::<Result<_>>()?;
            self.load(name, &dir.join(format!("{name}.hlo.txt")), input_shapes)?;
            names.push(name.to_string());
        }
        Ok(names)
    }

    /// True when `name` has been loaded.
    pub fn has(&self, name: &str) -> bool {
        self.models.contains_key(name)
    }

    /// Execute a loaded model on input tensors, returning output tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let model = self
            .models
            .get(name)
            .with_context(|| format!("model {name} not loaded"))?;
        if inputs.len() != model.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                model.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, want) in inputs.iter().zip(&model.input_shapes) {
            if &t.shape != want {
                bail!(
                    "{name}: input shape {:?} does not match artifact {want:?}",
                    t.shape
                );
            }
            let lit = xla::Literal::vec1(&t.data).reshape(&t.shape)?;
            literals.push(lit);
        }
        let result = model.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        // return_tuple=True lowering: unpack the tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = lit.array_shape()?;
            let dims: Vec<i64> = shape.dims().to_vec();
            let data = lit.to_vec::<f32>()?;
            out.push(Tensor { shape: dims, data });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_construction() {
        let rt = Runtime::new().expect("PJRT CPU client");
        assert!(rt.platform().to_lowercase().contains("cpu"));
        assert!(!rt.has("nothing"));
    }

    #[test]
    fn missing_model_errors() {
        let rt = Runtime::new().unwrap();
        let err = rt.execute("ghost", &[]).unwrap_err();
        assert!(err.to_string().contains("not loaded"));
    }
}
