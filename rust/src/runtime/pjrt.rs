//! Real PJRT backend (`pjrt` cargo feature): compile and execute the
//! AOT-lowered HLO artifacts through the vendored `xla` bindings.
//! Wiring follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::workloads::Tensor;

use super::{parse_manifest, Result, RuntimeError};

/// Map an `xla` backend error into a [`RuntimeError`] with context.
fn xe<T, E: std::fmt::Debug>(
    r: std::result::Result<T, E>,
    msg: impl Into<String>,
) -> Result<T> {
    r.map_err(|e| RuntimeError::new(format!("{}: {e:?}", msg.into())))
}

/// A loaded PJRT executable with its input/output shape manifest.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Input shapes as lowered (from `artifacts/manifest.txt`).
    pub input_shapes: Vec<Vec<i64>>,
}

/// The artifact runtime: a CPU PJRT client plus compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    models: BTreeMap<String, LoadedModel>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn new() -> Result<Self> {
        let client = xe(xla::PjRtClient::cpu(), "creating PJRT CPU client")?;
        Ok(Runtime { client, models: BTreeMap::new() })
    }

    /// True when this build uses the stub backend — never, here.
    pub fn is_stub(&self) -> bool {
        false
    }

    /// Platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one HLO-text artifact.
    pub fn load(
        &mut self,
        name: &str,
        path: &Path,
        input_shapes: Vec<Vec<i64>>,
    ) -> Result<()> {
        let text_path = path
            .to_str()
            .ok_or_else(|| RuntimeError::new("non-utf8 path"))?;
        let proto = xe(
            xla::HloModuleProto::from_text_file(text_path),
            format!("parsing {}", path.display()),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = xe(self.client.compile(&comp), format!("compiling {name}"))?;
        self.models
            .insert(name.to_string(), LoadedModel { exe, input_shapes });
        Ok(())
    }

    /// Load every artifact listed in `<dir>/manifest.txt` (written by
    /// `python -m compile.aot`). Returns the loaded names.
    pub fn load_dir(&mut self, dir: &Path) -> Result<Vec<String>> {
        let entries = parse_manifest(dir)?;
        let mut names = Vec::new();
        for (name, input_shapes) in entries {
            self.load(&name, &dir.join(format!("{name}.hlo.txt")), input_shapes)?;
            names.push(name);
        }
        Ok(names)
    }

    /// True when `name` has been loaded.
    pub fn has(&self, name: &str) -> bool {
        self.models.contains_key(name)
    }

    /// Execute a loaded model on input tensors, returning output tensors.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let model = self
            .models
            .get(name)
            .ok_or_else(|| RuntimeError::new(format!("model {name} not loaded")))?;
        if inputs.len() != model.input_shapes.len() {
            return Err(RuntimeError::new(format!(
                "{name}: expected {} inputs, got {}",
                model.input_shapes.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, want) in inputs.iter().zip(&model.input_shapes) {
            if &t.shape != want {
                return Err(RuntimeError::new(format!(
                    "{name}: input shape {:?} does not match artifact {want:?}",
                    t.shape
                )));
            }
            let lit = xe(
                xla::Literal::vec1(&t.data).reshape(&t.shape),
                format!("{name}: reshaping input"),
            )?;
            literals.push(lit);
        }
        let result = xe(
            xe(model.exe.execute::<xla::Literal>(&literals),
                format!("{name}: executing"))?[0][0]
                .to_literal_sync(),
            format!("{name}: fetching result"),
        )?;
        // return_tuple=True lowering: unpack the tuple.
        let parts = xe(result.to_tuple(), format!("{name}: unpacking tuple"))?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let shape = xe(lit.array_shape(), format!("{name}: output shape"))?;
            let dims: Vec<i64> = shape.dims().to_vec();
            let data = xe(lit.to_vec::<f32>(), format!("{name}: output data"))?;
            out.push(Tensor { shape: dims, data });
        }
        Ok(out)
    }
}
