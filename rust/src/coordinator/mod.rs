//! L3 coordinator: the command-line driver, the validation orchestrator,
//! the design-space-exploration engine, and the figure/table generators
//! that regenerate every artifact of the paper's evaluation section.

pub mod cli;
pub mod dse;
pub mod figures;
pub mod validate;

pub use cli::{run_cli, CliError};
#[allow(deprecated)]
pub use dse::{dse_sweep, DsePoint};
pub use figures::{fig4_rows, fig5_rows, Fig4Row, Fig5Row};
pub use validate::{
    validate_workload, validate_workload_mapped, ValidationRow,
};
