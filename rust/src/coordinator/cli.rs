//! Hand-rolled CLI (no clap in the offline vendor tree).
//!
//! ```text
//! tcpa-energy list
//! tcpa-energy analyze  --workload gesummv --array 8x8 [--bounds 64,64] [--report]
//! tcpa-energy simulate --workload gesummv --array 2x2 --bounds 8,8
//! tcpa-energy validate [--workload NAME] [--bounds 8,8] [--array 2x2]
//! tcpa-energy dse      --workload gemm --bounds 64,64 [--max-pes 64]
//! tcpa-energy figures  [--out results] [--quick]
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::analysis::SymbolicAnalysis;
use crate::energy::MemoryClass;
use crate::report::{ascii_chart, write_csv, CsvTable};
use crate::schedule::find_schedule;
use crate::sim::{simulate, ArchConfig};
use crate::tiling::{tile_pra, ArrayMapping};
use crate::workloads::{self, workload_inputs};

use super::dse::dse_sweep;
use super::figures::{fig4_rows, fig5_rows};
use super::validate::validate_workload;

/// CLI failure.
#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("usage: {0}")]
    Usage(String),
    #[error("unknown workload {0}; try `tcpa-energy list`")]
    UnknownWorkload(String),
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

fn parse_flags(args: &[String]) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--")
            {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn parse_vec(s: &str, sep: char) -> Vec<i64> {
    s.split(sep).map(|x| x.trim().parse().expect("integer list")).collect()
}

/// Run the CLI; returns the process exit code.
pub fn run_cli(args: &[String]) -> Result<i32, CliError> {
    let usage = "tcpa-energy <list|analyze|simulate|validate|dse|figures> \
                 [flags]";
    let Some(cmd) = args.first() else {
        return Err(CliError::Usage(usage.into()));
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "list" => {
            println!("workloads:");
            for wl in workloads::all() {
                let phases: Vec<String> = wl
                    .phases
                    .iter()
                    .map(|p| format!("{} ({}D)", p.name, p.ndims))
                    .collect();
                println!("  {:10} phases: {}", wl.name, phases.join(", "));
            }
            Ok(0)
        }
        "analyze" => {
            let name = flags
                .get("workload")
                .ok_or_else(|| CliError::Usage("--workload required".into()))?;
            let wl = workloads::by_name(name)
                .ok_or_else(|| CliError::UnknownWorkload(name.clone()))?;
            let array = parse_vec(
                flags.get("array").map(String::as_str).unwrap_or("8x8"),
                'x',
            );
            for phase in &wl.phases {
                let mut t = array.clone();
                while t.len() < phase.ndims {
                    t.push(1);
                }
                t.truncate(phase.ndims);
                let mapping = ArrayMapping::new(t);
                let ana = SymbolicAnalysis::analyze(phase, &mapping);
                println!(
                    "[{}] symbolic analysis took {:?}",
                    phase.name, ana.analysis_time
                );
                if flags.contains_key("report") {
                    println!("{}", ana.report());
                }
                if let Some(bounds) = flags.get("bounds") {
                    let mut b = parse_vec(bounds, ',');
                    while b.len() < phase.ndims {
                        b.push(*b.last().unwrap());
                    }
                    b.truncate(phase.ndims);
                    let params = ana.params_for(&b);
                    let e = ana.energy_at(&params);
                    let l = ana.latency_at(&params);
                    println!("  bounds {b:?} → params {params:?}");
                    for (c, v) in &e.mem_pj {
                        println!("    {c:4} {v:>18.2} pJ");
                    }
                    println!("    comp {:>18.2} pJ", e.compute_pj);
                    println!("    TOTAL {:>17.2} pJ   latency {} cycles", e.total, l);
                }
            }
            Ok(0)
        }
        "simulate" => {
            let name = flags
                .get("workload")
                .ok_or_else(|| CliError::Usage("--workload required".into()))?;
            let wl = workloads::by_name(name)
                .ok_or_else(|| CliError::UnknownWorkload(name.clone()))?;
            let array = parse_vec(
                flags.get("array").map(String::as_str).unwrap_or("2x2"),
                'x',
            );
            let bounds = parse_vec(
                flags.get("bounds").map(String::as_str).unwrap_or("8,8"),
                ',',
            );
            let params_all: Vec<Vec<i64>> = wl
                .phases
                .iter()
                .map(|ph| {
                    let mut b = bounds.clone();
                    while b.len() < ph.ndims {
                        b.push(*b.last().unwrap());
                    }
                    b.truncate(ph.ndims);
                    let mut t = array.clone();
                    while t.len() < ph.ndims {
                        t.push(1);
                    }
                    t.truncate(ph.ndims);
                    ArrayMapping::new(t).params_for(&b)
                })
                .collect();
            let mut env = workload_inputs(&wl, &params_all);
            for (phase, params) in wl.phases.iter().zip(&params_all) {
                let mut t = array.clone();
                while t.len() < phase.ndims {
                    t.push(1);
                }
                t.truncate(phase.ndims);
                let mapping = ArrayMapping::new(t.clone());
                let arch = ArchConfig::with_array(t);
                let tiled = tile_pra(phase, &mapping);
                let schedule = find_schedule(&tiled, arch.pi).unwrap();
                let res = simulate(phase, &arch, &schedule, params, &env);
                println!("[{}] {} cycles", phase.name, res.cycles);
                println!(
                    "  utilization {:.1}%  max-hop {}  FD pressure {}",
                    res.stats.utilization * 100.0,
                    res.stats.max_hop,
                    res.stats.fd_pressure
                );
                for (c, v) in &res.counters.mem {
                    println!("  {c:4} accesses {v}");
                }
                println!(
                    "  adds {}  muls {}  energy {:.2} pJ",
                    res.counters.adds,
                    res.counters.muls,
                    res.counters.energy_pj(&Default::default())
                );
                if !res.violations.is_empty() {
                    println!("  VIOLATIONS: {:?}", res.violations);
                }
                for (n, t) in res.outputs {
                    env.insert(n, t);
                }
            }
            Ok(0)
        }
        "validate" => {
            let bounds = parse_vec(
                flags.get("bounds").map(String::as_str).unwrap_or("8,8"),
                ',',
            );
            let array = parse_vec(
                flags.get("array").map(String::as_str).unwrap_or("2x2"),
                'x',
            );
            let wls: Vec<_> = match flags.get("workload") {
                Some(n) => vec![workloads::by_name(n)
                    .ok_or_else(|| CliError::UnknownWorkload(n.clone()))?],
                None => workloads::all(),
            };
            let mut all_ok = true;
            for wl in wls {
                for row in validate_workload(&wl, &bounds, &array) {
                    let status = if row.exact_match && row.functional_ok {
                        "EXACT"
                    } else {
                        all_ok = false;
                        "MISMATCH"
                    };
                    println!(
                        "{:10} {:9} N={:?} t={:?}  {status}  \
                         E_sym {:.1} pJ  E_sim {:.1} pJ  \
                         (eval {:.0} µs, sim {:.0} µs)",
                        row.workload,
                        row.phase,
                        row.bounds,
                        row.array,
                        row.energy_sym_pj,
                        row.energy_sim_pj,
                        row.sym_eval_us,
                        row.sim_us
                    );
                }
            }
            Ok(if all_ok { 0 } else { 1 })
        }
        "dse" => {
            let name = flags
                .get("workload")
                .ok_or_else(|| CliError::Usage("--workload required".into()))?;
            let wl = workloads::by_name(name)
                .ok_or_else(|| CliError::UnknownWorkload(name.clone()))?;
            let bounds = parse_vec(
                flags.get("bounds").map(String::as_str).unwrap_or("64,64"),
                ',',
            );
            let max_pes: i64 = flags
                .get("max-pes")
                .map(|s| s.parse().expect("integer"))
                .unwrap_or(64);
            let pts = dse_sweep(&wl, &bounds, max_pes);
            println!(
                "{:>6} {:>4} {:>16} {:>14} {:>12} {:>16}",
                "array", "PEs", "energy [pJ]", "DRAM [pJ]", "latency", "EDP"
            );
            for p in pts.iter().take(16) {
                println!(
                    "{:>3}x{:<3} {:>4} {:>16.1} {:>14.1} {:>12} {:>16.3e}",
                    p.array.0,
                    p.array.1,
                    p.pes,
                    p.energy_pj,
                    p.dram_pj,
                    p.latency_cycles,
                    p.edp
                );
            }
            Ok(0)
        }
        "figures" => {
            let out =
                flags.get("out").map(String::as_str).unwrap_or("results");
            let quick = flags.contains_key("quick");
            run_figures(Path::new(out), quick)?;
            Ok(0)
        }
        other => Err(CliError::Usage(format!("unknown command {other}; {usage}"))),
    }
}

/// Regenerate every paper table/figure into `out`.
fn run_figures(out: &Path, quick: bool) -> Result<(), CliError> {
    std::fs::create_dir_all(out)?;
    // Table I.
    let table1 = crate::energy::EnergyTable::table1_45nm().to_markdown();
    std::fs::write(out.join("table1.md"), &table1)?;
    println!("Table I → {}/table1.md", out.display());

    // Fig. 4.
    let sizes: &[i64] = if quick {
        &[16, 32, 64, 128]
    } else {
        &[16, 32, 64, 128, 256, 512]
    };
    let rows = fig4_rows(sizes);
    let mut t4 = CsvTable::new(vec![
        "N", "symbolic_analysis_s", "symbolic_eval_s", "simulation_s", "exact",
    ]);
    for r in &rows {
        t4.push(vec![
            r.n.to_string(),
            format!("{:.6}", r.symbolic_s),
            format!("{:.9}", r.symbolic_eval_s),
            format!("{:.6}", r.simulation_s),
            r.exact.to_string(),
        ]);
    }
    write_csv(&t4, out, "fig4_analysis_time")?;
    let chart = ascii_chart(
        "Fig. 4: analysis time vs matrix size (GESUMMV, 8x8) [log s]",
        &[
            (
                "symbolic (analysis+eval)",
                rows.iter()
                    .map(|r| (r.n as f64, r.symbolic_s + r.symbolic_eval_s))
                    .collect(),
            ),
            (
                "simulation",
                rows.iter().map(|r| (r.n as f64, r.simulation_s)).collect(),
            ),
        ],
        64,
        16,
        true,
    );
    println!("{chart}");
    std::fs::write(out.join("fig4.txt"), chart)?;

    // Fig. 5.
    let sizes5: &[i64] = if quick {
        &[16, 32, 64, 128]
    } else {
        &[16, 32, 64, 128, 256, 512, 1024]
    };
    let rows5 = fig5_rows(sizes5);
    let mut t5 = CsvTable::new(vec![
        "N", "total_pj", "DR_pj", "IOb_pj", "FD_pj", "RD_pj", "ID_pj",
        "OD_pj", "compute_pj", "latency_cycles",
    ]);
    for r in &rows5 {
        t5.push(vec![
            r.n.to_string(),
            format!("{:.1}", r.total_pj),
            format!("{:.1}", r.dram_pj),
            format!("{:.1}", r.iob_pj),
            format!("{:.1}", r.fd_pj),
            format!("{:.1}", r.rd_pj),
            format!("{:.1}", r.id_pj),
            format!("{:.1}", r.od_pj),
            format!("{:.1}", r.compute_pj),
            r.latency_cycles.to_string(),
        ]);
    }
    write_csv(&t5, out, "fig5_energy_scaling")?;
    let chart5 = ascii_chart(
        "Fig. 5: GEMM energy vs matrix size (8x8 grid) [log pJ]",
        &[
            ("total", rows5.iter().map(|r| (r.n as f64, r.total_pj)).collect()),
            ("DRAM", rows5.iter().map(|r| (r.n as f64, r.dram_pj)).collect()),
            (
                "FD+RD",
                rows5
                    .iter()
                    .map(|r| (r.n as f64, r.fd_pj + r.rd_pj))
                    .collect(),
            ),
            (
                "compute",
                rows5.iter().map(|r| (r.n as f64, r.compute_pj)).collect(),
            ),
        ],
        64,
        16,
        true,
    );
    println!("{chart5}");
    std::fs::write(out.join("fig5.txt"), chart5)?;

    // §V-A validation table.
    let mut tv = CsvTable::new(vec![
        "workload", "phase", "bounds", "array", "exact", "functional",
        "E_sym_pJ", "E_sim_pJ",
    ]);
    for wl in workloads::all() {
        let bounds: Vec<i64> = match wl.name.as_str() {
            "jacobi1d" => vec![4, 12],
            _ => vec![8, 8],
        };
        for row in validate_workload(&wl, &bounds, &[2, 2]) {
            tv.push(vec![
                row.workload.clone(),
                row.phase.clone(),
                format!("{:?}", row.bounds),
                format!("{:?}", row.array),
                row.exact_match.to_string(),
                row.functional_ok.to_string(),
                format!("{:.2}", row.energy_sym_pj),
                format!("{:.2}", row.energy_sim_pj),
            ]);
        }
    }
    write_csv(&tv, out, "validation_table")?;
    println!("validation table → {}/validation_table.csv", out.display());
    let _ = MemoryClass::ALL; // (rendered inside the validation rows)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flag_parsing() {
        let f = parse_flags(&s(&["--workload", "gemm", "--report"]));
        assert_eq!(f["workload"], "gemm");
        assert_eq!(f["report"], "true");
    }

    #[test]
    fn list_runs() {
        assert_eq!(run_cli(&s(&["list"])).unwrap(), 0);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cli(&s(&["frobnicate"])).is_err());
        assert!(run_cli(&[]).is_err());
    }

    #[test]
    fn unknown_workload_errors() {
        let e = run_cli(&s(&["analyze", "--workload", "nope"]));
        assert!(matches!(e, Err(CliError::UnknownWorkload(_))));
    }

    #[test]
    fn analyze_and_validate_roundtrip() {
        assert_eq!(
            run_cli(&s(&[
                "analyze", "--workload", "gesummv", "--array", "2x2",
                "--bounds", "8,8"
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "validate", "--workload", "gesummv", "--bounds", "8,8",
                "--array", "2x2"
            ]))
            .unwrap(),
            0
        );
    }
}
