//! Hand-rolled CLI (no clap in the offline vendor tree).
//!
//! ```text
//! tcpa-energy list
//! tcpa-energy backends
//! tcpa-energy analyze  --workload gesummv --array 8x8 [--bounds 64,64] [--report]
//! tcpa-energy simulate --workload gesummv --array 2x2 --bounds 8,8
//! tcpa-energy validate [--workload NAME] [--bounds 8,8] [--array 2x2]
//! tcpa-energy dse      --workload gemm --bounds 64,64 [--max-pes 64]
//!                      (analyze/simulate/dse/lint also accept
//!                       --workload-file FILE.wl instead of --workload)
//!                      [--arrays 1d|2d] [--bounds-sweep 32,64,128]
//!                      [--tile-scales 1,2]
//!                      [--backend all|tcpa,cgra,gpu-sm,systolic]
//!                      [--schedules all|first|N]
//!                      [--phase-shapes uniform|per-phase]
//!                      [--policies all|tcpa,no-fd,no-reuse]   (legacy)
//!                      [--prune-symmetric] [--workers N] [--out DIR]
//!                      [--analysis-cache DIR] [--prune-cache]
//!                      [--sim-verify-frontier]
//!                      [--checkpoint FILE] [--resume] [--deadline SECS]
//!                      [--point-timeout SECS] [--progress]
//!                      [--strategy exhaustive|beam[:W]] [--shard i/n]
//! tcpa-energy dse merge --workload gemm <same space flags as the sweeps>
//!                      --shards a.journal,b.journal,... [--out DIR]
//! tcpa-energy figures  [--out results] [--quick]
//! tcpa-energy lint     --workload NAME | --workload-file FILE.wl |
//!                      --all-builtins
//!                      [--array TxT] [--pi N] [--json] [--json-out FILE]
//!                      [--deny warnings]
//! ```
//!
//! `backends` lists the built-in cross-architecture energy backends;
//! `dse --backend` sweeps them as a first-class axis, emitting one Pareto
//! frontier per (bounds, backend) scenario from a single symbolic
//! analysis per array shape. `dse --schedules all` additionally sweeps
//! every feasible schedule vector `(permutation, λ^J, λ^K)` per mapping
//! — latency becomes an explored objective at identical energy, all
//! candidates priced against the same cached analysis (`first`, the
//! default, reproduces the single-schedule sweep bit-for-bit; an integer
//! caps candidates per phase). `dse --phase-shapes per-phase` lets every
//! phase of a multi-phase workload (ATAX, 2MM, GEMVER) take its
//! own array shape under the shared PE budget — the sweep covers every
//! shape combination while analyzing each (phase, shape) pair exactly
//! once (`uniform`, the default, reproduces the single-shape sweep
//! bit-for-bit). `--prune-cache` (with `--analysis-cache`) removes
//! spilled entries whose workload or phase fingerprint went stale.
//! `dse --sim-verify-frontier` re-simulates the Pareto-frontier points on
//! the discrete-event engine after the sweep — the report gains a
//! `sim_cycles` column, and any divergence from the symbolic prediction
//! is printed and escalated to a non-zero exit.
//!
//! Long sweeps are interruptible and resumable: `--checkpoint FILE`
//! journals every completed point (checksummed, atomic-rename batches),
//! `--resume` replays the journal bit-for-bit and evaluates only the
//! remainder, `--deadline SECS` bounds the wall clock,
//! `--point-timeout SECS` bounds any single point's analysis, and
//! Ctrl-C drains in-flight workers, flushes the journal and reports a
//! frontier explicitly marked `partial (k/n points)`. Exit codes:
//! `0` success, `1` every point failed, `2` error (stale journal,
//! sim-verify divergence, I/O), `3` partial result (cancelled —
//! deadline, SIGINT, or injected; the strongest signal wins).
//!
//! Big sweeps also scale *across* the points axis: `--strategy beam[:W]`
//! replaces the exhaustive enumeration (the default, and always the
//! oracle) with a deterministic Pareto-guided beam over the shape /
//! phase-shape axis ([`crate::dse::Strategy`]) — an anytime answer whose
//! report is explicitly marked heuristic — and `--shard i/n` runs the
//! `i`-th round-robin slice of the canonical enumeration
//! ([`crate::dse::Shard`]), journaling it with `--checkpoint`;
//! `dse merge --shards a.journal,b.journal,...` (with the *same*
//! workload and space flags) folds the finished slices into a report
//! byte-identical to the unsharded run, failing loudly on a missing,
//! duplicated, or stale shard. The two compose with the per-phase cap:
//! `--strategy beam` and a per-shard slice under the cap both lift the
//! 20 000-point refusal.
//!
//! `lint` runs the [`crate::lint`] static-analysis engine (structural +
//! symbolic polyhedral passes; add `--array` for the mapping/schedule
//! pass) and exits non-zero on deny-level findings — or on any finding
//! under `--deny warnings`. `analyze`, `simulate` and `dse` preflight
//! their workload through the same engine: deny findings are a hard
//! error, warnings go to stderr, and `--no-lint` restores the old
//! behavior bit-for-bit.
//!
//! `--workload-file FILE.wl` (mutually exclusive with `--workload`)
//! reads a textual loop-nest description ([`crate::workloads::text`],
//! grammar in the README) instead of a builtin. Parsed workloads are
//! untrusted input: malformed files fail with `path:line:col`
//! diagnostics (exit 2, never a panic), every parsed workload passes
//! through the same lint deny gate, and schedule causality is
//! additionally *proved* symbolically — `simulate` verifies the chosen
//! schedule, `dse` verifies every priced candidate.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use crate::analysis::SymbolicAnalysis;
use crate::dse::{
    explore_controlled, merge_shards, phase_cache_name, phase_fingerprint,
    sim_verify_frontier, workload_fingerprint, AnalysisCache, DesignSpace,
    ExploreConfig, ExploreControl, ExploreResult, FaultPlan, PhasePolicy,
    SchedulePolicy, Shard, Strategy,
};
use crate::energy::{AccessClass, Backend, MemoryClass, Policy};
use crate::report::{
    ascii_chart, dse_frontier_markdown, write_csv, write_dse_report,
    CsvTable,
};
use crate::schedule::find_schedule;
use crate::sim::{simulate, ArchConfig};
use crate::tiling::{pad_array, tile_pra, ArrayMapping};
use crate::workloads::{self, workload_inputs};

use super::figures::{fig4_rows, fig5_rows};
use super::validate::validate_workload;

/// CLI failure.
#[derive(Debug)]
pub enum CliError {
    Usage(String),
    UnknownWorkload(String),
    /// A `--workload-file` input failed to parse or lower. The message is
    /// `path:line:col: description` — stable, grep-able diagnostics for
    /// untrusted textual workloads.
    Parse(String),
    /// No causal LSGP schedule exists for a phase (or, for textual
    /// workloads, the schedule's symbolic causality proof failed); the
    /// message names the phase and the initiation interval π.
    Schedule(String),
    /// The preflight lint gate found deny-level findings
    /// (`analyze`/`simulate`/`dse` refuse to run; `--no-lint` bypasses).
    Lint(String),
    /// A checkpoint-journal problem that must stop the run before any
    /// analysis: stale fingerprints (the workload or space changed
    /// under the journal) or a quarantined corrupt header.
    Checkpoint(String),
    Io(std::io::Error),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Usage(u) => write!(f, "usage: {u}"),
            CliError::UnknownWorkload(w) => {
                write!(f, "unknown workload {w}; try `tcpa-energy list`")
            }
            CliError::Parse(m) => write!(f, "parse error: {m}"),
            CliError::Schedule(m) => write!(f, "schedule: {m}"),
            CliError::Lint(m) => write!(f, "lint: {m}"),
            CliError::Checkpoint(m) => write!(f, "checkpoint: {m}"),
            CliError::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for CliError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // Transparent wrapper: Display already forwards to the io
            // error, so the chain continues at *its* source.
            CliError::Io(e) => e.source(),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CliError {
    fn from(e: std::io::Error) -> Self {
        CliError::Io(e)
    }
}

fn parse_flags(args: &[String]) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--")
            {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn parse_vec(s: &str, sep: char) -> Result<Vec<i64>, CliError> {
    s.split(sep)
        .map(|x| {
            x.trim().parse().map_err(|_| {
                CliError::Usage(format!(
                    "expected a list of integers separated by {sep:?}, \
                     got {s:?}"
                ))
            })
        })
        .collect()
}

/// Preflight lint gate shared by `analyze` and `dse`: deny-level
/// findings abort the run, warnings go to stderr, `--no-lint` skips the
/// gate entirely (restoring the pre-lint behavior bit-for-bit). The
/// mapping pass is deliberately not run here — mapping hazards depend on
/// the design point, which these commands sweep or choose later.
fn lint_preflight(
    wl: &crate::pra::Workload,
    flags: &BTreeMap<String, String>,
) -> Result<(), CliError> {
    if flags.contains_key("no-lint") {
        return Ok(());
    }
    let opts = crate::lint::LintOptions::default();
    for rep in crate::lint::lint_workload(wl, &opts) {
        for f in &rep.findings {
            if f.code.severity() == crate::lint::Severity::Warn {
                eprintln!("lint warning [{}]: {f}", rep.pra);
            }
        }
        if rep.has_deny() {
            let denies: Vec<String> = rep
                .findings
                .iter()
                .filter(|f| {
                    f.code.severity() == crate::lint::Severity::Deny
                })
                .map(|f| format!("  {f}"))
                .collect();
            return Err(CliError::Lint(format!(
                "workload phase {} has {} deny-level finding(s):\n{}\n\
                 run `tcpa-energy lint --workload {}` for the full \
                 report, or pass --no-lint to bypass the gate",
                rep.pra,
                denies.len(),
                denies.join("\n"),
                wl.name
            )));
        }
    }
    Ok(())
}

/// Resolve the workload under analysis from `--workload NAME` (builtin
/// registry) or `--workload-file PATH` (textual frontend,
/// [`crate::workloads::text`]). The two are mutually exclusive. Returns
/// the workload plus a `from_file` marker — commands harden the
/// untrusted-input path further on that signal (schedule causality
/// proofs, pre-checked schedulability) while builtins keep the exact
/// pre-frontend behavior.
fn workload_from_flags(
    flags: &BTreeMap<String, String>,
) -> Result<(crate::pra::Workload, bool), CliError> {
    match (flags.get("workload"), flags.get("workload-file")) {
        (Some(_), Some(_)) => Err(CliError::Usage(
            "--workload and --workload-file are mutually exclusive".into(),
        )),
        (Some(name), None) => {
            let wl = workloads::by_name(name)
                .ok_or_else(|| CliError::UnknownWorkload(name.clone()))?;
            Ok((wl, false))
        }
        (None, Some(path)) => {
            // `parse_flags` maps a value-less flag to "true".
            if path == "true" {
                return Err(CliError::Usage(
                    "--workload-file requires a path".into(),
                ));
            }
            let src = std::fs::read_to_string(path)?;
            let wl = workloads::text::parse_workload(&src)
                .map_err(|e| CliError::Parse(format!("{path}:{e}")))?;
            Ok((wl, true))
        }
        (None, None) => Err(CliError::Usage(
            "--workload NAME or --workload-file PATH required".into(),
        )),
    }
}

/// Per-scenario knee summary shared by `dse` sweeps and `dse merge`.
fn print_knees(res: &ExploreResult) {
    for g in &res.groups {
        if let Some(k) = g.knee.map(|i| &res.points[i]) {
            // Name the schedule only when a non-default candidate
            // won — the default pick is implied otherwise — and
            // the phase assignment only when it is genuinely
            // heterogeneous.
            let sched = if k.point.schedule.is_default() {
                String::new()
            } else {
                format!(", schedule {}", k.schedule_label)
            };
            let phases = if k.point.phase_shapes.is_heterogeneous() {
                format!(", phases {}", k.point.phase_shapes.label())
            } else {
                String::new()
            };
            println!(
                "knee [bounds {:?}, {}]: {} ({} PEs, {:.1} pJ, \
                 {} cycles{sched}{phases})",
                g.bounds,
                g.backend.name(),
                k.point.array_label(),
                k.pes,
                k.energy_pj,
                k.latency_cycles
            );
        }
    }
}

/// Run the CLI; returns the process exit code.
pub fn run_cli(args: &[String]) -> Result<i32, CliError> {
    let usage = "tcpa-energy \
                 <list|backends|analyze|simulate|validate|dse|figures|lint> \
                 [flags]";
    let Some(cmd) = args.first() else {
        return Err(CliError::Usage(usage.into()));
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "list" => {
            println!("workloads:");
            for wl in workloads::all() {
                let phases: Vec<String> = wl
                    .phases
                    .iter()
                    .map(|p| format!("{} ({}D)", p.name, p.ndims))
                    .collect();
                println!("  {:10} phases: {}", wl.name, phases.join(", "));
            }
            Ok(0)
        }
        "backends" => {
            println!(
                "built-in energy backends (one symbolic analysis prices \
                 all of them; sweep with `dse --backend ...`):"
            );
            for b in Backend::builtins() {
                println!("\n  {:10} {}", b.name(), b.description());
                for class in AccessClass::ALL {
                    let route: Vec<&str> = b
                        .route(class)
                        .iter()
                        .map(|c| c.label())
                        .collect();
                    println!(
                        "    {:10} -> {:16} {:>10.2} pJ/access",
                        class.label(),
                        route.join("+"),
                        b.access_energy(class)
                    );
                }
            }
            Ok(0)
        }
        "analyze" => {
            let (wl, from_file) = workload_from_flags(&flags)?;
            lint_preflight(&wl, &flags)?;
            let array = parse_vec(
                flags.get("array").map(String::as_str).unwrap_or("8x8"),
                'x',
            )?;
            for phase in &wl.phases {
                let mapping =
                    ArrayMapping::new(pad_array(&array, phase.ndims));
                if from_file {
                    // Untrusted input: `SymbolicAnalysis::analyze` panics
                    // on an unschedulable PRA (an invariant violation for
                    // builtins); pre-check so a textual workload fails
                    // with a diagnostic instead.
                    let tiled = tile_pra(phase, &mapping);
                    find_schedule(&tiled, 1).map_err(|e| {
                        CliError::Schedule(format!(
                            "no causal schedule for phase {} at pi=1: {e}",
                            phase.name
                        ))
                    })?;
                }
                let ana = SymbolicAnalysis::analyze(phase, &mapping);
                println!(
                    "[{}] symbolic analysis took {:?}",
                    phase.name, ana.analysis_time
                );
                if flags.contains_key("report") {
                    println!("{}", ana.report());
                }
                if let Some(bounds) = flags.get("bounds") {
                    let b = crate::tiling::pad_bounds(
                        &parse_vec(bounds, ',')?,
                        phase.ndims,
                    );
                    let params = ana.params_for(&b);
                    let e = ana.energy_at(&params);
                    let l = ana.latency_at(&params);
                    println!("  bounds {b:?} → params {params:?}");
                    for (c, v) in &e.mem_pj {
                        println!("    {c:4} {v:>18.2} pJ");
                    }
                    println!("    comp {:>18.2} pJ", e.compute_pj);
                    println!("    TOTAL {:>17.2} pJ   latency {} cycles", e.total, l);
                }
            }
            Ok(0)
        }
        "simulate" => {
            let (wl, from_file) = workload_from_flags(&flags)?;
            // The same deny gate as `analyze`/`dse` — the discrete-event
            // engine trusts IR invariants the linter proves, so an
            // unvetted workload must not reach it (`--no-lint` bypasses).
            lint_preflight(&wl, &flags)?;
            let array = parse_vec(
                flags.get("array").map(String::as_str).unwrap_or("2x2"),
                'x',
            )?;
            let bounds = parse_vec(
                flags.get("bounds").map(String::as_str).unwrap_or("8,8"),
                ',',
            )?;
            let params_all: Vec<Vec<i64>> = wl
                .phases
                .iter()
                .map(|ph| {
                    let b = crate::tiling::pad_bounds(&bounds, ph.ndims);
                    let t = pad_array(&array, ph.ndims);
                    ArrayMapping::new(t).params_for(&b)
                })
                .collect();
            let mut env = workload_inputs(&wl, &params_all);
            for (phase, params) in wl.phases.iter().zip(&params_all) {
                let t = pad_array(&array, phase.ndims);
                let mapping = ArrayMapping::new(t.clone());
                let arch = ArchConfig::with_array(t);
                let tiled = tile_pra(phase, &mapping);
                // Unschedulable phases are a user-facing refusal (exit 2
                // via `main`), not a panic: a workload can carry
                // dependence vectors no LSGP permutation satisfies.
                let schedule =
                    find_schedule(&tiled, arch.pi).map_err(|e| {
                        CliError::Schedule(format!(
                            "no causal schedule for phase {} at pi={}: {e}",
                            phase.name, arch.pi
                        ))
                    })?;
                if from_file {
                    // Textual workloads additionally prove the chosen
                    // schedule's causality symbolically (for all
                    // parameter values), not just constructively.
                    let fails = schedule.verify_symbolic(&tiled);
                    if !fails.is_empty() {
                        return Err(CliError::Schedule(format!(
                            "causality proof failed for phase {} at \
                             pi={} (schedule {}): {}",
                            phase.name,
                            arch.pi,
                            schedule.perm_label(),
                            fails.join("; ")
                        )));
                    }
                }
                let res = simulate(phase, &arch, &schedule, params, &env);
                println!("[{}] {} cycles", phase.name, res.cycles);
                println!(
                    "  utilization {:.1}%  max-hop {}  FD pressure {}",
                    res.stats.utilization * 100.0,
                    res.stats.max_hop,
                    res.stats.fd_pressure
                );
                for (c, v) in &res.counters.mem {
                    println!("  {c:4} accesses {v}");
                }
                println!(
                    "  adds {}  muls {}  energy {:.2} pJ",
                    res.counters.adds,
                    res.counters.muls,
                    res.counters.energy_pj(&Default::default())
                );
                if !res.violations.is_empty() {
                    println!("  VIOLATIONS: {:?}", res.violations);
                }
                for (n, t) in res.outputs {
                    env.insert(n, t);
                }
            }
            Ok(0)
        }
        "validate" => {
            let bounds = parse_vec(
                flags.get("bounds").map(String::as_str).unwrap_or("8,8"),
                ',',
            )?;
            let array = parse_vec(
                flags.get("array").map(String::as_str).unwrap_or("2x2"),
                'x',
            )?;
            let wls: Vec<_> = match flags.get("workload") {
                Some(n) => vec![workloads::by_name(n)
                    .ok_or_else(|| CliError::UnknownWorkload(n.clone()))?],
                None => workloads::all(),
            };
            let mut all_ok = true;
            for wl in wls {
                for row in validate_workload(&wl, &bounds, &array) {
                    let status = if row.exact_match && row.functional_ok {
                        "EXACT"
                    } else {
                        all_ok = false;
                        "MISMATCH"
                    };
                    println!(
                        "{:10} {:9} N={:?} t={:?}  {status}  \
                         E_sym {:.1} pJ  E_sim {:.1} pJ  \
                         (eval {:.0} µs, sim {:.0} µs)",
                        row.workload,
                        row.phase,
                        row.bounds,
                        row.array,
                        row.energy_sym_pj,
                        row.energy_sim_pj,
                        row.sym_eval_us,
                        row.sim_us
                    );
                }
            }
            Ok(if all_ok { 0 } else { 1 })
        }
        "dse" => {
            let (wl, from_file) = workload_from_flags(&flags)?;
            lint_preflight(&wl, &flags)?;
            let max_pes: i64 = match flags.get("max-pes") {
                Some(s) => s.parse().map_err(|_| {
                    CliError::Usage(format!(
                        "--max-pes expects an integer, got {s}"
                    ))
                })?,
                None => 64,
            };
            if max_pes < 1 {
                return Err(CliError::Usage(format!(
                    "--max-pes must be >= 1, got {max_pes}"
                )));
            }
            let positive = |flag: &str, v: Vec<i64>| {
                if v.iter().all(|&x| x >= 1) {
                    Ok(v)
                } else {
                    Err(CliError::Usage(format!(
                        "{flag} expects loop bounds >= 1, got {v:?}"
                    )))
                }
            };

            let mut space = match flags
                .get("arrays")
                .map(String::as_str)
                .unwrap_or("2d")
            {
                "1d" => DesignSpace::new().with_arrays_1d(max_pes),
                "2d" => DesignSpace::new().with_arrays_2d(max_pes),
                other => {
                    return Err(CliError::Usage(format!(
                        "--arrays must be 1d or 2d, got {other}"
                    )))
                }
            };
            space = match flags.get("bounds-sweep") {
                Some(s) => {
                    if flags.contains_key("bounds") {
                        return Err(CliError::Usage(
                            "--bounds and --bounds-sweep are mutually \
                             exclusive"
                                .into(),
                        ));
                    }
                    space.with_bounds_sweep(
                        &positive("--bounds-sweep", parse_vec(s, ',')?)?,
                        2,
                    )
                }
                None => space.with_bounds(positive(
                    "--bounds",
                    parse_vec(
                        flags
                            .get("bounds")
                            .map(String::as_str)
                            .unwrap_or("64,64"),
                        ',',
                    )?,
                )?),
            };
            if let Some(s) = flags.get("tile-scales") {
                let scales = parse_vec(s, ',')?;
                if scales.is_empty() || scales.iter().any(|&k| k < 1) {
                    return Err(CliError::Usage(format!(
                        "--tile-scales expects integers >= 1, got {s}"
                    )));
                }
                space = space.with_tile_scales(scales);
            }
            if let Some(s) = flags.get("schedules") {
                let policy = match s.as_str() {
                    "all" => SchedulePolicy::All,
                    "first" => SchedulePolicy::First,
                    n => match n.parse::<usize>() {
                        Ok(cap) if cap >= 1 => SchedulePolicy::Limit(cap),
                        _ => {
                            return Err(CliError::Usage(format!(
                                "--schedules expects all, first, or a \
                                 per-phase candidate cap >= 1, got {s}"
                            )))
                        }
                    },
                };
                space = space.with_schedules(policy);
            }
            if let Some(s) = flags.get("phase-shapes") {
                let policy = match s.as_str() {
                    "uniform" => PhasePolicy::Uniform,
                    "per-phase" => PhasePolicy::PerPhase,
                    other => {
                        return Err(CliError::Usage(format!(
                            "--phase-shapes must be uniform or per-phase, \
                             got {other}"
                        )))
                    }
                };
                space = space.with_phase_shapes(policy);
            }
            if flags.contains_key("backend") && flags.contains_key("policies")
            {
                return Err(CliError::Usage(
                    "--backend and --policies (legacy) are mutually \
                     exclusive"
                        .into(),
                ));
            }
            if let Some(s) = flags.get("backend") {
                let backends: Vec<Backend> = if s == "all" {
                    Backend::builtins()
                } else {
                    s.split(',')
                        .map(|l| {
                            Backend::by_name(l.trim()).ok_or_else(|| {
                                CliError::Usage(format!(
                                    "unknown backend {l}; try `tcpa-energy \
                                     backends` for the list, or `all`"
                                ))
                            })
                        })
                        .collect::<Result<_, _>>()?
                };
                space = space.with_backends(backends);
            }
            if let Some(s) = flags.get("policies") {
                let policies: Vec<Policy> = if s == "all" {
                    Policy::ALL.to_vec()
                } else {
                    s.split(',')
                        .map(|l| {
                            Policy::ALL
                                .into_iter()
                                .find(|p| p.label() == l.trim())
                                .ok_or_else(|| {
                                    CliError::Usage(format!(
                                        "unknown policy {l}; try \
                                         tcpa,no-fd,no-reuse or `all`"
                                    ))
                                })
                        })
                        .collect::<Result<_, _>>()?
                };
                space = space.with_policies(policies);
            }
            if flags.contains_key("prune-symmetric") {
                space = space.with_symmetry_pruning();
            }
            if from_file {
                // Textual workloads are untrusted: every schedule the
                // sweep prices — the embedded default under
                // `--schedules first`, every enumerated candidate
                // otherwise — must carry a symbolic causality proof
                // ([`crate::schedule::Schedule::verify_symbolic`]).
                // An unprovable schedule fails the point, not the run.
                space = space.with_schedule_verification();
            }
            if let Some(sflag) = flags.get("strategy") {
                space = space.with_strategy(
                    Strategy::parse(sflag).map_err(CliError::Usage)?,
                );
            }
            let shard = match flags.get("shard") {
                Some(v) => Shard::parse(v)
                    .map_err(|e| CliError::Usage(format!("--shard: {e}")))?,
                None => Shard::solo(),
            };
            if !space.strategy.is_exhaustive() && !shard.is_solo() {
                return Err(CliError::Usage(
                    "--shard partitions the canonical exhaustive \
                     enumeration; it cannot combine with --strategy \
                     beam (a heuristic subset has no stable global \
                     indices to split)"
                        .into(),
                ));
            }
            if args.get(1).map(String::as_str) == Some("merge") {
                // `dse merge`: fold finished per-shard journals into
                // the full report. No analysis runs here, so the
                // interruptibility and explosion-refusal machinery
                // below does not apply — but the workload and space
                // flags must match the shard runs exactly (the
                // journals are fingerprint-locked to them).
                if flags.contains_key("shard") {
                    return Err(CliError::Usage(
                        "--shard names one slice of a sweep; `dse \
                         merge` folds finished slices and takes \
                         --shards a.journal,b.journal,... instead"
                            .into(),
                    ));
                }
                for banned in
                    ["checkpoint", "resume", "sim-verify-frontier"]
                {
                    if flags.contains_key(banned) {
                        return Err(CliError::Usage(format!(
                            "--{banned} applies to a sweep, not to \
                             `dse merge` (merge only replays finished \
                             shard journals)"
                        )));
                    }
                }
                let list = flags.get("shards").ok_or_else(|| {
                    CliError::Usage(
                        "dse merge requires --shards \
                         a.journal,b.journal,... (one finished \
                         journal per shard, any order)"
                            .into(),
                    )
                })?;
                let paths: Vec<std::path::PathBuf> = list
                    .split(',')
                    .map(|p| std::path::PathBuf::from(p.trim()))
                    .collect();
                let res = merge_shards(&wl, &space, &paths)
                    .map_err(CliError::Checkpoint)?;
                println!(
                    "{}: {} points merged from {} shard journal(s) \
                     ({} failed)",
                    res.workload,
                    res.points.len(),
                    paths.len(),
                    res.failures.len()
                );
                for (p, msg) in res.failures.iter().take(8) {
                    eprintln!(
                        "  failed: {} bounds {:?} ({}, scale {}): {msg}",
                        p.array_label(),
                        p.bounds,
                        p.backend.name(),
                        p.tile_scale
                    );
                }
                if res.failures.len() > 8 {
                    eprintln!(
                        "  ... and {} more",
                        res.failures.len() - 8
                    );
                }
                println!("{}", dse_frontier_markdown(&res));
                print_knees(&res);
                if let Some(out) = flags.get("out") {
                    let dir = Path::new(out);
                    write_dse_report(
                        &res,
                        dir,
                        &format!("dse_{}", res.workload),
                    )?;
                    println!(
                        "full point cloud + frontier → {}/dse_{}_*.csv",
                        dir.display(),
                        res.workload
                    );
                }
                return Ok(
                    if res.points.is_empty() && !res.failures.is_empty()
                    {
                        1
                    } else {
                        0
                    },
                );
            }
            if space.phase_policy == PhasePolicy::PerPhase
                && space.strategy.is_exhaustive()
            {
                // Shape combinations grow as shapes^phases; refuse an
                // explosion loudly (never cap coverage silently) before
                // any analysis runs — unless the user already bounded
                // the sweep: `--checkpoint` makes an interrupted run
                // resumable, `--deadline` bounds the wall clock,
                // `--strategy beam` bounds the points evaluated (so
                // the gate is skipped above), and a `--shard i/n` run
                // is judged on its own slice, since the enumeration
                // is split n ways across processes or machines.
                const MAX_PHASE_POINTS: u128 = 20_000;
                let est = space.phase_point_estimate(wl.phases.len());
                let slice = (est + shard.count as u128 - 1)
                    / shard.count as u128;
                let bounded = flags.contains_key("checkpoint")
                    || flags.contains_key("deadline");
                if slice > MAX_PHASE_POINTS && !bounded {
                    return Err(CliError::Usage(format!(
                        "--phase-shapes per-phase with --max-pes \
                         {max_pes} on {} would enumerate up to {est} \
                         design points ({} shapes ^ {} phases, over the \
                         {MAX_PHASE_POINTS}-point cap); lower --max-pes \
                         (e.g. 8) or narrow the other axes — or keep \
                         the space and bound the sweep, which lifts \
                         this cap: --checkpoint FILE (resumable \
                         journal) and/or --deadline SECS (bounded wall \
                         clock), --strategy beam (anytime Pareto-beam \
                         search; exhaustive stays the oracle), or \
                         --shard i/n slices folded by `dse merge` \
                         (split the enumeration across machines)",
                        wl.name,
                        space.arrays.len(),
                        wl.phases.len()
                    )));
                }
            }
            let workers: usize = match flags.get("workers") {
                Some(s) => s.parse().map_err(|_| {
                    CliError::Usage(format!(
                        "--workers expects an integer, got {s}"
                    ))
                })?,
                None => 0,
            };

            let cfg = ExploreConfig { workers };
            // Robustness controls: checkpoint journal, resume, wall
            // clock and per-point budgets, Ctrl-C draining.
            let parse_secs =
                |flag: &str, v: &str| -> Result<Duration, CliError> {
                    match v.parse::<f64>() {
                        Ok(x) if x > 0.0 && x.is_finite() => {
                            Ok(Duration::from_secs_f64(x))
                        }
                        _ => Err(CliError::Usage(format!(
                            "{flag} expects a positive number of \
                             seconds, got {v}"
                        ))),
                    }
                };
            let checkpoint = match flags.get("checkpoint") {
                Some(p) if p != "true" => {
                    Some(std::path::PathBuf::from(p))
                }
                Some(_) => {
                    return Err(CliError::Usage(
                        "--checkpoint expects a journal file path"
                            .into(),
                    ))
                }
                None => None,
            };
            let resume = flags.contains_key("resume");
            if resume && checkpoint.is_none() {
                return Err(CliError::Usage(
                    "--resume requires --checkpoint FILE (the journal \
                     to replay)"
                        .into(),
                ));
            }
            let deadline = flags
                .get("deadline")
                .map(|v| parse_secs("--deadline", v))
                .transpose()?;
            let point_timeout = flags
                .get("point-timeout")
                .map(|v| parse_secs("--point-timeout", v))
                .transpose()?;
            let mut ctl = ExploreControl {
                checkpoint,
                resume,
                point_timeout,
                shard,
                faults: FaultPlan::from_env(),
                ..ExploreControl::default()
            };
            if let Some(d) = deadline {
                ctl.cancel.set_deadline_in(d);
            }
            if ctl.checkpoint.is_some()
                || deadline.is_some()
                || point_timeout.is_some()
            {
                // Ctrl-C drains in-flight workers, flushes the journal
                // and reports a partial frontier instead of losing the
                // run (a second Ctrl-C exits immediately).
                ctl.cancel.watch_sigint();
            }
            if flags.contains_key("progress") {
                ctl.progress = Some(Box::new(|done, total| {
                    eprintln!("progress: {done}/{total} points");
                }));
            }
            // Persistent spill: repeated CLI invocations reload the
            // one-time symbolic volumes instead of recomputing. The
            // in-memory cache exists either way — the sim-verify pass
            // reuses its analyses after the sweep.
            let cache = match flags.get("analysis-cache") {
                Some(dir) if dir != "true" => AnalysisCache::with_disk(dir),
                Some(_) => {
                    return Err(CliError::Usage(
                        "--analysis-cache expects a directory".into(),
                    ))
                }
                None if flags.contains_key("prune-cache") => {
                    return Err(CliError::Usage(
                        "--prune-cache requires --analysis-cache DIR"
                            .into(),
                    ))
                }
                None => AnalysisCache::new(),
            };
            let mut res =
                explore_controlled(&wl, &space, &cfg, &cache, &ctl)
                    .map_err(CliError::Checkpoint)?;
            for w in &res.warnings {
                eprintln!("warning: {w}");
            }
            if flags.contains_key("analysis-cache")
                && flags.contains_key("prune-cache")
            {
                // Live keys: the whole-workload entry plus one
                // phase-scoped entry per phase (the per-phase
                // axis spills those), each under its own
                // structural fingerprint.
                let mut live =
                    vec![(wl.name.clone(), workload_fingerprint(&wl))];
                for (i, ph) in wl.phases.iter().enumerate() {
                    live.push((
                        phase_cache_name(&wl.name, i),
                        phase_fingerprint(ph),
                    ));
                }
                match cache.prune_disk(&live) {
                    Ok(0) => {}
                    Ok(n) => println!(
                        "pruned {n} stale analysis-cache file(s)"
                    ),
                    // Advisory, like the spill itself: a prune
                    // failure must not fail the sweep.
                    Err(e) => eprintln!(
                        "analysis-cache prune failed: {e}"
                    ),
                }
            }
            // Post-sweep confidence pass: re-simulate only the frontier
            // points on the event engine, annotate the report, escalate
            // divergence.
            let mut diverged = 0usize;
            if flags.contains_key("sim-verify-frontier")
                && res.cancelled.is_some()
            {
                eprintln!(
                    "sim-verify skipped: the sweep was cancelled and \
                     the partial frontier is not final"
                );
            } else if flags.contains_key("sim-verify-frontier") {
                sim_verify_frontier(&wl, &mut res, &cache);
                for (&i, v) in &res.sim_verify {
                    if !v.confirmed() {
                        diverged += 1;
                        for d in &v.divergences {
                            eprintln!(
                                "  sim-verify DIVERGENCE at {} bounds \
                                 {:?}: {d}",
                                res.points[i].point.array_label(),
                                res.points[i].point.bounds
                            );
                        }
                    }
                }
                println!(
                    "sim-verify: {} frontier point(s) simulated on the \
                     event engine, {}",
                    res.sim_verify.len(),
                    if diverged == 0 {
                        "all confirmed".to_string()
                    } else {
                        format!("{diverged} DIVERGED")
                    }
                );
                // Annotate each verified frontier shape with its static
                // mapping-hazard lint status: the dynamic (event-engine)
                // and static (FM/schedule-proof) verdicts side by side.
                let shapes: std::collections::BTreeSet<Vec<i64>> = res
                    .sim_verify
                    .keys()
                    .map(|&i| res.points[i].point.array.clone())
                    .collect();
                for shape in shapes {
                    let lopts = crate::lint::LintOptions {
                        array: Some(shape.clone()),
                        ..Default::default()
                    };
                    let reps = crate::lint::lint_workload(&wl, &lopts);
                    let deny: usize =
                        reps.iter().map(|r| r.deny_count()).sum();
                    let warn: usize =
                        reps.iter().map(|r| r.warn_count()).sum();
                    let label = shape
                        .iter()
                        .map(|x| x.to_string())
                        .collect::<Vec<_>>()
                        .join("x");
                    println!(
                        "  lint [{label}]: {}",
                        if deny == 0 && warn == 0 {
                            "clean".to_string()
                        } else {
                            format!("{deny} deny, {warn} warn")
                        }
                    );
                }
            }
            println!(
                "{}: {} points in {:?} ({} failed, {} replayed from \
                 journal; cache {} analyses, {:.0}% hit, {} from disk)",
                res.workload,
                res.points.len(),
                res.wall,
                res.failures.len(),
                res.replayed,
                res.cache.entries,
                res.cache.hit_rate() * 100.0,
                res.cache.disk_hits
            );
            if let Some(sh) = res.shard {
                println!(
                    "shard {}: this run owns {} point(s) of the full \
                     enumeration; fold finished shards with \
                     `tcpa-energy dse merge --shards ...`",
                    sh.label(),
                    res.total
                );
            }
            if let Some(reason) = res.cancelled {
                let hint = match &ctl.checkpoint {
                    Some(p) => format!(
                        "; resume with --checkpoint {} --resume",
                        p.display()
                    ),
                    None => "; add --checkpoint FILE to make \
                             interrupted sweeps resumable"
                        .to_string(),
                };
                println!(
                    "partial ({}/{} points): {}{hint}",
                    res.completed,
                    res.total,
                    reason.label()
                );
            }
            for (p, msg) in res.failures.iter().take(8) {
                eprintln!(
                    "  failed: {} bounds {:?} ({}, scale {}): {msg}",
                    p.array_label(),
                    p.bounds,
                    p.backend.name(),
                    p.tile_scale
                );
            }
            if res.failures.len() > 8 {
                eprintln!("  ... and {} more", res.failures.len() - 8);
            }
            println!("{}", dse_frontier_markdown(&res));
            print_knees(&res);
            if let Some(out) = flags.get("out") {
                let dir = Path::new(out);
                write_dse_report(&res, dir, &format!("dse_{}", res.workload))?;
                println!(
                    "full point cloud + frontier → {}/dse_{}_*.csv",
                    dir.display(),
                    res.workload
                );
            }
            // Total failure must be loud: empty tables with exit 0 would
            // read as success to a Makefile or CI step — and so must a
            // sim-verify divergence (exit 2: the sweep itself succeeded,
            // but its frontier is not to be trusted). A cancelled sweep
            // is the documented partial-result code 3, taking precedence:
            // an incomplete run says nothing final about failure totals.
            Ok(if res.cancelled.is_some() {
                3
            } else if res.points.is_empty() && !res.failures.is_empty() {
                1
            } else if diverged > 0 {
                2
            } else {
                0
            })
        }
        "figures" => {
            let out =
                flags.get("out").map(String::as_str).unwrap_or("results");
            let quick = flags.contains_key("quick");
            run_figures(Path::new(out), quick)?;
            Ok(0)
        }
        "lint" => {
            let deny_warnings = match flags.get("deny").map(String::as_str)
            {
                None => false,
                Some("warnings") => true,
                Some(other) => {
                    return Err(CliError::Usage(format!(
                        "--deny expects `warnings`, got {other}"
                    )))
                }
            };
            let mut opts = crate::lint::LintOptions::default();
            if let Some(a) = flags.get("array") {
                opts.array = Some(parse_vec(a, 'x')?);
            }
            if let Some(p) = flags.get("pi") {
                opts.pi = p.parse().map_err(|_| {
                    CliError::Usage(format!(
                        "--pi expects an integer, got {p}"
                    ))
                })?;
            }
            let wls: Vec<_> = if flags.contains_key("all-builtins") {
                if flags.contains_key("workload")
                    || flags.contains_key("workload-file")
                {
                    return Err(CliError::Usage(
                        "--all-builtins excludes --workload and \
                         --workload-file"
                            .into(),
                    ));
                }
                workloads::all()
            } else if flags.contains_key("workload")
                || flags.contains_key("workload-file")
            {
                vec![workload_from_flags(&flags)?.0]
            } else {
                return Err(CliError::Usage(
                    "lint needs --workload NAME, --workload-file PATH, \
                     or --all-builtins"
                        .into(),
                ));
            };
            let reports: Vec<crate::lint::LintReport> = wls
                .iter()
                .flat_map(|wl| crate::lint::lint_workload(wl, &opts))
                .collect();
            let json_doc = format!(
                "[{}]",
                reports
                    .iter()
                    .map(|r| r.to_json())
                    .collect::<Vec<_>>()
                    .join(",")
            );
            if flags.contains_key("json") {
                println!("{json_doc}");
            } else {
                for rep in &reports {
                    print!("{}", rep.render());
                }
                let deny: usize =
                    reports.iter().map(|r| r.deny_count()).sum();
                let warn: usize =
                    reports.iter().map(|r| r.warn_count()).sum();
                println!(
                    "lint: {} phase report(s), {deny} deny, {warn} warn",
                    reports.len()
                );
            }
            if let Some(path) = flags.get("json-out") {
                std::fs::write(path, &json_doc)?;
            }
            let clean =
                reports.iter().all(|r| r.is_clean(deny_warnings));
            Ok(if clean { 0 } else { 1 })
        }
        other => Err(CliError::Usage(format!("unknown command {other}; {usage}"))),
    }
}

/// Regenerate every paper table/figure into `out`.
fn run_figures(out: &Path, quick: bool) -> Result<(), CliError> {
    std::fs::create_dir_all(out)?;
    // Table I.
    let table1 = crate::energy::EnergyTable::table1_45nm().to_markdown();
    std::fs::write(out.join("table1.md"), &table1)?;
    println!("Table I → {}/table1.md", out.display());

    // Fig. 4.
    let sizes: &[i64] = if quick {
        &[16, 32, 64, 128]
    } else {
        &[16, 32, 64, 128, 256, 512]
    };
    let rows = fig4_rows(sizes);
    let mut t4 = CsvTable::new(vec![
        "N", "symbolic_analysis_s", "symbolic_eval_s", "simulation_s", "exact",
    ]);
    for r in &rows {
        t4.push(vec![
            r.n.to_string(),
            format!("{:.6}", r.symbolic_s),
            format!("{:.9}", r.symbolic_eval_s),
            format!("{:.6}", r.simulation_s),
            r.exact.to_string(),
        ]);
    }
    write_csv(&t4, out, "fig4_analysis_time")?;
    let chart = ascii_chart(
        "Fig. 4: analysis time vs matrix size (GESUMMV, 8x8) [log s]",
        &[
            (
                "symbolic (analysis+eval)",
                rows.iter()
                    .map(|r| (r.n as f64, r.symbolic_s + r.symbolic_eval_s))
                    .collect(),
            ),
            (
                "simulation",
                rows.iter().map(|r| (r.n as f64, r.simulation_s)).collect(),
            ),
        ],
        64,
        16,
        true,
    );
    println!("{chart}");
    std::fs::write(out.join("fig4.txt"), chart)?;

    // Fig. 5.
    let sizes5: &[i64] = if quick {
        &[16, 32, 64, 128]
    } else {
        &[16, 32, 64, 128, 256, 512, 1024]
    };
    let rows5 = fig5_rows(sizes5);
    let mut t5 = CsvTable::new(vec![
        "N", "total_pj", "DR_pj", "IOb_pj", "FD_pj", "RD_pj", "ID_pj",
        "OD_pj", "compute_pj", "latency_cycles",
    ]);
    for r in &rows5 {
        t5.push(vec![
            r.n.to_string(),
            format!("{:.1}", r.total_pj),
            format!("{:.1}", r.dram_pj),
            format!("{:.1}", r.iob_pj),
            format!("{:.1}", r.fd_pj),
            format!("{:.1}", r.rd_pj),
            format!("{:.1}", r.id_pj),
            format!("{:.1}", r.od_pj),
            format!("{:.1}", r.compute_pj),
            r.latency_cycles.to_string(),
        ]);
    }
    write_csv(&t5, out, "fig5_energy_scaling")?;
    let chart5 = ascii_chart(
        "Fig. 5: GEMM energy vs matrix size (8x8 grid) [log pJ]",
        &[
            ("total", rows5.iter().map(|r| (r.n as f64, r.total_pj)).collect()),
            ("DRAM", rows5.iter().map(|r| (r.n as f64, r.dram_pj)).collect()),
            (
                "FD+RD",
                rows5
                    .iter()
                    .map(|r| (r.n as f64, r.fd_pj + r.rd_pj))
                    .collect(),
            ),
            (
                "compute",
                rows5.iter().map(|r| (r.n as f64, r.compute_pj)).collect(),
            ),
        ],
        64,
        16,
        true,
    );
    println!("{chart5}");
    std::fs::write(out.join("fig5.txt"), chart5)?;

    // §V-A validation table.
    let mut tv = CsvTable::new(vec![
        "workload", "phase", "bounds", "array", "exact", "functional",
        "E_sym_pJ", "E_sim_pJ",
    ]);
    for wl in workloads::all() {
        let bounds: Vec<i64> = match wl.name.as_str() {
            "jacobi1d" => vec![4, 12],
            _ => vec![8, 8],
        };
        for row in validate_workload(&wl, &bounds, &[2, 2]) {
            tv.push(vec![
                row.workload.clone(),
                row.phase.clone(),
                format!("{:?}", row.bounds),
                format!("{:?}", row.array),
                row.exact_match.to_string(),
                row.functional_ok.to_string(),
                format!("{:.2}", row.energy_sym_pj),
                format!("{:.2}", row.energy_sim_pj),
            ]);
        }
    }
    write_csv(&tv, out, "validation_table")?;
    println!("validation table → {}/validation_table.csv", out.display());
    let _ = MemoryClass::ALL; // (rendered inside the validation rows)
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn flag_parsing() {
        let f = parse_flags(&s(&["--workload", "gemm", "--report"]));
        assert_eq!(f["workload"], "gemm");
        assert_eq!(f["report"], "true");
    }

    #[test]
    fn list_runs() {
        assert_eq!(run_cli(&s(&["list"])).unwrap(), 0);
    }

    #[test]
    fn backends_listing_runs() {
        assert_eq!(run_cli(&s(&["backends"])).unwrap(), 0);
    }

    #[test]
    fn dse_accepts_backend_axis() {
        for sel in ["all", "tcpa,cgra", "gpu-sm,systolic"] {
            assert_eq!(
                run_cli(&s(&[
                    "dse", "--workload", "gesummv", "--bounds", "8,8",
                    "--max-pes", "2", "--backend", sel
                ]))
                .unwrap(),
                0,
                "--backend {sel} should sweep"
            );
        }
    }

    #[test]
    fn dse_accepts_schedule_axis() {
        for sel in ["all", "first", "2"] {
            assert_eq!(
                run_cli(&s(&[
                    "dse", "--workload", "gesummv", "--bounds", "8,8",
                    "--max-pes", "2", "--schedules", sel
                ]))
                .unwrap(),
                0,
                "--schedules {sel} should sweep"
            );
        }
        for bad in ["0", "none", "-1"] {
            let e = run_cli(&s(&[
                "dse", "--workload", "gesummv", "--schedules", bad,
            ]));
            assert!(
                matches!(e, Err(CliError::Usage(_))),
                "--schedules {bad} should be a usage error, got {e:?}"
            );
        }
    }

    #[test]
    fn dse_accepts_phase_shapes_axis() {
        // Multi-phase workload, small budget: both policies sweep.
        for sel in ["uniform", "per-phase"] {
            assert_eq!(
                run_cli(&s(&[
                    "dse", "--workload", "atax", "--bounds", "8,8",
                    "--max-pes", "4", "--phase-shapes", sel
                ]))
                .unwrap(),
                0,
                "--phase-shapes {sel} should sweep"
            );
        }
        // Bad value is a usage error.
        let e = run_cli(&s(&[
            "dse", "--workload", "atax", "--phase-shapes", "hetero",
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))));
        // Combinatorial explosion is refused loudly, not swept silently:
        // gemver has 3 phases, so the default --max-pes 64 shape list
        // (283 shapes) would mean 283³ combinations.
        let e = run_cli(&s(&[
            "dse", "--workload", "gemver", "--bounds", "8,8",
            "--phase-shapes", "per-phase",
        ]));
        assert!(
            matches!(e, Err(CliError::Usage(_))),
            "oversized per-phase space should be a usage error, got {e:?}"
        );
    }

    #[test]
    fn dse_sim_verify_frontier_composes_with_axes() {
        let _env = crate::dse::verify::env_guard();
        // Plain sweep, then with both the schedule and per-phase axes
        // active — the verify pass must reconstruct every frontier
        // point's exact assignment in all cases.
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload", "gesummv", "--bounds", "16,16",
                "--max-pes", "4", "--sim-verify-frontier"
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload", "atax", "--bounds", "8,8",
                "--max-pes", "4", "--schedules", "all", "--phase-shapes",
                "per-phase", "--sim-verify-frontier"
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn dse_sim_verify_annotates_the_report_column() {
        let _env = crate::dse::verify::env_guard();
        let dir = std::env::temp_dir()
            .join(format!("tcpa-cli-simverify-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload", "gesummv", "--bounds", "8,8",
                "--max-pes", "2", "--sim-verify-frontier", "--out", &dir_s,
            ]))
            .unwrap(),
            0
        );
        let csv = std::fs::read_to_string(
            dir.join("dse_gesummv_frontier.csv"),
        )
        .unwrap();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.ends_with(",sim_cycles"), "header: {header}");
        for line in lines {
            let cell = line.rsplit(',').next().unwrap();
            assert!(
                !cell.is_empty() && cell.chars().all(|c| c.is_ascii_digit()),
                "frontier row should carry sim-confirmed cycles: {line}"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dse_sim_verify_divergence_is_a_loud_nonzero_exit() {
        use crate::dse::verify::FORCE_DIVERGE_ENV;
        let _env = crate::dse::verify::env_guard();
        std::env::set_var(FORCE_DIVERGE_ENV, "1");
        let args = [
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2",
        ];
        let with_flag = {
            let mut a = args.to_vec();
            a.push("--sim-verify-frontier");
            run_cli(&s(&a))
        };
        // Without the flag the seam is inert: no verification, exit 0.
        let without_flag = run_cli(&s(&args));
        std::env::remove_var(FORCE_DIVERGE_ENV);
        assert_eq!(
            with_flag.unwrap(),
            2,
            "a sim-verify divergence must be a distinct non-zero exit"
        );
        assert_eq!(without_flag.unwrap(), 0);
    }

    #[test]
    fn dse_prune_cache_requires_and_uses_analysis_cache() {
        // Without a cache directory the flag is a usage error, not a
        // silent no-op.
        let e = run_cli(&s(&[
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2", "--prune-cache",
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))));
        // With one, the sweep spills and the prune keeps live entries.
        let dir = std::env::temp_dir()
            .join(format!("tcpa-cli-prune-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        let args = [
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2", "--analysis-cache", &dir_s, "--prune-cache",
        ];
        assert_eq!(run_cli(&s(&args)).unwrap(), 0);
        let live = std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0);
        assert!(live > 0, "live entries must survive the prune");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dse_per_phase_sweep_spills_phase_entries_that_survive_prune() {
        let dir = std::env::temp_dir().join(format!(
            "tcpa-cli-phase-cache-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        let args = [
            "dse", "--workload", "atax", "--bounds", "8,8", "--max-pes",
            "2", "--phase-shapes", "per-phase", "--analysis-cache",
            &dir_s, "--prune-cache",
        ];
        assert_eq!(run_cli(&s(&args)).unwrap(), 0);
        let files: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        // One file per (phase, shape) pair; the prune (phase names are
        // listed live) must keep them all.
        assert!(
            files.iter().any(|f| f.starts_with("atax_p0-")),
            "phase-scoped spills expected, got {files:?}"
        );
        assert!(files.iter().any(|f| f.starts_with("atax_p1-")));
        // Second invocation reloads them from disk.
        assert_eq!(run_cli(&s(&args)).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dse_analysis_cache_persists_across_invocations() {
        let dir = std::env::temp_dir()
            .join(format!("tcpa-cli-cache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        let args = [
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2", "--analysis-cache", &dir_s,
        ];
        assert_eq!(run_cli(&s(&args)).unwrap(), 0);
        let spilled = std::fs::read_dir(&dir)
            .map(|d| d.count())
            .unwrap_or(0);
        assert!(spilled > 0, "first run must spill volume files");
        // Second "process": same directory, fresh in-memory cache.
        assert_eq!(run_cli(&s(&args)).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
        // Missing directory value is a usage error.
        let e = run_cli(&s(&[
            "dse", "--workload", "gemm", "--analysis-cache",
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))));
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cli(&s(&["frobnicate"])).is_err());
        assert!(run_cli(&[]).is_err());
    }

    #[test]
    fn unknown_workload_errors() {
        let e = run_cli(&s(&["analyze", "--workload", "nope"]));
        assert!(matches!(e, Err(CliError::UnknownWorkload(_))));
    }

    #[test]
    fn dse_emits_multi_objective_frontier() {
        // Acceptance: the dse subcommand runs end to end for the paper's
        // running example and GEMM, producing a Pareto frontier (the
        // frontier table is exercised inside run_cli).
        for wl in ["gesummv", "gemm"] {
            assert_eq!(
                run_cli(&s(&[
                    "dse", "--workload", wl, "--bounds", "16,16",
                    "--max-pes", "4"
                ]))
                .unwrap(),
                0
            );
        }
    }

    #[test]
    fn dse_rejects_bad_arrays_flag() {
        let e = run_cli(&s(&[
            "dse", "--workload", "gemm", "--arrays", "3d"
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))));
    }

    #[test]
    fn dse_rejects_bad_flag_values_with_usage_errors() {
        for bad in [
            vec!["dse", "--workload", "gemm", "--policies", "bogus"],
            vec!["dse", "--workload", "gemm", "--backend", "bogus"],
            vec![
                "dse", "--workload", "gemm", "--backend", "tcpa",
                "--policies", "tcpa",
            ],
            vec!["dse", "--workload", "gemm", "--tile-scales", "0"],
            vec!["dse", "--workload", "gemm", "--tile-scales", "1,x"],
            vec!["dse", "--workload", "gemm", "--workers", "abc"],
            vec!["dse", "--workload", "gemm", "--max-pes", "abc"],
            vec!["dse", "--workload", "gemm", "--bounds-sweep", "32,abc"],
            vec!["dse", "--workload", "gemm", "--bounds", "x,8"],
            vec![
                "dse", "--workload", "gemm", "--bounds", "8,8",
                "--bounds-sweep", "16,32",
            ],
            vec!["dse", "--workload", "gemm", "--bounds", "0,8"],
            vec!["dse", "--workload", "gemm", "--bounds-sweep", "-64"],
            vec!["dse", "--workload", "gemm", "--max-pes", "0"],
        ] {
            let e = run_cli(&s(&bad));
            assert!(
                matches!(e, Err(CliError::Usage(_))),
                "{bad:?} should be a usage error, got {e:?}"
            );
        }
    }

    #[test]
    fn analyze_and_validate_roundtrip() {
        assert_eq!(
            run_cli(&s(&[
                "analyze", "--workload", "gesummv", "--array", "2x2",
                "--bounds", "8,8"
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "validate", "--workload", "gesummv", "--bounds", "8,8",
                "--array", "2x2"
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn lint_clean_builtins_exit_zero() {
        // Every builtin is clean even under --deny warnings, with and
        // without the mapping pass.
        assert_eq!(
            run_cli(&s(&["lint", "--all-builtins", "--deny", "warnings"]))
                .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "lint", "--workload", "gesummv", "--array", "2x2",
                "--deny", "warnings", "--json"
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn lint_flag_validation() {
        let e = run_cli(&s(&["lint"]));
        assert!(matches!(e, Err(CliError::Usage(_))), "{e:?}");
        let e = run_cli(&s(&["lint", "--workload", "nope"]));
        assert!(matches!(e, Err(CliError::UnknownWorkload(_))), "{e:?}");
        let e = run_cli(&s(&[
            "lint", "--workload", "gemm", "--deny", "everything",
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))), "{e:?}");
        let e = run_cli(&s(&[
            "lint", "--workload", "gemm", "--pi", "abc",
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))), "{e:?}");
    }

    #[test]
    fn lint_json_out_writes_machine_report() {
        let path = std::env::temp_dir()
            .join(format!("tcpa-lint-{}.json", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        assert_eq!(
            run_cli(&s(&[
                "lint", "--workload", "gemm", "--json-out", &path_s,
            ]))
            .unwrap(),
            0
        );
        let doc = std::fs::read_to_string(&path).unwrap();
        assert!(doc.starts_with('[') && doc.ends_with(']'), "{doc}");
        assert!(doc.contains("\"pra\":\"gemm\""), "{doc}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn dse_phase_explosion_refusal_names_flags_and_mitigation() {
        let e = run_cli(&s(&[
            "dse", "--workload", "gemver", "--bounds", "8,8",
            "--phase-shapes", "per-phase",
        ]));
        let Err(CliError::Usage(msg)) = e else {
            panic!("expected a usage error, got {e:?}");
        };
        assert!(msg.contains("--phase-shapes per-phase"), "{msg}");
        assert!(msg.contains("--max-pes 64"), "{msg}");
        assert!(msg.contains("--checkpoint"), "{msg}");
        assert!(msg.contains("--deadline"), "{msg}");
        // PR 10: the refusal names every mitigation, including the
        // heuristic strategy and the sharded split.
        assert!(msg.contains("--strategy beam"), "{msg}");
        assert!(msg.contains("--shard"), "{msg}");
        assert!(msg.contains("dse merge"), "{msg}");
    }

    #[test]
    fn dse_beam_strategy_and_small_shard_slices_lift_the_refusal() {
        // gemver at --max-pes 12 enumerates 35^3 = 42 875 per-phase
        // combinations — over the cap, so exhaustive refuses...
        let e = run_cli(&s(&[
            "dse", "--workload", "gemver", "--bounds", "8,8",
            "--max-pes", "12", "--phase-shapes", "per-phase",
        ]));
        assert!(
            matches!(e, Err(CliError::Usage(_))),
            "35^3 combos must trip the exhaustive cap: {e:?}"
        );
        // ...but a beam search is budget-bounded, so the same space
        // sweeps (the report is marked heuristic).
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload", "gemver", "--bounds", "8,8",
                "--max-pes", "12", "--phase-shapes", "per-phase",
                "--strategy", "beam:4",
            ]))
            .unwrap(),
            0,
            "--strategy beam must lift the per-phase explosion refusal"
        );
        // ...and the refusal judges a sharded run on its own slice:
        // the estimate that trips solo must pass once split enough
        // ways. (Probed indirectly — a slice that is still over the
        // cap keeps refusing, so the gate is genuinely per-shard.)
        let e = run_cli(&s(&[
            "dse", "--workload", "gemver", "--phase-shapes",
            "per-phase", "--shard", "1/2",
        ]));
        assert!(
            matches!(e, Err(CliError::Usage(_))),
            "half of an enormous space is still over the cap: {e:?}"
        );
    }

    #[test]
    fn dse_strategy_and_shard_flag_validation() {
        for bad in ["beams", "beam:", "beam:0", "beam:x", "BEAM"] {
            let e = run_cli(&s(&[
                "dse", "--workload", "gesummv", "--bounds", "8,8",
                "--max-pes", "2", "--strategy", bad,
            ]));
            let Err(CliError::Usage(msg)) = e else {
                panic!(
                    "--strategy {bad} should be a usage error, got {e:?}"
                );
            };
            assert!(msg.contains(bad), "{msg}");
        }
        for bad in ["3", "0/3", "4/3", "2-3", "a/b"] {
            let e = run_cli(&s(&[
                "dse", "--workload", "gesummv", "--bounds", "8,8",
                "--max-pes", "2", "--shard", bad,
            ]));
            let Err(CliError::Usage(msg)) = e else {
                panic!(
                    "--shard {bad} should be a usage error, got {e:?}"
                );
            };
            assert!(msg.contains("--shard"), "{msg}");
        }
        // A heuristic subset has no stable global indices to shard.
        let e = run_cli(&s(&[
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2", "--strategy", "beam", "--shard", "1/2",
        ]));
        assert!(matches!(e, Err(CliError::Usage(_))), "{e:?}");
    }

    #[test]
    fn dse_merge_validates_its_inputs() {
        for bad in [
            // merge requires --shards,
            vec!["dse", "merge", "--workload", "gesummv"],
            // refuses the single-slice flag,
            vec![
                "dse", "merge", "--workload", "gesummv", "--shard",
                "1/2", "--shards", "x.journal",
            ],
            // and refuses sweep-only robustness flags.
            vec![
                "dse", "merge", "--workload", "gesummv", "--resume",
                "--shards", "x.journal",
            ],
        ] {
            let e = run_cli(&s(&bad));
            assert!(
                matches!(e, Err(CliError::Usage(_))),
                "{bad:?} should be a usage error, got {e:?}"
            );
        }
        // A merge over journals that do not exist is a loud checkpoint
        // error naming the path — never a silent empty report.
        let e = run_cli(&s(&[
            "dse", "merge", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2", "--shards", "/nonexistent/a.journal",
        ]));
        let Err(CliError::Checkpoint(msg)) = e else {
            panic!("expected a checkpoint error, got {e:?}");
        };
        assert!(msg.contains("/nonexistent/a.journal"), "{msg}");
    }

    #[test]
    fn dse_sharded_runs_then_merge_reports_the_full_frontier() {
        let dir = std::env::temp_dir()
            .join(format!("tcpa-cli-shard-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut journals = Vec::new();
        for i in 1..=2 {
            let j = dir.join(format!("shard{i}.journal"));
            let j_s = j.to_str().unwrap().to_string();
            let sh = format!("{i}/2");
            assert_eq!(
                run_cli(&s(&[
                    "dse", "--workload", "gesummv", "--bounds", "8,8",
                    "--max-pes", "2", "--shard", &sh, "--checkpoint",
                    &j_s,
                ]))
                .unwrap(),
                0,
                "shard {sh} must sweep its slice"
            );
            journals.push(j_s);
        }
        assert_eq!(
            run_cli(&s(&[
                "dse", "merge", "--workload", "gesummv", "--bounds",
                "8,8", "--max-pes", "2", "--shards",
                &journals.join(","),
            ]))
            .unwrap(),
            0,
            "merging both finished slices must succeed"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dse_beam_strategy_sweeps_a_small_space() {
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload", "gesummv", "--bounds", "8,8",
                "--max-pes", "2", "--strategy", "beam:4",
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn dse_checkpoint_flag_validation() {
        // --resume needs the journal path; bare --checkpoint has none.
        for bad in [
            vec!["dse", "--workload", "gesummv", "--resume"],
            vec!["dse", "--workload", "gesummv", "--checkpoint"],
            vec!["dse", "--workload", "gesummv", "--deadline", "0"],
            vec!["dse", "--workload", "gesummv", "--deadline", "abc"],
            vec!["dse", "--workload", "gesummv", "--point-timeout", "-1"],
            vec![
                "dse", "--workload", "gesummv", "--point-timeout", "inf",
            ],
        ] {
            let e = run_cli(&s(&bad));
            assert!(
                matches!(e, Err(CliError::Usage(_))),
                "{bad:?} should be a usage error, got {e:?}"
            );
        }
    }

    #[test]
    fn dse_checkpoint_writes_then_resume_replays() {
        let dir = std::env::temp_dir().join(format!(
            "tcpa-cli-checkpoint-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let j = dir.join("sweep.journal");
        let j_s = j.to_str().unwrap().to_string();
        let args = [
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "2", "--checkpoint", &j_s,
        ];
        assert_eq!(run_cli(&s(&args)).unwrap(), 0);
        assert!(j.exists(), "journal must be flushed on completion");
        // Resuming a complete journal replays every point and still
        // succeeds (fresh in-memory cache; zero analyses needed).
        let mut again = args.to_vec();
        again.push("--resume");
        assert_eq!(run_cli(&s(&again)).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dse_injected_deadline_exits_with_the_partial_code() {
        let _env = crate::dse::verify::env_guard();
        std::env::set_var(crate::dse::FAULT_DEADLINE_AFTER_ENV, "1");
        let code = run_cli(&s(&[
            "dse", "--workload", "gesummv", "--bounds", "8,8",
            "--max-pes", "4", "--deadline", "3600",
        ]))
        .unwrap();
        std::env::remove_var(crate::dse::FAULT_DEADLINE_AFTER_ENV);
        assert_eq!(code, 3, "cancelled sweeps exit with the partial code");
    }

    #[test]
    fn preflight_gate_blocks_nothing_for_clean_workloads() {
        // The gate is on by default and all builtins pass it — the
        // analyze path above already proves that. --no-lint must also
        // run cleanly (bit-for-bit the old behavior).
        assert_eq!(
            run_cli(&s(&[
                "analyze", "--workload", "gesummv", "--array", "2x2",
                "--no-lint"
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload", "gesummv", "--bounds", "8,8",
                "--max-pes", "2", "--no-lint"
            ]))
            .unwrap(),
            0
        );
    }

    const GESUMMV_WL: &str = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/../examples/workloads/gesummv.wl"
    );

    #[test]
    fn workload_file_flag_validation() {
        // --workload and --workload-file are mutually exclusive; the
        // file flag needs a path; one of the two is required.
        for bad in [
            vec![
                "analyze", "--workload", "gesummv", "--workload-file",
                GESUMMV_WL,
            ],
            vec!["analyze", "--workload-file"],
            vec!["analyze"],
            vec!["simulate"],
            vec!["dse"],
            vec![
                "lint", "--all-builtins", "--workload-file", GESUMMV_WL,
            ],
        ] {
            let e = run_cli(&s(&bad));
            assert!(
                matches!(e, Err(CliError::Usage(_))),
                "{bad:?} should be a usage error, got {e:?}"
            );
        }
        // A missing file is an I/O error carrying the OS diagnostic.
        let e = run_cli(&s(&["analyze", "--workload-file", "/no/such.wl"]));
        assert!(matches!(e, Err(CliError::Io(_))), "{e:?}");
    }

    #[test]
    fn workload_file_runs_the_analysis_commands() {
        assert_eq!(
            run_cli(&s(&[
                "lint", "--workload-file", GESUMMV_WL, "--deny",
                "warnings",
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "analyze", "--workload-file", GESUMMV_WL, "--array",
                "2x2", "--bounds", "8,8",
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "simulate", "--workload-file", GESUMMV_WL, "--array",
                "2x2", "--bounds", "8,8",
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run_cli(&s(&[
                "dse", "--workload-file", GESUMMV_WL, "--bounds", "8,8",
                "--max-pes", "2",
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn workload_file_parse_errors_carry_path_line_and_column() {
        let path = std::env::temp_dir()
            .join(format!("tcpa-cli-parse-{}.wl", std::process::id()));
        std::fs::write(
            &path,
            "workload broken\nloop i0 in 0..N0\nloop i1 in 0..N1*N1\n",
        )
        .unwrap();
        let e = run_cli(&s(&[
            "analyze",
            "--workload-file",
            path.to_str().unwrap(),
        ]));
        let Err(CliError::Parse(msg)) = e else {
            panic!("expected a parse error, got {e:?}");
        };
        // `path:line:col: description` — stable, grep-able anchor.
        assert!(
            msg.contains(&format!("{}:3:", path.display())),
            "diagnostic should name file and line: {msg}"
        );
        assert!(msg.contains("non-affine"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn simulate_gates_on_lint_and_reports_unschedulable_without_panic() {
        // twist's dependence vectors admit no causal order: the lint
        // gate refuses it (L006 is deny-level), and under --no-lint the
        // scheduler's refusal surfaces as a CliError naming the phase
        // and π — the old code path panicked on unwrap.
        let wl = workloads::twist_unschedulable();
        let text = workloads::text::render_workload(&wl);
        let path = std::env::temp_dir()
            .join(format!("tcpa-cli-twist-{}.wl", std::process::id()));
        std::fs::write(&path, text).unwrap();
        let path_s = path.to_str().unwrap().to_string();
        let gated = run_cli(&s(&[
            "simulate", "--workload-file", &path_s, "--array", "2x2",
            "--bounds", "8,8",
        ]));
        assert!(
            matches!(gated, Err(CliError::Lint(_))),
            "simulate must run the deny gate, got {gated:?}"
        );
        let bypassed = run_cli(&s(&[
            "simulate", "--workload-file", &path_s, "--array", "2x2",
            "--bounds", "8,8", "--no-lint",
        ]));
        let Err(CliError::Schedule(msg)) = bypassed else {
            panic!("expected a schedule error, got {bypassed:?}");
        };
        assert!(msg.contains("twist"), "{msg}");
        assert!(msg.contains("pi="), "{msg}");
        let _ = std::fs::remove_file(&path);
    }
}
