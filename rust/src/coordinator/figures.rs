//! Generators for the paper's figures: data rows for Fig. 4 (analysis-time
//! scaling) and Fig. 5 (energy/latency scaling with breakdown), shared by
//! the CLI `figures` subcommand and the `cargo bench` targets.

use crate::analysis::SymbolicAnalysis;
use crate::bench_util::time_once;
use crate::energy::MemoryClass;
use crate::schedule::find_schedule;
use crate::sim::{simulate, ArchConfig};
use crate::tiling::{tile_pra, ArrayMapping};
use crate::workloads::{self, workload_inputs};

/// One Fig. 4 data point: analysis time, symbolic vs simulation.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub n: i64,
    /// One-time symbolic analysis (s). Constant in `n` — reported per row
    /// for transparency, the paper's "symbolic" series.
    pub symbolic_s: f64,
    /// Symbolic evaluation at this `n` (s) — the marginal per-size cost.
    pub symbolic_eval_s: f64,
    pub simulation_s: f64,
    /// Exactness check: symbolic counts equal the simulator's.
    pub exact: bool,
}

/// Fig. 4: GESUMMV on an 8×8 array across matrix sizes.
pub fn fig4_rows(sizes: &[i64]) -> Vec<Fig4Row> {
    let wl = workloads::by_name("gesummv").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![8, 8]);
    // One-time symbolic analysis (measured once, reused for every size —
    // that is the method's point).
    let (analysis_time, ana) =
        time_once(|| SymbolicAnalysis::analyze(phase, &mapping));
    let mut out = Vec::new();
    for &n in sizes {
        let params = mapping.params_for(&[n, n]);
        let (eval_t, sym) = time_once(|| ana.counts_at(&params));
        // Simulation at the same configuration.
        let mut arch = ArchConfig::with_array(vec![8, 8]);
        arch.regs.fd = 1 << 20;
        let tiled = tile_pra(phase, &mapping);
        let schedule = find_schedule(&tiled, 1).unwrap();
        let env = workload_inputs(&wl, &[params.clone()]);
        let (sim_t, res) =
            time_once(|| simulate(phase, &arch, &schedule, &params, &env));
        out.push(Fig4Row {
            n,
            symbolic_s: analysis_time.as_secs_f64(),
            symbolic_eval_s: eval_t.as_secs_f64(),
            simulation_s: sim_t.as_secs_f64(),
            exact: res.counters.diff_symbolic(&sym).is_empty(),
        });
    }
    out
}

/// One Fig. 5 data point: GEMM energy breakdown + latency at matrix size n.
#[derive(Debug, Clone)]
pub struct Fig5Row {
    pub n: i64,
    pub total_pj: f64,
    pub dram_pj: f64,
    pub iob_pj: f64,
    pub fd_pj: f64,
    pub rd_pj: f64,
    pub id_pj: f64,
    pub od_pj: f64,
    pub compute_pj: f64,
    pub latency_cycles: i64,
}

/// Fig. 5: GEMM on an 8×8 grid across matrix sizes (pure symbolic
/// evaluation; the iteration space grows as N³ but the cost per row is
/// constant).
pub fn fig5_rows(sizes: &[i64]) -> Vec<Fig5Row> {
    let wl = workloads::by_name("gemm").unwrap();
    let phase = &wl.phases[0];
    let mapping = ArrayMapping::new(vec![8, 8, 1]);
    let ana = SymbolicAnalysis::analyze(phase, &mapping);
    sizes
        .iter()
        .map(|&n| {
            let params = mapping.params_for(&[n, n, n]);
            let e = ana.energy_at(&params);
            let g = |c: MemoryClass| e.mem_pj.get(&c).copied().unwrap_or(0.0);
            Fig5Row {
                n,
                total_pj: e.total,
                dram_pj: g(MemoryClass::Dram),
                iob_pj: g(MemoryClass::IOb),
                fd_pj: g(MemoryClass::Fd),
                rd_pj: g(MemoryClass::Rd),
                id_pj: g(MemoryClass::Id),
                od_pj: g(MemoryClass::Od),
                compute_pj: e.compute_pj,
                latency_cycles: ana.latency_at(&params),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_holds_at_small_scale() {
        // Simulation time grows with N; symbolic eval stays ~flat and the
        // counts match exactly at every size.
        let rows = fig4_rows(&[16, 64]);
        assert!(rows.iter().all(|r| r.exact));
        assert!(rows[1].simulation_s > rows[0].simulation_s);
        // symbolic evaluation is orders of magnitude below simulation at
        // the larger size
        assert!(rows[1].symbolic_eval_s < rows[1].simulation_s);
    }

    #[test]
    fn fig5_dram_share_shrinks_with_n() {
        // The paper's qualitative claim: DRAM-dominated at small N, with
        // on-chip (FD/RD) + compute share growing as tiles grow.
        let rows = fig5_rows(&[16, 256]);
        let share = |r: &Fig5Row| r.dram_pj / r.total_pj;
        assert!(share(&rows[0]) > share(&rows[1]));
        let onchip =
            |r: &Fig5Row| (r.fd_pj + r.rd_pj + r.compute_pj) / r.total_pj;
        assert!(onchip(&rows[1]) > onchip(&rows[0]));
        // Latency grows roughly as N³/64.
        assert!(rows[1].latency_cycles > rows[0].latency_cycles * 1000);
    }
}
