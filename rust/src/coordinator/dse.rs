//! Deprecated shim over the [`crate::dse`] subsystem.
//!
//! The original serial double-loop sweep lived here; it re-ran the full
//! symbolic analysis per design point, could only sweep 2-D shapes, and
//! ranked by a single scalar (EDP) with a NaN-unsafe `partial_cmp`.
//! [`dse_sweep`] now delegates to the parallel, cache-backed explorer and
//! keeps the old signature/ordering so existing callers compile; new code
//! should use [`crate::dse::DesignSpace`] + [`crate::dse::explore`]
//! directly and get multi-objective frontiers instead of an EDP sort.

use crate::dse::{explore, DesignSpace, ExploreConfig};
use crate::pra::Workload;

/// One evaluated design point (legacy shape: 2-D arrays only).
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// 2-D array shape (t0, t1).
    pub array: (i64, i64),
    pub pes: i64,
    pub energy_pj: f64,
    pub dram_pj: f64,
    pub latency_cycles: i64,
    pub edp: f64,
    /// One-time symbolic analysis cost for this design point (near zero
    /// when the explorer's cache already held the shape).
    pub analysis_ms: f64,
}

/// Sweep 2-D array shapes up to `max_pes` PEs for a workload at fixed loop
/// bounds; returns points sorted by energy-delay product (NaN-safe total
/// order, best first).
#[deprecated(
    since = "0.1.0",
    note = "use `dse::DesignSpace` + `dse::explore` for multi-axis, \
            multi-objective exploration"
)]
pub fn dse_sweep(
    wl: &Workload,
    base_bounds: &[i64],
    max_pes: i64,
) -> Vec<DsePoint> {
    let space = DesignSpace::new()
        .with_arrays_2d(max_pes)
        .with_bounds(base_bounds.to_vec());
    let res = explore(wl, &space, &ExploreConfig::default());
    let mut out: Vec<DsePoint> = res
        .points
        .iter()
        .map(|p| DsePoint {
            array: (p.point.array[0], p.point.array.get(1).copied().unwrap_or(1)),
            pes: p.pes,
            energy_pj: p.energy_pj,
            dram_pj: p.dram_pj,
            latency_cycles: p.latency_cycles,
            edp: p.edp,
            analysis_ms: p.analysis_ms,
        })
        .collect();
    out.sort_by(|a, b| a.edp.total_cmp(&b.edp));
    out
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    #[test]
    fn sweep_finds_parallel_better_than_serial_latency() {
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let pts = dse_sweep(&wl, &[16, 16], 8);
        assert!(pts.len() > 3);
        let serial = pts.iter().find(|p| p.array == (1, 1)).unwrap();
        let best = &pts[0];
        assert!(
            best.latency_cycles < serial.latency_cycles,
            "parallel mapping should cut latency: {} vs {}",
            best.latency_cycles,
            serial.latency_cycles
        );
        // Sorted by EDP.
        for w in pts.windows(2) {
            assert!(w[0].edp <= w[1].edp);
        }
    }

    #[test]
    fn energy_nearly_mapping_invariant_for_gesummv() {
        // GESUMMV's DRAM traffic is mapping-independent; total energy
        // varies only through FD/ID shifts — well within 20%.
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let pts = dse_sweep(&wl, &[16, 16], 4);
        let e0 = pts[0].energy_pj;
        for p in &pts {
            assert!(
                (p.energy_pj - e0).abs() / e0 < 0.2,
                "{:?}: {} vs {e0}",
                p.array,
                p.energy_pj
            );
        }
    }

    #[test]
    fn shim_matches_subsystem_results() {
        // The legacy view and the subsystem must agree point for point.
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let pts = dse_sweep(&wl, &[8, 8], 4);
        let res = explore(
            &wl,
            &DesignSpace::new().with_arrays_2d(4).with_bounds(vec![8, 8]),
            &ExploreConfig::default(),
        );
        assert_eq!(pts.len(), res.points.len());
        for p in &pts {
            let twin = res
                .points
                .iter()
                .find(|q| {
                    q.point.array == vec![p.array.0, p.array.1]
                })
                .unwrap();
            assert_eq!(p.energy_pj.to_bits(), twin.energy_pj.to_bits());
            assert_eq!(p.latency_cycles, twin.latency_cycles);
        }
    }
}
