//! Design-space exploration (§V-B / §VI): because the analysis is
//! symbolic, sweeping architectural configurations — array shapes, tile
//! sizes — is a sequence of cheap expression evaluations, enabling the
//! "rapid comparison of architectural configurations" the paper motivates.

use crate::analysis::WorkloadAnalysis;
use crate::energy::MemoryClass;
use crate::pra::Workload;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    /// 2-D array shape (t0, t1).
    pub array: (i64, i64),
    pub pes: i64,
    pub energy_pj: f64,
    pub dram_pj: f64,
    pub latency_cycles: i64,
    pub edp: f64,
    /// One-time symbolic analysis cost for this design point.
    pub analysis_ms: f64,
}

/// Sweep 2-D array shapes up to `max_pes` PEs for a workload at fixed loop
/// bounds; returns points sorted by energy-delay product.
pub fn dse_sweep(
    wl: &Workload,
    base_bounds: &[i64],
    max_pes: i64,
) -> Vec<DsePoint> {
    let mut out = Vec::new();
    for t0 in 1..=max_pes {
        for t1 in 1..=max_pes {
            if t0 * t1 > max_pes {
                continue;
            }
            // Skip shapes larger than the problem.
            let b1 = base_bounds.get(1).copied().unwrap_or(base_bounds[0]);
            if t0 > base_bounds[0] || t1 > b1 {
                continue;
            }
            let t = vec![t0, t1];
            let start = std::time::Instant::now();
            let ana = WorkloadAnalysis::analyze_uniform(wl, &t);
            let analysis_ms = start.elapsed().as_secs_f64() * 1e3;
            let params: Vec<Vec<i64>> = ana
                .phases
                .iter()
                .map(|ph| {
                    let nd = ph.tiled.pra.ndims;
                    let mut b = base_bounds.to_vec();
                    while b.len() < nd {
                        b.push(*base_bounds.last().unwrap());
                    }
                    b.truncate(nd);
                    ph.tiled.mapping.params_for(&b)
                })
                .collect();
            let e = ana.energy_at(&params);
            let l = ana.latency_at(&params);
            out.push(DsePoint {
                array: (t0, t1),
                pes: t0 * t1,
                energy_pj: e.total,
                dram_pj: e.mem_pj.get(&MemoryClass::Dram).copied().unwrap_or(0.0),
                latency_cycles: l,
                edp: e.total * l as f64,
                analysis_ms,
            });
        }
    }
    out.sort_by(|a, b| a.edp.partial_cmp(&b.edp).unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_finds_parallel_better_than_serial_latency() {
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let pts = dse_sweep(&wl, &[16, 16], 8);
        assert!(pts.len() > 3);
        let serial = pts.iter().find(|p| p.array == (1, 1)).unwrap();
        let best = &pts[0];
        assert!(
            best.latency_cycles < serial.latency_cycles,
            "parallel mapping should cut latency: {} vs {}",
            best.latency_cycles,
            serial.latency_cycles
        );
        // Sorted by EDP.
        for w in pts.windows(2) {
            assert!(w[0].edp <= w[1].edp);
        }
    }

    #[test]
    fn energy_nearly_mapping_invariant_for_gesummv() {
        // GESUMMV's DRAM traffic is mapping-independent; total energy
        // varies only through FD/ID shifts — well within 20%.
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let pts = dse_sweep(&wl, &[16, 16], 4);
        let e0 = pts[0].energy_pj;
        for p in &pts {
            assert!(
                (p.energy_pj - e0).abs() / e0 < 0.2,
                "{:?}: {} vs {e0}",
                p.array,
                p.energy_pj
            );
        }
    }
}
