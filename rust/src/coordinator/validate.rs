//! Validation orchestration (§V-A): run the symbolic analysis and the
//! cycle-accurate simulator on the same configuration and compare counts,
//! energy, and functional outputs.

use crate::analysis::SymbolicAnalysis;
use crate::energy::MemoryClass;
use crate::pra::Workload;
use crate::schedule::find_schedule;
use crate::sim::{simulate, ArchConfig};
use crate::tiling::{tile_pra, ArrayMapping};
use crate::workloads::{interpret, workload_inputs};

/// One validation configuration's outcome.
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub workload: String,
    pub phase: String,
    pub bounds: Vec<i64>,
    pub array: Vec<i64>,
    /// (class label, symbolic, simulated) triples.
    pub counts: Vec<(String, i128, i128)>,
    pub energy_sym_pj: f64,
    pub energy_sim_pj: f64,
    pub sym_eval_us: f64,
    pub sim_us: f64,
    pub exact_match: bool,
    pub functional_ok: bool,
}

/// Validate one workload at given loop bounds on a given array shape
/// (the same shape for every phase).
pub fn validate_workload(
    wl: &Workload,
    base_bounds: &[i64],
    array: &[i64],
) -> Vec<ValidationRow> {
    let arrays: Vec<Vec<i64>> =
        wl.phases.iter().map(|_| array.to_vec()).collect();
    validate_workload_mapped(wl, base_bounds, &arrays)
}

/// Validate one workload with an explicit array shape *per phase* — the
/// sim differential behind the DSE per-phase heterogeneous mapping axis
/// (`dse::DesignSpace::with_phase_shapes`): each phase is tiled,
/// scheduled, symbolically counted **and** cycle-accurately simulated on
/// its own shape, with intermediate tensors streaming between phases
/// through the environment exactly as on a uniform array.
pub fn validate_workload_mapped(
    wl: &Workload,
    base_bounds: &[i64],
    arrays: &[Vec<i64>],
) -> Vec<ValidationRow> {
    assert_eq!(
        arrays.len(),
        wl.phases.len(),
        "one array shape per phase of {}",
        wl.name
    );
    // Shared structural gate: the same helper the workload builders run
    // at construction time, so hand-built phases reaching the validator
    // directly fail with the identical report.
    for phase in &wl.phases {
        crate::pra::assert_valid(phase);
    }
    let mut rows = Vec::new();
    let params_all: Vec<Vec<i64>> = wl
        .phases
        .iter()
        .zip(arrays)
        .map(|(ph, array)| {
            let b = crate::tiling::pad_bounds(base_bounds, ph.ndims);
            let t = crate::tiling::pad_array(array, ph.ndims);
            ArrayMapping::new(t).params_for(&b)
        })
        .collect();
    let mut env = workload_inputs(wl, &params_all);
    for ((phase, params), array) in
        wl.phases.iter().zip(&params_all).zip(arrays)
    {
        let t = crate::tiling::pad_array(array, phase.ndims);
        let mapping = ArrayMapping::new(t.clone());
        let ana = SymbolicAnalysis::analyze(phase, &mapping);
        let t0 = std::time::Instant::now();
        let sym = ana.counts_at(params);
        let sym_eval_us = t0.elapsed().as_secs_f64() * 1e6;

        let mut arch = ArchConfig::with_array(t);
        arch.regs.fd = 1 << 20;
        let tiled = tile_pra(phase, &mapping);
        let schedule = find_schedule(&tiled, 1).unwrap();
        let t1 = std::time::Instant::now();
        let res = simulate(phase, &arch, &schedule, params, &env);
        let sim_us = t1.elapsed().as_secs_f64() * 1e6;

        let mut counts = Vec::new();
        for &c in &MemoryClass::ALL {
            counts.push((
                c.label().to_string(),
                sym.mem.get(&c).copied().unwrap_or(0),
                res.counters.mem.get(&c).copied().unwrap_or(0),
            ));
        }
        counts.push(("add".into(), sym.adds, res.counters.adds));
        counts.push(("mul".into(), sym.muls, res.counters.muls));

        let golden = interpret(phase, params, &env);
        let functional_ok = res.violations.is_empty()
            && res
                .outputs
                .iter()
                .all(|(n, t)| t.allclose(&golden[n], 1e-4, 1e-4));
        let exact_match = counts.iter().all(|(_, a, b)| a == b);
        rows.push(ValidationRow {
            workload: wl.name.clone(),
            phase: phase.name.clone(),
            bounds: (0..phase.ndims)
                .map(|l| params[phase.space.n_index(l)])
                .collect(),
            array: mapping.t.clone(),
            counts,
            energy_sym_pj: ana.energy_at(params).total,
            energy_sim_pj: res.counters.energy_pj(&ana.table),
            sym_eval_us,
            sim_us,
            exact_match,
            functional_ok,
        });
        for (name, tensor) in res.outputs {
            env.insert(name, tensor);
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gesummv_row_is_exact() {
        let wl = crate::workloads::by_name("gesummv").unwrap();
        let rows = validate_workload(&wl, &[8, 8], &[2, 2]);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].exact_match, "{:?}", rows[0].counts);
        assert!(rows[0].functional_ok);
        assert!(
            (rows[0].energy_sym_pj - rows[0].energy_sim_pj).abs()
                < 1e-6 * rows[0].energy_sym_pj
        );
    }

    #[test]
    fn two_phase_workload_produces_two_rows() {
        let wl = crate::workloads::by_name("atax").unwrap();
        let rows = validate_workload(&wl, &[8, 8], &[2, 2]);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.exact_match && r.functional_ok));
    }

    #[test]
    fn heterogeneous_phase_shapes_validate_exactly() {
        // Each phase on its own orientation: symbolic counts must match
        // the cycle-accurate simulator per phase, and the chained
        // functional outputs must match the interpreter — the sim
        // differential for the per-phase mapping axis.
        let wl = crate::workloads::by_name("atax").unwrap();
        let rows = validate_workload_mapped(
            &wl,
            &[8, 8],
            &[vec![1, 4], vec![4, 1]],
        );
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].array, vec![1, 4]);
        assert_eq!(rows[1].array, vec![4, 1]);
        for r in &rows {
            assert!(r.exact_match, "{}: {:?}", r.phase, r.counts);
            assert!(r.functional_ok, "{}: outputs diverge", r.phase);
        }
    }
}
