//! Structural validation of PRAs: catches malformed workload definitions
//! before they reach tiling, analysis, or simulation.

use std::collections::BTreeSet;

use super::ir::{IndexMap, Lhs, Operand, Pra};
use super::rdg::Rdg;

/// Validation failure.
#[derive(Debug, PartialEq, Eq)]
pub enum PraError {
    Arity(String, String, usize, usize),
    DepLen(String, usize, usize),
    UnknownTensor(String, String),
    UndefinedVar(String, String),
    CondLen(String, usize, usize),
    ZeroDepCycle,
    NonLexPositiveDep(String, Vec<i64>),
    DuplicateName(String),
    /// Tensor access function has a different rank than the declared
    /// tensor shape: (statement, tensor, access rank, declared rank).
    AccessRank(String, String, usize, usize),
    /// A row of a tensor access function has the wrong number of
    /// iteration-space coefficients: (statement, tensor, row width,
    /// loop depth).
    AccessDims(String, String, usize, usize),
    /// Tensor access offset vector length differs from the access rank:
    /// (statement, tensor, offset length, access rank).
    AccessOffset(String, String, usize, usize),
}

impl std::fmt::Display for PraError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PraError::Arity(s, op, want, got) => write!(
                f,
                "statement {s}: op {op} expects {want} args, got {got}"
            ),
            PraError::DepLen(s, got, depth) => write!(
                f,
                "statement {s}: dependence vector has {got} entries, loop \
                 depth is {depth}"
            ),
            PraError::UnknownTensor(s, t) => {
                write!(f, "statement {s}: reads undeclared tensor {t}")
            }
            PraError::UndefinedVar(s, v) => write!(
                f,
                "statement {s}: reads variable {v} that no statement defines"
            ),
            PraError::CondLen(s, got, depth) => write!(
                f,
                "statement {s}: condition coefficient vector has {got} \
                 entries, loop depth is {depth}"
            ),
            PraError::ZeroDepCycle => write!(
                f,
                "intra-iteration dependence cycle (zero-dependence subgraph \
                 is cyclic)"
            ),
            PraError::NonLexPositiveDep(s, d) => write!(
                f,
                "statement {s}: dependence vector {d:?} is not \
                 lexicographically non-negative; the lexicographic \
                 interpreter cannot execute this PRA"
            ),
            PraError::DuplicateName(s) => {
                write!(f, "duplicate statement name {s}")
            }
            PraError::AccessRank(s, t, got, want) => write!(
                f,
                "statement {s}: access to tensor {t} has rank {got}, \
                 declared shape has rank {want}"
            ),
            PraError::AccessDims(s, t, got, depth) => write!(
                f,
                "statement {s}: access row for tensor {t} has {got} \
                 coefficients, loop depth is {depth}"
            ),
            PraError::AccessOffset(s, t, got, rank) => write!(
                f,
                "statement {s}: access offset for tensor {t} has {got} \
                 entries, access rank is {rank}"
            ),
        }
    }
}

impl std::error::Error for PraError {}

/// Check a tensor access function against the declared tensor shape and
/// the loop depth (the satellite of lint code `L003`: a malformed
/// `IndexMap` used to flow silently into classification and counting).
fn check_access(
    errs: &mut Vec<PraError>,
    pra: &Pra,
    stmt: &str,
    tensor: &str,
    map: &IndexMap,
) {
    match pra.tensor(tensor) {
        None => errs.push(PraError::UnknownTensor(
            stmt.to_string(),
            tensor.to_string(),
        )),
        Some(decl) => {
            if map.rank() != decl.shape.len() {
                errs.push(PraError::AccessRank(
                    stmt.to_string(),
                    tensor.to_string(),
                    map.rank(),
                    decl.shape.len(),
                ));
            }
        }
    }
    for row in &map.rows {
        if row.len() != pra.ndims {
            errs.push(PraError::AccessDims(
                stmt.to_string(),
                tensor.to_string(),
                row.len(),
                pra.ndims,
            ));
        }
    }
    if map.offset.len() != map.rows.len() {
        errs.push(PraError::AccessOffset(
            stmt.to_string(),
            tensor.to_string(),
            map.offset.len(),
            map.rows.len(),
        ));
    }
}

/// Validate a PRA. Returns all detected problems (empty = valid).
pub fn validate(pra: &Pra) -> Vec<PraError> {
    let mut errs = Vec::new();
    let mut names = BTreeSet::new();
    let defined: BTreeSet<&str> = pra
        .statements
        .iter()
        .filter_map(|s| match &s.lhs {
            Lhs::Var(n) => Some(n.as_str()),
            Lhs::Tensor { .. } => None,
        })
        .collect();
    for s in &pra.statements {
        if !names.insert(s.name.clone()) {
            errs.push(PraError::DuplicateName(s.name.clone()));
        }
        if s.args.len() != s.op.arity() {
            errs.push(PraError::Arity(
                s.name.clone(),
                s.op.to_string(),
                s.op.arity(),
                s.args.len(),
            ));
        }
        for a in &s.args {
            match a {
                Operand::Var { name, dep } => {
                    if dep.len() != pra.ndims {
                        errs.push(PraError::DepLen(
                            s.name.clone(),
                            dep.len(),
                            pra.ndims,
                        ));
                    }
                    if !defined.contains(name.as_str()) {
                        errs.push(PraError::UndefinedVar(
                            s.name.clone(),
                            name.clone(),
                        ));
                    }
                    // Lexicographic positivity: first nonzero must be > 0.
                    if let Some(&first) = dep.iter().find(|&&d| d != 0) {
                        if first < 0 {
                            errs.push(PraError::NonLexPositiveDep(
                                s.name.clone(),
                                dep.clone(),
                            ));
                        }
                    }
                }
                Operand::Tensor { name, map } => {
                    check_access(&mut errs, pra, &s.name, name, map);
                }
            }
        }
        if let Lhs::Tensor { name, map } = &s.lhs {
            check_access(&mut errs, pra, &s.name, name, map);
        }
        for c in &s.cond {
            if c.a.len() != pra.ndims {
                errs.push(PraError::CondLen(
                    s.name.clone(),
                    c.a.len(),
                    pra.ndims,
                ));
            }
        }
    }
    let rdg = Rdg::build(pra);
    if rdg.intra_iteration_order(pra.statements.len()).is_none() {
        errs.push(PraError::ZeroDepCycle);
    }
    errs
}

/// Panic with a readable report unless the PRA is structurally valid.
///
/// This is the one shared gate all trusted construction paths funnel
/// through: [`crate::workloads::PraBuilder::build`] calls it on every
/// builtin workload, and `coordinator::validate_workload` calls it on
/// its input. Untrusted input should instead go through the non-fatal
/// [`crate::lint`] engine, whose structural pass reports the same
/// findings with stable lint codes.
pub fn assert_valid(pra: &Pra) {
    let errs = validate(pra);
    assert!(
        errs.is_empty(),
        "PRA {:?} failed structural validation:\n  {}",
        pra.name,
        errs.iter()
            .map(|e| e.to_string())
            .collect::<Vec<_>>()
            .join("\n  ")
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::ParamSpace;
    use crate::pra::ir::*;

    #[test]
    fn all_builtin_workloads_validate() {
        // The builders already assert this on construction; running the
        // shared helper here keeps the failure message pinned.
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                assert_valid(phase);
            }
        }
    }

    #[test]
    fn bad_arity_detected() {
        let nd = 1;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Add,
                args: vec![Operand::var0("a", nd)],
                cond: vec![],
            }],
            tensors: vec![],
            requires: vec![],
        };
        let errs = validate(&pra);
        assert!(errs.iter().any(|e| matches!(e, PraError::Arity(..))));
    }

    #[test]
    fn undefined_var_and_tensor_detected() {
        let nd = 1;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Add,
                args: vec![
                    Operand::var0("ghost", nd),
                    Operand::tensor("T", IndexMap::identity(1, nd)),
                ],
                cond: vec![],
            }],
            tensors: vec![],
            requires: vec![],
        };
        let errs = validate(&pra);
        assert!(errs.iter().any(|e| matches!(e, PraError::UndefinedVar(..))));
        assert!(errs.iter().any(|e| matches!(e, PraError::UnknownTensor(..))));
    }

    #[test]
    fn non_lex_positive_dep_detected() {
        let nd = 2;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Copy,
                args: vec![Operand::var("a", vec![-1, 0])],
                cond: vec![],
            }],
            tensors: vec![],
            requires: vec![],
        };
        let errs = validate(&pra);
        assert!(errs
            .iter()
            .any(|e| matches!(e, PraError::NonLexPositiveDep(..))));
    }

    #[test]
    fn malformed_access_functions_detected() {
        let nd = 2;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![
                Statement {
                    name: "S1".into(),
                    // Rank-1 access to a rank-2 tensor.
                    lhs: Lhs::Var("a".into()),
                    op: Op::Copy,
                    args: vec![Operand::tensor(
                        "T",
                        IndexMap::select(&[0], nd),
                    )],
                    cond: vec![],
                },
                Statement {
                    name: "S2".into(),
                    // Access row with 1 coefficient in a 2-deep nest, and
                    // an offset vector longer than the access rank.
                    lhs: Lhs::Var("b".into()),
                    op: Op::Copy,
                    args: vec![Operand::Tensor {
                        name: "T".into(),
                        map: IndexMap {
                            rows: vec![vec![1], vec![0, 1]],
                            offset: vec![0, 0, 0],
                        },
                    }],
                    cond: vec![],
                },
            ],
            tensors: vec![TensorDecl {
                name: "T".into(),
                shape: vec![TensorDim::Param(0), TensorDim::Param(1)],
            }],
            requires: vec![],
        };
        let errs = validate(&pra);
        assert!(
            errs.iter().any(|e| matches!(
                e,
                PraError::AccessRank(s, t, 1, 2) if s == "S1" && t == "T"
            )),
            "{errs:?}"
        );
        assert!(
            errs.iter().any(|e| matches!(
                e,
                PraError::AccessDims(s, _, 1, 2) if s == "S2"
            )),
            "{errs:?}"
        );
        assert!(
            errs.iter().any(|e| matches!(
                e,
                PraError::AccessOffset(s, _, 3, 2) if s == "S2"
            )),
            "{errs:?}"
        );
    }

    #[test]
    #[should_panic(expected = "failed structural validation")]
    fn assert_valid_panics_on_malformed() {
        let nd = 1;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Add,
                args: vec![Operand::var0("a", nd)],
                cond: vec![],
            }],
            tensors: vec![],
            requires: vec![],
        };
        assert_valid(&pra);
    }
}
