//! Structural validation of PRAs: catches malformed workload definitions
//! before they reach tiling, analysis, or simulation.

use std::collections::BTreeSet;

use super::ir::{Lhs, Operand, Pra};
use super::rdg::Rdg;

/// Validation failure.
#[derive(Debug, PartialEq, Eq)]
pub enum PraError {
    Arity(String, String, usize, usize),
    DepLen(String, usize, usize),
    UnknownTensor(String, String),
    UndefinedVar(String, String),
    CondLen(String, usize, usize),
    ZeroDepCycle,
    NonLexPositiveDep(String, Vec<i64>),
    DuplicateName(String),
}

impl std::fmt::Display for PraError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PraError::Arity(s, op, want, got) => write!(
                f,
                "statement {s}: op {op} expects {want} args, got {got}"
            ),
            PraError::DepLen(s, got, depth) => write!(
                f,
                "statement {s}: dependence vector has {got} entries, loop \
                 depth is {depth}"
            ),
            PraError::UnknownTensor(s, t) => {
                write!(f, "statement {s}: reads undeclared tensor {t}")
            }
            PraError::UndefinedVar(s, v) => write!(
                f,
                "statement {s}: reads variable {v} that no statement defines"
            ),
            PraError::CondLen(s, got, depth) => write!(
                f,
                "statement {s}: condition coefficient vector has {got} \
                 entries, loop depth is {depth}"
            ),
            PraError::ZeroDepCycle => write!(
                f,
                "intra-iteration dependence cycle (zero-dependence subgraph \
                 is cyclic)"
            ),
            PraError::NonLexPositiveDep(s, d) => write!(
                f,
                "statement {s}: dependence vector {d:?} is not \
                 lexicographically non-negative; the lexicographic \
                 interpreter cannot execute this PRA"
            ),
            PraError::DuplicateName(s) => {
                write!(f, "duplicate statement name {s}")
            }
        }
    }
}

impl std::error::Error for PraError {}

/// Validate a PRA. Returns all detected problems (empty = valid).
pub fn validate(pra: &Pra) -> Vec<PraError> {
    let mut errs = Vec::new();
    let mut names = BTreeSet::new();
    let defined: BTreeSet<&str> = pra
        .statements
        .iter()
        .filter_map(|s| match &s.lhs {
            Lhs::Var(n) => Some(n.as_str()),
            Lhs::Tensor { .. } => None,
        })
        .collect();
    for s in &pra.statements {
        if !names.insert(s.name.clone()) {
            errs.push(PraError::DuplicateName(s.name.clone()));
        }
        if s.args.len() != s.op.arity() {
            errs.push(PraError::Arity(
                s.name.clone(),
                s.op.to_string(),
                s.op.arity(),
                s.args.len(),
            ));
        }
        for a in &s.args {
            match a {
                Operand::Var { name, dep } => {
                    if dep.len() != pra.ndims {
                        errs.push(PraError::DepLen(
                            s.name.clone(),
                            dep.len(),
                            pra.ndims,
                        ));
                    }
                    if !defined.contains(name.as_str()) {
                        errs.push(PraError::UndefinedVar(
                            s.name.clone(),
                            name.clone(),
                        ));
                    }
                    // Lexicographic positivity: first nonzero must be > 0.
                    if let Some(&first) = dep.iter().find(|&&d| d != 0) {
                        if first < 0 {
                            errs.push(PraError::NonLexPositiveDep(
                                s.name.clone(),
                                dep.clone(),
                            ));
                        }
                    }
                }
                Operand::Tensor { name, .. } => {
                    if pra.tensor(name).is_none() {
                        errs.push(PraError::UnknownTensor(
                            s.name.clone(),
                            name.clone(),
                        ));
                    }
                }
            }
        }
        if let Lhs::Tensor { name, .. } = &s.lhs {
            if pra.tensor(name).is_none() {
                errs.push(PraError::UnknownTensor(s.name.clone(), name.clone()));
            }
        }
        for c in &s.cond {
            if c.a.len() != pra.ndims {
                errs.push(PraError::CondLen(
                    s.name.clone(),
                    c.a.len(),
                    pra.ndims,
                ));
            }
        }
    }
    let rdg = Rdg::build(pra);
    if rdg.intra_iteration_order(pra.statements.len()).is_none() {
        errs.push(PraError::ZeroDepCycle);
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::ParamSpace;
    use crate::pra::ir::*;

    #[test]
    fn all_builtin_workloads_validate() {
        for wl in crate::workloads::all() {
            for phase in &wl.phases {
                let errs = validate(phase);
                assert!(
                    errs.is_empty(),
                    "{} phase {}: {errs:?}",
                    wl.name,
                    phase.name
                );
            }
        }
    }

    #[test]
    fn bad_arity_detected() {
        let nd = 1;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Add,
                args: vec![Operand::var0("a", nd)],
                cond: vec![],
            }],
            tensors: vec![],
        };
        let errs = validate(&pra);
        assert!(errs.iter().any(|e| matches!(e, PraError::Arity(..))));
    }

    #[test]
    fn undefined_var_and_tensor_detected() {
        let nd = 1;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Add,
                args: vec![
                    Operand::var0("ghost", nd),
                    Operand::tensor("T", IndexMap::identity(1, nd)),
                ],
                cond: vec![],
            }],
            tensors: vec![],
        };
        let errs = validate(&pra);
        assert!(errs.iter().any(|e| matches!(e, PraError::UndefinedVar(..))));
        assert!(errs.iter().any(|e| matches!(e, PraError::UnknownTensor(..))));
    }

    #[test]
    fn non_lex_positive_dep_detected() {
        let nd = 2;
        let pra = Pra {
            name: "bad".into(),
            ndims: nd,
            space: ParamSpace::loop_nest(nd),
            statements: vec![Statement {
                name: "S1".into(),
                lhs: Lhs::Var("a".into()),
                op: Op::Copy,
                args: vec![Operand::var("a", vec![-1, 0])],
                cond: vec![],
            }],
            tensors: vec![],
        };
        let errs = validate(&pra);
        assert!(errs
            .iter()
            .any(|e| matches!(e, PraError::NonLexPositiveDep(..))));
    }
}
