//! PRA/PLA loop-nest intermediate representation (§III-B of the paper),
//! variable classification, the reduced dependence graph, and structural
//! validation.

pub mod classify;
pub mod ir;
pub mod rdg;
pub mod validate;

pub use classify::{classify, VarClass};
pub use ir::{
    CondConstraint, IndexMap, Lhs, Op, Operand, Pra, Statement, TensorDecl,
    TensorDim, Workload,
};
pub use rdg::{Rdg, RdgEdge};
pub use validate::{assert_valid, validate, PraError};
