//! Variable classification (§III-B): *input* variables appear only on the
//! right-hand side of statements, *output* variables only on the left-hand
//! side, everything else is *internal*. External tensors referenced through
//! [`Operand::Tensor`]/[`Lhs::Tensor`] are inputs/outputs by construction.

use std::collections::BTreeMap;

use super::ir::{Lhs, Operand, Pra};

/// Classification of a named variable or tensor within a PRA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VarClass {
    /// Read but never defined inside the iteration space: lives in DRAM and
    /// streams in through an I/O buffer (first case of the `L(x)` table).
    Input,
    /// Defined but never read inside the iteration space: streams out to
    /// DRAM through an I/O buffer.
    Output,
    /// Defined and read inside the iteration space: lives in the PE
    /// register hierarchy.
    Internal,
}

/// Classify every variable and tensor of a PRA.
pub fn classify(pra: &Pra) -> BTreeMap<String, VarClass> {
    let mut defined: BTreeMap<&str, bool> = BTreeMap::new();
    let mut used: BTreeMap<&str, bool> = BTreeMap::new();
    for s in &pra.statements {
        match &s.lhs {
            Lhs::Var(n) => {
                defined.insert(n, true);
            }
            Lhs::Tensor { name, .. } => {
                defined.insert(name, true);
            }
        }
        for a in &s.args {
            match a {
                Operand::Var { name, .. } => {
                    used.insert(name, true);
                }
                Operand::Tensor { name, .. } => {
                    used.insert(name, true);
                }
            }
        }
    }
    let mut out = BTreeMap::new();
    for (&name, _) in defined.iter() {
        let cls = if used.contains_key(name) {
            VarClass::Internal
        } else {
            VarClass::Output
        };
        out.insert(name.to_string(), cls);
    }
    for (&name, _) in used.iter() {
        if !defined.contains_key(name) {
            out.insert(name.to_string(), VarClass::Input);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::gesummv::gesummv;

    #[test]
    fn gesummv_classification_matches_paper() {
        // Paper Example 1/5: A, B, X inputs; Y output; x, a, b, sA, sA*,
        // sB, sB* internal.
        let pra = gesummv();
        let cls = classify(&pra);
        for input in ["A", "B", "X"] {
            assert_eq!(cls[input], VarClass::Input, "{input}");
        }
        assert_eq!(cls["Y"], VarClass::Output);
        for internal in ["x", "a", "b", "sA", "sA*", "sB", "sB*"] {
            assert_eq!(cls[internal], VarClass::Internal, "{internal}");
        }
    }
}
