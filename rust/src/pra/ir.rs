//! Piecewise Regular Algorithm (PRA) intermediate representation.
//!
//! A PRA (§III-B, Eq. 2) describes an `n`-dimensional loop nest as a set of
//! quantified single-assignment statements
//!
//! ```text
//! S_q : x_q[i] = F_q(…, x_{q,r}[i − d_{q,r}], …)   if i ∈ I_q
//! ```
//!
//! over a rectangular iteration space `I = {i | 0 ≤ i_ℓ < N_ℓ}` with
//! parametric bounds. Input/output tensors live outside the iteration
//! space and are accessed through affine index maps (the `P_q i + f_q`
//! projections of the general PLA form, Eq. 1).

use std::fmt;

use crate::polyhedral::{AffineExpr, Constraint, ParamSpace};

/// Operation computed by a statement (the `F_q`).
///
/// `Copy` marks pure data-transport statements — the memory-statement set
/// `M` of §IV-A; everything else belongs to the computational set `C`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Identity transport (1 argument).
    Copy,
    /// Addition (2 arguments).
    Add,
    /// Subtraction (2 arguments).
    Sub,
    /// Multiplication (2 arguments).
    Mul,
    /// `a + b + c` three-way addition (stencil convenience; counts as two
    /// adder activations in the energy model).
    Add3,
    /// Maximum (2 arguments).
    Max,
}

impl Op {
    /// Number of arguments the operation consumes.
    pub fn arity(&self) -> usize {
        match self {
            Op::Copy => 1,
            Op::Add3 => 3,
            _ => 2,
        }
    }

    /// True for pure transport statements (set `M`).
    pub fn is_copy(&self) -> bool {
        matches!(self, Op::Copy)
    }

    /// Apply functionally (used by the cycle-accurate simulator and the
    /// golden-model comparison).
    pub fn apply(&self, args: &[f32]) -> f32 {
        match self {
            Op::Copy => args[0],
            Op::Add => args[0] + args[1],
            Op::Sub => args[0] - args[1],
            Op::Mul => args[0] * args[1],
            Op::Add3 => args[0] + args[1] + args[2],
            Op::Max => args[0].max(args[1]),
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Op::Copy => "copy",
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::Add3 => "add3",
            Op::Max => "max",
        };
        write!(f, "{s}")
    }
}

/// Affine index map for an external tensor access: `index = M·i + f`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexMap {
    /// One row per tensor dimension; each row has `ndims` coefficients.
    pub rows: Vec<Vec<i64>>,
    /// Constant offset per tensor dimension.
    pub offset: Vec<i64>,
}

impl IndexMap {
    /// Identity map on the first `rank` iteration dimensions.
    pub fn identity(rank: usize, ndims: usize) -> Self {
        let mut rows = Vec::with_capacity(rank);
        for r in 0..rank {
            let mut row = vec![0; ndims];
            row[r] = 1;
            rows.push(row);
        }
        IndexMap { rows, offset: vec![0; rank] }
    }

    /// Map selecting single iteration dims: `dims[r]` is the iteration
    /// dimension used for tensor dimension `r`.
    pub fn select(dims: &[usize], ndims: usize) -> Self {
        let mut rows = Vec::with_capacity(dims.len());
        for &d in dims {
            let mut row = vec![0; ndims];
            row[d] = 1;
            rows.push(row);
        }
        IndexMap { rows, offset: vec![0; dims.len()] }
    }

    /// Add a constant offset (builder).
    pub fn with_offset(mut self, offset: Vec<i64>) -> Self {
        assert_eq!(offset.len(), self.rows.len());
        self.offset = offset;
        self
    }

    /// Tensor rank.
    pub fn rank(&self) -> usize {
        self.rows.len()
    }

    /// Evaluate at a concrete iteration vector.
    pub fn apply(&self, i: &[i64]) -> Vec<i64> {
        self.rows
            .iter()
            .zip(&self.offset)
            .map(|(row, off)| {
                row.iter().zip(i).map(|(a, x)| a * x).sum::<i64>() + off
            })
            .collect()
    }
}

/// A right-hand-side operand of a statement.
#[derive(Debug, Clone, PartialEq)]
pub enum Operand {
    /// Internal PRA variable `x[i − dep]`; `dep` is the dependence vector
    /// `d_{q,r}` (all zeros for an intra-iteration read).
    Var { name: String, dep: Vec<i64> },
    /// External input tensor read `T[map(i)]`.
    Tensor { name: String, map: IndexMap },
}

impl Operand {
    /// Intra-iteration read of an internal variable.
    pub fn var0(name: &str, ndims: usize) -> Self {
        Operand::Var { name: name.into(), dep: vec![0; ndims] }
    }

    /// Read with a dependence vector.
    pub fn var(name: &str, dep: Vec<i64>) -> Self {
        Operand::Var { name: name.into(), dep }
    }

    /// Input tensor read.
    pub fn tensor(name: &str, map: IndexMap) -> Self {
        Operand::Tensor { name: name.into(), map }
    }
}

/// Left-hand side of a statement.
#[derive(Debug, Clone, PartialEq)]
pub enum Lhs {
    /// Internal variable `x[i]` (PRA form: identity index, zero offset).
    Var(String),
    /// Output tensor write `T[map(i)]`.
    Tensor { name: String, map: IndexMap },
}

impl Lhs {
    /// Name of the written variable/tensor.
    pub fn name(&self) -> &str {
        match self {
            Lhs::Var(n) => n,
            Lhs::Tensor { name, .. } => name,
        }
    }
}

/// One affine condition `Σ a_ℓ·i_ℓ + konst ≥ 0` of a condition space `I_q`
/// (the `konst` may be parametric, e.g. `N_1 − 1` for `i_1 = N_1 − 1`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CondConstraint {
    pub a: Vec<i64>,
    pub konst: AffineExpr,
}

impl CondConstraint {
    /// `i_dim ≥ c`.
    pub fn ge_const(dim: usize, c: i64, ndims: usize, nparams: usize) -> Self {
        let mut a = vec![0; ndims];
        a[dim] = 1;
        CondConstraint { a, konst: AffineExpr::constant(nparams, -c) }
    }

    /// `i_dim ≤ c`.
    pub fn le_const(dim: usize, c: i64, ndims: usize, nparams: usize) -> Self {
        let mut a = vec![0; ndims];
        a[dim] = -1;
        CondConstraint { a, konst: AffineExpr::constant(nparams, c) }
    }

    /// `i_dim ≥ N_{ndim} − 1 + c` (offsets from the top of a loop bound);
    /// `n_param` is the parameter index of `N`.
    pub fn ge_n_plus(
        dim: usize,
        n_param: usize,
        c: i64,
        ndims: usize,
        nparams: usize,
    ) -> Self {
        let mut a = vec![0; ndims];
        a[dim] = 1;
        CondConstraint {
            a,
            konst: (-&AffineExpr::param(nparams, n_param)).plus(1 - c),
        }
    }

    /// `i_dim ≤ N_{ndim} − 2` (i.e. strictly below the last index).
    pub fn le_n_minus_2(
        dim: usize,
        n_param: usize,
        ndims: usize,
        nparams: usize,
    ) -> Self {
        let mut a = vec![0; ndims];
        a[dim] = -1;
        CondConstraint {
            a,
            konst: AffineExpr::param(nparams, n_param).plus(-2),
        }
    }

    /// Evaluate at concrete iteration point + parameters.
    pub fn holds(&self, i: &[i64], params: &[i64]) -> bool {
        let lin: i64 = self.a.iter().zip(i).map(|(a, x)| a * x).sum();
        lin + self.konst.eval(params) >= 0
    }
}

/// A quantified statement (Eq. 2 plus tensor I/O projections).
#[derive(Debug, Clone, PartialEq)]
pub struct Statement {
    /// Human-readable label, e.g. `"S7"`.
    pub name: String,
    pub lhs: Lhs,
    pub op: Op,
    pub args: Vec<Operand>,
    /// Conjunction of conditions forming `I_q` (empty = whole space).
    pub cond: Vec<CondConstraint>,
}

impl Statement {
    /// True for transport statements (set `M` of §IV-A).
    pub fn is_memory(&self) -> bool {
        self.op.is_copy()
    }

    /// Condition-space membership at a concrete iteration point.
    pub fn active_at(&self, i: &[i64], params: &[i64]) -> bool {
        self.cond.iter().all(|c| c.holds(i, params))
    }
}

/// Declaration of an external tensor with its shape in terms of loop-bound
/// parameters (each dimension is one `N` parameter index, or a fixed size).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorDecl {
    pub name: String,
    /// Per-dimension extent: parameter index into the PRA's [`ParamSpace`].
    pub shape: Vec<TensorDim>,
}

/// One tensor dimension extent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorDim {
    /// Extent is loop-bound parameter with this index.
    Param(usize),
    /// Fixed extent.
    Fixed(i64),
}

impl TensorDim {
    /// Concrete extent under the given parameter values.
    pub fn extent(&self, params: &[i64]) -> i64 {
        match self {
            TensorDim::Param(i) => params[*i],
            TensorDim::Fixed(v) => *v,
        }
    }
}

impl TensorDecl {
    /// Concrete shape under parameter values.
    pub fn concrete_shape(&self, params: &[i64]) -> Vec<i64> {
        self.shape.iter().map(|d| d.extent(params)).collect()
    }

    /// Number of elements under parameter values.
    pub fn num_elems(&self, params: &[i64]) -> i64 {
        self.concrete_shape(params).iter().product()
    }
}

/// A full PRA: iteration space `0 ≤ i_ℓ < N_ℓ`, statements, tensors.
#[derive(Debug, Clone)]
pub struct Pra {
    pub name: String,
    /// Loop depth `n`.
    pub ndims: usize,
    /// Parameter space (`N0.., p0..` by convention).
    pub space: ParamSpace,
    pub statements: Vec<Statement>,
    /// External tensors (inputs and outputs).
    pub tensors: Vec<TensorDecl>,
    /// Parameter preconditions the kernel assumes, as constraints over
    /// [`Pra::space`] (e.g. squareness `N0 = N1` for transposed-access
    /// kernels like MVT/SYRK). Static verification ([`crate::lint`])
    /// proves its polyhedral obligations *under* these constraints; they
    /// are also checked at concrete parameters via
    /// [`Pra::requires_hold`]. Empty = valid for all parameter values.
    pub requires: Vec<Constraint>,
}

impl Pra {
    /// Look up a tensor declaration.
    pub fn tensor(&self, name: &str) -> Option<&TensorDecl> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Look up a statement by name.
    pub fn statement(&self, name: &str) -> Option<&Statement> {
        self.statements.iter().find(|s| s.name == name)
    }

    /// True when every declared parameter precondition holds at the
    /// given concrete parameter values.
    pub fn requires_hold(&self, params: &[i64]) -> bool {
        self.requires.iter().all(|c| c.holds(params))
    }

    /// Concrete iteration-space volume `Π N_ℓ`.
    pub fn iter_volume(&self, params: &[i64]) -> i128 {
        (0..self.ndims)
            .map(|l| params[self.space.n_index(l)] as i128)
            .product()
    }

    /// Iterate all points of the concrete iteration space in lexicographic
    /// order (used by test oracles; the simulator walks schedule order).
    pub fn iter_points(&self, params: &[i64]) -> Vec<Vec<i64>> {
        let bounds: Vec<i64> =
            (0..self.ndims).map(|l| params[self.space.n_index(l)]).collect();
        let mut out = vec![vec![]];
        for &b in &bounds {
            let mut next = Vec::with_capacity(out.len() * b as usize);
            for base in &out {
                for v in 0..b {
                    let mut x = base.clone();
                    x.push(v);
                    next.push(x);
                }
            }
            out = next;
        }
        out
    }
}

/// A multi-phase workload: a sequence of PRAs executed back to back (e.g.
/// ATAX = `tmp = A·x` then `y = Aᵀ·tmp`). Energy/latency are additive over
/// phases; tensors named identically flow from one phase to the next.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub phases: Vec<Pra>,
}

impl Workload {
    /// Single-phase wrapper.
    pub fn single(pra: Pra) -> Self {
        Workload { name: pra.name.clone(), phases: vec![pra] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_semantics() {
        assert_eq!(Op::Copy.apply(&[3.5]), 3.5);
        assert_eq!(Op::Add.apply(&[1.0, 2.0]), 3.0);
        assert_eq!(Op::Sub.apply(&[1.0, 2.0]), -1.0);
        assert_eq!(Op::Mul.apply(&[3.0, 2.0]), 6.0);
        assert_eq!(Op::Add3.apply(&[1.0, 2.0, 4.0]), 7.0);
        assert_eq!(Op::Max.apply(&[1.0, 2.0]), 2.0);
        assert_eq!(Op::Copy.arity(), 1);
        assert_eq!(Op::Add3.arity(), 3);
        assert_eq!(Op::Mul.arity(), 2);
        assert!(Op::Copy.is_copy());
        assert!(!Op::Add.is_copy());
    }

    #[test]
    fn index_map_apply() {
        // X[i1] from a 2-deep nest.
        let m = IndexMap::select(&[1], 2);
        assert_eq!(m.apply(&[3, 7]), vec![7]);
        // A[i0, i2] from a 3-deep nest.
        let m2 = IndexMap::select(&[0, 2], 3);
        assert_eq!(m2.apply(&[1, 2, 3]), vec![1, 3]);
        // stencil offset A[i1 - 1]
        let m3 = IndexMap::select(&[1], 2).with_offset(vec![-1]);
        assert_eq!(m3.apply(&[0, 5]), vec![4]);
        let id = IndexMap::identity(2, 2);
        assert_eq!(id.apply(&[4, 9]), vec![4, 9]);
        assert_eq!(id.rank(), 2);
    }

    #[test]
    fn cond_constraints() {
        let nd = 2;
        let np = 4; // N0 N1 p0 p1
        // i0 = 0 → (i0 >= 0) ∧ (i0 <= 0)
        let ge = CondConstraint::ge_const(0, 0, nd, np);
        let le = CondConstraint::le_const(0, 0, nd, np);
        assert!(ge.holds(&[0, 3], &[4, 5, 2, 3]));
        assert!(le.holds(&[0, 3], &[4, 5, 2, 3]));
        assert!(!le.holds(&[1, 3], &[4, 5, 2, 3]));
        // i1 = N1 - 1
        let top = CondConstraint::ge_n_plus(1, 1, 0, nd, np);
        assert!(top.holds(&[0, 4], &[4, 5, 2, 3]));
        assert!(!top.holds(&[0, 3], &[4, 5, 2, 3]));
        // i1 <= N1 - 2
        let below = CondConstraint::le_n_minus_2(1, 1, nd, np);
        assert!(below.holds(&[0, 3], &[4, 5, 2, 3]));
        assert!(!below.holds(&[0, 4], &[4, 5, 2, 3]));
    }

    #[test]
    fn tensor_decl_shapes() {
        let t = TensorDecl {
            name: "A".into(),
            shape: vec![TensorDim::Param(0), TensorDim::Param(1)],
        };
        assert_eq!(t.concrete_shape(&[4, 5, 2, 3]), vec![4, 5]);
        assert_eq!(t.num_elems(&[4, 5, 2, 3]), 20);
        let f = TensorDecl { name: "w".into(), shape: vec![TensorDim::Fixed(3)] };
        assert_eq!(f.num_elems(&[4, 5, 2, 3]), 3);
    }

    #[test]
    fn pra_iter_points() {
        let pra = Pra {
            name: "t".into(),
            ndims: 2,
            space: ParamSpace::loop_nest(2),
            statements: vec![],
            tensors: vec![],
            requires: vec![],
        };
        let pts = pra.iter_points(&[2, 3, 1, 1]);
        assert_eq!(pts.len(), 6);
        assert_eq!(pra.iter_volume(&[2, 3, 1, 1]), 6);
        assert_eq!(pts[0], vec![0, 0]);
        assert_eq!(pts[5], vec![1, 2]);
    }
}
